from repro.data.pipeline import (
    ShardedFeeder,
    lm_batch,
    recsys_batch,
    synthetic_attributes,
    synthetic_embeddings,
)

__all__ = [
    "ShardedFeeder", "lm_batch", "recsys_batch", "synthetic_attributes",
    "synthetic_embeddings",
]
