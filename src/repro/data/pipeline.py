"""Host data pipeline: synthetic generators per family + sharded feed.

Every generator is a deterministic function of (seed, step) so a restarted
job regenerates the exact stream from its checkpointed cursor — data-side
fault tolerance without persisting samples.  ``ShardedFeeder`` double-buffers
one batch ahead on a worker thread (host-side prefetch overlapping step
compute, the CPU analogue of device prefetch).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


# -------------------------------------------------------- LAION-like ANN ---
def synthetic_embeddings(seed: int, n: int, dim: int, n_clusters: int = 64,
                         dtype=np.float32) -> np.ndarray:
    """Clustered unit-norm embeddings (CLIP-like geometry, paper §5.1)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)).astype(dtype)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    a = rng.integers(0, n_clusters, n)
    x = centers[a] + 0.3 * rng.standard_normal((n, dim)).astype(dtype)
    x /= np.linalg.norm(x, axis=-1, keepdims=True)
    return x


def synthetic_attributes(seed: int, n: int, m: int,
                         cardinalities: Optional[list] = None) -> np.ndarray:
    """int16 attribute rows (paper §5.1: uniform over the int16 range for
    stress tests; realistic low-cardinality columns when given)."""
    rng = np.random.default_rng(seed + 1)
    if cardinalities is None:
        return rng.integers(-32768, 32768, (n, m)).astype(np.int16)
    cols = [
        rng.integers(0, c, n).astype(np.int16)
        for c in (cardinalities * m)[:m]
    ]
    return np.stack(cols, axis=1)


# ----------------------------------------------------------------- LM ------
def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int
             ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, step))
    tokens = rng.integers(0, vocab, (batch, seq)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1
    return {"tokens": tokens, "labels": labels}


# -------------------------------------------------------------- recsys -----
def recsys_batch(seed: int, step: int, batch: int, seq_len: int,
                 n_dense: int, n_sparse: int, vocab_items: int,
                 vocab_sparse: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, step))
    L = max(seq_len, 1)
    hist = rng.integers(0, vocab_items, (batch, L)).astype(np.int32)
    hist[rng.random((batch, L)) < 0.15] = -1
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32),
        "sparse": rng.integers(
            0, vocab_sparse, (batch, max(n_sparse, 1))
        ).astype(np.int32),
        "hist": hist,
        "target": rng.integers(0, vocab_items, batch).astype(np.int32),
        "label": (rng.random(batch) > 0.5).astype(np.float32),
    }


# ------------------------------------------------------------- feeder ------
@dataclasses.dataclass
class ShardedFeeder:
    """Prefetching iterator over a (seed, step) generator.

    generator(seed, step) -> dict of host arrays for the GLOBAL batch; the
    launch layer device_puts with batch shardings (jax splits rows across
    data-parallel chips).
    """

    generator: Callable[[int, int], Dict[str, np.ndarray]]
    seed: int
    start_step: int = 0
    prefetch: int = 2

    def __post_init__(self):
        self._q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._step = self.start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.start_step
        while not self._stop.is_set():
            batch = self.generator(self.seed, step)
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:  # unblock the worker
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
