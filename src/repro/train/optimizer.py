"""Optimizers: AdamW and Adafactor (factored second moments).

Adafactor matters at assigned-architecture scale: deepseek-v3-671b with AdamW
needs 12 bytes/param of state+grad+param — 15.7 GB/chip at 512 chips, over
the v5e HBM budget.  Factored second moments (row+col statistics for ≥2-D
tensors) cut state to ~2 bytes/param: the dry-run proves the 671B train step
fits because of this choice (EXPERIMENTS §Dry-run).

Both are functional: ``init(params) → state``, ``update(grads, state,
params, lr) → (new_params, new_state)``; states inherit the param shardings
leaf-by-leaf (same tree structure), so pjit propagates layouts with no extra
annotations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # "adamw" | "adafactor"
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999  # adafactor: decay exponent handled separately
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    factored_min_dim: int = 128
    decay_rate: float = 0.8


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[Array], Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


# ------------------------------------------------------------------ adamw ---
class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: Array


def adamw_init(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(grads, state: AdamWState, params, lr: Array,
                 cfg: OptimizerConfig):
    c = state.count + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(new_m, new_v, c)


# -------------------------------------------------------------- adafactor ---
class AdafactorState(NamedTuple):
    v_row: Any  # factored stats ([..., R] per ≥2-D leaf) or full v (1-D)
    v_col: Any
    count: Array


def _factored(p, min_dim) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor_init(params, cfg: OptimizerConfig) -> AdafactorState:
    def rows(p):
        if _factored(p, cfg.factored_min_dim):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)  # full v

    def cols(p):
        if _factored(p, cfg.factored_min_dim):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)  # unused

    return AdafactorState(
        v_row=jax.tree.map(rows, params),
        v_col=jax.tree.map(cols, params),
        count=jnp.zeros((), jnp.int32),
    )


def adafactor_update(grads, state: AdafactorState, params, lr: Array,
                     cfg: OptimizerConfig):
    c = state.count + 1
    beta2 = 1.0 - c.astype(jnp.float32) ** (-cfg.decay_rate)

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if _factored(p, cfg.factored_min_dim):
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr2 / jnp.maximum(
                jnp.mean(vr2, axis=-1, keepdims=True), 1e-30
            )
            step = g32 / (
                jnp.sqrt(r)[..., None] * jnp.sqrt(vc2)[..., None, :] + cfg.eps
            )
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            step = g32 / (jnp.sqrt(vr2) + cfg.eps)
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), vr2, vc2

    out = jax.tree.map(upd, params, grads, state.v_row, state.v_col)
    pick = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    return pick(0), AdafactorState(pick(1), pick(2), c)


# ------------------------------------------------------- state shardings ---
def adamw_state_pspecs(param_pspecs) -> AdamWState:
    """m/v inherit the param specs exactly (same shapes)."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(m=param_pspecs, v=param_pspecs, count=P())


def adafactor_state_pspecs(param_pspecs, param_shapes,
                           cfg: OptimizerConfig) -> AdafactorState:
    """v_row drops the last param dim's spec; v_col drops the second-to-last.
    Non-factored leaves keep the full spec (v_row) / are replicated (v_col).
    Keeping factored stats sharded like their parent matters: a replicated
    row stat for [58, 256, 7168] experts would be 425 GB/chip."""
    from jax.sharding import PartitionSpec as P

    def rows(spec, shp):
        if _factored(shp, cfg.factored_min_dim):
            return P(*spec[:-1])
        return spec

    def cols(spec, shp):
        if _factored(shp, cfg.factored_min_dim):
            return P(*(tuple(spec[:-2]) + (spec[-1],)))
        return P(None)

    is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
    return AdafactorState(
        v_row=jax.tree.map(rows, param_pspecs, param_shapes, is_leaf=is_spec),
        v_col=jax.tree.map(cols, param_pspecs, param_shapes, is_leaf=is_spec),
        count=P(),
    )


# ------------------------------------------------------------- dispatcher ---
def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return (lambda p: adamw_init(p),
                lambda g, s, p, lr: adamw_update(g, s, p, lr, cfg))
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p, lr: adafactor_update(g, s, p, lr, cfg))
    raise ValueError(cfg.name)
