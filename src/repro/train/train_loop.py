"""Training loop with checkpoint/restart, preemption handling, and metrics.

The loop is deliberately boring: jitted step + feeder + periodic checkpoint.
Fault tolerance is the point —
  * restart: ``run()`` restores the newest complete checkpoint (params,
    optimizer state, data cursor) and continues bit-exact (the feeder is a
    deterministic function of (seed, step));
  * preemption: SIGTERM-style ``request_stop()`` finishes the in-flight step,
    checkpoints, and exits cleanly;
  * divergence guard: non-finite loss restores the last checkpoint and
    re-runs with a decayed LR (a standard large-run babysitter policy).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import (
    OptimizerConfig,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 10
    grad_clip: float = 1.0
    optimizer: str = "adamw"
    lr_decay_on_divergence: float = 0.5


class Trainer:
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def __init__(self, loss_fn: Callable, params: Any, cfg: TrainLoopConfig,
                 donate: bool = True):
        self.cfg = cfg
        self.loss_fn = loss_fn
        opt_cfg = OptimizerConfig(name=cfg.optimizer, lr=cfg.lr,
                                  grad_clip=cfg.grad_clip)
        self.opt_init, self.opt_update = make_optimizer(opt_cfg)
        self.schedule = cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)
        self.params = params
        self.opt_state = self.opt_init(params)
        self.step = 0
        self._stop_requested = False
        self._lr_scale = 1.0

        def train_step(params, opt_state, batch, step, lr_scale):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True
            )(params, batch)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            lr = self.schedule(step) * lr_scale
            new_params, new_state = self.opt_update(
                grads, opt_state, params, lr
            )
            metrics = dict(metrics)
            metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
            return new_params, new_state, metrics

        self._jit_step = jax.jit(
            train_step, donate_argnums=(0, 1) if donate else ()
        )

    # ---- fault-tolerance API ----
    def request_stop(self):
        """Preemption hook: finish the current step, checkpoint, return."""
        self._stop_requested = True

    def save(self):
        if not self.cfg.ckpt_dir:
            return
        ckpt_lib.save_checkpoint(
            self.cfg.ckpt_dir, self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"lr_scale": self._lr_scale},
            keep=self.cfg.ckpt_keep,
        )

    def maybe_restore(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        res = ckpt_lib.restore_checkpoint(
            self.cfg.ckpt_dir, {"params": self.params, "opt": self.opt_state}
        )
        if res is None:
            return False
        step, state, extra = res
        self.step = step
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.opt_state = jax.tree.map(jnp.asarray, state["opt"])
        self._lr_scale = float(extra.get("lr_scale", 1.0))
        return True

    # ---- the loop ----
    def run(self, feeder, max_steps: Optional[int] = None
            ) -> Dict[str, list]:
        self.maybe_restore()
        history: Dict[str, list] = {"loss": [], "step": []}
        target = min(
            self.cfg.total_steps,
            self.step + (max_steps or self.cfg.total_steps),
        )
        t0 = time.time()
        while self.step < target and not self._stop_requested:
            data_step, batch = next(feeder)
            if data_step < self.step:  # skip ahead after restore
                continue
            batch = jax.tree.map(jnp.asarray, batch)
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch,
                jnp.int32(self.step), jnp.float32(self._lr_scale),
            )
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                # divergence: restore last good state, decay LR, continue
                restored = self.maybe_restore()
                self._lr_scale *= self.cfg.lr_decay_on_divergence
                if not restored:
                    raise FloatingPointError(
                        f"non-finite loss at step {self.step}, no checkpoint"
                    )
                continue
            self.step += 1
            history["loss"].append(loss)
            history["step"].append(self.step)
            if self.step % self.cfg.ckpt_every == 0:
                self.save()
            if self.step % self.cfg.log_every == 0:
                rate = self.step / max(time.time() - t0, 1e-9)
                print(f"step {self.step} loss {loss:.4f} "
                      f"({rate:.2f} steps/s)")
        self.save()
        return history
