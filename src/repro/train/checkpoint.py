"""Fault-tolerant training checkpoints.

Same discipline as the index store (core/storage.py): atomic writes
(tmp+rename), a manifest that is written LAST (a crash mid-save can never
yield a loadable-but-partial checkpoint), monotonically numbered step
directories, and automatic latest-step discovery on restore — the restart
path after preemption is ``state = restore(dir) or fresh_init()``.

Arrays are saved leaf-by-leaf with their tree paths as keys (npz); shardings
are reapplied by the caller (restore returns host numpy; the train loop
device_puts with its own NamedShardings, which also makes checkpoints
portable across mesh sizes — elastic restart).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Writes ``<dir>/step_<n>/`` atomically; prunes old steps to ``keep``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten_with_paths(state)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **flat)
        manifest = dict(step=step, n_arrays=len(flat),
                        extra=extra or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
    # prune
    steps = sorted(all_steps(directory))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{old}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = STEP_RE.match(name)
        if m and os.path.exists(
            os.path.join(directory, name, "manifest.json")
        ):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None
                       ) -> Optional[Tuple[int, Any, dict]]:
    """Restores into the structure of ``like``. Returns (step, state, extra)
    or None if no complete checkpoint exists."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        return None
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = _flatten_with_paths(like)
    if set(data.files) != set(flat_like):
        raise ValueError(
            f"checkpoint/state structure mismatch: "
            f"{set(data.files) ^ set(flat_like)}"
        )
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = data[key]
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return step, state, manifest.get("extra", {})
