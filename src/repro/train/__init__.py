"""Training substrate: optimizers, loop, checkpointing."""
