"""K-Means / MiniBatchKMeans for centroid computation (paper §4.2 step 1).

The paper builds centroids with sklearn's MiniBatchKMeans on one CPU host.
Here both Lloyd and the mini-batch variant are implemented in JAX so the build
runs data-parallel on the pod: the assignment step is an argmin over
``x @ C^T`` (MXU), the update step is ``segment_sum`` over assignments — both
shard over the batch axis under pjit, with XLA inserting the cross-chip
reductions.

All functions are functional (state in, state out) so they jit/scan cleanly
and checkpoint mid-build (fault tolerance for multi-hour billion-vector
builds, paper §5.2).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KMeansState:
    centroids: Array  # [K, D] f32
    counts: Array  # [K] f32 — per-centroid sample counts (minibatch lr)
    step: Array  # scalar int32


def init_from_sample(key: Array, x: Array, n_clusters: int) -> KMeansState:
    """Random-subset init (the sklearn default for MiniBatchKMeans at scale)."""
    n = x.shape[0]
    idx = jax.random.choice(key, n, (n_clusters,), replace=n < n_clusters)
    return KMeansState(
        centroids=x[idx].astype(jnp.float32),
        counts=jnp.zeros((n_clusters,), jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )


def pairwise_neg_dist2(x: Array, c: Array) -> Array:
    """-(||x - c||^2) up to a per-row constant: 2 x·c - ||c||^2. [B, K]."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    dots = x @ c.T
    c2 = jnp.sum(c * c, axis=-1)
    return 2.0 * dots - c2[None, :]


def assign(
    x: Array, centroids: Array, *, chunk: Optional[int] = None
) -> Array:
    """Nearest-centroid assignment (paper §4.2 step 2). Returns int32 [N].

    ``chunk`` bounds the [chunk, K] score intermediate for large N·K.
    """
    if chunk is None or x.shape[0] <= chunk:
        return jnp.argmax(pairwise_neg_dist2(x, centroids), axis=-1).astype(
            jnp.int32
        )
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xc = xp.reshape(-1, chunk, x.shape[-1])
    out = jax.lax.map(
        lambda xb: jnp.argmax(pairwise_neg_dist2(xb, centroids), -1).astype(
            jnp.int32
        ),
        xc,
    )
    return out.reshape(-1)[:n]


def lloyd_step(state: KMeansState, x: Array) -> Tuple[KMeansState, Array]:
    """One full-batch Lloyd iteration. Returns (state, inertia)."""
    k = state.centroids.shape[0]
    scores = pairwise_neg_dist2(x, state.centroids)
    a = jnp.argmax(scores, axis=-1)
    best = jnp.max(scores, axis=-1)
    x32 = x.astype(jnp.float32)
    sums = jax.ops.segment_sum(x32, a, num_segments=k)
    cnts = jax.ops.segment_sum(jnp.ones_like(a, jnp.float32), a, num_segments=k)
    new_c = jnp.where(
        cnts[:, None] > 0, sums / jnp.maximum(cnts[:, None], 1.0), state.centroids
    )
    x2 = jnp.sum(x32 * x32, axis=-1)
    inertia = jnp.sum(x2 - best)  # ||x-c||^2 = ||x||^2 - (2x·c - ||c||^2)
    return (
        KMeansState(new_c, state.counts + cnts, state.step + 1),
        inertia,
    )


def minibatch_step(state: KMeansState, batch: Array) -> KMeansState:
    """One MiniBatchKMeans step (Sculley 2010, as in sklearn [30]).

    Per-center learning rate 1/count: c ← c + (1/cnt) Σ (x - c) over the
    batch members assigned to c.  segment_sum keeps it scatter-based (no
    one-hot matmuls), so HLO FLOPs stay honest.
    """
    k = state.centroids.shape[0]
    a = assign(batch, state.centroids)
    b32 = batch.astype(jnp.float32)
    sums = jax.ops.segment_sum(b32, a, num_segments=k)
    cnts = jax.ops.segment_sum(
        jnp.ones_like(a, jnp.float32), a, num_segments=k
    )
    new_counts = state.counts + cnts
    lr = jnp.where(new_counts > 0, 1.0 / jnp.maximum(new_counts, 1.0), 0.0)
    # c_new = c + lr * (sum_x - cnt * c)
    delta = sums - cnts[:, None] * state.centroids
    new_c = state.centroids + lr[:, None] * delta
    return KMeansState(new_c, new_counts, state.step + 1)


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_steps", "batch_size"))
def minibatch_kmeans(
    key: Array,
    x: Array,
    *,
    n_clusters: int,
    n_steps: int,
    batch_size: int,
) -> KMeansState:
    """Runs MiniBatchKMeans over random batches of ``x`` via lax.scan."""
    ikey, skey = jax.random.split(key)
    state = init_from_sample(ikey, x, n_clusters)

    def body(carry, step_key):
        idx = jax.random.choice(step_key, x.shape[0], (batch_size,))
        return minibatch_step(carry, x[idx]), ()

    state, _ = jax.lax.scan(body, state, jax.random.split(skey, n_steps))
    return state


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters"))
def kmeans_lloyd(
    key: Array, x: Array, *, n_clusters: int, n_iters: int
) -> Tuple[KMeansState, Array]:
    """Full Lloyd K-Means; returns (state, inertia trace [n_iters])."""
    state = init_from_sample(key, x, n_clusters)

    def body(carry, _):
        new, inertia = lloyd_step(carry, x)
        return new, inertia

    state, trace = jax.lax.scan(body, state, None, length=n_iters)
    return state, trace
