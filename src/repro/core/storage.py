"""Disk persistence: layout v2.1 (memory-mappable records + resident
cluster attribute summaries), v1/v2-read compat.

The paper's index lives on disk and is paged in per query.  Layout v2 is the
format that makes that an actual serving mode (``core/disk.py``'s
``DiskIVFIndex``) instead of a cold-start checkpoint:

    <dir>/manifest.json            — schema, shapes, metric, field table,
                                     record stride, shard map, SQ8 flag
    <dir>/centroids.npy            — [K, D] f32   (always resident)
    <dir>/counts.npy               — [K]    int32 (always resident)
    <dir>/shard_<i>_of_<n>.bin     — raw records for a contiguous cluster
                                     range; cluster ``c`` of shard ``s`` lives
                                     at byte ``(c - lo_s) · record_stride``

Every cluster record has the same fixed stride: the fields
``(vectors [Vpad, D], attrs [Vpad, M], ids [Vpad], norms [Vpad]?,
scales [Vpad]?)`` packed back to back at 64-byte-aligned offsets, with the
stride rounded up to 512 bytes.  Fixed stride + an explicit field table in
the manifest means a reader can ``mmap`` a shard and address any cluster with
pure arithmetic — no per-cluster index, no deserialization.  ``norms`` is
present only for metric="l2"; ``scales`` only for SQ8 (the manifest's
``quantized`` flag), in which case ``vectors`` is int8 codes.

Layout v2.1 adds the *resident* per-cluster attribute summaries
(``core/summaries.py``): interval bounds, fixed-width histograms and their
global bin edges, one small ``.npy`` per field next to ``centroids.npy``.
They are what lets the probe planner prune filtered-out clusters before the
disk tier fetches them.  The manifest carries ``has_summaries`` /
``summary_bins``; checkpoints without them (v2.0, v1) load fine and simply
disable pruning.

Layout v3 (the default writer) adds *generation tags* for live-updating
serving: every cluster record carries a monotonically increasing ``gen``
(int64, bumped each time a background ``compact_deltas`` republish rewrites
the cluster) and ``<dir>/gens.npy`` holds the resident per-cluster
generation vector.  Caches key on ``(cluster_id, gen)``, so a republish
invalidates exactly the rewritten clusters.  v2/v2.1 checkpoints load with
``gen == 0`` everywhere and serve unchanged.

Versioning: ``manifest["layout"]`` is 3 for the current format, 2 for the
pre-generation record format (``layout_minor`` 1 marks v2.1 summary
writers).  Layout v1 (one
``.npz`` of stacked arrays per shard) is still *read* — ``load_index``
dispatches on the manifest — and v1/v2 can still be written with
``save_index(..., layout=1|2)`` for tooling that expects them.  v1
checkpoints
written before the SQ8 fix (no ``scales`` key) load as unquantized raw codes
and are rejected with a clear error rather than silently mis-scored.

Elastic re-sharding is unchanged: runtime sharding is "contiguous cluster
ranges over a flat chip list", so a checkpoint from S chips restores onto S'
chips by re-slicing ranges.  ``pad_k`` pads with empty clusters so K divides
any target chip count; padded clusters have ``counts == 0``, which the
centroid top-k masks to NEG_INF — they are *unprobeable* under every metric
(the old sentinel-coordinate trick was sign-sensitive for dot queries).

Writes are atomic (tmp + rename) and ``load_index`` verifies completeness
before touching any array — a partially written checkpoint is never loaded.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridSpec
from repro.core.ivf import IVFFlatIndex
from repro.core.summaries import (
    ClusterBounds,
    ClusterSummaries,
    build_bounds,
    pad_clusters,
)

MANIFEST = "manifest.json"
GENS_FILE = "gens.npy"  # layout v3: resident per-cluster generation vector


class GenerationMismatchError(ValueError):
    """The checkpoint's generation vector disagrees with its manifest (or a
    peer served a block older than the generation the fetch demanded)."""
# Resident per-cluster attribute summaries (layout v2.1): one .npy per
# field, loaded whole — like centroids/counts, they are consulted at plan
# time before any flat list is touched.
SUMMARY_FILES = dict(
    amin="summaries_amin.npy",
    amax="summaries_amax.npy",
    hist="summaries_hist.npy",
    edges_lo="summaries_edges_lo.npy",
    edges_hi="summaries_edges_hi.npy",
)
# Resident per-cluster geometric score bounds (bound-driven early
# termination): like the summaries, tiny always-resident .npy files next to
# centroids.npy.  The manifest's ``has_bounds`` flag gates them; checkpoints
# without them load fine and simply can't serve termination= from disk until
# re-saved.
BOUNDS_FILES = dict(
    radius="bounds_radius.npy",
    slack="bounds_slack.npy",
)
_FIELD_ALIGN = 64     # per-field offset alignment inside a record
_RECORD_ALIGN = 512   # record stride alignment (mmap-friendly)


def np_dtype(name: str) -> np.dtype:
    """Resolves a manifest dtype name; bfloat16 via ml_dtypes (jax dep)."""
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _dtype_name(dtype) -> str:
    name = np.dtype(dtype).name if dtype != jnp.bfloat16 else "bfloat16"
    return name


def _align(off: int, a: int) -> int:
    return ((off + a - 1) // a) * a


def record_layout(
    *, vpad: int, dim: int, n_attrs: int, store_dtype: str,
    has_norms: bool, quantized: bool, with_gen: bool = False,
) -> Tuple[List[dict], int]:
    """The v2/v3 per-cluster record: ordered field table + fixed stride.

    Returns ``(fields, stride)`` where each field is
    ``{name, dtype, shape, offset}`` (shape is per-cluster, e.g. ``[Vpad, D]``
    for vectors) and ``stride`` is the record size in bytes.  ``with_gen``
    (layout v3) appends the record's generation stamp.
    """
    specs = [("vectors", store_dtype, (vpad, dim)),
             ("attrs", "int16", (vpad, n_attrs)),
             ("ids", "int32", (vpad,))]
    if has_norms:
        specs.append(("norms", "float32", (vpad,)))
    if quantized:
        specs.append(("scales", "float32", (vpad,)))
    if with_gen:
        specs.append(("gen", "int64", (1,)))
    fields, off = [], 0
    for name, dt, shape in specs:
        off = _align(off, _FIELD_ALIGN)
        fields.append(dict(name=name, dtype=dt, shape=list(shape), offset=off))
        off += int(np.prod(shape)) * np_dtype(dt).itemsize
    return fields, _align(off, _RECORD_ALIGN)


def _atomic_save(path: str, save_fn):
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        save_fn(tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def pad_k(index: IVFFlatIndex, k_new: int) -> IVFFlatIndex:
    """Pads the cluster axis to ``k_new`` with empty, unprobeable clusters.

    Padded clusters have ``counts == 0``; ``search_centroids`` masks them out
    of the centroid top-k, so no probe budget is ever spent on them — for any
    metric and any query sign.  Their centroid rows are plain zeros (inert;
    correctness does not ride on a sentinel coordinate).  Every per-cluster
    array — including SQ8 ``scales`` — is padded, so a resharded quantized
    index keeps its ``[K, Vpad]`` shape contract.
    """
    k = index.n_clusters
    if k_new < k:
        raise ValueError(f"cannot shrink K: {k} -> {k_new}")
    if k_new == k:
        return index
    dk = k_new - k
    pad = lambda a, fill: jnp.concatenate(
        [a, jnp.full((dk,) + a.shape[1:], fill, a.dtype)], axis=0
    )
    return dataclasses.replace(
        index,
        centroids=pad(index.centroids, 0.0),
        vectors=pad(index.vectors, 0),
        attrs=pad(index.attrs, 0),
        ids=pad(index.ids, -1),
        counts=pad(index.counts, 0),
        norms=None if index.norms is None else pad(index.norms, 0),
        scales=None if index.scales is None else pad(index.scales, 1.0),
        summaries=(
            None if index.summaries is None
            else pad_clusters(index.summaries, k_new)  # void rows: never match
        ),
    )


def _index_arrays(index: IVFFlatIndex) -> Dict[str, np.ndarray]:
    arrays = dict(
        vectors=np.asarray(index.vectors),
        attrs=np.asarray(index.attrs),
        ids=np.asarray(index.ids),
    )
    if index.norms is not None:
        arrays["norms"] = np.asarray(index.norms, np.float32)
    if index.scales is not None:
        arrays["scales"] = np.asarray(index.scales, np.float32)
    return arrays


def _base_manifest(index: IVFFlatIndex, *, n_shards: int, version: int
                   ) -> dict:
    return dict(
        version=version,
        n_clusters=index.n_clusters,
        n_shards=n_shards,
        vpad=index.vpad,
        dim=index.spec.dim,
        n_attrs=index.spec.n_attrs,
        metric=index.spec.metric,
        core_dtype=_dtype_name(index.spec.core_dtype),
        store_dtype=_dtype_name(index.vectors.dtype),
        has_norms=index.norms is not None,
        quantized=index.quantized,
        has_summaries=index.summaries is not None,
        summary_bins=(
            index.summaries.n_bins if index.summaries is not None else 0
        ),
        n_live=int(jnp.sum(index.counts)),
    )


def save_index(index: IVFFlatIndex, directory: str, *, n_shards: int = 1,
               version: int = 0, layout: int = 3,
               gens: Optional[np.ndarray] = None) -> None:
    """Writes the index as ``n_shards`` contiguous cluster-range files.

    ``layout=3`` (default) writes the fixed-stride record format above with
    per-cluster generation stamps (``gens``, default all-zero) plus the
    resident ``gens.npy``; ``layout=2`` is the same record format without
    generations; ``layout=1`` writes the legacy one-npz-per-shard format
    (all carry SQ8 ``scales`` and the ``quantized`` manifest flag).
    """
    k = index.n_clusters
    if k % n_shards:
        raise ValueError(f"K={k} not divisible by n_shards={n_shards}; pad_k first")
    if layout not in (1, 2, 3):
        raise ValueError(f"unknown layout {layout}")
    if gens is None:
        gens = np.zeros(k, np.int64)
    gens = np.asarray(gens, np.int64)
    if gens.shape != (k,):
        raise GenerationMismatchError(
            f"gens shape {gens.shape} != ({k},) clusters"
        )
    os.makedirs(directory, exist_ok=True)
    kl = k // n_shards
    manifest = _base_manifest(index, n_shards=n_shards, version=version)
    arrays = _index_arrays(index)
    if layout == 3:
        arrays["gen"] = gens[:, None]

    def _np_save(p, arr):
        with open(p, "wb") as f:  # file handle: np.save must not append .npy
            np.save(f, arr, allow_pickle=False)

    _atomic_save(
        os.path.join(directory, "centroids.npy"),
        lambda p: _np_save(p, np.asarray(index.centroids, np.float32)),
    )
    if index.summaries is not None:  # resident, layout-independent (v2.1)
        for field, fname in SUMMARY_FILES.items():
            _atomic_save(
                os.path.join(directory, fname),
                lambda p, f=field: _np_save(
                    p, np.asarray(getattr(index.summaries, f))
                ),
            )
    # Resident score bounds: recomputed from the flat lists at save time (the
    # writer holds them all anyway) so every fresh checkpoint can serve
    # termination= from disk without touching a shard.
    bounds = build_bounds(
        index.centroids, index.vectors, index.ids, index.norms, index.scales
    )
    for field, fname in BOUNDS_FILES.items():
        _atomic_save(
            os.path.join(directory, fname),
            lambda p, f=field: _np_save(p, np.asarray(getattr(bounds, f))),
        )
    manifest["has_bounds"] = True

    if layout == 1:
        for s in range(n_shards):
            lo, hi = s * kl, (s + 1) * kl
            payload = {name: a[lo:hi] for name, a in arrays.items()}
            payload["counts"] = np.asarray(index.counts[lo:hi], np.int32)

            def _npz_save(p, pl):
                with open(p, "wb") as f:
                    np.savez(f, **pl)

            _atomic_save(
                os.path.join(directory, f"shard_{s}_of_{n_shards}.npz"),
                lambda p, pl=payload: _npz_save(p, pl),
            )
        manifest["layout"] = 1
    else:
        fields, stride = record_layout(
            vpad=index.vpad, dim=index.spec.dim, n_attrs=index.spec.n_attrs,
            store_dtype=manifest["store_dtype"],
            has_norms=manifest["has_norms"], quantized=index.quantized,
            with_gen=layout == 3,
        )
        _atomic_save(
            os.path.join(directory, "counts.npy"),
            lambda p: _np_save(p, np.asarray(index.counts, np.int32)),
        )
        if layout == 3:
            _atomic_save(
                os.path.join(directory, GENS_FILE),
                lambda p: _np_save(p, gens),
            )
        for s in range(n_shards):
            lo, hi = s * kl, (s + 1) * kl

            def _bin_save(p, lo=lo, hi=hi):
                with open(p, "wb") as f:
                    rec = np.zeros(stride, np.uint8)
                    for c in range(lo, hi):
                        rec[:] = 0
                        for fld in fields:
                            raw = np.ascontiguousarray(
                                arrays[fld["name"]][c]
                            ).tobytes()
                            o = fld["offset"]
                            rec[o:o + len(raw)] = np.frombuffer(raw, np.uint8)
                        f.write(rec.tobytes())

            _atomic_save(
                os.path.join(directory, f"shard_{s}_of_{n_shards}.bin"),
                _bin_save,
            )
        manifest.update(layout=layout, layout_minor=1, record_stride=stride,
                        fields=fields)

    _atomic_save(
        os.path.join(directory, MANIFEST),
        lambda p: open(p, "w").write(json.dumps(manifest, indent=2)),
    )


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, MANIFEST)) as f:
        man = json.load(f)
    man.setdefault("layout", 1)        # pre-v2 checkpoints
    man.setdefault("quantized", False)  # pre-SQ8-fix checkpoints
    man.setdefault("has_summaries", False)  # pre-v2.1: no pruning, sound
    man.setdefault("has_bounds", False)  # pre-PR-9: no disk-tier termination
    return man


def load_summaries(directory: str, man: dict) -> Optional[ClusterSummaries]:
    """Loads the resident summary arrays, or None for pre-v2.1 checkpoints
    (missing summaries simply disable probe pruning)."""
    if not man.get("has_summaries"):
        return None
    fields = {
        f: jnp.asarray(np.load(os.path.join(directory, fname)))
        for f, fname in SUMMARY_FILES.items()
    }
    return ClusterSummaries(**fields)


def load_bounds(directory: str, man: dict) -> Optional[ClusterBounds]:
    """Loads the resident per-cluster score bounds, or None for checkpoints
    written before they existed (bound-driven termination then needs a
    re-save; exact search is unaffected)."""
    if not man.get("has_bounds"):
        return None
    fields = {
        f: jnp.asarray(np.load(os.path.join(directory, fname)))
        for f, fname in BOUNDS_FILES.items()
    }
    return ClusterBounds(**fields)


def load_gens(directory: str, man: dict) -> np.ndarray:
    """Resident per-cluster generation vector ``[K] int64``.

    Pre-v3 checkpoints have no generations: every cluster is ``gen == 0``
    (and serves unchanged — the back-compat contract).  On v3 the vector
    must exist and match the manifest's cluster count, else the checkpoint
    is inconsistent and refuses to load.
    """
    k = man["n_clusters"]
    if man.get("layout", 1) < 3:
        return np.zeros(k, np.int64)
    path = os.path.join(directory, GENS_FILE)
    if not os.path.exists(path):
        raise GenerationMismatchError(
            f"layout-3 checkpoint missing {GENS_FILE}: {directory}"
        )
    gens = np.asarray(np.load(path), np.int64)
    if gens.shape != (k,):
        raise GenerationMismatchError(
            f"{GENS_FILE} has {gens.shape} entries, manifest says "
            f"{k} clusters: {directory}"
        )
    return gens


def shard_paths(directory: str, man: dict) -> List[str]:
    ext = "bin" if man["layout"] >= 2 else "npz"
    n = man["n_shards"]
    return [
        os.path.join(directory, f"shard_{s}_of_{n}.{ext}") for s in range(n)
    ]


def check_complete(directory: str, man: dict) -> List[str]:
    paths = shard_paths(directory, man)
    required = list(paths)
    if man.get("has_summaries"):
        required += [
            os.path.join(directory, f) for f in SUMMARY_FILES.values()
        ]
    if man.get("has_bounds"):
        required += [
            os.path.join(directory, f) for f in BOUNDS_FILES.values()
        ]
    if man.get("layout", 1) >= 3:
        required.append(os.path.join(directory, GENS_FILE))
    missing = [p for p in required if not os.path.exists(p)]
    if missing:
        raise FileNotFoundError(f"incomplete checkpoint, missing: {missing}")
    if man.get("layout", 1) >= 3:
        load_gens(directory, man)  # raises GenerationMismatchError on skew
    return paths


def spec_from_manifest(man: dict) -> HybridSpec:
    core_dtype = (
        jnp.bfloat16 if man["core_dtype"] == "bfloat16"
        else jnp.dtype(man["core_dtype"])
    )
    return HybridSpec(
        dim=man["dim"], n_attrs=man["n_attrs"], core_dtype=core_dtype,
        metric=man["metric"],
    )


def _load_v1(directory: str, man: dict, paths: List[str]) -> IVFFlatIndex:
    parts = [np.load(p) for p in paths]
    cat = lambda k: jnp.asarray(np.concatenate([p[k] for p in parts], 0))
    spec = spec_from_manifest(man)
    stored_int8 = parts[0]["vectors"].dtype == np.int8
    if man["quantized"] or stored_int8:
        # int8 vectors with no (or unflagged) scales = a checkpoint written
        # by the pre-fix save_index, which dropped `scales` and the
        # `quantized` flag; casting the codes to float would silently score
        # garbage, so refuse to load it.
        if "scales" not in parts[0].files:
            raise ValueError(
                "quantized checkpoint has no 'scales' payload (written by a "
                "pre-fix save_index); rebuild and re-save the index"
            )
        vectors = cat("vectors")  # int8 codes, no cast
        scales = cat("scales")
    else:
        vectors = cat("vectors").astype(spec.core_dtype)
        scales = None
    return IVFFlatIndex(
        spec=spec,
        centroids=jnp.asarray(np.load(os.path.join(directory, "centroids.npy"))),
        vectors=vectors,
        attrs=cat("attrs"),
        ids=cat("ids"),
        counts=cat("counts"),
        norms=cat("norms") if man["has_norms"] else None,
        scales=scales,
        summaries=load_summaries(directory, man),
    )


def read_shard_fields(path: str, man: dict) -> Dict[str, np.ndarray]:
    """Reads one v2 shard file into per-field arrays ``[kl, *field_shape]``."""
    stride = man["record_stride"]
    raw = np.fromfile(path, np.uint8)
    if raw.size % stride:
        raise ValueError(f"{path}: size {raw.size} not a stride multiple")
    raw = raw.reshape(-1, stride)
    out = {}
    for fld in man["fields"]:
        dt = np_dtype(fld["dtype"])
        nb = int(np.prod(fld["shape"])) * dt.itemsize
        o = fld["offset"]
        flat = np.ascontiguousarray(raw[:, o:o + nb]).view(dt)
        out[fld["name"]] = flat.reshape((raw.shape[0],) + tuple(fld["shape"]))
    return out


def _load_v2(directory: str, man: dict, paths: List[str]) -> IVFFlatIndex:
    spec = spec_from_manifest(man)
    parts = [read_shard_fields(p, man) for p in paths]
    cat = lambda k: jnp.asarray(np.concatenate([p[k] for p in parts], 0))
    return IVFFlatIndex(
        spec=spec,
        centroids=jnp.asarray(np.load(os.path.join(directory, "centroids.npy"))),
        vectors=cat("vectors"),
        attrs=cat("attrs"),
        ids=cat("ids"),
        counts=jnp.asarray(np.load(os.path.join(directory, "counts.npy"))),
        norms=cat("norms") if man["has_norms"] else None,
        scales=cat("scales") if man["quantized"] else None,
        summaries=load_summaries(directory, man),
    )


def load_index(
    directory: str, *, target_shards: Optional[int] = None
) -> IVFFlatIndex:
    """Restores an index into RAM; ``target_shards`` pads K for a new chip
    count.  Reads both layout v2 (fixed-stride records) and legacy v1 (npz).

    Verifies every shard file exists before loading anything (a save that
    died mid-write leaves no manifest or a manifest pointing at a complete
    older set — either way no partial state is observable).  For serving an
    index larger than host memory, open it with
    :class:`repro.core.disk.DiskIVFIndex` instead.
    """
    man = load_manifest(directory)
    paths = check_complete(directory, man)
    index = (
        _load_v2(directory, man, paths) if man["layout"] >= 2
        else _load_v1(directory, man, paths)
    )
    if target_shards and index.n_clusters % target_shards:
        k_new = ((index.n_clusters + target_shards - 1) // target_shards
                 ) * target_shards
        index = pad_k(index, k_new)
    return index
