"""Disk persistence, sharded checkpointing, elastic re-sharding (DESIGN §4).

The paper's index lives on disk and is paged in per query; ours lives in pod
HBM and the disk tier is the durability/cold-start layer.  Layout:

    <dir>/manifest.json                 — schema, shapes, shard map, metric
    <dir>/centroids.npy                 — [K, D] f32 (replicated at load)
    <dir>/shard_<i>_of_<n>.npz          — contiguous cluster range per shard
                                          (vectors, attrs, ids, counts, norms)

Because the runtime sharding is "contiguous cluster ranges over a flat chip
list", a checkpoint written from S chips can be restored onto S' chips by
re-slicing ranges — no rebuild, no reassignment (elastic scaling).  ``pad_k``
pads with empty clusters so K divides any target chip count; empty clusters
are never probed in practice (their centroids sit at +inf) and cost only
centroid-table rows.

Writes are atomic (tmp + rename) and the manifest carries a content version;
``load_index`` verifies completeness before touching any array — a partially
written checkpoint is never loaded (fault tolerance during save).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridSpec
from repro.core.ivf import IVFFlatIndex

MANIFEST = "manifest.json"
_FAR = 1.0e30  # centroid coordinate for padded (empty) clusters


def _atomic_save(path: str, save_fn):
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        save_fn(tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def pad_k(index: IVFFlatIndex, k_new: int) -> IVFFlatIndex:
    """Pads the cluster axis to ``k_new`` with empty, unprobeable clusters."""
    k = index.n_clusters
    if k_new < k:
        raise ValueError(f"cannot shrink K: {k} -> {k_new}")
    if k_new == k:
        return index
    dk = k_new - k
    far = np.full((dk, index.centroids.shape[1]), _FAR, np.float32)
    pad = lambda a, fill: jnp.concatenate(
        [a, jnp.full((dk,) + a.shape[1:], fill, a.dtype)], axis=0
    )
    return dataclasses.replace(
        index,
        centroids=jnp.concatenate([index.centroids, jnp.asarray(-far)], 0),
        vectors=pad(index.vectors, 0),
        attrs=pad(index.attrs, 0),
        ids=pad(index.ids, -1),
        counts=pad(index.counts, 0),
        norms=None if index.norms is None else pad(index.norms, 0),
    )


def save_index(index: IVFFlatIndex, directory: str, *, n_shards: int = 1,
               version: int = 0) -> None:
    """Writes the index as ``n_shards`` contiguous cluster-range files."""
    k = index.n_clusters
    if k % n_shards:
        raise ValueError(f"K={k} not divisible by n_shards={n_shards}; pad_k first")
    os.makedirs(directory, exist_ok=True)
    kl = k // n_shards
    def _np_save(p, arr):
        with open(p, "wb") as f:  # file handle: np.save must not append .npy
            np.save(f, arr, allow_pickle=False)

    _atomic_save(
        os.path.join(directory, "centroids.npy"),
        lambda p: _np_save(p, np.asarray(index.centroids)),
    )
    for s in range(n_shards):
        lo, hi = s * kl, (s + 1) * kl
        payload = dict(
            vectors=np.asarray(index.vectors[lo:hi]),
            attrs=np.asarray(index.attrs[lo:hi]),
            ids=np.asarray(index.ids[lo:hi]),
            counts=np.asarray(index.counts[lo:hi]),
        )
        if index.norms is not None:
            payload["norms"] = np.asarray(index.norms[lo:hi])
        def _npz_save(p, pl):
            with open(p, "wb") as f:
                np.savez(f, **pl)

        _atomic_save(
            os.path.join(directory, f"shard_{s}_of_{n_shards}.npz"),
            lambda p, pl=payload: _npz_save(p, pl),
        )
    manifest = dict(
        version=version,
        n_clusters=k,
        n_shards=n_shards,
        vpad=index.vpad,
        dim=index.spec.dim,
        n_attrs=index.spec.n_attrs,
        metric=index.spec.metric,
        core_dtype=str(np.dtype(index.spec.core_dtype).name)
        if index.spec.core_dtype != jnp.bfloat16 else "bfloat16",
        has_norms=index.norms is not None,
        n_live=int(jnp.sum(index.counts)),
    )
    _atomic_save(
        os.path.join(directory, MANIFEST),
        lambda p: open(p, "w").write(json.dumps(manifest, indent=2)),
    )


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, MANIFEST)) as f:
        return json.load(f)


def load_index(
    directory: str, *, target_shards: Optional[int] = None
) -> IVFFlatIndex:
    """Restores an index; ``target_shards`` pads K for a new chip count.

    Verifies every shard file exists before loading anything (a save that
    died mid-write leaves no manifest or a manifest pointing at a complete
    older set — either way no partial state is observable).
    """
    man = load_manifest(directory)
    n_shards = man["n_shards"]
    paths = [
        os.path.join(directory, f"shard_{s}_of_{n_shards}.npz")
        for s in range(n_shards)
    ]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        raise FileNotFoundError(f"incomplete checkpoint, missing: {missing}")

    cents = np.load(os.path.join(directory, "centroids.npy"))
    parts = [np.load(p) for p in paths]
    cat = lambda k: jnp.asarray(np.concatenate([p[k] for p in parts], 0))
    core_dtype = jnp.bfloat16 if man["core_dtype"] == "bfloat16" else jnp.dtype(
        man["core_dtype"]
    )
    spec = HybridSpec(
        dim=man["dim"], n_attrs=man["n_attrs"], core_dtype=core_dtype,
        metric=man["metric"],
    )
    index = IVFFlatIndex(
        spec=spec,
        centroids=jnp.asarray(cents),
        vectors=cat("vectors").astype(core_dtype),
        attrs=cat("attrs"),
        ids=cat("ids"),
        counts=cat("counts"),
        norms=cat("norms") if man["has_norms"] else None,
    )
    if target_shards and index.n_clusters % target_shards:
        k_new = ((index.n_clusters + target_shards - 1) // target_shards
                 ) * target_shards
        index = pad_k(index, k_new)
    return index
