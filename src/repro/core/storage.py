"""Disk persistence: layout v2.1 (memory-mappable records + resident
cluster attribute summaries), v1/v2-read compat.

The paper's index lives on disk and is paged in per query.  Layout v2 is the
format that makes that an actual serving mode (``core/disk.py``'s
``DiskIVFIndex``) instead of a cold-start checkpoint:

    <dir>/manifest.json            — schema, shapes, metric, field table,
                                     record stride, shard map, SQ8 flag
    <dir>/centroids.npy            — [K, D] f32   (always resident)
    <dir>/counts.npy               — [K]    int32 (always resident)
    <dir>/shard_<i>_of_<n>.bin     — raw records for a contiguous cluster
                                     range; cluster ``c`` of shard ``s`` lives
                                     at byte ``(c - lo_s) · record_stride``

Every cluster record has the same fixed stride: the fields
``(vectors [Vpad, D], attrs [Vpad, M], ids [Vpad], norms [Vpad]?,
scales [Vpad]?)`` packed back to back at 64-byte-aligned offsets, with the
stride rounded up to 512 bytes.  Fixed stride + an explicit field table in
the manifest means a reader can ``mmap`` a shard and address any cluster with
pure arithmetic — no per-cluster index, no deserialization.  ``norms`` is
present only for metric="l2"; ``scales`` only for SQ8 (the manifest's
``quantized`` flag), in which case ``vectors`` is int8 codes.

Layout v2.1 adds the *resident* per-cluster attribute summaries
(``core/summaries.py``): interval bounds, fixed-width histograms and their
global bin edges, one small ``.npy`` per field next to ``centroids.npy``.
They are what lets the probe planner prune filtered-out clusters before the
disk tier fetches them.  The manifest carries ``has_summaries`` /
``summary_bins``; checkpoints without them (v2.0, v1) load fine and simply
disable pruning.

Layout v3 (the default writer) adds *generation tags* for live-updating
serving: every cluster record carries a monotonically increasing ``gen``
(int64, bumped each time a background ``compact_deltas`` republish rewrites
the cluster) and ``<dir>/gens.npy`` holds the resident per-cluster
generation vector.  Caches key on ``(cluster_id, gen)``, so a republish
invalidates exactly the rewritten clusters.  v2/v2.1 checkpoints load with
``gen == 0`` everywhere and serve unchanged.

Layout v4 adds *filter-specialized sub-partitions* (``core/partitions.py``):
selected clusters are re-sliced along high-traffic attributes and each
sub-partition persists as its own generation-tagged cluster record in
``<dir>/partitions.bin`` — variable-stride records (each padded to its own
row capacity, a multiple of 128) addressed through the resident
``partition_offsets.npy`` byte-offset table.  The resident **partition
catalog** (predicate boxes, entry→sub-cluster membership, per-sub selection
boxes / intervals / counts) lives in small always-resident ``.npy`` files
like the summaries.  ``manifest["n_clusters"]`` stays the *base* cluster
count — sub-partitions occupy ids ``[K, K + n_subs)`` and ``gens.npy`` grows
to cover them, so every (cluster_id, gen)-keyed layer serves them unchanged.
v3 checkpoints load fine and simply have no catalog (flat routing only).

Versioning: ``manifest["layout"]`` is 4 for the current format (3 without
sub-partitions), 2 for the pre-generation record format (``layout_minor`` 1
marks v2.1 summary writers).  Layout v1 (one
``.npz`` of stacked arrays per shard) is still *read* — ``load_index``
dispatches on the manifest — and v1/v2 can still be written with
``save_index(..., layout=1|2)`` for tooling that expects them.  v1
checkpoints
written before the SQ8 fix (no ``scales`` key) load as unquantized raw codes
and are rejected with a clear error rather than silently mis-scored.

Elastic re-sharding is unchanged: runtime sharding is "contiguous cluster
ranges over a flat chip list", so a checkpoint from S chips restores onto S'
chips by re-slicing ranges.  ``pad_k`` pads with empty clusters so K divides
any target chip count; padded clusters have ``counts == 0``, which the
centroid top-k masks to NEG_INF — they are *unprobeable* under every metric
(the old sentinel-coordinate trick was sign-sensitive for dot queries).

Writes are atomic (tmp + rename) and ``load_index`` verifies completeness
before touching any array — a partially written checkpoint is never loaded.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridSpec
from repro.core.ivf import IVFFlatIndex
from repro.core.summaries import (
    ClusterBounds,
    ClusterSummaries,
    build_bounds,
    pad_clusters,
)

MANIFEST = "manifest.json"
GENS_FILE = "gens.npy"  # layout v3: resident per-cluster generation vector


class GenerationMismatchError(ValueError):
    """The checkpoint's generation vector disagrees with its manifest (or a
    peer served a block older than the generation the fetch demanded)."""
# Resident per-cluster attribute summaries (layout v2.1): one .npy per
# field, loaded whole — like centroids/counts, they are consulted at plan
# time before any flat list is touched.
SUMMARY_FILES = dict(
    amin="summaries_amin.npy",
    amax="summaries_amax.npy",
    hist="summaries_hist.npy",
    edges_lo="summaries_edges_lo.npy",
    edges_hi="summaries_edges_hi.npy",
)
# Resident per-cluster geometric score bounds (bound-driven early
# termination): like the summaries, tiny always-resident .npy files next to
# centroids.npy.  The manifest's ``has_bounds`` flag gates them; checkpoints
# without them load fine and simply can't serve termination= from disk until
# re-saved.
BOUNDS_FILES = dict(
    radius="bounds_radius.npy",
    slack="bounds_slack.npy",
)
# Filter-specialized sub-partitions (layout v4): resident catalog arrays
# (one .npy per PartitionCatalog field) plus the variable-stride record
# region ``partitions.bin`` addressed by ``partition_offsets.npy``.
PARTITION_FILES = dict(
    pred_lo="partition_pred_lo.npy",
    pred_hi="partition_pred_hi.npy",
    members="partition_members.npy",
    entry_rows="partition_entry_rows.npy",
    parent="partition_parent.npy",
    sub_lo="partition_sub_lo.npy",
    sub_hi="partition_sub_hi.npy",
    sub_counts="partition_sub_counts.npy",
    sub_amin="partition_sub_amin.npy",
    sub_amax="partition_sub_amax.npy",
)
PARTITION_VPADS = "partition_vpads.npy"    # [P] int32 per-sub row capacity
PARTITION_OFFSETS = "partition_offsets.npy"  # [P+1] int64 byte offsets
PARTITION_DATA = "partitions.bin"
_FIELD_ALIGN = 64     # per-field offset alignment inside a record
_RECORD_ALIGN = 512   # record stride alignment (mmap-friendly)


def np_dtype(name: str) -> np.dtype:
    """Resolves a manifest dtype name; bfloat16 via ml_dtypes (jax dep)."""
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _dtype_name(dtype) -> str:
    name = np.dtype(dtype).name if dtype != jnp.bfloat16 else "bfloat16"
    return name


def _align(off: int, a: int) -> int:
    return ((off + a - 1) // a) * a


def record_layout(
    *, vpad: int, dim: int, n_attrs: int, store_dtype: str,
    has_norms: bool, quantized: bool, with_gen: bool = False,
) -> Tuple[List[dict], int]:
    """The v2/v3 per-cluster record: ordered field table + fixed stride.

    Returns ``(fields, stride)`` where each field is
    ``{name, dtype, shape, offset}`` (shape is per-cluster, e.g. ``[Vpad, D]``
    for vectors) and ``stride`` is the record size in bytes.  ``with_gen``
    (layout v3) appends the record's generation stamp.
    """
    specs = [("vectors", store_dtype, (vpad, dim)),
             ("attrs", "int16", (vpad, n_attrs)),
             ("ids", "int32", (vpad,))]
    if has_norms:
        specs.append(("norms", "float32", (vpad,)))
    if quantized:
        specs.append(("scales", "float32", (vpad,)))
    if with_gen:
        specs.append(("gen", "int64", (1,)))
    fields, off = [], 0
    for name, dt, shape in specs:
        off = _align(off, _FIELD_ALIGN)
        fields.append(dict(name=name, dtype=dt, shape=list(shape), offset=off))
        off += int(np.prod(shape)) * np_dtype(dt).itemsize
    return fields, _align(off, _RECORD_ALIGN)


def _atomic_save(path: str, save_fn):
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        save_fn(tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def pad_k(index: IVFFlatIndex, k_new: int) -> IVFFlatIndex:
    """Pads the cluster axis to ``k_new`` with empty, unprobeable clusters.

    Padded clusters have ``counts == 0``; ``search_centroids`` masks them out
    of the centroid top-k, so no probe budget is ever spent on them — for any
    metric and any query sign.  Their centroid rows are plain zeros (inert;
    correctness does not ride on a sentinel coordinate).  Every per-cluster
    array — including SQ8 ``scales`` — is padded, so a resharded quantized
    index keeps its ``[K, Vpad]`` shape contract.
    """
    k = index.n_clusters
    if k_new < k:
        raise ValueError(f"cannot shrink K: {k} -> {k_new}")
    if k_new == k:
        return index
    dk = k_new - k
    pad = lambda a, fill: jnp.concatenate(
        [a, jnp.full((dk,) + a.shape[1:], fill, a.dtype)], axis=0
    )
    return dataclasses.replace(
        index,
        centroids=pad(index.centroids, 0.0),
        vectors=pad(index.vectors, 0),
        attrs=pad(index.attrs, 0),
        ids=pad(index.ids, -1),
        counts=pad(index.counts, 0),
        norms=None if index.norms is None else pad(index.norms, 0),
        scales=None if index.scales is None else pad(index.scales, 1.0),
        summaries=(
            None if index.summaries is None
            else pad_clusters(index.summaries, k_new)  # void rows: never match
        ),
    )


def _index_arrays(index: IVFFlatIndex) -> Dict[str, np.ndarray]:
    arrays = dict(
        vectors=np.asarray(index.vectors),
        attrs=np.asarray(index.attrs),
        ids=np.asarray(index.ids),
    )
    if index.norms is not None:
        arrays["norms"] = np.asarray(index.norms, np.float32)
    if index.scales is not None:
        arrays["scales"] = np.asarray(index.scales, np.float32)
    return arrays


def _base_manifest(index: IVFFlatIndex, *, n_shards: int, version: int
                   ) -> dict:
    return dict(
        version=version,
        n_clusters=index.n_clusters,
        n_shards=n_shards,
        vpad=index.vpad,
        dim=index.spec.dim,
        n_attrs=index.spec.n_attrs,
        metric=index.spec.metric,
        core_dtype=_dtype_name(index.spec.core_dtype),
        store_dtype=_dtype_name(index.vectors.dtype),
        has_norms=index.norms is not None,
        quantized=index.quantized,
        has_summaries=index.summaries is not None,
        summary_bins=(
            index.summaries.n_bins if index.summaries is not None else 0
        ),
        n_live=int(jnp.sum(index.counts)),
    )


def partition_record_layout(man: dict, vpad: int) -> Tuple[List[dict], int]:
    """The field table + stride of one sub-partition record (layout v4):
    same field order as the base records, at the sub's own row capacity."""
    return record_layout(
        vpad=int(vpad), dim=man["dim"], n_attrs=man["n_attrs"],
        store_dtype=man["store_dtype"], has_norms=man["has_norms"],
        quantized=man["quantized"], with_gen=True,
    )


def write_partition_region(directory: str, man: dict, build,
                           sub_gens: np.ndarray) -> None:
    """Writes the v4 partition plane: the variable-stride record region
    (``partitions.bin`` + byte offsets) and the resident catalog ``.npy``
    files.  Shared by ``save_index`` and ``compact_deltas`` so a republish
    rewrites sub-partitions in exactly the build's format."""
    cat = build.catalog
    p = build.n_subs
    sub_gens = np.asarray(sub_gens, np.int64)
    offsets = np.zeros(p + 1, np.int64)

    def _np_save(path, arr):
        with open(path, "wb") as f:
            np.save(f, arr, allow_pickle=False)

    def _bin_save(path):
        with open(path, "wb") as f:
            off = 0
            for j, rec in enumerate(build.records):
                fields, stride = partition_record_layout(
                    man, int(build.vpads[j])
                )
                buf = np.zeros(stride, np.uint8)
                payload = dict(rec)
                payload["gen"] = np.asarray([sub_gens[j]], np.int64)
                for fld in fields:
                    raw = np.ascontiguousarray(
                        payload[fld["name"]]
                    ).tobytes()
                    o = fld["offset"]
                    buf[o:o + len(raw)] = np.frombuffer(raw, np.uint8)
                f.write(buf.tobytes())
                offsets[j] = off
                off += stride
            offsets[p] = off

    _atomic_save(os.path.join(directory, PARTITION_DATA), _bin_save)
    _atomic_save(
        os.path.join(directory, PARTITION_OFFSETS),
        lambda path: _np_save(path, offsets),
    )
    _atomic_save(
        os.path.join(directory, PARTITION_VPADS),
        lambda path: _np_save(path, np.asarray(build.vpads, np.int32)),
    )
    for field, fname in PARTITION_FILES.items():
        _atomic_save(
            os.path.join(directory, fname),
            lambda path, f=field: _np_save(path, np.asarray(getattr(cat, f))),
        )


def save_index(index: IVFFlatIndex, directory: str, *, n_shards: int = 1,
               version: int = 0, layout: int = 3,
               gens: Optional[np.ndarray] = None,
               partitions=None) -> None:
    """Writes the index as ``n_shards`` contiguous cluster-range files.

    ``layout=3`` (default) writes the fixed-stride record format above with
    per-cluster generation stamps (``gens``, default all-zero) plus the
    resident ``gens.npy``; ``layout=2`` is the same record format without
    generations; ``layout=1`` writes the legacy one-npz-per-shard format
    (all carry SQ8 ``scales`` and the ``quantized`` manifest flag).

    ``layout=4`` additionally persists filter-specialized sub-partitions:
    ``partitions`` must be a :class:`repro.core.partitions.PartitionBuild`
    (from ``partitions.build_partitions``).  ``gens`` may cover the base
    clusters only (``[K]`` — sub generations inherit their parent's) or the
    full extended id space (``[K + n_subs]``).
    """
    k = index.n_clusters
    if k % n_shards:
        raise ValueError(f"K={k} not divisible by n_shards={n_shards}; pad_k first")
    if layout not in (1, 2, 3, 4):
        raise ValueError(f"unknown layout {layout}")
    if layout == 4 and partitions is None:
        raise ValueError("layout=4 needs partitions= (a PartitionBuild)")
    if layout != 4 and partitions is not None:
        raise ValueError("partitions= needs layout=4")
    n_subs = partitions.n_subs if partitions is not None else 0
    if gens is None:
        gens = np.zeros(k + n_subs, np.int64)
    gens = np.asarray(gens, np.int64)
    if layout == 4 and gens.shape == (k,):
        # base-only vector: sub-partitions inherit their parent's generation
        sub = gens[np.asarray(partitions.catalog.parent, np.int64)]
        gens = np.concatenate([gens, sub])
    expect = (k + n_subs,) if layout == 4 else (k,)
    if gens.shape != expect:
        raise GenerationMismatchError(
            f"gens shape {gens.shape} != {expect} clusters"
        )
    os.makedirs(directory, exist_ok=True)
    kl = k // n_shards
    manifest = _base_manifest(index, n_shards=n_shards, version=version)
    arrays = _index_arrays(index)
    if layout >= 3:
        arrays["gen"] = gens[:k, None]

    def _np_save(p, arr):
        with open(p, "wb") as f:  # file handle: np.save must not append .npy
            np.save(f, arr, allow_pickle=False)

    _atomic_save(
        os.path.join(directory, "centroids.npy"),
        lambda p: _np_save(p, np.asarray(index.centroids, np.float32)),
    )
    if index.summaries is not None:  # resident, layout-independent (v2.1)
        for field, fname in SUMMARY_FILES.items():
            _atomic_save(
                os.path.join(directory, fname),
                lambda p, f=field: _np_save(
                    p, np.asarray(getattr(index.summaries, f))
                ),
            )
    # Resident score bounds: recomputed from the flat lists at save time (the
    # writer holds them all anyway) so every fresh checkpoint can serve
    # termination= from disk without touching a shard.
    bounds = build_bounds(
        index.centroids, index.vectors, index.ids, index.norms, index.scales
    )
    for field, fname in BOUNDS_FILES.items():
        _atomic_save(
            os.path.join(directory, fname),
            lambda p, f=field: _np_save(p, np.asarray(getattr(bounds, f))),
        )
    manifest["has_bounds"] = True

    if layout == 1:
        for s in range(n_shards):
            lo, hi = s * kl, (s + 1) * kl
            payload = {name: a[lo:hi] for name, a in arrays.items()}
            payload["counts"] = np.asarray(index.counts[lo:hi], np.int32)

            def _npz_save(p, pl):
                with open(p, "wb") as f:
                    np.savez(f, **pl)

            _atomic_save(
                os.path.join(directory, f"shard_{s}_of_{n_shards}.npz"),
                lambda p, pl=payload: _npz_save(p, pl),
            )
        manifest["layout"] = 1
    else:
        fields, stride = record_layout(
            vpad=index.vpad, dim=index.spec.dim, n_attrs=index.spec.n_attrs,
            store_dtype=manifest["store_dtype"],
            has_norms=manifest["has_norms"], quantized=index.quantized,
            with_gen=layout >= 3,
        )
        _atomic_save(
            os.path.join(directory, "counts.npy"),
            lambda p: _np_save(p, np.asarray(index.counts, np.int32)),
        )
        if layout >= 3:
            _atomic_save(
                os.path.join(directory, GENS_FILE),
                lambda p: _np_save(p, gens),
            )
        for s in range(n_shards):
            lo, hi = s * kl, (s + 1) * kl

            def _bin_save(p, lo=lo, hi=hi):
                with open(p, "wb") as f:
                    rec = np.zeros(stride, np.uint8)
                    for c in range(lo, hi):
                        rec[:] = 0
                        for fld in fields:
                            raw = np.ascontiguousarray(
                                arrays[fld["name"]][c]
                            ).tobytes()
                            o = fld["offset"]
                            rec[o:o + len(raw)] = np.frombuffer(raw, np.uint8)
                        f.write(rec.tobytes())

            _atomic_save(
                os.path.join(directory, f"shard_{s}_of_{n_shards}.bin"),
                _bin_save,
            )
        manifest.update(layout=layout, layout_minor=1, record_stride=stride,
                        fields=fields)
        if layout == 4:
            write_partition_region(directory, manifest, partitions,
                                   gens[k:])
            manifest["has_partitions"] = True
            manifest["partitions"] = dict(
                n_subs=n_subs,
                n_entries=partitions.catalog.n_entries,
            )

    _atomic_save(
        os.path.join(directory, MANIFEST),
        lambda p: open(p, "w").write(json.dumps(manifest, indent=2)),
    )


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, MANIFEST)) as f:
        man = json.load(f)
    man.setdefault("layout", 1)        # pre-v2 checkpoints
    man.setdefault("quantized", False)  # pre-SQ8-fix checkpoints
    man.setdefault("has_summaries", False)  # pre-v2.1: no pruning, sound
    man.setdefault("has_bounds", False)  # pre-PR-9: no disk-tier termination
    man.setdefault("has_partitions", False)  # pre-v4: flat routing only
    return man


def load_summaries(directory: str, man: dict) -> Optional[ClusterSummaries]:
    """Loads the resident summary arrays, or None for pre-v2.1 checkpoints
    (missing summaries simply disable probe pruning)."""
    if not man.get("has_summaries"):
        return None
    fields = {
        f: jnp.asarray(np.load(os.path.join(directory, fname)))
        for f, fname in SUMMARY_FILES.items()
    }
    return ClusterSummaries(**fields)


def load_bounds(directory: str, man: dict) -> Optional[ClusterBounds]:
    """Loads the resident per-cluster score bounds, or None for checkpoints
    written before they existed (bound-driven termination then needs a
    re-save; exact search is unaffected)."""
    if not man.get("has_bounds"):
        return None
    fields = {
        f: jnp.asarray(np.load(os.path.join(directory, fname)))
        for f, fname in BOUNDS_FILES.items()
    }
    return ClusterBounds(**fields)


def load_gens(directory: str, man: dict) -> np.ndarray:
    """Resident per-cluster generation vector ``[K] int64`` (layout v4:
    ``[K + n_subs]`` — sub-partition generations extend the base vector).

    Pre-v3 checkpoints have no generations: every cluster is ``gen == 0``
    (and serves unchanged — the back-compat contract).  On v3+ the vector
    must exist and match the manifest's cluster count, else the checkpoint
    is inconsistent and refuses to load.
    """
    k = man["n_clusters"]
    if man.get("layout", 1) >= 4:
        k += int(man.get("partitions", {}).get("n_subs", 0))
    if man.get("layout", 1) < 3:
        return np.zeros(k, np.int64)
    path = os.path.join(directory, GENS_FILE)
    if not os.path.exists(path):
        raise GenerationMismatchError(
            f"layout-3 checkpoint missing {GENS_FILE}: {directory}"
        )
    gens = np.asarray(np.load(path), np.int64)
    if gens.shape != (k,):
        raise GenerationMismatchError(
            f"{GENS_FILE} has {gens.shape} entries, manifest says "
            f"{k} clusters: {directory}"
        )
    return gens


def load_partitions(directory: str, man: dict):
    """Loads the resident partition catalog, or None for pre-v4 checkpoints
    (no catalog simply means every query takes the flat path)."""
    if not man.get("has_partitions"):
        return None
    from repro.core.partitions import PartitionCatalog

    fields = {
        f: np.load(os.path.join(directory, fname))
        for f, fname in PARTITION_FILES.items()
    }
    return PartitionCatalog(n_base=man["n_clusters"], **fields)


def load_partition_vpads(directory: str) -> np.ndarray:
    return np.asarray(np.load(os.path.join(directory, PARTITION_VPADS)),
                      np.int32)


def load_partition_records(directory: str, man: dict
                           ) -> List[Dict[str, np.ndarray]]:
    """Reads every sub-partition record from the variable-stride region
    (offline use: RAM-tier load, compaction rewrite — the serving path pages
    single records through ``ShardReader.read`` instead)."""
    vpads = load_partition_vpads(directory)
    offsets = np.asarray(
        np.load(os.path.join(directory, PARTITION_OFFSETS)), np.int64
    )
    raw = np.fromfile(os.path.join(directory, PARTITION_DATA), np.uint8)
    out = []
    for j, vp in enumerate(vpads):
        fields, stride = partition_record_layout(man, int(vp))
        chunk = raw[offsets[j]:offsets[j] + stride]
        rec = {}
        for fld in fields:
            dt = np_dtype(fld["dtype"])
            nb = int(np.prod(fld["shape"])) * dt.itemsize
            o = fld["offset"]
            rec[fld["name"]] = np.ascontiguousarray(
                chunk[o:o + nb]
            ).view(dt).reshape(tuple(fld["shape"]))
        out.append(rec)
    return out


def shard_paths(directory: str, man: dict) -> List[str]:
    ext = "bin" if man["layout"] >= 2 else "npz"
    n = man["n_shards"]
    return [
        os.path.join(directory, f"shard_{s}_of_{n}.{ext}") for s in range(n)
    ]


def check_complete(directory: str, man: dict) -> List[str]:
    paths = shard_paths(directory, man)
    required = list(paths)
    if man.get("has_summaries"):
        required += [
            os.path.join(directory, f) for f in SUMMARY_FILES.values()
        ]
    if man.get("has_bounds"):
        required += [
            os.path.join(directory, f) for f in BOUNDS_FILES.values()
        ]
    if man.get("layout", 1) >= 3:
        required.append(os.path.join(directory, GENS_FILE))
    if man.get("has_partitions"):
        required += [
            os.path.join(directory, f) for f in PARTITION_FILES.values()
        ]
        required += [
            os.path.join(directory, f)
            for f in (PARTITION_VPADS, PARTITION_OFFSETS, PARTITION_DATA)
        ]
    missing = [p for p in required if not os.path.exists(p)]
    if missing:
        raise FileNotFoundError(f"incomplete checkpoint, missing: {missing}")
    if man.get("layout", 1) >= 3:
        load_gens(directory, man)  # raises GenerationMismatchError on skew
    return paths


def spec_from_manifest(man: dict) -> HybridSpec:
    core_dtype = (
        jnp.bfloat16 if man["core_dtype"] == "bfloat16"
        else jnp.dtype(man["core_dtype"])
    )
    return HybridSpec(
        dim=man["dim"], n_attrs=man["n_attrs"], core_dtype=core_dtype,
        metric=man["metric"],
    )


def _load_v1(directory: str, man: dict, paths: List[str]) -> IVFFlatIndex:
    parts = [np.load(p) for p in paths]
    cat = lambda k: jnp.asarray(np.concatenate([p[k] for p in parts], 0))
    spec = spec_from_manifest(man)
    stored_int8 = parts[0]["vectors"].dtype == np.int8
    if man["quantized"] or stored_int8:
        # int8 vectors with no (or unflagged) scales = a checkpoint written
        # by the pre-fix save_index, which dropped `scales` and the
        # `quantized` flag; casting the codes to float would silently score
        # garbage, so refuse to load it.
        if "scales" not in parts[0].files:
            raise ValueError(
                "quantized checkpoint has no 'scales' payload (written by a "
                "pre-fix save_index); rebuild and re-save the index"
            )
        vectors = cat("vectors")  # int8 codes, no cast
        scales = cat("scales")
    else:
        vectors = cat("vectors").astype(spec.core_dtype)
        scales = None
    return IVFFlatIndex(
        spec=spec,
        centroids=jnp.asarray(np.load(os.path.join(directory, "centroids.npy"))),
        vectors=vectors,
        attrs=cat("attrs"),
        ids=cat("ids"),
        counts=cat("counts"),
        norms=cat("norms") if man["has_norms"] else None,
        scales=scales,
        summaries=load_summaries(directory, man),
    )


def read_shard_fields(path: str, man: dict) -> Dict[str, np.ndarray]:
    """Reads one v2 shard file into per-field arrays ``[kl, *field_shape]``."""
    stride = man["record_stride"]
    raw = np.fromfile(path, np.uint8)
    if raw.size % stride:
        raise ValueError(f"{path}: size {raw.size} not a stride multiple")
    raw = raw.reshape(-1, stride)
    out = {}
    for fld in man["fields"]:
        dt = np_dtype(fld["dtype"])
        nb = int(np.prod(fld["shape"])) * dt.itemsize
        o = fld["offset"]
        flat = np.ascontiguousarray(raw[:, o:o + nb]).view(dt)
        out[fld["name"]] = flat.reshape((raw.shape[0],) + tuple(fld["shape"]))
    return out


def _load_v2(directory: str, man: dict, paths: List[str]) -> IVFFlatIndex:
    spec = spec_from_manifest(man)
    parts = [read_shard_fields(p, man) for p in paths]
    cat = lambda k: jnp.asarray(np.concatenate([p[k] for p in parts], 0))
    return IVFFlatIndex(
        spec=spec,
        centroids=jnp.asarray(np.load(os.path.join(directory, "centroids.npy"))),
        vectors=cat("vectors"),
        attrs=cat("attrs"),
        ids=cat("ids"),
        counts=jnp.asarray(np.load(os.path.join(directory, "counts.npy"))),
        norms=cat("norms") if man["has_norms"] else None,
        scales=cat("scales") if man["quantized"] else None,
        summaries=load_summaries(directory, man),
    )


def load_index(
    directory: str, *, target_shards: Optional[int] = None
) -> IVFFlatIndex:
    """Restores an index into RAM; ``target_shards`` pads K for a new chip
    count.  Reads both layout v2 (fixed-stride records) and legacy v1 (npz).

    Verifies every shard file exists before loading anything (a save that
    died mid-write leaves no manifest or a manifest pointing at a complete
    older set — either way no partial state is observable).  For serving an
    index larger than host memory, open it with
    :class:`repro.core.disk.DiskIVFIndex` instead.
    """
    man = load_manifest(directory)
    paths = check_complete(directory, man)
    index = (
        _load_v2(directory, man, paths) if man["layout"] >= 2
        else _load_v1(directory, man, paths)
    )
    if man.get("has_partitions"):
        # v4: extend the RAM index with the sub-partition lists and hang
        # the catalog off it, so the RAM-tier engine routes like the disk
        # tier does.  Re-sharding pads would break the catalog's base-id
        # space, so it applies to the base index before attach.
        from repro.core import partitions as partitions_lib

        catalog = load_partitions(directory, man)
        records = load_partition_records(directory, man)
        build = partitions_lib.PartitionBuild(
            catalog=catalog,
            records=[
                {k: v for k, v in rec.items() if k != "gen"}
                for rec in records
            ],
            vpads=load_partition_vpads(directory),
        )
        if target_shards and index.n_clusters % target_shards:
            raise ValueError(
                "target_shards re-padding is unsupported for a partitioned "
                "(layout v4) checkpoint — re-save the base index first"
            )
        return partitions_lib.attach(index, build)
    if target_shards and index.n_clusters % target_shards:
        k_new = ((index.n_clusters + target_shards - 1) // target_shards
                 ) * target_shards
        index = pad_k(index, k_new)
    return index
