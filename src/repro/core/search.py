"""Filtered similarity search over the hybrid index (paper §4.4).

Three implementations of the same contract, fastest last:

  * :func:`brute_force`    — exact oracle over flat arrays (tests, recall refs;
                             also the paper's implicit exact baseline).
  * :func:`search_reference` — the paper's five steps in pure jnp: probe T
                             centroids, gather the probed lists, mask by
                             filter, score with a BLAS-style einsum, merge.
                             Materializes the [Q, T, Vpad, D] gather — fine at
                             test scale, ruinous at pod scale.
  * :func:`search_fused`   — same contract through the Pallas kernel
                             (``kernels/filtered_scan``): streams probed
                             cluster blocks HBM→VMEM by scalar-prefetched
                             probe ids, fuses the filter mask into the scoring
                             pass, never materializes the gather.

The fastest path is the search execution engine
(``core/engine.py::SearchEngine``, functional entry point
``search_fused_tiled``): it additionally tiles queries, deduplicates
overlapping probes per tile (``core/probes.py``), streams a per-probe
top-k — so neither the gather nor any ``[Q·T, Vpad]`` score matrix ever
exists — and on the disk tier can double-buffer cluster fetches against
the scan (``pipeline="on"``) while provisioning the slot table adaptively
from observed unique-probe counts.

All return ``SearchResult(scores [Q,k] f32, ids [Q,k] int32)`` where ids are
original vector ids (-1 where fewer than k vectors satisfy the filter) and
scores are "larger is more similar" (dot, or -||q-v||² for metric="l2").
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import topk as topk_lib
from repro.core.filters import FilterSpec, filter_mask
from repro.core.ivf import IVFFlatIndex, validity_mask

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SearchResult:
    scores: Array  # [Q, k] f32
    ids: Array  # [Q, k] int32, -1 = no hit
    n_scanned: Array  # [Q] int32 — candidates scanned (perf accounting)
    n_passed: Array  # [Q] int32 — candidates passing the filter
    # [Q] int32 — probes the filter-aware planner pruned (clusters the
    # query's filter provably cannot match; see core/summaries.py).  None on
    # paths without a plan stage (reference, brute force, old fused).
    n_pruned: Optional[Array] = None


def _query_scores(index: IVFFlatIndex, queries: Array, vectors: Array,
                  norms: Optional[Array],
                  scales: Optional[Array] = None) -> Array:
    """Scores of queries against a gathered vector block ([..., D])."""
    q32 = queries.astype(jnp.float32)
    v32 = vectors.astype(jnp.float32)
    dots = jnp.einsum("qd,q...d->q...", q32, v32)
    if scales is not None:  # SQ8: fold the per-vector scale into the dot
        dots = dots * scales
    if index.spec.metric == "dot":
        return dots
    q2 = jnp.sum(q32 * q32, axis=-1)
    q2 = q2.reshape(q2.shape + (1,) * (dots.ndim - 1))
    return 2.0 * dots - norms - q2  # -(||q-v||²)


def centroid_scores(
    centroids: Array, counts: Array, queries: Array, *, metric: str
) -> Array:
    """[Q, K] centroid scores with empty clusters masked unprobeable.

    Clusters with ``counts == 0`` (``pad_k`` fills, kmeans casualties) score
    NEG_INF so the probe budget never lands on them — regardless of metric or
    of the sign of any sentinel centroid coordinate.
    """
    q32 = queries.astype(jnp.float32)
    if metric == "dot":
        scores = q32 @ centroids.T
    else:
        scores = 2.0 * (q32 @ centroids.T) - jnp.sum(
            centroids * centroids, -1
        )[None, :]
    return jnp.where(counts[None, :] > 0, scores, topk_lib.NEG_INF)


def search_centroids(index, queries: Array, n_probes: int
                     ) -> Tuple[Array, Array]:
    """§4.4 step 2: T nearest non-empty centroids per query. [Q, T] ids+scores.

    ``index`` needs only ``.spec`` / ``.centroids`` / ``.counts`` — both
    :class:`IVFFlatIndex` and the disk tier's ``DiskIVFIndex`` qualify.
    """
    scores = centroid_scores(
        index.centroids, index.counts, queries, metric=index.spec.metric
    )
    vals, ids = jax.lax.top_k(scores, n_probes)
    return ids.astype(jnp.int32), vals


@functools.partial(jax.jit, static_argnames=("k", "n_probes"))
def search_reference(
    index: IVFFlatIndex,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
) -> SearchResult:
    """Pure-jnp §4.4 pipeline. Shapes: queries [Q, D]; fspec len Q."""
    q = queries.shape[0]
    probe_ids, _ = search_centroids(index, queries, n_probes)  # [Q, T]

    # Step 3+4 fused at the semantic level: gather probed lists and build the
    # combined (validity AND filter) mask, then score everything and let the
    # mask void the losers.  One pass over the data instead of the paper's
    # filter-then-score two passes.
    vecs = jnp.take(index.vectors, probe_ids, axis=0)  # [Q, T, Vpad, D]
    attr = jnp.take(index.attrs, probe_ids, axis=0)  # [Q, T, Vpad, M]
    ids = jnp.take(index.ids, probe_ids, axis=0)  # [Q, T, Vpad]
    valid = jnp.take(validity_mask(index), probe_ids, axis=0)
    norms = (
        jnp.take(index.norms, probe_ids, axis=0)
        if index.norms is not None
        else None
    )
    scales = (
        jnp.take(index.scales, probe_ids, axis=0)
        if index.scales is not None
        else None
    )

    qidx = jnp.broadcast_to(
        jnp.arange(q)[:, None, None], attr.shape[:-1]
    )
    fmask = filter_mask(fspec, attr, query_idx=qidx)
    mask = jnp.logical_and(valid, fmask)

    scores = _query_scores(index, queries, vecs, norms, scales)  # [Q,T,Vpad]
    flat_scores = scores.reshape(q, -1)
    flat_mask = mask.reshape(q, -1)
    flat_ids = ids.reshape(q, -1)
    vals, out_ids = topk_lib.masked_topk(flat_scores, flat_mask, k, ids=flat_ids)
    n_scanned = jnp.sum(valid.reshape(q, -1).astype(jnp.int32), axis=-1)
    n_passed = jnp.sum(flat_mask.astype(jnp.int32), axis=-1)
    return SearchResult(vals, out_ids, n_scanned, n_passed)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def brute_force(
    vectors: Array,
    attrs: Array,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    metric: str = "dot",
    ids: Optional[Array] = None,
) -> SearchResult:
    """Exact filtered search over flat [N, D] / [N, M] arrays (the oracle)."""
    q = queries.shape[0]
    n = vectors.shape[0]
    q32 = queries.astype(jnp.float32)
    v32 = vectors.astype(jnp.float32)
    dots = q32 @ v32.T  # [Q, N]
    if metric == "dot":
        scores = dots
    else:
        scores = (
            2.0 * dots
            - jnp.sum(v32 * v32, -1)[None, :]
            - jnp.sum(q32 * q32, -1)[:, None]
        )
    amask = filter_mask(
        fspec, jnp.broadcast_to(attrs, (q,) + attrs.shape)
    )  # [Q, N]
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    vals, out_ids = topk_lib.masked_topk(
        scores, amask, k, ids=jnp.broadcast_to(ids, (q, n))
    )
    n_scanned = jnp.full((q,), n, jnp.int32)
    n_passed = jnp.sum(amask.astype(jnp.int32), axis=-1)
    return SearchResult(vals, out_ids, n_scanned, n_passed)


def recall_at_k(result: SearchResult, oracle: SearchResult) -> float:
    """Fraction of oracle ids recovered (standard ANN recall@k).

    Vectorized (one [Q, k, k'] membership test) — this runs inside benchmark
    sweeps, where the old per-row Python set loop dominated at large Q.
    """
    import numpy as np

    res = np.asarray(jax.device_get(result.ids))
    ref = np.asarray(jax.device_get(oracle.ids))
    ref_live = ref >= 0  # [Q, k']
    hit = np.logical_and(
        ref[:, :, None] == res[:, None, :], ref_live[:, :, None]
    ).any(-1)  # [Q, k'] — res -1 pads never equal a live ref id
    total = int(ref_live.sum())
    return int(hit.sum()) / max(total, 1)
