"""Filter-specialized sub-partitions: attribute-aware cluster layouts.

The summaries plane (PR 3) prunes clusters a filter provably cannot match,
but a 0.5%-selectivity query still scans *full* clusters where 99.5% of the
rows fail the filter.  Following SIEVE's "collection of indexes keyed by
popular predicates", this module materializes **sub-partitions**: physical
re-slices of selected clusters along high-traffic attributes, each persisted
as its own generation-tagged cluster record (storage layout v4).  A resident
**partition catalog** maps predicate boxes to sub-cluster ids; the planner
picks, per query, the *narrowest* catalog entry whose predicate subsumes the
query's filter and remaps that query's probes from base cluster ids to sub
ids.  Every layer below the planner — disk reads, BlockStore ring, device
cache, delta fold — already keys on ``(cluster_id, gen)``, so sub-partitions
are just more cluster ids with smaller records.

Exactness contract (the whole design hangs on it):

  * an entry's predicate box **subsumes** a query filter iff every non-void
    DNF term's interval box is per-attribute contained in the entry box.
    Subsumption guarantees no filter-passing row lives outside the entry's
    row set, so scanning the entry's sub-partitions (or the parent cluster
    where no sub was materialized) sees the exact same filter-passing
    candidate multiset as the flat scan;
  * each sub-partition copies its parent's live rows **in parent slot
    order**, so per-probe top-k fragments — which break score ties by slot
    index — come out bit-identical to the flat path;
  * ``members[e, c] = -1`` means "scan the parent cluster" (always exact: a
    superset of the window's rows, the filter masks the rest), so an entry
    can never be *invalid*, only less effective.

Entry shapes:

  * **sliding-window ladder** per ordered attribute: level ℓ covers the
    attribute's observed range with ``base_windows · 2^ℓ`` windows of width
    ``2·range/n_ℓ`` at stride ``range/n_ℓ`` — any query interval of width
    ≤ ``range/n_ℓ`` is contained in some window, so narrower filters route
    to geometrically narrower partitions;
  * **per-value entries** for low-cardinality attributes (≤ ``max_values``
    distinct values): exact-match and IN-set filters route to the value's
    partition directly.

Attribute choice combines the engine's observed filter traffic (the
:class:`FilterTrafficRecorder` counts which attributes queries actually
constrain) with the summary plane's global value spread.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hybrid import ATTR_MAX, ATTR_MIN

# Sub-partition rows are padded to the TPU lane width, like every flat list.
SUB_ALIGN = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class PartitionCatalog:
    """Resident predicate → sub-cluster routing table (host-side).

    Entries (E of them) are predicate boxes; subs (P of them) are the
    materialized sub-partition records.  Sub-cluster ids live in
    ``[n_base, n_base + P)`` — the id space every (cluster_id, gen)-keyed
    layer already understands.
    """

    pred_lo: np.ndarray    # [E, M] int16 — entry predicate box (lo)
    pred_hi: np.ndarray    # [E, M] int16 — entry predicate box (hi)
    members: np.ndarray    # [E, K_base] int32 — sub cid, or -1 = scan parent
    entry_rows: np.ndarray  # [E] int64 — rows reachable via the entry
    parent: np.ndarray     # [P] int32 — base cluster each sub re-slices
    sub_lo: np.ndarray     # [P, M] int16 — selection box that built the sub
    sub_hi: np.ndarray     # [P, M] int16
    sub_counts: np.ndarray  # [P] int32 — live rows per sub
    sub_amin: np.ndarray   # [P, M] int16 — per-sub attribute intervals
    sub_amax: np.ndarray   # [P, M] int16
    n_base: int

    @property
    def n_entries(self) -> int:
        return int(self.pred_lo.shape[0])

    @property
    def n_subs(self) -> int:
        return int(self.parent.shape[0])

    @property
    def n_attrs(self) -> int:
        return int(self.pred_lo.shape[1])

    def nbytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize
            for a in (self.pred_lo, self.pred_hi, self.members,
                      self.entry_rows, self.parent, self.sub_lo, self.sub_hi,
                      self.sub_counts, self.sub_amin, self.sub_amax)
        )

    def route(self, lo, hi) -> np.ndarray:
        """Narrowest subsuming entry per query, or -1 (flat fallback).

        ``lo, hi``: [Q, n_terms, M] int16 filter boxes (void terms have
        lo > hi on some attribute).  An entry subsumes a query iff every
        non-void term's box is per-attribute contained in the entry box and
        the query has at least one non-void term; among subsuming entries
        the one reaching the fewest rows wins.
        """
        lo = np.asarray(lo, np.int16)
        hi = np.asarray(hi, np.int16)
        if lo.ndim == 2:  # single query convenience
            lo, hi = lo[None], hi[None]
        nonvoid = np.all(lo <= hi, axis=-1)  # [Q, T]
        # [Q, T, E]: term box contained in entry box on every attribute
        cont = np.all(
            (self.pred_lo[None, None, :, :] <= lo[:, :, None, :])
            & (hi[:, :, None, :] <= self.pred_hi[None, None, :, :]),
            axis=-1,
        )
        ok = np.all(cont | ~nonvoid[:, :, None], axis=1)  # [Q, E]
        ok &= nonvoid.any(axis=1)[:, None]
        rows = np.where(ok, self.entry_rows[None, :], np.iinfo(np.int64).max)
        best = np.argmin(rows, axis=1).astype(np.int32)
        return np.where(ok.any(axis=1), best, np.int32(-1))

    def to_base(self, cids: np.ndarray) -> np.ndarray:
        """Maps sub-cluster ids back to their parent base ids (identity on
        base ids) — the planner's bridge to base-width arrays (centroids,
        bounds, summaries) that never grew sub rows."""
        cids = np.asarray(cids)
        out = cids.copy()
        is_sub = cids >= self.n_base
        if is_sub.any():
            out[is_sub] = self.parent[cids[is_sub] - self.n_base]
        return out


@dataclasses.dataclass
class PartitionBuild:
    """A catalog plus the host-side sub-partition records to persist."""

    catalog: PartitionCatalog
    records: List[Dict[str, np.ndarray]]  # per sub: vectors/attrs/ids/...
    vpads: np.ndarray  # [P] int32 — per-sub padded capacity

    @property
    def n_subs(self) -> int:
        return len(self.records)


class FilterTrafficRecorder:
    """Counts which attributes live filter traffic actually constrains.

    The engine calls :meth:`observe` per planned batch (cheap host numpy);
    :meth:`top_attrs` feeds the partition builder the attributes worth
    specializing the physical layout for.  Thread-safe (the serving loop and
    an offline rebuild may race).
    """

    def __init__(self, n_attrs: int):
        self.n_attrs = int(n_attrs)
        self.constrained = np.zeros(self.n_attrs, np.int64)
        self.queries = 0
        self._lock = threading.Lock()

    def observe(self, lo, hi) -> None:
        lo = np.asarray(lo)
        hi = np.asarray(hi)
        nonvoid = np.all(lo <= hi, axis=-1, keepdims=True)  # [Q, T, 1]
        narrowed = (lo > ATTR_MIN) | (hi < ATTR_MAX)        # [Q, T, M]
        per_query = np.any(narrowed & nonvoid, axis=1)      # [Q, M]
        with self._lock:
            self.constrained += per_query.sum(axis=0).astype(np.int64)
            self.queries += int(lo.shape[0])

    def top_attrs(self, n: int = 2) -> List[int]:
        with self._lock:
            counts = self.constrained.copy()
        order = np.argsort(-counts, kind="stable")
        return [int(a) for a in order[:n] if counts[a] > 0]

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return dict(
                queries=int(self.queries),
                constrained=self.constrained.tolist(),
            )


def choose_attrs(
    summaries,
    traffic: Optional[FilterTrafficRecorder] = None,
    n: int = 2,
) -> List[int]:
    """Partition-attribute choice: observed filter traffic first, global
    value spread (from the summary plane's histogram edges) as tie-break /
    cold-start fallback."""
    if traffic is not None:
        top = traffic.top_attrs(n)
        if top:
            return top
    if summaries is None:
        return []
    lo = np.asarray(summaries.edges_lo, np.int32)
    hi = np.asarray(summaries.edges_hi, np.int32)
    spread = hi - lo
    order = np.argsort(-spread, kind="stable")
    return [int(a) for a in order[:n] if spread[a] > 0]


def _ladder_windows(glo: int, ghi: int, *, base_windows: int,
                    max_depth: int) -> List[Tuple[int, int]]:
    """Sliding-window ladder over [glo, ghi]: level ℓ has n = base·2^ℓ
    windows of width 2·range/n at stride range/n, so any query interval of
    width ≤ range/n is contained in some level-ℓ window."""
    windows: List[Tuple[int, int]] = []
    span = max(int(ghi) - int(glo), 1)
    for level in range(max_depth):
        n = base_windows * (2 ** level)
        if n >= 2 * span:  # windows narrower than 1 value: stop subdividing
            break
        stride = span / n
        width = 2 * stride
        for i in range(n):
            wlo = int(np.floor(glo + i * stride))
            whi = int(np.ceil(glo + i * stride + width))
            wlo = int(np.clip(wlo, ATTR_MIN, ATTR_MAX))
            whi = int(np.clip(whi, ATTR_MIN, ATTR_MAX))
            windows.append((wlo, whi))
    return windows


def build_partitions(
    index,
    *,
    attrs: Optional[Sequence[int]] = None,
    max_depth: int = 3,
    base_windows: int = 8,
    max_values: int = 32,
    max_subs: int = 4096,
    traffic: Optional[FilterTrafficRecorder] = None,
) -> PartitionBuild:
    """Builds the partition catalog + sub-partition records for an index.

    Runs at save/compact time with the full index host-accessible.  For each
    chosen attribute, low-cardinality values get per-value entries and
    ordered ranges get the sliding-window ladder (``max_depth`` levels of
    ``base_windows·2^ℓ`` windows).  Per (entry, cluster), a sub is
    materialized only when the window's live-row subset is a *strict* subset
    of the parent's live rows (otherwise the parent is scanned — same rows,
    no duplicate storage); identical row subsets are deduplicated across
    entries, and the total sub count is capped at ``max_subs`` (further
    entries fall back to parent scans — sound, just less effective).
    """
    A = np.asarray(index.attrs)          # [K, Vpad, M]
    ids = np.asarray(index.ids)          # [K, Vpad]
    counts = np.asarray(index.counts)    # [K]
    vectors = np.asarray(index.vectors)
    norms = None if index.norms is None else np.asarray(index.norms)
    scales = None if index.scales is None else np.asarray(index.scales)
    k, vpad, m = A.shape

    if attrs is None:
        attrs = choose_attrs(index.summaries, traffic)
    attrs = [int(a) for a in attrs]
    for a in attrs:
        if not 0 <= a < m:
            raise ValueError(f"partition attr {a} out of range [0, {m})")

    slot = np.arange(vpad)[None, :]
    live = (slot < counts[:, None]) & (ids >= 0)  # [K, Vpad]
    live_counts = live.sum(axis=1).astype(np.int64)  # [K]

    # entry predicate boxes: full-range except the partition attribute
    entry_boxes: List[Tuple[int, int, int]] = []  # (attr, wlo, whi)
    for a in attrs:
        vals = A[:, :, a][live]
        if vals.size == 0:
            continue
        distinct = np.unique(vals)
        if distinct.size <= max_values:
            freq_order = distinct  # small sets: every value gets an entry
            for v in freq_order:
                entry_boxes.append((a, int(v), int(v)))
        else:
            glo, ghi = int(vals.min()), int(vals.max())
            for wlo, whi in _ladder_windows(
                glo, ghi, base_windows=base_windows, max_depth=max_depth
            ):
                entry_boxes.append((a, wlo, whi))

    # materialize subs, deduplicating identical row subsets per cluster
    sub_key: Dict[Tuple[int, bytes], int] = {}
    sub_rows: List[np.ndarray] = []      # selected slot indices, slot order
    sub_parent: List[int] = []
    sub_box: List[Tuple[int, int, int]] = []
    members = np.full((len(entry_boxes), k), -1, np.int32)
    entry_rows = np.zeros(len(entry_boxes), np.int64)

    for e, (a, wlo, whi) in enumerate(entry_boxes):
        col = A[:, :, a]
        sel = live & (col >= wlo) & (col <= whi)  # [K, Vpad]
        nsel = sel.sum(axis=1).astype(np.int64)
        for c in range(k):
            if nsel[c] == live_counts[c]:
                entry_rows[e] += live_counts[c]  # window covers the cluster
                continue
            rows = np.nonzero(sel[c])[0].astype(np.int32)
            key = (c, rows.tobytes())
            p = sub_key.get(key)
            if p is None:
                if len(sub_rows) >= max_subs:
                    entry_rows[e] += live_counts[c]  # cap hit: parent scan
                    continue
                p = len(sub_rows)
                sub_key[key] = p
                sub_rows.append(rows)
                sub_parent.append(c)
                sub_box.append((a, wlo, whi))
            members[e, c] = k + p
            entry_rows[e] += int(nsel[c])

    n_subs = len(sub_rows)
    records: List[Dict[str, np.ndarray]] = []
    vpads = np.zeros(n_subs, np.int32)
    sub_counts = np.zeros(n_subs, np.int32)
    sub_amin = np.full((n_subs, m), ATTR_MAX, np.int16)
    sub_amax = np.full((n_subs, m), ATTR_MIN, np.int16)
    sub_lo = np.full((n_subs, m), ATTR_MIN, np.int16)
    sub_hi = np.full((n_subs, m), ATTR_MAX, np.int16)

    parent_vpad = int(vectors.shape[1])
    for p, rows in enumerate(sub_rows):
        c = sub_parent[p]
        n = int(rows.size)
        # pad to the alignment the scan kernels like, but never past the
        # parent's own height (small test indexes have Vpad < SUB_ALIGN;
        # a sub taller than its parent would break RAM attach / device
        # compose, and could never hold more rows anyway)
        vp = min(max(_round_up(n, SUB_ALIGN), SUB_ALIGN), parent_vpad)
        vp = max(vp, n, 1)
        rec: Dict[str, np.ndarray] = {}
        vec = np.zeros((vp,) + vectors.shape[2:], vectors.dtype)
        att = np.zeros((vp, m), A.dtype)
        rid = np.full((vp,), -1, np.int32)
        if n:
            vec[:n] = vectors[c, rows]
            att[:n] = A[c, rows]
            rid[:n] = ids[c, rows]
            sub_amin[p] = att[:n].min(axis=0)
            sub_amax[p] = att[:n].max(axis=0)
        rec["vectors"], rec["attrs"], rec["ids"] = vec, att, rid
        if norms is not None:
            nr = np.zeros((vp,), norms.dtype)
            if n:
                nr[:n] = norms[c, rows]
            rec["norms"] = nr
        if scales is not None:
            sc = np.zeros((vp,), scales.dtype)
            if n:
                sc[:n] = scales[c, rows]
            rec["scales"] = sc
        records.append(rec)
        vpads[p] = vp
        sub_counts[p] = n
        a, wlo, whi = sub_box[p]
        sub_lo[p, a] = np.int16(np.clip(wlo, ATTR_MIN, ATTR_MAX))
        sub_hi[p, a] = np.int16(np.clip(whi, ATTR_MIN, ATTR_MAX))

    pred_lo = np.full((len(entry_boxes), m), ATTR_MIN, np.int16)
    pred_hi = np.full((len(entry_boxes), m), ATTR_MAX, np.int16)
    for e, (a, wlo, whi) in enumerate(entry_boxes):
        pred_lo[e, a] = np.int16(np.clip(wlo, ATTR_MIN, ATTR_MAX))
        pred_hi[e, a] = np.int16(np.clip(whi, ATTR_MIN, ATTR_MAX))

    catalog = PartitionCatalog(
        pred_lo=pred_lo, pred_hi=pred_hi, members=members,
        entry_rows=entry_rows, parent=np.asarray(sub_parent, np.int32),
        sub_lo=sub_lo, sub_hi=sub_hi, sub_counts=sub_counts,
        sub_amin=sub_amin, sub_amax=sub_amax, n_base=k,
    )
    return PartitionBuild(catalog=catalog, records=records, vpads=vpads)


def select_sub_rows(attrs_row: np.ndarray, ids_row: np.ndarray, count: int,
                    box_lo: np.ndarray, box_hi: np.ndarray) -> np.ndarray:
    """Slot indices of a cluster's live rows inside a sub's selection box,
    in slot order — the single definition build and compact share, so a
    rebuilt sub reproduces the build's row choice exactly."""
    slot = np.arange(ids_row.shape[0])
    live = (slot < int(count)) & (ids_row >= 0)
    inside = np.all(
        (attrs_row >= box_lo[None, :]) & (attrs_row <= box_hi[None, :]),
        axis=1,
    )
    return np.nonzero(live & inside)[0].astype(np.int32)


def attach(index, build: PartitionBuild):
    """RAM-tier attach: extends the in-memory index with the sub-partition
    lists (padded to the parent Vpad) and hangs the catalog off the result.

    The planner only consults rows ``[:n_base]`` of the per-cluster arrays;
    sub rows exist purely as scan targets, so their summary rows are void
    and their centroids copy the parent's (never probed directly).
    """
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.core import summaries as summaries_lib

    cat = build.catalog
    k, vpad = np.asarray(index.ids).shape
    p = build.n_subs
    if p == 0:
        index.partitions = cat
        return index

    def _extend(base, per_sub_key, fill):
        base = np.asarray(base)
        ext = np.full((p,) + base.shape[1:], fill, base.dtype)
        for j, rec in enumerate(build.records):
            rows = rec[per_sub_key].shape[0]
            ext[j, :rows] = rec[per_sub_key]
        return jnp.asarray(np.concatenate([base, ext], axis=0))

    vectors = _extend(index.vectors, "vectors", 0)
    attrs = _extend(index.attrs, "attrs", 0)
    ids = _extend(index.ids, "ids", -1)
    norms = (None if index.norms is None
             else _extend(index.norms, "norms", 0))
    scales = (None if index.scales is None
              else _extend(index.scales, "scales", 0))
    centroids = jnp.concatenate(
        [index.centroids,
         jnp.asarray(np.asarray(index.centroids)[cat.parent])], axis=0
    )
    counts = jnp.concatenate(
        [index.counts, jnp.asarray(cat.sub_counts, np.int32)], axis=0
    )
    summ = index.summaries
    if summ is not None:
        summ = summaries_lib.pad_clusters(summ, k + p)
    out = _dc.replace(
        index, centroids=centroids, vectors=vectors, attrs=attrs, ids=ids,
        counts=counts, norms=norms, scales=scales, summaries=summ,
    )
    out.partitions = cat
    return out
