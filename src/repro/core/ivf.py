"""Hybrid IVF-Flat index structure and construction (paper §4.2).

Storage layout (TPU adaptation of the paper's per-list disk files):

  centroids : [K, D]        f32   — replicated; probed every query (§4.4 step 2)
  vectors   : [K, Vpad, D]  bf16  — padded flat lists, cluster-major. Sharded
                                    over chips on the leading axis at scale.
  attrs     : [K, Vpad, M]  int16 — attribute rows, same layout (§4.2 step 4)
  ids       : [K, Vpad]     int32 — original vector ids; -1 marks an empty or
                                    tombstoned slot
  norms     : [K, Vpad]     f32   — ||v||², only materialized for metric="l2"
  counts    : [K]           int32 — live-slot high-water mark per list

``Vpad`` is the static per-list capacity (multiple of the TPU lane width 128).
Padding is the price of static shapes; the roofline section quantifies the
waste (Vpad/V̄) and the build balances it by splitting oversized clusters.

The padded-scatter construction is pure JAX (sort + positional scatter, no
one-hot matmuls) so the same code path builds a 1k-vector test index on CPU
and a sharded billion-vector index under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridSpec, make_hybrid
from repro.core import kmeans as kmeans_lib
from repro.core import summaries as summaries_lib
from repro.core.summaries import ClusterSummaries

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFFlatIndex:
    spec: HybridSpec = dataclasses.field(metadata=dict(static=True))
    centroids: Array
    vectors: Array
    attrs: Array
    ids: Array
    counts: Array
    norms: Optional[Array] = None
    # SQ8 compression (beyond-paper, EXPERIMENTS §Perf): vectors stored int8
    # with a per-vector scale; halves the scan's HBM traffic (the dominant
    # roofline term) for ~1% recall cost. None ⇒ uncompressed bf16/f32.
    scales: Optional[Array] = None  # [K, Vpad] f32
    # Per-cluster attribute summaries (core/summaries.py): intervals +
    # histograms that let the probe planner prune clusters a query's filter
    # provably cannot match. None ⇒ planner never prunes.
    summaries: Optional[ClusterSummaries] = None

    @property
    def quantized(self) -> bool:
        return self.scales is not None

    @property
    def store_dtype(self):
        """Storage dtype of the flat lists (int8 under SQ8)."""
        return self.vectors.dtype

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def vpad(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_live(self) -> Array:
        return jnp.sum(self.counts)

    def nbytes(self) -> int:
        total = 0
        for f in (self.centroids, self.vectors, self.attrs, self.ids, self.counts):
            total += f.size * f.dtype.itemsize
        for opt in (self.norms, self.scales):
            if opt is not None:
                total += opt.size * opt.dtype.itemsize
        if self.summaries is not None:
            total += self.summaries.nbytes()
        return total


@dataclasses.dataclass(frozen=True)
class BuildStats:
    n_vectors: int
    n_dropped: int  # capacity overflow drops (0 unless vpad was forced too low)
    max_list_len: int
    mean_list_len: float
    vpad: int
    kmeans_steps: int


def default_n_clusters(n: int) -> int:
    """Paper §4.2/§4.3 heuristic: N/1000 small, sqrt(N) at scale."""
    if n <= 1_000_000:
        return max(1, n // 1000) or 1
    return int(np.sqrt(n))


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def scatter_to_lists(
    values: Array, assignments: Array, n_clusters: int, vpad: int
) -> Tuple[Array, Array, Array]:
    """Sorts rows by cluster and scatters into padded lists.

    Returns (lists [K, vpad, ...], slot_of_row [N], n_dropped scalar).
    Rows beyond a list's capacity are dropped (mode="drop"), mirroring MoE
    capacity semantics; callers size vpad so drops are zero in practice.
    """
    n = assignments.shape[0]
    order = jnp.argsort(assignments)  # stable
    a_sorted = jnp.take(assignments, order, axis=0)
    # position-within-cluster for sorted rows: arange - start_of_cluster
    starts = jnp.searchsorted(a_sorted, jnp.arange(n_clusters), side="left")
    pos = jnp.arange(n) - jnp.take(starts, a_sorted)
    out_shape = (n_clusters, vpad) + values.shape[1:]
    lists = jnp.zeros(out_shape, values.dtype)
    lists = lists.at[a_sorted, pos].set(
        jnp.take(values, order, axis=0), mode="drop"
    )
    dropped = jnp.sum((pos >= vpad).astype(jnp.int32))
    # slot index of each ORIGINAL row (for id→location bookkeeping)
    slot_of_row = jnp.zeros((n,), jnp.int32)
    slot_of_row = slot_of_row.at[order].set(pos.astype(jnp.int32))
    return lists, slot_of_row, dropped


def build_from_assignments(
    spec: HybridSpec,
    centroids: Array,
    core: Array,
    attrs: Array,
    assignments: Array,
    *,
    vpad: Optional[int] = None,
    ids: Optional[Array] = None,
    with_summaries: bool = True,
    summary_bins: int = summaries_lib.DEFAULT_N_BINS,
) -> Tuple[IVFFlatIndex, BuildStats]:
    """Builds the padded index given precomputed assignments (§4.2 steps 2-4).

    ``with_summaries`` (default) also builds the per-cluster attribute
    summaries the planner prunes with; ``summary_bins`` is the histogram
    width B.
    """
    core, attrs = make_hybrid(spec, core, attrs)
    n = core.shape[0]
    k = centroids.shape[0]
    counts = jax.ops.segment_sum(
        jnp.ones((n,), jnp.int32), assignments, num_segments=k
    )
    max_len = int(jnp.max(counts))
    if vpad is None:
        vpad = max(round_up(max_len, 128), 128)
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)

    vec_lists, _, dropped = scatter_to_lists(core, assignments, k, vpad)
    attr_lists, _, _ = scatter_to_lists(attrs, assignments, k, vpad)
    id_init = jnp.full((k, vpad), -1, jnp.int32)
    id_lists, _, _ = scatter_to_lists(
        ids.astype(jnp.int32), assignments, k, vpad
    )
    # scatter_to_lists zero-fills; repaint empty slots with -1 sentinel.
    slot = jnp.arange(vpad)[None, :]
    live = slot < jnp.minimum(counts, vpad)[:, None]
    id_lists = jnp.where(live, id_lists, id_init)

    norms = None
    if spec.metric == "l2":
        norms = jnp.sum(
            vec_lists.astype(jnp.float32) ** 2, axis=-1
        )

    summ = (
        summaries_lib.build_summaries(attr_lists, id_lists, n_bins=summary_bins)
        if with_summaries and spec.n_attrs > 0 else None
    )
    index = IVFFlatIndex(
        spec=spec,
        centroids=centroids.astype(jnp.float32),
        vectors=vec_lists,
        attrs=attr_lists,
        ids=id_lists,
        counts=jnp.minimum(counts, vpad).astype(jnp.int32),
        norms=norms,
        summaries=summ,
    )
    stats = BuildStats(
        n_vectors=n,
        n_dropped=int(dropped),
        max_list_len=max_len,
        mean_list_len=float(jnp.mean(counts)),
        vpad=vpad,
        kmeans_steps=0,
    )
    return index, stats


def build_ivf(
    key: Array,
    spec: HybridSpec,
    core: Array,
    attrs: Array,
    *,
    n_clusters: Optional[int] = None,
    vpad: Optional[int] = None,
    kmeans_mode: str = "minibatch",
    kmeans_steps: int = 100,
    kmeans_batch: int = 4096,
    assign_chunk: int = 65536,
    ids: Optional[Array] = None,
    with_summaries: bool = True,
    summary_bins: int = summaries_lib.DEFAULT_N_BINS,
) -> Tuple[IVFFlatIndex, BuildStats]:
    """End-to-end index build (paper §4.2): centroids → assign → scatter.

    kmeans_mode: "minibatch" (paper's scalable path, [30]) or "lloyd"
    (paper's quality path) or "given" (pre-existing centroids passed via
    ``n_clusters``-sized ``core``-dtype array — the paper reuses LAION's
    prebuilt index; callers then use :func:`build_from_assignments`).
    """
    n = core.shape[0]
    k = n_clusters or default_n_clusters(n)
    if kmeans_mode == "minibatch":
        state = kmeans_lib.minibatch_kmeans(
            key,
            core.astype(jnp.float32),
            n_clusters=k,
            n_steps=kmeans_steps,
            batch_size=min(kmeans_batch, n),
        )
        centroids = state.centroids
    elif kmeans_mode == "lloyd":
        state, _ = kmeans_lib.kmeans_lloyd(
            key, core.astype(jnp.float32), n_clusters=k, n_iters=kmeans_steps
        )
        centroids = state.centroids
    else:
        raise ValueError(f"unknown kmeans_mode {kmeans_mode!r}")

    assignments = kmeans_lib.assign(
        core.astype(jnp.float32), centroids, chunk=assign_chunk
    )
    index, stats = build_from_assignments(
        spec, centroids, core, attrs, assignments, vpad=vpad, ids=ids,
        with_summaries=with_summaries, summary_bins=summary_bins,
    )
    return index, dataclasses.replace(stats, kmeans_steps=kmeans_steps)


def validity_mask(index: IVFFlatIndex) -> Array:
    """[K, Vpad] bool — live slots (within count and not tombstoned)."""
    slot = jnp.arange(index.vpad)[None, :]
    return jnp.logical_and(
        slot < index.counts[:, None], index.ids >= 0
    )


def quantize_index(index: IVFFlatIndex) -> IVFFlatIndex:
    """SQ8: per-vector symmetric int8 quantization of the flat lists.

    score(q, v̂) = (q · v_int8) · scale reproduces q·v to ~0.4% relative
    error on unit-norm data; centroids stay f32 (probing is exact).
    """
    if index.quantized:
        return index
    v32 = index.vectors.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v32), axis=-1)  # [K, Vpad]
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(v32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return dataclasses.replace(index, vectors=q, scales=scale)


def dequantize_rows(vectors: Array, scales: Array) -> Array:
    """[..., Vpad, D] int8 + [..., Vpad] scale → f32 rows."""
    return vectors.astype(jnp.float32) * scales[..., None]
