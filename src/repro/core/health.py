"""Per-peer circuit breakers: the ring is an optimization, never a dependency.

Every pod in the sharded-cache deployment holds a full index copy on disk;
the consistent-hash ring only decides whose *cache* is warm for each
cluster.  That makes peer failure a performance event, not an availability
event — provided the fetch path notices quickly and routes around the dead
peer instead of paying a timeout per batch.  This module is the noticing:

  * :class:`CircuitBreaker` — classic closed → open → half-open per peer,
    driven by passive signals the transport already produces (typed
    :class:`~repro.core.transport.TransportError` failures, per-request
    latency fed into an EWMA for brownout detection) plus whatever active
    probe the owner wires in (``ShardedBlockStore.probe_peers`` pings open
    peers so recovery is noticed without sacrificing a real request).
  * :class:`PeerHealth` — the registry a :class:`ShardedBlockStore`
    consults per fetch: ``allow(node)`` gates traffic (and hands out the
    single half-open probe token), ``on_success``/``on_failure`` feed the
    breakers.

State machine (all transitions under the breaker's lock):

  closed      normal traffic.  ``failure_threshold`` consecutive failures
              — or a latency EWMA above ``brownout_latency_s`` (a peer
              that answers slowly is as harmful as one that doesn't) —
              trips to open.
  open        no traffic; the owner serves this peer's clusters from the
              local full copy.  After ``cooldown_s`` the next ``allow``
              hands out one probe token (→ half-open).
  half-open   exactly one request (or active ping) in flight at a time.
              ``half_open_successes`` consecutive successes close the
              circuit (hysteresis against flapping on a peer that answers
              one request then dies again); any failure re-opens with the
              cooldown escalated ×``cooldown_factor`` up to
              ``cooldown_max_s``, so a peer that keeps failing is knocked
              on less and less often.

``clock`` is injectable so the state machine unit-tests run on a fake
clock instead of sleeping through cooldowns.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Iterable, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """One peer's health state machine.  Thread-safe; cheap enough to
    consult on every fetch."""

    def __init__(self, *, failure_threshold: int = 3,
                 cooldown_s: float = 1.0, cooldown_factor: float = 2.0,
                 cooldown_max_s: float = 30.0, half_open_successes: int = 2,
                 latency_alpha: float = 0.2,
                 brownout_latency_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = failure_threshold
        self.base_cooldown_s = cooldown_s
        self.cooldown_factor = cooldown_factor
        self.cooldown_max_s = cooldown_max_s
        self.half_open_successes = max(int(half_open_successes), 1)
        self.latency_alpha = latency_alpha
        self.brownout_latency_s = brownout_latency_s
        self._clock = clock
        self._lock = threading.Lock()
        self.state = CLOSED
        self.ewma_latency_s: Optional[float] = None
        self._consec_failures = 0
        self._half_open_ok = 0
        self._probe_inflight = False
        self._cooldown_s = cooldown_s
        self._opened_at = 0.0
        # lifetime counters (snapshot/observability)
        self.trips = 0
        self.failures = 0
        self.successes = 0

    # ---- gating ----
    def allow(self) -> bool:
        """May a request go to this peer right now?  In half-open, a True
        return IS the probe token — the caller must report the outcome via
        ``record_success``/``record_failure`` or the token leaks."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                if self._clock() - self._opened_at >= self._cooldown_s:
                    self.state = HALF_OPEN
                    self._half_open_ok = 0
                    self._probe_inflight = True
                    return True
                return False
            # HALF_OPEN: one probe in flight at a time
            if self._probe_inflight:
                return False
            self._probe_inflight = True
            return True

    # ---- passive signals ----
    def record_success(self, latency_s: Optional[float] = None):
        with self._lock:
            self.successes += 1
            if latency_s is not None:
                a = self.latency_alpha
                prev = self.ewma_latency_s
                self.ewma_latency_s = (
                    latency_s if prev is None else a * latency_s + (1 - a) * prev
                )
            if self.state == HALF_OPEN:
                self._probe_inflight = False
                slow = (self.brownout_latency_s is not None
                        and latency_s is not None
                        and latency_s >= self.brownout_latency_s)
                if slow:  # answered, but still browned out — not recovered
                    self._trip_locked(escalate=True)
                    return
                self._half_open_ok += 1
                if self._half_open_ok >= self.half_open_successes:
                    self.state = CLOSED
                    self._consec_failures = 0
                    self._cooldown_s = self.base_cooldown_s
                    self.ewma_latency_s = None  # rebuild from healthy traffic
                return
            self._consec_failures = 0
            if (self.state == CLOSED
                    and self.brownout_latency_s is not None
                    and self.ewma_latency_s is not None
                    and self.ewma_latency_s >= self.brownout_latency_s):
                self._trip_locked()

    def record_failure(self):
        with self._lock:
            self.failures += 1
            if self.state == HALF_OPEN:
                self._probe_inflight = False
                self._trip_locked(escalate=True)
                return
            if self.state == OPEN:
                return
            self._consec_failures += 1
            if self._consec_failures >= self.failure_threshold:
                self._trip_locked()

    def _trip_locked(self, escalate: bool = False):
        if escalate:
            self._cooldown_s = min(self._cooldown_s * self.cooldown_factor,
                                   self.cooldown_max_s)
        self.state = OPEN
        self.trips += 1
        self._opened_at = self._clock()
        self._consec_failures = 0
        self._half_open_ok = 0
        self._probe_inflight = False
        self.ewma_latency_s = None  # stale latency must not re-trip recovery

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                state=self.state, trips=self.trips, failures=self.failures,
                successes=self.successes,
                ewma_latency_ms=(None if self.ewma_latency_s is None
                                 else round(self.ewma_latency_s * 1e3, 3)),
                cooldown_s=self._cooldown_s,
            )


class PeerHealth:
    """Breaker registry for a set of peers (the sharded store's view).

    ``breaker_kwargs`` configure every breaker identically (thresholds are
    a fleet policy, not a per-peer one); ``clock`` is forwarded for
    deterministic tests.
    """

    def __init__(self, nodes: Iterable = (), *,
                 breaker_kwargs: Optional[dict] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._kwargs = dict(breaker_kwargs or {})
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: Dict = {}
        for n in nodes:
            self.breaker(n)

    def breaker(self, node) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(node)
            if br is None:
                br = self._breakers[node] = CircuitBreaker(
                    clock=self._clock, **self._kwargs
                )
            return br

    def drop(self, node):
        with self._lock:
            self._breakers.pop(node, None)

    def allow(self, node) -> bool:
        return self.breaker(node).allow()

    def on_success(self, node, latency_s: Optional[float] = None):
        self.breaker(node).record_success(latency_s)

    def on_failure(self, node):
        self.breaker(node).record_failure()

    def state(self, node) -> str:
        return self.breaker(node).state

    @property
    def degraded(self) -> bool:
        """True while any peer's circuit is not closed."""
        with self._lock:
            breakers = list(self._breakers.values())
        return any(br.state != CLOSED for br in breakers)

    def snapshot(self) -> Dict:
        with self._lock:
            items = list(self._breakers.items())
        return {node: br.snapshot() for node, br in items}

    def probe(self, node, probe_fn: Callable[[], None]) -> bool:
        """Runs one active probe against a non-closed peer if the breaker
        grants a token; feeds the outcome back.  Returns True iff the probe
        ran and succeeded."""
        br = self.breaker(node)
        if br.state == CLOSED or not br.allow():
            return False
        t0 = self._clock()
        try:
            probe_fn()
        except Exception:
            br.record_failure()
            return False
        br.record_success(self._clock() - t0)
        return True
