"""Filter conditions F over attribute vectors (paper §3.4).

The paper's filters are per-attribute relational constraints combined
conjunctively ("vectors satisfying *all* specified conditions").  We compile
every supported predicate to a closed int16 interval per attribute:

  * exact match        a_m == v        →  [v, v]
  * range              lo <= a_m <= hi →  [lo, hi]
  * one-sided          a_m >= v        →  [v, ATTR_MAX]   (resp. <=)
  * wildcard           —               →  [ATTR_MIN, ATTR_MAX]

so a batched query filter is two int16 arrays ``lo, hi ∈ [Q, M]`` and the
membership test is a branch-free VPU reduction::

    mask[q, n] = AND_m ( lo[q, m] <= attrs[n, m] <= hi[q, m] )

Disjunctions over *values of one attribute* (IN-sets) are supported by
splitting a query into a small static number of interval rows (DNF terms)
OR-combined at mask level — see ``FilterSpec.terms``.  This covers the paper's
"SQL-like filter expressions" (conjunctions of range/equality/IN predicates)
without any data-dependent shapes, which is what makes it fusable into the
Pallas scan kernel.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import ATTR_MAX, ATTR_MIN

Array = jax.Array


@dataclasses.dataclass
class FilterBuilder:
    """Imperative builder for one query's filter condition F.

    Example (conjunction with an IN-set)::

        f = (FilterBuilder(n_attrs=10)
             .eq(0, 5)            # attr0 == 5
             .between(2, -10, 90) # -10 <= attr2 <= 90
             .ge(3, 0)            # attr3 >= 0
             .isin(4, [1, 7, 9])) # attr4 in {1, 7, 9}
        lo, hi = f.intervals()    # [n_terms, M] each
    """

    n_attrs: int

    def __post_init__(self):
        # One DNF term = one (lo, hi) row.  isin() multiplies terms.
        self._terms: List[Tuple[np.ndarray, np.ndarray]] = [
            (
                np.full(self.n_attrs, ATTR_MIN, np.int16),
                np.full(self.n_attrs, ATTR_MAX, np.int16),
            )
        ]

    def _clamp(self, v: int) -> int:
        return int(np.clip(v, ATTR_MIN, ATTR_MAX))

    def _narrow(self, attr: int, lo: int, hi: int) -> "FilterBuilder":
        if not 0 <= attr < self.n_attrs:
            raise ValueError(f"attribute index {attr} out of range [0,{self.n_attrs})")
        for tlo, thi in self._terms:
            tlo[attr] = max(tlo[attr], self._clamp(lo))
            thi[attr] = min(thi[attr], self._clamp(hi))
        return self

    def eq(self, attr: int, value: int) -> "FilterBuilder":
        return self._narrow(attr, value, value)

    def between(self, attr: int, lo: int, hi: int) -> "FilterBuilder":
        return self._narrow(attr, lo, hi)

    def ge(self, attr: int, value: int) -> "FilterBuilder":
        return self._narrow(attr, value, ATTR_MAX)

    def le(self, attr: int, value: int) -> "FilterBuilder":
        return self._narrow(attr, ATTR_MIN, value)

    def isin(self, attr: int, values: Sequence[int]) -> "FilterBuilder":
        """OR over values of one attribute: splits every term per value."""
        if not values:
            raise ValueError("isin() needs at least one value")
        new_terms: List[Tuple[np.ndarray, np.ndarray]] = []
        for tlo, thi in self._terms:
            for v in values:
                nlo, nhi = tlo.copy(), thi.copy()
                v = self._clamp(v)
                nlo[attr] = max(nlo[attr], v)
                nhi[attr] = min(nhi[attr], v)
                new_terms.append((nlo, nhi))
        self._terms = new_terms
        return self

    def intervals(self) -> Tuple[np.ndarray, np.ndarray]:
        lo = np.stack([t[0] for t in self._terms])
        hi = np.stack([t[1] for t in self._terms])
        return lo, hi


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FilterSpec:
    """A batch of compiled filters, one per query.

    lo, hi: [Q, n_terms, M] int16 — conjunctive interval bounds per DNF term.
      ``n_terms`` is a static batch-wide maximum; unused terms are voided
      (lo > hi everywhere → term matches nothing).  A vector passes if it
      matches ANY term (OR), and matches a term iff it is inside the interval
      of EVERY attribute (AND).
    """

    lo: Array
    hi: Array

    @property
    def n_terms(self) -> int:
        return self.lo.shape[-2]

    @property
    def n_attrs(self) -> int:
        return self.lo.shape[-1]

    def __len__(self) -> int:
        return self.lo.shape[0]


def match_all(n_queries: int, n_attrs: int, n_terms: int = 1) -> FilterSpec:
    """The no-filter (wildcard) spec: every vector passes."""
    lo = np.full((n_queries, n_terms, n_attrs), ATTR_MIN, np.int16)
    hi = np.full((n_queries, n_terms, n_attrs), ATTR_MAX, np.int16)
    if n_terms > 1:  # void the spare terms so counts stay exact
        lo[:, 1:, :] = ATTR_MAX
        hi[:, 1:, :] = ATTR_MIN
    return FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))


def from_builders(
    builders: Sequence[FilterBuilder], n_terms: Optional[int] = None
) -> FilterSpec:
    """Pads a batch of per-query builders to a common static term count."""
    per_query = [b.intervals() for b in builders]
    max_terms = max(lo.shape[0] for lo, _ in per_query)
    n_terms = max_terms if n_terms is None else n_terms
    if n_terms < max_terms:
        raise ValueError(f"n_terms={n_terms} < required {max_terms}")
    M = builders[0].n_attrs
    Q = len(builders)
    lo = np.full((Q, n_terms, M), ATTR_MAX, np.int16)  # void by default
    hi = np.full((Q, n_terms, M), ATTR_MIN, np.int16)
    for q, (tlo, thi) in enumerate(per_query):
        lo[q, : tlo.shape[0]] = tlo
        hi[q, : thi.shape[0]] = thi
    return FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))


def filter_mask(spec: FilterSpec, attrs: Array, query_idx: Optional[Array] = None) -> Array:
    """Evaluates the filter against attribute rows.

    Args:
      spec: FilterSpec with lo/hi [Q, n_terms, M].
      attrs: [..., M] int16 attribute rows.
      query_idx: if given, an int array broadcastable to ``attrs.shape[:-1]``
        selecting which query's filter applies to each row.  If None, ``attrs``
        must be [Q, ..., M] with the leading axis aligned to queries.

    Returns:
      bool mask of shape ``attrs.shape[:-1]``.
    """
    lo, hi = spec.lo, spec.hi
    if query_idx is not None:
        lo = jnp.take(lo, query_idx, axis=0)  # [..., n_terms, M]
        hi = jnp.take(hi, query_idx, axis=0)
    else:
        extra = attrs.ndim - 2  # broadcast over middle axes
        lo = lo.reshape(lo.shape[0], *([1] * extra), *lo.shape[1:])
        hi = hi.reshape(hi.shape[0], *([1] * extra), *hi.shape[1:])
    a = attrs[..., None, :]  # [..., 1, M]
    inside = jnp.logical_and(a >= lo, a <= hi)  # [..., n_terms, M]
    per_term = jnp.all(inside, axis=-1)  # AND over attributes
    return jnp.any(per_term, axis=-1)  # OR over DNF terms


def selectivity(
    spec: FilterSpec,
    attrs: Array,
    *,
    sample_size: Optional[int] = None,
    seed: int = 0,
    chunk: int = 4096,
) -> Array:
    """Fraction of rows passing each query's filter — used by the planner
    to pick T adaptively (paper §4.3 'filter selectivity').

    The old implementation broadcast a ``[Q, N, M]`` view through
    ``filter_mask`` (a ``[Q, N, n_terms, M]`` intermediate) — ruinous at
    index scale.  Now rows are optionally subsampled (``sample_size`` rows,
    deterministic in ``seed``) and evaluated in fixed-size chunks, so peak
    memory is ``O(Q · chunk · n_terms · M)`` regardless of N.

    Args:
      spec: FilterSpec with lo/hi [Q, n_terms, M].
      attrs: [N, M] attribute rows.
      sample_size: if set and < N, estimate from that many uniformly sampled
        rows (the planner's at-scale mode); None = exact over all rows.
      seed: sampling seed (ignored when sample_size is None).
      chunk: rows evaluated per step.

    Returns [Q] f32 passing fractions (estimates under sampling).
    """
    n = attrs.shape[0]
    if sample_size is not None and sample_size < n:
        rows = np.random.default_rng(seed).choice(n, sample_size,
                                                  replace=False)
        attrs = jnp.take(jnp.asarray(attrs), jnp.asarray(rows), axis=0)
        n = sample_size
    q = spec.lo.shape[0]
    passed = jnp.zeros((q,), jnp.int32)
    for start in range(0, n, chunk):
        block = attrs[start:start + chunk]
        mask = filter_mask(
            spec, jnp.broadcast_to(block, (q,) + block.shape)
        )  # [Q, chunk]
        passed = passed + jnp.sum(mask.astype(jnp.int32), axis=-1)
    return passed.astype(jnp.float32) / max(n, 1)
