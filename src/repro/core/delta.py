"""RAM delta tier + generation-tagged republish: live-updating serving.

The disk tier serves a frozen checkpoint; ``update.py`` mutates a resident
index.  This module is the bridge that makes mutation a *serving* concern —
the hot/cold split the percolate-node exemplar ships, recast in engine
terms:

  * :class:`DeltaTier` — a small, byte-bounded, RAM-resident append-only
    segment (vectors + attrs + ids + cluster assignments + a tombstone set).
    ``SearchEngine`` scans it *exactly* every batch (``scan_snapshot``, the
    same per-row arithmetic as the cold kernel) and folds the fragment into
    the top-k monoid after the merge stage; tombstones mask cold-tier hits
    by id inside the scan, so the (k+1)-th cold candidate surfaces exactly
    as a rebuild without the deleted rows would rank it.
  * :func:`compact_deltas` — the background republish: folds the frozen
    delta rows and tombstones into their cluster records on disk, rewrites
    only the touched shards, bumps each rewritten cluster's **generation**
    (layout v3) and the resident ``gens.npy``.  Every cache layer keys on
    ``(cluster_id, gen)``, so the republish invalidates exactly the
    rewritten clusters — locally and across the sharded peer ring.
  * The freeze/commit handshake — ``compact_deltas`` freezes the segment's
    prefix; adds keep landing behind the freeze and tombstones landing on
    frozen rows are queued (``late_tombs``); ``DeltaTier.commit`` (called
    from ``DiskIVFIndex.refresh`` between batches) atomically drops the
    republished prefix and replays the queued tombstones against the new
    cold generation.  No drain, no double-serving, no lost delete.

Parity contract (the tentpole invariant): for any interleaving of
add / tombstone / compact / publish, search over the live two-tier index is
bit-identical to a from-scratch rebuild at the same logical state.  Three
properties carry it:

  1. the delta scan replicates the cold kernel's row arithmetic (same cast
     chain, same score expression, same masked top-k, same l2 fix-up);
  2. a delta row only competes for queries whose *geometric*
     top-``n_probes`` candidate set contains the row's cluster
     (``geo_probes``/``geo_valid`` from the plan) — precisely the queries
     that would scan it in the rebuilt index;
  3. the planner sees tombstone/append-adjusted cluster counts, so the
     centroid top-k (which masks empty clusters by count) ranks clusters
     exactly as the rebuilt index's planner would.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockstore as blockstore_lib
from repro.core import kmeans as kmeans_lib
from repro.core import storage
from repro.core import summaries as summaries_lib
from repro.core import topk as topk_lib
from repro.core.hybrid import make_hybrid

Array = jax.Array


class DeltaOverflowError(RuntimeError):
    """The RAM delta segment is full: republish (``compact_deltas`` +
    ``refresh``) before adding more rows.  Raised instead of silently
    dropping — a lost add is a correctness bug in a live-serving tier."""


# ---------------------------------------------------------------------------
# Snapshot + jitted scans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeltaSnapshot:
    """Immutable per-batch view of the delta segment.

    ``vectors/attrs/clusters/norms/scales`` reference the tier's append-only
    buffers (rows beyond ``n_rows`` are masked by the scan); ``ids`` is a
    copy (tombstoning mutates it in place).  ``tombstones`` is the cold-id
    tombstone set as a sorted int32 array padded *at the front* with ``-2``
    to the next power of two — sorted for ``searchsorted``, pow2 so the
    jitted mask sees a bounded set of shapes, and ``-2`` never collides
    with a real id (≥ 0) or the dead-slot sentinel (-1).
    """

    n_rows: int
    vectors: np.ndarray            # [cap, D] store dtype
    attrs: np.ndarray              # [cap, M] int16
    ids: np.ndarray                # [cap] int32 (−1 = dead)
    clusters: np.ndarray           # [cap] int32
    norms: Optional[np.ndarray]    # [cap] f32 (l2 only)
    scales: Optional[np.ndarray]   # [cap] f32 (SQ8 only)
    tombstones: Optional[np.ndarray]  # sorted pow2 int32, −2-padded
    version: int = 0
    # lazily-built 1-cluster attribute summary over the live rows (see
    # snapshot_summary) — shared by every batch on this snapshot via the
    # tier's version-keyed snapshot cache.  None is a valid built value
    # (no live rows), hence the separate ready flag.
    summary: object = None
    summary_ready: bool = False
    # per-attribute [M] envelope over the segment's rows, refreshed on every
    # append (grows monotonically; commit() recomputes it from the surviving
    # rows).  A filter disjoint from it on ANY attribute proves the fold's
    # mask is identically zero — the engine skips the fold without building
    # the histogram summary.
    attr_lo: Optional[np.ndarray] = None   # [M] int16
    attr_hi: Optional[np.ndarray] = None   # [M] int16


@jax.jit
def mask_tombstones(ids: Array, tombs: Array) -> Array:
    """Replaces tombstoned ids with −1 (the scan's dead-slot sentinel).

    ``tombs`` is the snapshot's sorted −2-padded array; applied to the ids
    *operand* (not the merged result) so the cold scan's masked top-k
    naturally promotes the next-best live candidate.
    """
    idx = jnp.searchsorted(tombs, ids)
    hit = jnp.take(tombs, jnp.clip(idx, 0, tombs.shape[0] - 1)) == ids
    return jnp.where(hit, -1, ids)


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _delta_scan(
    queries, queries_pad, lo_pad, hi_pad, geo, geo_ok,
    vectors, attrs, ids, clusters, norms, scales, n_rows,
    *, metric: str, k: int,
):
    """Exact scan of the delta rows, bit-matching the cold kernel's math.

    Mirrors ``tiled_scan_xla.one()`` per row: queries arrive as the plan's
    cast ``queries_pad`` and are re-cast to f32 (the cold path's double
    cast), rows go store-dtype → f32, scores are ``q @ v.T`` (+ SQ8 scale,
    + l2 ``2s − ‖v‖²`` with the guarded ``−‖q‖²`` fix-up), and the filter
    mask is the same DNF interval test.  On top the *membership* mask: a
    row counts for query ``q`` iff the row's cluster is in ``q``'s
    geometric top-``n_probes`` candidate set — the rebuilt index would
    only scan it there.
    """
    q32 = queries_pad.astype(jnp.float32)           # [Qpad, D]
    v32 = vectors.astype(jnp.float32)               # [C, D]
    scores = q32 @ v32.T                            # [Qpad, C]
    if scales is not None:
        scores = scores * scales[None, :]
    if metric == "l2":
        scores = 2.0 * scores - norms[None, :]
    a = attrs.astype(jnp.int32)                     # [C, M]
    inside = jnp.logical_and(
        a[None, :, None, :] >= lo_pad[:, None],
        a[None, :, None, :] <= hi_pad[:, None],
    )                                               # [Qpad, C, F, M]
    fmask = jnp.any(jnp.all(inside, -1), -1)        # [Qpad, C]
    cap = ids.shape[0]
    live = jnp.logical_and(ids >= 0, jnp.arange(cap) < n_rows)  # [C]
    member = jnp.any(
        jnp.logical_and(
            geo[:, :, None] == clusters[None, None, :],
            geo_ok[:, :, None],
        ),
        axis=1,
    )                                               # [Qpad, C]
    reach = jnp.logical_and(member, live[None, :])
    mask = jnp.logical_and(fmask, reach)
    dvals, dids = topk_lib.masked_topk(
        scores, mask, k,
        ids=jnp.broadcast_to(ids[None, :], scores.shape),
    )
    if metric == "l2":
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        q2p = jnp.zeros((queries_pad.shape[0],), jnp.float32)
        q2p = q2p.at[: q2.shape[0]].set(q2)
        dvals = jnp.where(
            dvals > topk_lib.NEG_INF / 2, dvals - q2p[:, None], dvals
        )
    dscanned = jnp.sum(reach.astype(jnp.int32), axis=-1)
    dpassed = jnp.sum(mask.astype(jnp.int32), axis=-1)
    return dvals, dids, dscanned, dpassed


def scan_snapshot(
    snap: DeltaSnapshot, queries, queries_pad, lo_pad, hi_pad, geo, geo_ok,
    *, metric: str, k: int,
):
    """[Qpad, k] delta-tier top-k fragment + per-query scan accounting."""
    return _delta_scan(
        queries, queries_pad, lo_pad, hi_pad, geo, geo_ok,
        jnp.asarray(snap.vectors), jnp.asarray(snap.attrs),
        jnp.asarray(snap.ids), jnp.asarray(snap.clusters),
        None if snap.norms is None else jnp.asarray(snap.norms),
        None if snap.scales is None else jnp.asarray(snap.scales),
        jnp.int32(snap.n_rows), metric=metric, k=k,
    )


DELTA_SUMMARY_BINS = 8


def snapshot_summary(snap: DeltaSnapshot):
    """1-cluster interval/histogram summary over the snapshot's live rows.

    The same conservative machinery the cold planner prunes clusters with
    (:mod:`repro.core.summaries`), applied to the delta segment as a single
    pseudo-cluster: ``can_match == False`` for every query proves the delta
    scan's filter mask is identically zero, so the fold can be skipped
    outright.  Built lazily, cached on the snapshot (snapshots are shared
    across batches until the tier's version changes), and computed from the
    snapshot's own ``ids`` copy so a tombstone landing after the snapshot
    cannot narrow the summary out from under a batch mid-flight.

    Returns None when the snapshot has no live rows (every fold over it is
    a no-op).
    """
    if snap.summary_ready:
        return snap.summary
    n = snap.n_rows
    live = np.zeros(snap.ids.shape[0], bool)
    live[:n] = snap.ids[:n] >= 0
    if not live.any():
        summ = None
    else:
        ids_row = np.where(live, snap.ids, -1).astype(np.int32)
        summ = summaries_lib.build_summaries(
            jnp.asarray(snap.attrs)[None], jnp.asarray(ids_row)[None],
            n_bins=DELTA_SUMMARY_BINS,
        )
    snap.summary = summ
    snap.summary_ready = True
    return summ


@jax.jit
def _delta_reach(geo, geo_ok, clusters, ids, n_rows):
    """[Qpad] count of delta rows each query's scan would reach (live ∧
    geometric-member) — ``_delta_scan``'s ``dscanned``, without the scan."""
    cap = ids.shape[0]
    live = jnp.logical_and(ids >= 0, jnp.arange(cap) < n_rows)
    member = jnp.any(
        jnp.logical_and(
            geo[:, :, None] == clusters[None, None, :],
            geo_ok[:, :, None],
        ),
        axis=1,
    )
    return jnp.sum(
        jnp.logical_and(member, live[None, :]).astype(jnp.int32), axis=-1
    )


def snapshot_reach(snap: DeltaSnapshot, geo, geo_ok):
    """Per-query ``n_scanned`` contribution of a skipped delta fold —
    bit-identical to what the full scan would have reported."""
    return _delta_reach(
        geo, geo_ok, jnp.asarray(snap.clusters), jnp.asarray(snap.ids),
        jnp.int32(snap.n_rows),
    )


def _pack_tombstones(tombs) -> Optional[np.ndarray]:
    if not tombs:
        return None
    arr = np.fromiter(tombs, np.int64, len(tombs)).astype(np.int32)
    arr.sort()
    p = 1 << (len(arr) - 1).bit_length()
    out = np.full(p, -2, np.int32)
    out[p - len(arr):] = arr  # front-padded: stays sorted, −2 never matches
    return out


# ---------------------------------------------------------------------------
# The tier
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FrozenDelta:
    """The segment prefix a republish is folding to disk — copies, so late
    tombstones on the live buffers cannot change what lands in the
    checkpoint mid-write."""

    n0: int
    ids: np.ndarray
    clusters: np.ndarray
    vectors: np.ndarray
    attrs: np.ndarray
    norms: Optional[np.ndarray]
    scales: Optional[np.ndarray]
    tombs: frozenset
    # tombstones that hit a frozen row *while the republish ran*: the row
    # was written live to the new cold generation, so the delete must be
    # replayed against cold at commit — queued here, merged by commit()
    late_tombs: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list
    )


class DeltaTier:
    """Byte-bounded RAM-resident append segment over a cold index.

    ``add`` mirrors ``update.add_vectors`` exactly (same centroid
    assignment, same SQ8 quantization, same norms) so a later republish —
    or a from-scratch rebuild — stores bit-identical rows.  ``tombstone``
    kills delta rows in place and records cold-row deletes in the tombstone
    set (with an optional cluster hint that keeps planned cluster counts in
    lockstep with a rebuild).  All methods are thread-safe; a snapshot is
    immutable for the batch that captured it.
    """

    def __init__(self, index, capacity: int, quantize: str = "auto"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if quantize not in ("auto", "on"):
            raise ValueError(f"quantize must be 'auto'|'on', got "
                             f"{quantize!r}")
        bspec = blockstore_lib.BlockSpec.from_index(index)
        self.spec = index.spec
        self.metric = index.spec.metric
        # quantize="on" stores SQ8 rows (same codes/scales path as
        # add_vectors) even over a float cold tier — ~4× the rows per byte
        # budget.  Over a float cold index this is a *semantic* knob: the
        # delta scan scores the quantized representation (≈1e-2 relative),
        # and a republish dequantizes codes·scales back to the cold store
        # dtype — so folded rows match the delta scan's scores approximately
        # rather than bitwise.  quantize="auto" follows the index exactly
        # (the bit-parity default).
        self.quantized = bool(bspec.quantized) or quantize == "on"
        self.quantize = quantize
        self.capacity = int(capacity)
        # a partitioned RAM index carries duplicated sub centroids past the
        # base id space; delta rows must assign to BASE clusters (the
        # membership mask and republish fold both key on base ids)
        cents = index.centroids
        cat = getattr(index, "partitions", None)
        if cat is not None:
            cents = np.asarray(cents)[: cat.n_base]
        self._centroids = jnp.asarray(cents)
        self._store_dtype = (
            np.dtype(np.int8) if self.quantized
            else np.dtype(index.store_dtype)
        )
        d, m = bspec.dim, bspec.n_attrs
        self._vectors = np.zeros((capacity, d), self._store_dtype)
        self._attrs = np.zeros((capacity, m), np.int16)
        self._ids = np.full((capacity,), -1, np.int32)
        self._clusters = np.zeros((capacity,), np.int32)
        self._norms = (
            np.zeros((capacity,), np.float32) if bspec.has_norms else None
        )
        self._scales = (
            np.zeros((capacity,), np.float32) if self.quantized else None
        )
        # per-attribute envelope over appended rows (empty = void: lo > hi)
        self._attr_lo = np.full((m,), summaries_lib.ATTR_MAX, np.int16)
        self._attr_hi = np.full((m,), summaries_lib.ATTR_MIN, np.int16)
        self._n = 0
        self._id2row: Dict[int, int] = {}
        self._tombs: set = set()
        self._tomb_clusters: Dict[int, int] = {}  # cold id → cluster hint
        self._pending: Optional[FrozenDelta] = None
        self._version = 0
        self._lock = threading.Lock()
        # counters (metrics() / tests)
        self._adds = 0
        self._tombstoned = 0
        self._commits = 0
        self._snap_cache: Optional[Tuple[int, Optional[DeltaSnapshot]]] = None
        self._adj_cache: Optional[Tuple[int, Optional[np.ndarray]]] = None

    @classmethod
    def for_index(cls, index, budget_mb: float,
                  quantize: str = "auto") -> "DeltaTier":
        """Sizes the segment from a byte budget (`--delta-budget-mb`).
        ``quantize="on"`` sizes rows at 1 byte/dim + 4-byte scale — ~4× the
        capacity of a float32 cold tier's budget."""
        bspec = blockstore_lib.BlockSpec.from_index(index)
        quantized = bool(bspec.quantized) or quantize == "on"
        row = (
            bspec.dim * (1 if quantized
                         else np.dtype(index.store_dtype).itemsize)
            + bspec.n_attrs * 2   # attrs int16
            + 4 + 4               # ids + cluster assignment
            + (4 if bspec.has_norms else 0)
            + (4 if quantized else 0)
        )
        cap = max(int(budget_mb * 2 ** 20) // row, 8)
        return cls(index, capacity=cap, quantize=quantize)

    # ---- mutation ----
    def add(self, core, attrs, ids) -> int:
        """Appends a batch of hybrid rows; returns rows added.

        Raises :class:`DeltaOverflowError` when the batch would overflow
        the byte budget — the caller's signal to republish.
        """
        core_j, attrs_j = make_hybrid(self.spec, core, attrs)
        assign = kmeans_lib.assign(
            core_j.astype(jnp.float32), self._centroids
        )
        if self.quantized:  # the add_vectors SQ8 path, bit for bit
            c32 = core_j.astype(jnp.float32)
            amax = jnp.max(jnp.abs(c32), axis=-1)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            codes = jnp.clip(jnp.round(c32 / scale[:, None]), -127, 127)
            rows = np.asarray(codes).astype(self._store_dtype)
            scales = np.asarray(scale, np.float32)
        else:
            rows = np.asarray(core_j.astype(jnp.dtype(self._store_dtype)))
            scales = None
        norms = (
            np.asarray(jnp.sum(core_j.astype(jnp.float32) ** 2, -1),
                       np.float32)
            if self._norms is not None else None
        )
        a_np = np.asarray(attrs_j, np.int16)
        ids_np = np.asarray(ids, np.int32)
        cl_np = np.asarray(assign, np.int32)
        b = ids_np.shape[0]
        with self._lock:
            if self._n + b > self.capacity:
                raise DeltaOverflowError(
                    f"delta segment full: {self._n}+{b} > capacity "
                    f"{self.capacity} rows — run compact_deltas() and "
                    f"refresh() before adding more"
                )
            lo = self._n
            self._vectors[lo:lo + b] = rows
            self._attrs[lo:lo + b] = a_np
            self._clusters[lo:lo + b] = cl_np
            if self._norms is not None:
                self._norms[lo:lo + b] = norms
            if self._scales is not None:
                self._scales[lo:lo + b] = scales
            # ids last: a snapshot taken concurrently masks rows ≥ its
            # n_rows anyway, but dead-until-assigned keeps this append
            # invisible even to a torn read
            self._ids[lo:lo + b] = ids_np
            if b:
                np.minimum(self._attr_lo, a_np.min(axis=0),
                           out=self._attr_lo)
                np.maximum(self._attr_hi, a_np.max(axis=0),
                           out=self._attr_hi)
            for j in range(b):
                self._id2row[int(ids_np[j])] = lo + j
            self._n += b
            self._adds += b
            self._version += 1
        return b

    def tombstone(self, ids, clusters=None) -> int:
        """Deletes rows by id; returns how many were newly tombstoned.

        Delta rows die in place.  Ids not in the segment are cold rows:
        they join the tombstone set the scan masks against, with
        ``clusters`` (aligned per-id hints, −1 = unknown) keeping the
        planner's adjusted counts exact — without a hint the row still
        never surfaces, but a cluster emptied purely by hint-less deletes
        would stay probeable where a rebuild's planner would skip it.
        """
        ids_np = np.asarray(ids, np.int64).reshape(-1)
        hints = (
            None if clusters is None
            else np.asarray(clusters, np.int64).reshape(-1)
        )
        n_new = 0
        with self._lock:
            for j, _id in enumerate(int(i) for i in ids_np):
                row = self._id2row.pop(_id, None)
                if row is not None:
                    self._ids[row] = -1
                    n_new += 1
                    if (self._pending is not None
                            and row < self._pending.n0):
                        # frozen row: it is being written live to the new
                        # cold generation right now — queue the delete for
                        # replay against cold at commit
                        self._pending.late_tombs.append(
                            (_id, int(self._clusters[row]))
                        )
                    continue
                if _id in self._tombs:
                    continue
                self._tombs.add(_id)
                n_new += 1
                if hints is not None and hints[j] >= 0:
                    self._tomb_clusters[_id] = int(hints[j])
            self._tombstoned += n_new
            self._version += 1
        return n_new

    # ---- per-batch views ----
    def snapshot(self) -> Optional[DeltaSnapshot]:
        """The batch's immutable view (None when the tier is truly empty —
        frozen-checkpoint batches pay zero delta overhead).  Cached by
        version: back-to-back batches with no interleaved mutation share
        one snapshot (and its packed tombstone array)."""
        with self._lock:
            if self._snap_cache is not None and \
                    self._snap_cache[0] == self._version:
                return self._snap_cache[1]
            if self._n == 0 and not self._tombs:
                snap = None
            else:
                snap = DeltaSnapshot(
                    n_rows=self._n,
                    vectors=self._vectors,
                    attrs=self._attrs,
                    ids=self._ids.copy(),
                    clusters=self._clusters,
                    norms=self._norms,
                    scales=self._scales,
                    tombstones=_pack_tombstones(self._tombs),
                    version=self._version,
                    attr_lo=self._attr_lo.copy(),
                    attr_hi=self._attr_hi.copy(),
                )
            self._snap_cache = (self._version, snap)
            return snap

    def count_adjustment(self, n_clusters: int) -> Optional[np.ndarray]:
        """[K] int32 live-delta-adds minus hinted cold tombstones — what the
        planner adds to the cold counts so ``centroid_scores``'s
        empty-cluster mask agrees with a rebuild.  None when all-zero."""
        with self._lock:
            if self._adj_cache is not None and \
                    self._adj_cache[0] == self._version:
                return self._adj_cache[1]
            adj = np.zeros(n_clusters, np.int32)
            n = self._n
            if n:
                live = self._ids[:n] >= 0
                np.add.at(adj, self._clusters[:n][live], 1)
            for c in self._tomb_clusters.values():
                adj[c] -= 1
            out = adj if adj.any() else None
            self._adj_cache = (self._version, out)
            return out

    # ---- republish handshake ----
    def freeze(self) -> FrozenDelta:
        """Snapshots the segment prefix + tombstone set for a republish.
        Adds keep landing behind the freeze; only one republish may be in
        flight."""
        with self._lock:
            if self._pending is not None:
                raise RuntimeError(
                    "a republish is already in flight (freeze without "
                    "commit) — refresh() the serving index first"
                )
            n0 = self._n
            fro = FrozenDelta(
                n0=n0,
                ids=self._ids[:n0].copy(),
                clusters=self._clusters[:n0].copy(),
                vectors=self._vectors[:n0].copy(),
                attrs=self._attrs[:n0].copy(),
                norms=(None if self._norms is None
                       else self._norms[:n0].copy()),
                scales=(None if self._scales is None
                        else self._scales[:n0].copy()),
                tombs=frozenset(self._tombs),
            )
            self._pending = fro
            return fro

    def commit(self) -> bool:
        """Drops the republished prefix (the new cold generation now serves
        those rows) and replays queued late tombstones against it.  Called
        from ``refresh()`` between batches — the same atomic flip that
        swaps in the new generation vector.  Returns False when no
        republish was in flight."""
        with self._lock:
            fro = self._pending
            if fro is None:
                return False
            n0, n = fro.n0, self._n
            keep = n - n0
            for arr in (self._vectors, self._attrs, self._clusters):
                arr[:keep] = arr[n0:n]
            if self._norms is not None:
                self._norms[:keep] = self._norms[n0:n]
            if self._scales is not None:
                self._scales[:keep] = self._scales[n0:n]
            self._ids[:keep] = self._ids[n0:n]
            self._ids[keep:n] = -1
            self._n = keep
            # the envelope only ever widened; recompute it from the rows
            # that survive the republish so pruning recovers its bite
            m = self._attrs.shape[1]
            self._attr_lo = np.full((m,), summaries_lib.ATTR_MAX, np.int16)
            self._attr_hi = np.full((m,), summaries_lib.ATTR_MIN, np.int16)
            live = self._ids[:keep] >= 0
            if live.any():
                rows = self._attrs[:keep][live]
                self._attr_lo = rows.min(axis=0).astype(np.int16)
                self._attr_hi = rows.max(axis=0).astype(np.int16)
            self._id2row = {
                int(i): r for r, i in enumerate(self._ids[:keep]) if i >= 0
            }
            # folded tombstones are physically gone from the new records
            self._tombs -= fro.tombs
            for _id in fro.tombs:
                self._tomb_clusters.pop(_id, None)
            # deletes that raced the republish: their rows are live in the
            # new cold generation — mask them there from the next batch on
            for _id, c in fro.late_tombs:
                self._tombs.add(_id)
                self._tomb_clusters[_id] = c
            self._pending = None
            self._version += 1
            self._commits += 1
            return True

    # ---- observability ----
    def stats(self) -> Dict[str, object]:
        with self._lock:
            live = int((self._ids[: self._n] >= 0).sum())
            return dict(
                rows=self._n,
                live_rows=live,
                capacity=self.capacity,
                tombstones=len(self._tombs),
                adds=self._adds,
                tombstoned=self._tombstoned,
                commits=self._commits,
                pending=self._pending is not None,
                version=self._version,
            )


# ---------------------------------------------------------------------------
# Republish
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RepublishStats:
    """What one ``compact_deltas`` run rewrote (the bench's invalidation
    accounting reads ``clusters_rewritten``)."""

    clusters_rewritten: int
    shards_rewritten: int
    rows_folded: int        # delta rows written into cluster records
    rows_reclaimed: int     # dead (tombstoned/stale) slots dropped
    tombstones_applied: int
    gen_max: int
    # what scheduled this republish: "manual" (explicit call), "every"
    # (fixed batch counter), "rows" (delta.rows watermark) or "stale"
    # (tombstone-debt watermark) — see republish_pressure()
    trigger: str = "manual"


def republish_pressure(
    tier: DeltaTier,
    *,
    rows_watermark: Optional[int] = None,
    stale_frac: Optional[float] = None,
    n_live: int = 0,
) -> Optional[str]:
    """Which watermark (if any) says the tier should republish *now*.

    ``rows_watermark`` trips on the segment's row count (``delta.rows`` —
    every query's delta fold competes against the whole segment, so this
    bounds the per-batch fold cost), ``stale_frac`` on tombstone debt
    relative to the cold corpus (``n_live``) — the ``stale_counts``
    pressure: dead cold slots the scan still pages and masks.  Returns the
    :class:`RepublishStats` trigger string (``"rows"`` / ``"stale"``) or
    None when neither watermark is hit.

    A tier with a republish already frozen (``pending``) never reports
    pressure — the relief is in flight, waiting for the serving side's
    between-batch commit; double-triggering would trip the freeze guard.
    """
    st = tier.stats()
    if st["pending"]:
        return None
    if rows_watermark is not None and st["rows"] >= rows_watermark > 0:
        return "rows"
    if stale_frac is not None and stale_frac > 0:
        debt = st["tombstones"] / max(int(n_live), 1)
        if debt >= stale_frac:
            return "stale"
    return None


def compact_deltas(
    directory: str,
    tier: Optional[DeltaTier] = None,
    *,
    include_stale: bool = True,
    trigger: str = "manual",
) -> RepublishStats:
    """Folds the tier's frozen rows + tombstones into the checkpoint.

    Rewrites *only* the shards holding touched clusters; each touched
    cluster's record gets its rows compacted (tombstones reclaimed, delta
    rows appended in add order — matching the stable scatter a from-scratch
    rebuild performs), its summary row rebuilt exactly, and its ``gen``
    bumped, then ``counts.npy`` / ``gens.npy`` / the manifest follow
    atomically.  A serving pod keeps reading its old mmap until
    ``refresh()`` flips it between batches; gen-keyed caches then miss on
    exactly the rewritten clusters.

    ``include_stale`` also folds clusters whose only debt is pre-existing
    tombstoned slots under the count high-water mark (the ``stale_counts``
    debt), so prune effectiveness recovers on republish.

    The freeze taken here stays pending until ``tier.commit()`` — run via
    ``DiskIVFIndex.refresh()`` / ``SearchEngine.refresh()`` — so serving
    never double-counts or drops a row mid-republish.
    """
    man = storage.load_manifest(directory)
    if man.get("layout", 1) < 3:
        raise storage.GenerationMismatchError(
            f"compact_deltas needs a generation-tagged (layout 3) "
            f"checkpoint, found layout {man.get('layout', 1)} at "
            f"{directory!r} — re-save with save_index(..., layout=3)"
        )
    paths = storage.check_complete(directory, man)
    gens = storage.load_gens(directory, man)
    counts = np.array(
        np.load(os.path.join(directory, "counts.npy")), np.int32
    )
    k, n_shards, vpad = man["n_clusters"], man["n_shards"], man["vpad"]
    kl = k // n_shards
    parts = [storage.read_shard_fields(p, man) for p in paths]

    frozen = tier.freeze() if tier is not None else None
    if frozen is not None and frozen.n0:
        f_live = np.nonzero(frozen.ids >= 0)[0]
    else:
        f_live = np.zeros(0, np.int64)
    tombs = frozen.tombs if frozen is not None else frozenset()
    tomb_arr = (
        np.fromiter(tombs, np.int64, len(tombs)) if tombs
        else np.zeros(0, np.int64)
    )

    per_cluster: Dict[int, List[int]] = {}
    for i in f_live:
        per_cluster.setdefault(int(frozen.clusters[i]), []).append(int(i))
    touched = set(per_cluster)
    tombstones_applied = 0
    for s, part in enumerate(parts):
        ids_s = part["ids"]                      # [kl, Vpad]
        if tomb_arr.size:
            hit = np.isin(ids_s, tomb_arr)
            tombstones_applied += int(hit.sum())
            touched.update(s * kl + lc for lc in np.nonzero(
                hit.any(axis=1))[0])
        if include_stale:
            crow = counts[s * kl:(s + 1) * kl]
            within = np.arange(vpad)[None, :] < crow[:, None]
            stale = np.logical_and(within, ids_s < 0)
            touched.update(s * kl + lc for lc in np.nonzero(
                stale.any(axis=1))[0])

    if not touched:
        # nothing to publish; the (empty) freeze is dropped at the next
        # refresh()'s commit
        return RepublishStats(0, 0, 0, 0, 0, int(gens.max(initial=0)),
                              trigger=trigger)

    summ = storage.load_summaries(directory, man)
    bounds = storage.load_bounds(directory, man)
    centroids = (
        np.load(os.path.join(directory, "centroids.npy"))
        if bounds is not None else None
    )
    field_names = [f["name"] for f in man["fields"] if f["name"] != "gen"]
    f_vectors = None if frozen is None else frozen.vectors
    if (frozen is not None and tier is not None and tier.quantized
            and not man.get("quantized", False)):
        # forced-SQ8 tier over a float cold checkpoint: republish
        # dequantizes codes·scales back to the cold store dtype (the
        # manifest has no scales field, so only the rows change shape)
        f_vectors = (
            frozen.vectors.astype(np.float32) * frozen.scales[:, None]
        ).astype(storage.np_dtype(man["store_dtype"]))
    frozen_fields = (
        {} if frozen is None else dict(
            vectors=f_vectors, attrs=frozen.attrs, ids=frozen.ids,
            norms=frozen.norms, scales=frozen.scales,
        )
    )
    rows_folded = rows_reclaimed = 0
    for c in sorted(touched):
        s, lc = divmod(c, kl)
        part = parts[s]
        old_ids = part["ids"][lc]
        cnt = int(counts[c])
        within = np.arange(vpad) < cnt
        keep = np.logical_and(within, old_ids >= 0)
        if tomb_arr.size:
            keep = np.logical_and(keep, ~np.isin(old_ids, tomb_arr))
        keep_idx = np.nonzero(keep)[0]           # stable slot order
        add_rows = per_cluster.get(c, [])
        n_new = len(keep_idx) + len(add_rows)
        if n_new > vpad:
            raise ValueError(
                f"cluster {c} overflows vpad={vpad} with {n_new} rows "
                f"after folding {len(add_rows)} delta rows — the cluster "
                f"needs a split/rebuild, not a republish"
            )
        for name in field_names:
            row = part[name][lc]
            new = np.zeros_like(row)
            if name == "ids":
                new[:] = -1
            new[: len(keep_idx)] = row[keep_idx]
            if add_rows:
                new[len(keep_idx): n_new] = frozen_fields[name][add_rows]
            part[name][lc] = new
        part["gen"][lc, 0] = gens[c] + 1
        gens[c] += 1
        rows_folded += len(add_rows)
        rows_reclaimed += cnt - len(keep_idx)
        counts[c] = n_new
        if summ is not None:
            summ = summaries_lib.rebuild_cluster(
                summ, jnp.asarray(part["attrs"][lc]),
                jnp.asarray(part["ids"][lc]), c,
            )
        if bounds is not None:
            bounds = summaries_lib.rebuild_cluster_bounds(
                bounds, jnp.asarray(centroids[c]),
                jnp.asarray(part["vectors"][lc]),
                jnp.asarray(part["ids"][lc]),
                (jnp.asarray(part["norms"][lc])
                 if "norms" in part else None),
                (jnp.asarray(part["scales"][lc])
                 if man.get("quantized", False) else None),
                c,
            )

    # layout v4: a touched base cluster's sub-partitions are stale — rebuild
    # each one from the folded record with the same row-selection rule the
    # build used (select_sub_rows), bump its generation past the base id
    # space, and rewrite the whole partition plane.  Sub vpads only grow
    # (records are rewritten whole, so growth is just a bigger pad).
    part_build = None
    if man.get("has_partitions"):
        from repro.core import partitions as partitions_lib

        cat = storage.load_partitions(directory, man)
        records = storage.load_partition_records(directory, man)
        vpads = np.asarray(storage.load_partition_vpads(directory),
                           np.int64).copy()
        parent = np.asarray(cat.parent, np.int64)
        sub_counts = np.asarray(cat.sub_counts, np.int32).copy()
        sub_amin = np.asarray(cat.sub_amin, np.int16).copy()
        sub_amax = np.asarray(cat.sub_amax, np.int16).copy()
        resubbed = np.nonzero(np.isin(parent, np.fromiter(
            touched, np.int64, len(touched))))[0]
        for p_ in resubbed:
            p_ = int(p_)
            c = int(parent[p_])
            s, lc = divmod(c, kl)
            part = parts[s]
            rows = partitions_lib.select_sub_rows(
                part["attrs"][lc], part["ids"][lc], int(counts[c]),
                np.asarray(cat.sub_lo[p_]), np.asarray(cat.sub_hi[p_]),
            )
            n = int(rows.size)
            vp = max(
                int(vpads[p_]),
                min(
                    partitions_lib._round_up(
                        max(n, 1), partitions_lib.SUB_ALIGN
                    ),
                    vpad,
                ),
                n,
            )
            vpads[p_] = vp
            rec: Dict[str, np.ndarray] = {}
            for name in field_names:
                src = part[name][lc]
                new = np.zeros((vp,) + src.shape[1:], src.dtype)
                if name == "ids":
                    new[:] = -1
                if n:
                    new[:n] = src[rows]
                rec[name] = new
            records[p_] = rec
            sub_counts[p_] = n
            if n:
                sub_amin[p_] = rec["attrs"][:n].min(axis=0)
                sub_amax[p_] = rec["attrs"][:n].max(axis=0)
            else:
                sub_amin[p_] = summaries_lib.ATTR_MAX
                sub_amax[p_] = summaries_lib.ATTR_MIN
            gens[k + p_] += 1
        mem = np.asarray(cat.members, np.int64)          # [E, K]
        entry_rows = np.where(
            mem >= 0,
            sub_counts[np.clip(mem - k, 0, None)].astype(np.int64),
            counts[:k].astype(np.int64)[None, :],
        ).sum(axis=1)
        new_cat = dataclasses.replace(
            cat, entry_rows=entry_rows, sub_counts=sub_counts,
            sub_amin=sub_amin, sub_amax=sub_amax,
        )
        part_build = partitions_lib.PartitionBuild(
            catalog=new_cat, records=records,
            vpads=vpads.astype(np.int32),
        )

    # rewrite only the shards that hold touched clusters, then the resident
    # vectors, summaries and manifest — each atomically, manifest last
    stride = man["record_stride"]
    shards_touched = sorted({c // kl for c in touched})
    for s in shards_touched:
        def _bin_save(p, s=s):
            with open(p, "wb") as f:
                rec = np.zeros(stride, np.uint8)
                for lc in range(kl):
                    rec[:] = 0
                    for fld in man["fields"]:
                        raw = np.ascontiguousarray(
                            parts[s][fld["name"]][lc]
                        ).tobytes()
                        o = fld["offset"]
                        rec[o:o + len(raw)] = np.frombuffer(raw, np.uint8)
                    f.write(rec.tobytes())

        storage._atomic_save(paths[s], _bin_save)

    def _np_save(p, arr):
        with open(p, "wb") as f:
            np.save(f, arr, allow_pickle=False)

    storage._atomic_save(
        os.path.join(directory, "counts.npy"),
        lambda p: _np_save(p, counts),
    )
    storage._atomic_save(
        os.path.join(directory, storage.GENS_FILE),
        lambda p: _np_save(p, gens),
    )
    if summ is not None:
        for field, fname in storage.SUMMARY_FILES.items():
            storage._atomic_save(
                os.path.join(directory, fname),
                lambda p, f=field: _np_save(p, np.asarray(getattr(summ, f))),
            )
    if bounds is not None:
        for field, fname in storage.BOUNDS_FILES.items():
            storage._atomic_save(
                os.path.join(directory, fname),
                lambda p, f=field: _np_save(
                    p, np.asarray(getattr(bounds, f))
                ),
            )
    if part_build is not None:
        storage.write_partition_region(
            directory, man, part_build, gens[k:]
        )
    man["n_live"] = int(counts.sum())
    storage._atomic_save(
        os.path.join(directory, storage.MANIFEST),
        lambda p: open(p, "w").write(json.dumps(man, indent=2)),
    )
    return RepublishStats(
        clusters_rewritten=len(touched),
        shards_rewritten=len(shards_touched),
        rows_folded=rows_folded,
        rows_reclaimed=rows_reclaimed,
        tombstones_applied=tombstones_applied,
        gen_max=int(gens.max(initial=0)),
        trigger=trigger,
    )
