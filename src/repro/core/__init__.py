"""The paper's primary contribution: hybrid IVF-Flat filtered similarity search.

Public API surface (see DESIGN.md §3):

  HybridSpec, make_hybrid, l2_normalize            — hybrid vector layout
  FilterBuilder, FilterSpec, match_all, filter_mask — SQL-like filters
  build_ivf, IVFFlatIndex                           — index construction
  ClusterSummaries, build_summaries, can_match      — filter-aware pruning
  search_reference, brute_force, recall_at_k        — search paths + oracle
  add_vectors, tombstone                            — online updates
  DeltaTier, compact_deltas                         — live hot/cold serving
  PartitionCatalog, build_partitions                — filter-specialized
                                                      sub-partition layouts
"""

from repro.core.hybrid import (
    ATTR_MAX,
    ATTR_MIN,
    HybridSpec,
    concat_hybrid,
    encode_categorical_attr,
    encode_numeric_attr,
    l2_normalize,
    make_hybrid,
    split_hybrid,
)
from repro.core.filters import (
    FilterBuilder,
    FilterSpec,
    filter_mask,
    from_builders,
    match_all,
    selectivity,
)
from repro.core.ivf import (
    BuildStats,
    IVFFlatIndex,
    build_from_assignments,
    build_ivf,
    default_n_clusters,
    validity_mask,
)
from repro.core.search import (
    SearchResult,
    brute_force,
    centroid_scores,
    recall_at_k,
    search_centroids,
    search_reference,
)
from repro.core.blockstore import (
    BlockSpec,
    BlockStoreServer,
    HashRing,
    LocalBlockStore,
    LoopbackTransport,
    RangeOwnership,
    ResidentBlockStore,
    ShardedBlockStore,
    SocketTransport,
    StoreStats,
    open_sharded,
)
from repro.core.transport import TransportError, TransportTimeout
from repro.core.health import CircuitBreaker, PeerHealth
from repro.core.faults import (
    FaultRule,
    FaultSchedule,
    FaultyBlockStore,
    FaultyTransport,
)
from repro.core.disk import ClusterCache, DiskIVFIndex
from repro.core.engine import (
    SearchEngine,
    SearchPlan,
    TileWork,
    scan_compile_count,
    search_fused_tiled,
    u_cap_buckets,
)
from repro.core.probes import dedup_rows, fetch_order, plan_probe_tiles
from repro.core.summaries import (
    ClusterSummaries,
    build_summaries,
    can_match,
    expected_passing,
)
from repro.core.topk import (
    masked_topk,
    merge_topk,
    merge_topk_many,
    topk_tree_merge,
)
from repro.core.partitions import (
    FilterTrafficRecorder,
    PartitionBuild,
    PartitionCatalog,
    build_partitions,
    choose_attrs,
)
from repro.core.update import (
    add_vectors,
    compact_cluster,
    compact_stale,
    resync_partitions,
    stale_counts,
    tombstone,
)
from repro.core.delta import (
    DeltaOverflowError,
    DeltaTier,
    RepublishStats,
    compact_deltas,
)
from repro.core.storage import GenerationMismatchError

__all__ = [k for k in dir() if not k.startswith("_")]
