"""Disk-resident index tier: page clusters in per query (paper's cost claim).

The paper's economics rest on the index living on disk and only probed lists
being loaded per query.  :class:`DiskIVFIndex` is that serving mode over a
layout-v2 checkpoint (``core/storage.py``):

  * **Resident set**: centroids ``[K, D]``, counts ``[K]``, the per-cluster
    attribute summaries (layout v2.1, ``core/summaries.py``) and the
    manifest's offset arithmetic — kilobytes per thousand clusters.
    Everything a query needs *before* it knows which lists to touch,
    including the filter-aware pruning that decides which lists NOT to
    touch.
  * **Paged set**: per-cluster records ``(vectors, attrs, ids, norms?,
    scales?)`` read from the memory-mapped shard files through
    :class:`ClusterCache` — a pinned host-buffer LRU keyed by cluster id,
    capped so ``resident_bytes() ≤ resident_budget_bytes``.
  * **Probe-driven fill**: the tiled search plan (``core/probes.py``) already
    deduplicates each batch's probes into per-tile unique-cluster tables;
    ``probes.fetch_order`` turns that plan into the cache's fetch list, and
    ``prefetch`` loads it on a background thread while the previous batch
    computes (SIEVE's batch-sharing observation, PipeANN's SSD pipelining).
  * **Hot/cold split**: the cache counts probes per cluster and periodically
    pins the most-probed clusters (SIEVE's hot-index placement) — hot lists
    stay mapped across batches, cold lists churn through the LRU tail.

Search runs through the *same* tiled kernel as the RAM path: the engine's
fetch stage pulls records through the index's
:class:`repro.core.blockstore.LocalBlockStore` (reader + cache behind the
pluggable BlockStore protocol — swap in a ``ShardedBlockStore`` to split
cache ownership across pods) and swaps the kernel's full ``[K, Vpad, ...]``
operands for batch-local gathered ``[S, Vpad, ...]`` blocks with slot-local
cluster ids — bit-identical results, bounded memory.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import queue
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import blockstore as blockstore_lib
from repro.core import storage
from repro.core.hybrid import HybridSpec


class ShardReader:
    """mmap-backed reader of layout-v2/v3 shard files, one cluster per read.

    Thread-safe: maps are opened lazily under a lock and reads copy the
    record out of the map into a fresh host buffer, so returned arrays never
    alias pageable mmap memory.  Every record carries a ``gen`` field —
    read from layout-v3 records, synthesized as 0 for v2 — so gen-keyed
    cache layers treat both uniformly.
    """

    def __init__(self, directory: str, man: dict):
        if man["layout"] not in (2, 3, 4):
            raise ValueError(
                "DiskIVFIndex requires a layout-v2/v3/v4 checkpoint; "
                "re-save it with storage.save_index(index, dir) — v1 .npz "
                "shards are not cluster-addressable"
            )
        self.directory = directory
        self._lock = threading.Lock()
        self._apply_manifest(man)

    def _apply_manifest(self, man: dict):
        self.man = man
        self.paths = storage.shard_paths(self.directory, man)
        self.n_base = man["n_clusters"]
        self.kl = man["n_clusters"] // man["n_shards"]
        self.stride: int = man["record_stride"]
        self.fields = [
            (f["name"], storage.np_dtype(f["dtype"]), tuple(f["shape"]),
             f["offset"])
            for f in man["fields"]
        ]
        # eager: mapping is just a VM reservation (pages fault in on
        # read), and a lazy first map after a republish rename would pin
        # the NEW inode against the old counts/gens — a torn view
        self._mm: List[Optional[np.memmap]] = [
            np.memmap(p, dtype=np.uint8, mode="r") for p in self.paths
        ]
        # layout v4: the sub-partition record region — variable-stride
        # records addressed by the resident byte-offset table; ids
        # ``>= n_base`` read from here instead of the shard files
        self._part_mm: Optional[np.memmap] = None
        self._part_offsets: Optional[np.ndarray] = None
        self._part_layouts: List[Tuple] = []
        if man.get("has_partitions"):
            self._part_offsets = np.asarray(
                np.load(os.path.join(
                    self.directory, storage.PARTITION_OFFSETS
                )), np.int64,
            )
            vpads = storage.load_partition_vpads(self.directory)
            for vp in vpads:
                fields, stride = storage.partition_record_layout(
                    man, int(vp)
                )
                self._part_layouts.append((
                    [(f["name"], storage.np_dtype(f["dtype"]),
                      tuple(f["shape"]), f["offset"]) for f in fields],
                    stride,
                ))
            self._part_mm = np.memmap(
                os.path.join(self.directory, storage.PARTITION_DATA),
                dtype=np.uint8, mode="r",
            )

    def reopen(self, man: Optional[dict] = None):
        """Re-reads the manifest and drops the shard mmaps — the local half
        of a generation flip.  ``compact_deltas`` rewrites shard files
        atomically (tmp + rename), so old maps keep serving the *old* inode
        consistently until this swap; reads racing the swap may still
        return old-generation records, which the gen-keyed caches catch and
        re-read rather than serve."""
        with self._lock:
            self._apply_manifest(
                man if man is not None
                else storage.load_manifest(self.directory)
            )

    def _mmap(self, s: int) -> np.memmap:
        mm = self._mm
        if mm[s] is None:
            with self._lock:
                mm = self._mm  # reopen() may have swapped the list
                if mm[s] is None:
                    mm[s] = np.memmap(
                        self.paths[s], dtype=np.uint8, mode="r"
                    )
        return mm[s]

    def read(self, cid: int) -> Dict[str, np.ndarray]:
        """Reads cluster ``cid``'s record into one pinned host buffer and
        returns zero-copy per-field views into it.  Ids ``>= n_base`` are
        sub-partitions (layout v4): their variable-stride records come from
        the partition region through the offset table."""
        cid = int(cid)
        if cid >= self.n_base:
            return self._read_partition(cid - self.n_base)
        s, r = divmod(cid, self.kl)
        mm = self._mmap(s)
        off = r * self.stride
        buf = np.array(mm[off:off + self.stride])  # the one copy
        rec = {}
        for name, dt, shape, o in self.fields:
            nb = int(np.prod(shape)) * dt.itemsize
            rec[name] = buf[o:o + nb].view(dt).reshape(shape)
        if "gen" not in rec:  # layout v2: pre-generation records are gen 0
            rec["gen"] = np.zeros(1, np.int64)
        return rec

    def _read_partition(self, p: int) -> Dict[str, np.ndarray]:
        if self._part_mm is None or p >= len(self._part_layouts):
            raise ValueError(
                f"sub-partition {p} out of range for this checkpoint "
                f"({len(self._part_layouts)} subs)"
            )
        fields, stride = self._part_layouts[p]
        off = int(self._part_offsets[p])
        buf = np.array(self._part_mm[off:off + stride])
        rec = {}
        for name, dt, shape, o in fields:
            nb = int(np.prod(shape)) * dt.itemsize
            rec[name] = buf[o:o + nb].view(dt).reshape(shape)
        return rec


@dataclasses.dataclass
class CacheStats:
    hits: int = 0        # served from cache (incl. waits on in-flight loads)
    misses: int = 0      # loaded synchronously by the requesting thread
    evictions: int = 0
    prefetched: int = 0  # loaded by the background thread
    errors: int = 0      # prefetch-thread load failures (retried inline by
    #                      the next get_many touching the cluster)
    stalled_waits: int = 0  # waits on an in-flight load that outlived the
    #                         waiter timeout (loader hung or died); the
    #                         waiter re-loaded inline instead of hanging
    invalidations: int = 0  # cached records dropped because a fetch carried
    #                         a newer expected generation (republish flips
    #                         exactly the rewritten clusters)


class ClusterCache:
    """Pinned host-buffer LRU over cluster records, with probe-driven
    prefetch and hot-cluster pinning.

    * ``get_many`` is the synchronous path: returns every requested record,
      loading misses inline (deduplicated against in-flight prefetches).
    * ``prefetch`` enqueues ids to a daemon thread so the next batch's
      clusters stream from disk while the current batch computes.
    * Every ``pin_refresh`` batches, the ``pin_fraction`` most-probed
      clusters are pinned: the LRU never evicts them, so hot lists stay
      resident across batches (SIEVE's hot/cold split).  Capacity is a hard
      cap either way — the cache holds at most ``capacity_records`` records.
    """

    def __init__(self, reader: ShardReader, *, capacity_records: int,
                 n_clusters: int, pin_fraction: float = 0.5,
                 pin_refresh: int = 64, waiter_timeout_s: float = 30.0):
        if capacity_records < 1:
            raise ValueError("capacity_records must be >= 1")
        if not 0.0 <= pin_fraction <= 1.0:
            raise ValueError(f"pin_fraction must be in [0, 1], got "
                             f"{pin_fraction}")
        self.reader = reader
        self.record_nbytes = reader.stride
        self.capacity_records = capacity_records
        # Pin-aware eviction accounting: at least one slot always stays
        # evictable.  A pin_refresh swap that pinned the whole capacity left
        # _insert_locked no legal victim; inserting without evicting would
        # push resident_bytes() past the budget, and the old fallback that
        # prevented that instead evicted a *pinned* entry — the pin contract
        # broke exactly when pinning mattered most.  Capping pins at
        # capacity-1 makes eviction always find an unpinned victim, so
        # resident_bytes() ≤ capacity_records·stride holds through every
        # swap AND pinned entries are never evicted (asserted in the
        # lifecycle tests).
        self.pin_records = min(int(pin_fraction * capacity_records),
                               max(capacity_records - 1, 0))
        self.pin_refresh = pin_refresh
        self.waiter_timeout_s = waiter_timeout_s
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict[int, dict]" = (
            collections.OrderedDict()
        )
        self._inflight: Dict[int, list] = {}  # cid -> [Event, record|None]
        self._probe_count = np.zeros(n_clusters, np.int64)
        self._pinned: set = set()
        self._batches = 0
        self._lock = threading.Lock()
        self._stopped = False
        self._queue: "queue.Queue[Optional[int]]" = queue.Queue()
        self._worker = threading.Thread(target=self._prefetch_loop,
                                        daemon=True)
        self._worker.start()

    # ---- internal (lock held) ----
    def _insert_locked(self, cid: int, rec: dict):
        if cid in self._entries:
            self._entries.move_to_end(cid)
            return
        while len(self._entries) >= self.capacity_records:
            victim = next(
                (c for c in self._entries if c not in self._pinned), None
            )
            if victim is None:  # everything pinned: fall back to plain LRU
                victim = next(iter(self._entries))
            del self._entries[victim]
            self.stats.evictions += 1
        self._entries[cid] = rec

    def _refresh_pins_locked(self):
        if self.pin_records == 0:
            return
        order = np.argsort(self._probe_count)[::-1][: self.pin_records]
        self._pinned = {
            int(c) for c in order if self._probe_count[c] > 0
        }

    def _load(self, cid: int, *, prefetched: bool) -> dict:
        # On any read failure the in-flight entry must still be resolved —
        # a waiter blocked on the Event would otherwise hang forever.  The
        # holder carries the exception to waiters instead of a record.
        try:
            rec = self.reader.read(cid)
        except BaseException as e:
            with self._lock:
                holder = self._inflight.pop(cid, None)
            if holder is not None:
                holder[1] = e
                holder[0].set()
            raise
        with self._lock:
            holder = self._inflight.pop(cid, None)
            self._insert_locked(cid, rec)
            if prefetched:
                self.stats.prefetched += 1
        if holder is not None:
            holder[1] = rec
            holder[0].set()
        return rec

    def _prefetch_loop(self):
        while True:
            cid = self._queue.get()
            try:
                if cid is None:
                    return
                self._load(cid, prefetched=True)
            except Exception:
                # failed prefetch = missed hint; get_many retries inline —
                # but surface it: a silently failing disk turns every
                # "prefetched" batch into synchronous reads.
                with self._lock:
                    self.stats.errors += 1
            finally:
                self._queue.task_done()

    def _validated(self, cid: int, rec: dict, exp: Optional[Dict[int, int]]
                   ) -> dict:
        """Gen-checks a freshly loaded / waiter-delivered record.

        Expected gen is a *minimum*: a record at or above it is current (a
        republish may have advanced the cluster further than the caller
        knows).  Below it the load raced a republish through a stale mmap —
        reopen the reader and read once more; a second stale read means the
        checkpoint on disk genuinely lags the caller and is a loud error,
        never a silent stale serve.
        """
        if exp is None or cid not in exp:
            return rec
        want = exp[cid]
        if int(rec["gen"][0]) >= want:
            return rec
        with self._lock:
            self._entries.pop(cid, None)
            self.stats.invalidations += 1
        self.reader.reopen()
        rec = self._load(cid, prefetched=False)
        got = int(rec["gen"][0])
        if got < want:
            raise storage.GenerationMismatchError(
                f"cluster {cid}: shard on disk serves gen {got} but gen "
                f">= {want} was published — checkpoint republish "
                f"incomplete or rolled back"
            )
        return rec

    # ---- public ----
    def probe_heat(self, cid: int) -> int:
        """Observed probe count for one cluster — the heat signal the
        device-resident block cache weighs its eviction by (the same
        counter that drives hot-pinning here)."""
        return int(self._probe_count[int(cid)])

    def get_many(self, cids: Sequence[int],
                 gens: Optional[Sequence[int]] = None) -> Dict[int, dict]:
        """Returns {cid: record} for every id, blocking on disk as needed.

        ``gens`` (parallel to ``cids``) carries the minimum acceptable
        generation per cluster; cached records below it are dropped
        (counted in ``stats.invalidations``) and re-read — the mechanism by
        which a republish invalidates exactly the rewritten clusters.
        """
        exp: Optional[Dict[int, int]] = None
        if gens is not None:
            exp = {int(c): int(g) for c, g in zip(cids, gens)}
        out: Dict[int, dict] = {}
        to_load: List[int] = []
        waiters: List[Tuple[int, list]] = []
        with self._lock:
            self._batches += 1
            for cid in cids:
                self._probe_count[int(cid)] += 1
            if self._batches % self.pin_refresh == 0:
                self._refresh_pins_locked()
            for cid in cids:
                cid = int(cid)
                if cid in self._entries:
                    rec = self._entries[cid]
                    if exp is not None and cid in exp and \
                            int(rec["gen"][0]) < exp[cid]:
                        del self._entries[cid]  # stale generation
                        self.stats.invalidations += 1
                        self._inflight[cid] = [threading.Event(), None]
                        to_load.append(cid)
                        self.stats.misses += 1
                        continue
                    self._entries.move_to_end(cid)
                    out[cid] = rec
                    self.stats.hits += 1
                elif cid in self._inflight:  # prefetch already racing
                    waiters.append((cid, self._inflight[cid]))
                    self.stats.hits += 1
                else:
                    self._inflight[cid] = [threading.Event(), None]
                    to_load.append(cid)
                    self.stats.misses += 1
        for i, cid in enumerate(to_load):
            try:
                out[cid] = self._validated(
                    cid, self._load(cid, prefetched=False), exp
                )
            except BaseException as e:
                # _load resolved cid's own in-flight entry; the rest of this
                # call's registrations must be resolved too or any other
                # thread waiting on them hangs forever.  They carry the
                # exception — waiters retry inline, exactly like a failed
                # prefetch.
                with self._lock:
                    for rest in to_load[i + 1:]:
                        holder = self._inflight.pop(rest, None)
                        if holder is not None:
                            holder[1] = e
                            holder[0].set()
                raise
        for cid, holder in waiters:
            # Bounded wait: a loader that hung or died (fault injection, a
            # stuck disk) must not hang every batch that raced its load —
            # after waiter_timeout_s the waiter loads inline.  _load is
            # idempotent under the cache lock, so a late-finishing original
            # loader is harmless (the insert just refreshes LRU position).
            if not holder[0].wait(timeout=self.waiter_timeout_s):
                with self._lock:
                    self.stats.stalled_waits += 1
                out[cid] = self._load(cid, prefetched=False)
            elif isinstance(holder[1], BaseException):  # prefetch failed;
                out[cid] = self._load(cid, prefetched=False)  # retry inline
            else:
                out[cid] = holder[1]
            # A prefetch started before a generation flip can deliver the
            # old record — gen-check waiter results like inline loads.
            out[cid] = self._validated(cid, out[cid], exp)
        return out

    def prefetch(self, cids: Sequence[int]):
        """Queues cluster loads on the background thread (fire and forget).

        A no-op after :meth:`stop` — registering in-flight entries with no
        worker left to resolve them would hang any later ``get_many`` on
        those clusters forever.
        """
        with self._lock:
            if self._stopped:
                return
            # enqueue under the same lock as the in-flight registration: a
            # concurrent stop() would otherwise slip its shutdown sentinel
            # between the two, leaving entries no worker will ever resolve
            # (the queue is unbounded, so put() cannot block here)
            for cid in cids:
                cid = int(cid)
                if cid in self._entries or cid in self._inflight:
                    continue
                self._inflight[cid] = [threading.Event(), None]
                self._queue.put(cid)

    def drain(self):
        """Blocks until every queued prefetch has landed (tests, shutdown).
        A no-op after :meth:`stop` (the sentinel leaves the queue nonempty)."""
        with self._lock:
            if self._stopped:
                return
        self._queue.join()

    def stop(self):
        """Stops the prefetch thread.  Idempotent — serve/bench teardown
        paths (context manager exit, explicit close, atexit) may all call
        it; only the first enqueues the sentinel and joins."""
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
        self._queue.put(None)
        self._worker.join(timeout=10)

    def resident_bytes(self) -> int:
        return len(self._entries) * self.record_nbytes

    @property
    def pinned(self) -> frozenset:
        return frozenset(self._pinned)

    @property
    def hit_rate(self) -> float:
        tot = self.stats.hits + self.stats.misses
        return self.stats.hits / tot if tot else 0.0


def _resident_overhead(centroids, counts, summaries, bounds=None,
                       partitions=None) -> int:
    """Bytes of the always-resident set (everything except the cluster
    cache) — the single formula both the budget check in ``open`` and
    ``resident_bytes()`` accounting rely on."""
    return centroids.nbytes + counts.nbytes + (
        summaries.nbytes() if summaries is not None else 0
    ) + (bounds.nbytes() if bounds is not None else 0) + (
        partitions.nbytes() if partitions is not None else 0
    )


class DiskIVFIndex:
    """Disk-resident serving view of a layout-v2/v3 index checkpoint.

    Only centroids, counts and offset arithmetic stay in memory; flat lists
    page through :class:`ClusterCache` under ``resident_budget_bytes``.
    Satisfies the ``.spec / .centroids / .counts`` contract of
    ``search_centroids``, and plugs into the tiled kernel as its
    ``gather_fn`` — so RAM and disk tiers share one search implementation
    and return identical results.

    Live-update surface: ``gens`` holds the per-cluster generation vector
    the serving plan pins fetches to; ``delta`` (attached by
    ``make_fused_search_fn(delta_budget_mb=...)``) is the RAM tier of
    fresh writes; :meth:`refresh` flips both to a republished checkpoint
    between batches, with no drain.
    """

    def __init__(self, directory: str, man: dict, spec: HybridSpec,
                 centroids: np.ndarray, counts: np.ndarray,
                 reader: ShardReader, cache: ClusterCache,
                 resident_budget_bytes: Optional[int],
                 summaries=None, bounds=None, partitions=None):
        self.directory = directory
        self.man = man
        self.spec = spec
        self.centroids = jnp.asarray(centroids)
        self.counts = jnp.asarray(counts)
        self.reader = reader
        self.cache = cache
        self.resident_budget_bytes = resident_budget_bytes
        # Partition catalog (layout v4): resident predicate → sub-cluster
        # routing table.  None for pre-v4 checkpoints (flat routing only).
        self.partitions = partitions
        # Cluster attribute summaries (layout v2.1): resident like centroids,
        # consulted by the plan stage so filtered-out clusters never reach
        # the fetch list.  None for pre-v2.1 checkpoints (no pruning).
        self.summaries = summaries
        # Per-cluster score-bound statistics (radius/slack): resident like
        # the summaries, consumed by the engine's bound-driven termination.
        # None for checkpoints saved before bounds existed — termination
        # then raises with a re-save hint.
        self.bounds = bounds
        # Per-cluster generation vector (layout v3; zeros for v2): the plan
        # stamps each fetch with the cluster's published gen, so every cache
        # layer rejects records a republish has superseded.
        self.gens = storage.load_gens(directory, man)
        # RAM delta tier (attached by the serving layer when live updates
        # are enabled); None = frozen checkpoint, zero serving overhead.
        self.delta = None
        # Cross-batch device-resident block cache (attached by the serving
        # layer via make_fused_search_fn(device_cache_mb=...)); engines
        # built over this index pick it up automatically.
        self.device_cache = None
        self._overhead = _resident_overhead(centroids, counts, summaries,
                                            bounds, partitions)
        # The fetch layer: this host's reader + cache behind the BlockStore
        # protocol.  The search engine routes its fetch stage through it
        # (or through a ShardedBlockStore composed over several of them);
        # the gather* methods below stay as thin delegates for callers of
        # the pre-protocol surface.
        self.blockstore = blockstore_lib.LocalBlockStore(
            reader, cache, blockstore_lib.BlockSpec.from_manifest(man)
        )

    @classmethod
    def open(cls, directory: str, *,
             resident_budget_bytes: Optional[int] = None,
             pin_fraction: float = 0.5,
             pin_refresh: int = 64) -> "DiskIVFIndex":
        """Opens a checkpoint for disk-tier serving.

        ``resident_budget_bytes`` caps centroids + counts + cluster cache;
        ``None`` sizes the cache to hold every cluster (pure page-on-demand,
        no eviction pressure — useful as the parity baseline).
        """
        man = storage.load_manifest(directory)
        storage.check_complete(directory, man)
        reader = ShardReader(directory, man)
        centroids = np.load(os.path.join(directory, "centroids.npy"))
        counts = np.load(os.path.join(directory, "counts.npy"))
        summaries = storage.load_summaries(directory, man)
        bounds = storage.load_bounds(directory, man)
        partitions = storage.load_partitions(directory, man)
        overhead = _resident_overhead(centroids, counts, summaries, bounds,
                                      partitions)
        n_total = man["n_clusters"] + (
            partitions.n_subs if partitions is not None else 0
        )
        if resident_budget_bytes is None:
            cap = n_total
        else:
            budget = int(resident_budget_bytes) - overhead
            cap = budget // reader.stride
            if cap < 1:
                raise ValueError(
                    f"resident_budget_bytes={resident_budget_bytes} cannot "
                    f"hold the resident set ({overhead} B, incl. attribute "
                    f"summaries) plus one cluster record ({reader.stride} B)"
                )
            cap = min(cap, n_total)
        cache = ClusterCache(
            reader, capacity_records=cap, n_clusters=n_total,
            pin_fraction=pin_fraction, pin_refresh=pin_refresh,
        )
        return cls(directory, man, storage.spec_from_manifest(man),
                   centroids, counts, reader, cache, resident_budget_bytes,
                   summaries=summaries, bounds=bounds, partitions=partitions)

    # ---- IVFFlatIndex-compatible surface (what search paths touch) ----
    @property
    def n_clusters(self) -> int:
        return self.man["n_clusters"]

    @property
    def vpad(self) -> int:
        return self.man["vpad"]

    @property
    def quantized(self) -> bool:
        return self.man["quantized"]

    @property
    def store_dtype(self):
        return storage.np_dtype(self.man["store_dtype"])

    def resident_bytes(self) -> int:
        """Current bytes held in host memory for this index."""
        return self._overhead + self.cache.resident_bytes()

    def refresh(self) -> bool:
        """Adopts a republished checkpoint: the serving half of the
        ``compact_deltas`` → ``refresh`` handshake.

        Re-reads the manifest + generation vector; when the published gens
        moved, swaps in the new counts/summaries/gens and reopens the shard
        reader — all host-side bookkeeping, safe between batches with no
        drain.  Cached cluster records are *not* flushed here: the next
        fetch carries the new expected gens, so exactly the rewritten
        clusters invalidate (``cache.stats.invalidations``) while everything
        else keeps its resident copy.  Finally commits the attached delta
        tier (folded rows leave RAM; late tombstones carry over).  Returns
        whether the on-disk generation changed.
        """
        man = storage.load_manifest(self.directory)
        gens = storage.load_gens(self.directory, man)
        changed = not np.array_equal(gens, self.gens)
        if changed:
            storage.check_complete(self.directory, man)
            self.reader.reopen(man)
            self.man = man
            self.counts = jnp.asarray(
                np.load(os.path.join(self.directory, "counts.npy"))
            )
            self.summaries = storage.load_summaries(self.directory, man)
            self.bounds = storage.load_bounds(self.directory, man)
            self.partitions = storage.load_partitions(self.directory, man)
            self.gens = gens
            self._overhead = _resident_overhead(
                np.asarray(self.centroids), np.asarray(self.counts),
                self.summaries, self.bounds, self.partitions,
            )
        if self.delta is not None:
            self.delta.commit()
        return changed

    # ---- paging (delegates to the BlockStore fetch layer) ----
    @staticmethod
    def _first_need_unique(flat: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Unique cluster ids in *first-occurrence* order + inverse map
        (moved to :func:`repro.core.blockstore.first_need_unique`; kept as a
        delegate for the pre-protocol surface)."""
        return blockstore_lib.first_need_unique(flat)

    def gather(self, slot_cluster) -> Tuple:
        """``gather_fn`` for the search engine's scan stage.

        Maps the plan's global cluster ids to batch-local rows, pages the
        distinct clusters through the cache, and returns
        ``(local_ids [S], vectors [S, Vpad, D], attrs, ids, norms, scales)``
        — static shapes (S = n_tiles·u_cap), so the jitted scan never
        recompiles as the working set shifts.
        """
        return self.blockstore.gather(slot_cluster)

    def gather_submit(self, slot_cluster) -> "Future":
        """Asynchronous half of the legacy fetch surface: starts paging +
        assembling ``slot_cluster``'s blocks off-thread and returns a handle.

        The store's single worker pages the distinct ids through the cache
        in first-need order and device-puts the assembled blocks, so the
        host→device copy hides behind the previous tile's scan.
        ``gather_wait`` must be called exactly once per handle; a load
        failure is re-raised there.
        """
        return self.blockstore.gather_submit(slot_cluster)

    def gather_wait(self, handle: "Future") -> Tuple:
        """Blocks until a :meth:`gather_submit` handle's blocks are ready and
        returns them (same tuple as :meth:`gather`).  Propagates any read
        failure; the cache is left consistent (no stuck in-flight entries)."""
        return self.blockstore.gather_wait(handle)

    def prefetch(self, cluster_ids):
        """Background-loads clusters (e.g. ``probes.fetch_order`` output)."""
        self.cache.prefetch(np.asarray(cluster_ids).reshape(-1))

    def prefetch_for_queries(self, queries, n_probes: int,
                             q_block: int = 64, fspec=None,
                             prune: str = "auto",
                             t_max: Optional[int] = None):
        """Plans the next batch's probes and starts paging them in while the
        current batch is still computing on device.

        Clusters are enqueued in ``probes.fetch_order``'s first-need order —
        tile 0's unique probes first — so by the time the scan reaches a
        tile, its clusters are the ones most likely to have landed.  Pass
        the same ``q_block`` (and, for a filtered batch, the same ``fspec``
        / ``prune`` / ``t_max``) the search will use: with the batch's
        filters in hand the plan is filter-aware, so clusters the summaries
        prove empty are never read off disk at all — the fetch list shrinks
        with the filter's selectivity.  The jitted plan is shared with the
        search itself, so this costs no extra compilation.
        """
        from repro.core import probes as probes_lib
        from repro.core.engine import (
            plan_fused_tiled,
            resolve_auto_t_max,
            resolve_prune,
        )

        q = queries.shape[0]
        qb = min(q_block, ((q + 7) // 8) * 8)
        if fspec is None:  # no filters known yet: geometry-only plan
            from repro.core.filters import match_all

            fspec = match_all(q, self.spec.n_attrs)
            summ = None
        else:
            summ = resolve_prune(self, prune)
        if t_max == "auto":  # same per-batch resolution the engine applies,
            # so the prefetch plan's width matches the paired search's
            t_max = resolve_auto_t_max(
                summ, self.counts, fspec.lo, fspec.hi, n_probes,
                self.n_clusters,
            )
        if t_max is not None:
            if t_max < n_probes:  # same validation as search_fused_tiled —
                # prefetch must not succeed where the paired search raises
                raise ValueError(f"t_max={t_max} < n_probes={n_probes}")
            t_max = min(t_max, self.n_clusters)
            if summ is None or t_max == n_probes:
                t_max = None
        width = n_probes if t_max is None else t_max
        u_cap = min(qb * width, self.n_clusters)
        cast_dtype = (
            np.dtype(np.float32) if self.quantized
            else np.dtype(self.store_dtype)
        )
        slot_cluster, _, _, _, n_unique, *_ = plan_fused_tiled(
            self.centroids, self.counts, queries, fspec.lo, fspec.hi,
            metric=self.spec.metric, n_probes=n_probes, q_block=qb,
            u_cap=u_cap, cast_dtype=cast_dtype, summaries=summ, t_max=t_max,
        )
        self.prefetch(probes_lib.fetch_order(slot_cluster, n_unique, u_cap))

    # ---- search ----
    def search(self, queries, fspec, *, k: int, n_probes: int,
               q_block: int = 64, v_block: int = 256,
               u_cap: Optional[int] = None, backend: Optional[str] = None,
               prune: str = "auto", t_max=None,
               pipeline: str = "off", pipeline_depth: int = 2,
               blockstore=None, operand_cache: str = "auto",
               device_cache=None,
               termination: Optional[str] = None, epsilon: float = 0.0):
        """Disk-tier filtered search; same contract (and bit-identical ids)
        as the RAM path's ``search_fused_tiled``.  With summaries resident
        (layout v2.1) and ``prune`` active, clusters the filter excludes are
        pruned at plan time and never fetched from disk.  ``pipeline="on"``
        runs the double-buffered executor (scan tile *i* while tile *i+1*'s
        clusters page in) — identical results, overlapped IO."""
        from repro.core.engine import SearchEngine

        eng = SearchEngine(
            self, k=k, n_probes=n_probes, q_block=q_block, v_block=v_block,
            u_cap=u_cap, backend=backend, prune=prune, t_max=t_max,
            pipeline=pipeline, pipeline_depth=pipeline_depth,
            blockstore=blockstore, operand_cache=operand_cache,
            device_cache=device_cache,
            termination=termination, epsilon=epsilon,
        )
        return eng.search(queries, fspec)

    def close(self):
        """Stops the prefetch thread and the fetch worker.  Idempotent."""
        self.blockstore.close()  # shuts the fetch pool down, stops the cache

    # Context-manager support: serve/bench paths that open a disk tier can
    # no longer leak the prefetch thread on an exception path.
    def __enter__(self) -> "DiskIVFIndex":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False
