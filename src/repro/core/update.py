"""Online index updates (paper §4.5) plus deletion/compaction extensions.

The paper's add path: assign the new hybrid vector to its nearest centroid and
append to that centroid's flat list.  Here the append is a batched, jittable
scatter with capacity semantics: vectors that would overflow a full list are
reported back (``n_dropped``) so the caller can trigger a split/rebuild —
billion-scale indexes in production must surface capacity pressure rather than
silently degrade.

Deletion (beyond-paper, needed for real serving): tombstone the slot by
negating its id.  Search masks tombstones via ``validity_mask``; the slot is
reclaimed by :func:`compact_cluster` or a full rebuild.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as kmeans_lib
from repro.core import summaries as summaries_lib
from repro.core.hybrid import make_hybrid
from repro.core.ivf import IVFFlatIndex

Array = jax.Array


@jax.jit
def add_vectors(
    index: IVFFlatIndex,
    core: Array,
    attrs: Array,
    new_ids: Array,
) -> Tuple[IVFFlatIndex, Array]:
    """Appends a batch of vectors (paper §4.5 steps 1-4, batched).

    Returns (index', n_dropped).  Assignment uses the core part only, exactly
    as the paper prescribes (step 2 'calculated from x_new part').
    """
    core, attrs = make_hybrid(index.spec, core, attrs)
    b = core.shape[0]
    a = kmeans_lib.assign(core.astype(jnp.float32), index.centroids)  # [B]

    # Slot for each new row: current count of its cluster + its rank among
    # batch rows that target the same cluster (stable within batch).
    order = jnp.argsort(a)
    a_sorted = jnp.take(a, order)
    starts = jnp.searchsorted(a_sorted, jnp.arange(index.n_clusters), "left")
    rank_sorted = jnp.arange(b) - jnp.take(starts, a_sorted)
    rank = jnp.zeros((b,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    slot = jnp.take(index.counts, a) + rank  # [B]
    ok = slot < index.vpad

    if index.quantized:  # SQ8 index: quantize the incoming rows
        c32 = core.astype(jnp.float32)
        amax = jnp.max(jnp.abs(c32), axis=-1)
        new_scale = jnp.maximum(amax, 1e-12) / 127.0
        core_store = jnp.clip(
            jnp.round(c32 / new_scale[:, None]), -127, 127
        )
    else:
        core_store = core
    vec = index.vectors.at[a, slot].set(
        core_store.astype(index.vectors.dtype), mode="drop"
    )
    att = index.attrs.at[a, slot].set(
        attrs.astype(index.attrs.dtype), mode="drop"
    )
    ids = index.ids.at[a, slot].set(
        jnp.where(ok, new_ids.astype(jnp.int32), -1), mode="drop"
    )
    norms = index.norms
    if norms is not None:
        norms = norms.at[a, slot].set(
            jnp.sum(core.astype(jnp.float32) ** 2, -1), mode="drop"
        )
    scales = index.scales
    if scales is not None:
        scales = scales.at[a, slot].set(new_scale, mode="drop")
    added = jax.ops.segment_sum(
        ok.astype(jnp.int32), a, num_segments=index.n_clusters
    )
    counts = index.counts + added
    n_dropped = b - jnp.sum(added)
    summ = index.summaries
    if summ is not None:
        # Widen intervals / add histogram mass for the landed rows only, so
        # the summaries keep their pruning contract: a cluster is pruned only
        # if it provably holds zero passing rows.
        summ = summaries_lib.widen_for_add(
            summ, a, attrs.astype(jnp.int16), ok
        )
    return (
        dataclasses.replace(
            index, vectors=vec, attrs=att, ids=ids, counts=counts,
            norms=norms, scales=scales, summaries=summ,
        ),
        n_dropped,
    )


@jax.jit
def tombstone(index: IVFFlatIndex, cluster: Array, slot: Array) -> IVFFlatIndex:
    """Marks (cluster, slot) pairs deleted. Ids become -1; counts unchanged
    (the high-water mark still bounds the scan).

    Cluster summaries are deliberately left stale: an interval/histogram that
    still covers a deleted row over-approximates the live set, which is the
    sound direction (never prunes a cluster with a live passing row).  Stale
    summaries cost prune effectiveness, not correctness — track the debt
    with :func:`stale_counts` and pay it down with :func:`compact_stale`
    (or let ``delta.compact_deltas`` fold stale clusters into its next
    republish); each cluster compaction rebuilds its summary row exactly.
    """
    ids = index.ids.at[cluster, slot].set(-1, mode="drop")
    return dataclasses.replace(index, ids=ids)


@jax.jit
def stale_counts(index: IVFFlatIndex) -> Array:
    """Per-cluster staleness: tombstoned rows still under the count
    high-water mark ``[K] int32``.

    These rows burn scan slots and — because :func:`tombstone` leaves
    summaries covering them — keep summary intervals wider than the live
    set, degrading probe pruning after heavy deletes.  Derivable from the
    index itself, so no extra bookkeeping field to persist or desync.
    """
    within = jnp.arange(index.vpad)[None, :] < index.counts[:, None]
    dead = jnp.logical_and(within, index.ids < 0)
    return jnp.sum(dead.astype(jnp.int32), axis=1)


def compact_stale(
    index: IVFFlatIndex, threshold: int = 1
) -> Tuple[IVFFlatIndex, int]:
    """Compacts every cluster holding ``>= threshold`` tombstoned rows.

    Returns ``(index', n_compacted)``.  Each touched cluster's summary row
    is rebuilt exactly (via :func:`compact_cluster`), so prune
    effectiveness recovers after heavy deletes instead of decaying forever.
    """
    import numpy as np

    stale = np.asarray(stale_counts(index))
    touched = np.nonzero(stale >= max(threshold, 1))[0]
    for c in touched:
        index = compact_cluster(index, int(c))
    return index, int(touched.size)


def resync_partitions(index) -> IVFFlatIndex:
    """Rebuilds an attached RAM index's sub-partition rows from their parents.

    ``add_vectors`` / ``tombstone`` / ``compact_cluster`` mutate BASE cluster
    rows only (the planner's id space); the attached sub-partition copies go
    stale until this maintenance pass re-selects each sub's rows with the
    same rule the build used (``partitions.select_sub_rows``), refreshes the
    catalog's per-sub counts/interval summaries, and recomputes the
    entry-row estimates the router ranks by.  Host-side and O(subs · Vpad) —
    the same cost class as ``compact_stale``.  Returns the resynced index
    (no-op for an unpartitioned one).
    """
    import numpy as np

    cat = getattr(index, "partitions", None)
    if cat is None or cat.n_subs == 0:
        return index
    from repro.core import partitions as partitions_lib

    k = cat.n_base
    vectors = np.asarray(index.vectors).copy()
    attrs = np.asarray(index.attrs).copy()
    ids = np.asarray(index.ids).copy()
    counts = np.asarray(index.counts).copy()
    norms = None if index.norms is None else np.asarray(index.norms).copy()
    scales = (None if index.scales is None
              else np.asarray(index.scales).copy())
    sub_counts = np.asarray(cat.sub_counts, np.int32).copy()
    sub_amin = np.asarray(cat.sub_amin, np.int16).copy()
    sub_amax = np.asarray(cat.sub_amax, np.int16).copy()
    for p in range(cat.n_subs):
        c = int(cat.parent[p])
        rows = partitions_lib.select_sub_rows(
            attrs[c], ids[c], int(counts[c]),
            np.asarray(cat.sub_lo[p]), np.asarray(cat.sub_hi[p]),
        )
        n = int(rows.size)
        g = k + p
        vectors[g] = 0
        attrs[g] = 0
        ids[g] = -1
        if n:
            vectors[g, :n] = vectors[c, rows]
            attrs[g, :n] = attrs[c, rows]
            ids[g, :n] = ids[c, rows]
        if norms is not None:
            norms[g] = 0
            if n:
                norms[g, :n] = norms[c, rows]
        if scales is not None:
            scales[g] = 0
            if n:
                scales[g, :n] = scales[c, rows]
        counts[g] = n
        sub_counts[p] = n
        if n:
            sub_amin[p] = attrs[g, :n].min(axis=0)
            sub_amax[p] = attrs[g, :n].max(axis=0)
        else:
            sub_amin[p] = summaries_lib.ATTR_MAX
            sub_amax[p] = summaries_lib.ATTR_MIN
    mem = np.asarray(cat.members, np.int64)
    entry_rows = np.where(
        mem >= 0,
        sub_counts[np.clip(mem - k, 0, None)].astype(np.int64),
        counts[:k].astype(np.int64)[None, :],
    ).sum(axis=1)
    new_cat = dataclasses.replace(
        cat, entry_rows=entry_rows, sub_counts=sub_counts,
        sub_amin=sub_amin, sub_amax=sub_amax,
    )
    out = dataclasses.replace(
        index,
        vectors=jnp.asarray(vectors), attrs=jnp.asarray(attrs),
        ids=jnp.asarray(ids), counts=jnp.asarray(counts),
        norms=None if norms is None else jnp.asarray(norms),
        scales=None if scales is None else jnp.asarray(scales),
    )
    out.partitions = new_cat
    return out


@jax.jit
def compact_cluster(index: IVFFlatIndex, cluster: int) -> IVFFlatIndex:
    """Reclaims tombstoned slots of one cluster by stable-compacting live rows."""
    live = index.ids[cluster] >= 0  # [Vpad]
    # stable order: live rows first, preserving slot order
    key = jnp.where(live, jnp.arange(index.vpad), index.vpad + jnp.arange(index.vpad))
    perm = jnp.argsort(key)
    vec = index.vectors.at[cluster].set(jnp.take(index.vectors[cluster], perm, 0))
    att = index.attrs.at[cluster].set(jnp.take(index.attrs[cluster], perm, 0))
    ids_row = jnp.take(index.ids[cluster], perm, 0)
    n_live = jnp.sum(live.astype(jnp.int32))
    ids_row = jnp.where(jnp.arange(index.vpad) < n_live, ids_row, -1)
    ids = index.ids.at[cluster].set(ids_row)
    norms = index.norms
    if norms is not None:
        norms = norms.at[cluster].set(jnp.take(norms[cluster], perm, 0))
    scales = index.scales
    if scales is not None:  # SQ8 rows move with their dequantization scale
        scales = scales.at[cluster].set(jnp.take(scales[cluster], perm, 0))
    counts = index.counts.at[cluster].set(n_live)
    summ = index.summaries
    if summ is not None:
        # Compaction is the tightening point: tombstoned rows are gone from
        # the flat list, so this cluster's summary row is rebuilt exactly
        # (intervals shrink back, histogram mass drops the dead rows).
        summ = summaries_lib.rebuild_cluster(summ, att[cluster], ids_row,
                                             cluster)
    return dataclasses.replace(
        index, vectors=vec, attrs=att, ids=ids, counts=counts, norms=norms,
        scales=scales, summaries=summ,
    )
