"""Cross-batch device-resident cluster-block cache (heat-aware LRU).

The disk tier's per-batch operand cache (PR 5) stops paying the BlockStore
for a cluster more than once *per batch* — but the next batch pays host
assembly and the host→device copy all over again, even when serving traffic
probes the same hot clusters for minutes at a time.  Generation-tagged
cluster blocks (PR 7, storage layout v3) supply the missing piece: a sound
invalidation key.  This module keeps each hot cluster's *fully-assembled,
device-put* operand block resident across batches, keyed on
``(cluster_id, gen)`` exactly like every host cache layer:

  * a **device hit** costs a dict lookup — no disk read, no peer RPC, no
    host assembly, no H2D transfer.  The scan's ``[S, Vpad, ...]`` blocks
    are composed on device by stacking the per-cluster entries (a
    device-to-device copy), padded exactly like
    :func:`repro.core.blockstore.assemble_blocks`, so results are
    bit-identical to the uncached path.
  * a **miss** fetches through the BlockStore as before; the fetched
    record is device-put once and becomes the cache entry — the same
    arrays the current batch scans, so caching adds no extra copy.
  * eviction is **heat-weighted LRU** under a byte budget: among the
    least-recently-used window, the entry with the lowest observed probe
    heat goes first.  The heat signal is the ClusterCache's per-cluster
    probe counter when available (``heat_fn``), falling back to the device
    cache's own request counts.
  * invalidation mirrors the host caches' precision contract: a republish
    bumps the rewritten clusters' generations, and
    :meth:`DeviceBlockCache.invalidate_below` (called from
    ``SearchEngine.refresh()``) drops exactly those ``(cid, gen)`` entries
    — untouched clusters stay resident.  Lookups also carry the batch's
    expected minimum generations, so a stale device block can never be
    scanned even before the refresh lands.

The per-batch operand cache is the in-batch special case of this cache:
when a ``DeviceBlockCache`` is active the engine routes all reuse —
within a batch and across batches — through it.

Two granularities share the byte budget:

  * **per-cluster entries** (the LRU above) serve partial overlap — any
    tile reusing *some* of a previous tile's clusters skips their fetch
    and H2D, paying only the device-side stack;
  * a **composed-tile memo** serves exact repeats — session traffic that
    probes the same cluster set again gets the previous ``[S, Vpad, ...]``
    blocks back verbatim (zero work, not even a stack).  A memoized tile
    is keyed on its members' ``(cluster_id, gen)`` pairs plus the slot
    count, so the generation plane invalidates it exactly like the
    entries it was composed from.  Tiles are derived data: they are
    admitted only into budget the entries aren't using, and evict
    (plain LRU) before any entry does.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockstore import BlockSpec, Record, record_gen

Array = jax.Array


def record_nbytes(spec: BlockSpec) -> int:
    """Device bytes of one cluster's operand entry under ``spec``."""
    v = spec.vpad
    n = v * spec.dim * spec.store_dtype.itemsize   # vectors
    n += v * spec.n_attrs * 2                      # attrs (int16)
    n += v * 4                                     # ids (int32)
    if spec.has_norms:
        n += v * 4
    if spec.quantized:
        n += v * 4
    return n


@dataclasses.dataclass
class DeviceEntry:
    """One cluster's operand block, resident on device."""

    gen: int
    vectors: Array                 # [Vpad, D] store dtype
    attrs: Array                   # [Vpad, M] int16
    ids: Array                     # [Vpad] int32
    norms: Optional[Array]         # [Vpad] f32 (l2 only)
    scales: Optional[Array]        # [Vpad] f32 (SQ8 only)


class DeviceBlockCache:
    """``(cluster_id, gen)``-keyed LRU of device-resident operand blocks.

    Thread-safe: the pipelined executor's fetch worker and the sync path
    (and ``refresh()`` on the serving thread) share one instance.  Entries
    handed out by :meth:`get_many` stay valid after a concurrent eviction —
    eviction only drops the cache's reference, never the arrays a batch in
    flight is composing from.
    """

    # eviction scans this many LRU-oldest entries and evicts the coldest —
    # a recently-probed cluster that merely aged to the LRU tail survives
    # over a genuinely cold one
    HEAT_WINDOW = 8

    def __init__(self, spec: BlockSpec, budget_bytes: int,
                 heat_fn: Optional[Callable[[int], float]] = None):
        if budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.spec = spec
        self.budget_bytes = int(budget_bytes)
        self.entry_nbytes = record_nbytes(spec)
        self.capacity_records = self.budget_bytes // self.entry_nbytes
        self.heat_fn = heat_fn
        self._entries: "OrderedDict[int, DeviceEntry]" = OrderedDict()
        self._requests: Dict[int, int] = {}   # fallback heat: cid → lookups
        # composed-tile memo: (cids tuple, s) → (gens tuple, blocks tuple)
        self._tiles: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._tile_bytes = 0
        self._pad: Optional[DeviceEntry] = None
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0
        self.invalidations = 0
        self.tile_hits = 0
        self.tile_puts = 0

    # ---- lookup ----
    def get_many(self, cids: Sequence[int],
                 gens: Optional[np.ndarray] = None
                 ) -> Tuple[Dict[int, DeviceEntry], List[int]]:
        """Resident entries for ``cids`` + the miss list (first-need order
        preserved).  ``gens`` aligns with ``cids`` and carries the batch's
        expected *minimum* generations: an entry below its minimum was
        superseded by a republish — it is dropped (counted as an
        invalidation) and reported as a miss, never served."""
        hits: Dict[int, DeviceEntry] = {}
        missing: List[int] = []
        with self._lock:
            for j, c in enumerate(cids):
                cid = int(c)
                self._requests[cid] = self._requests.get(cid, 0) + 1
                e = self._entries.get(cid)
                if e is not None and gens is not None \
                        and e.gen < int(gens[j]):
                    del self._entries[cid]
                    self.invalidations += 1
                    e = None
                if e is None:
                    self.misses += 1
                    missing.append(cid)
                else:
                    self.hits += 1
                    self._entries.move_to_end(cid)
                    hits[cid] = e
        return hits, missing

    def filter_missing(self, cids: np.ndarray,
                       gens: Optional[np.ndarray] = None) -> np.ndarray:
        """The subset of ``cids`` the store must be asked for (pure peek —
        no stats, no LRU touch; the authoritative lookup happens at
        assembly time via :meth:`get_many`)."""
        with self._lock:
            keep = []
            for j, c in enumerate(cids):
                e = self._entries.get(int(c))
                if e is None or (gens is not None and e.gen < int(gens[j])):
                    keep.append(j)
        return np.asarray(cids)[keep]

    # ---- composed-tile memo ----
    def get_tile(self, cids: Sequence[int], s: int,
                 gens: Optional[np.ndarray] = None) -> Optional[Tuple]:
        """The memoized ``[S, Vpad, ...]`` blocks for this exact cluster
        set, or None.  A memo whose members fell below the batch's expected
        minimum generations is dropped (counted as an invalidation), never
        served.  A hit counts every member as a device hit — the same
        blocks avoided the same fetches."""
        key = (tuple(int(c) for c in cids), int(s))
        with self._lock:
            hit = self._tiles.get(key)
            if hit is None:
                return None
            tile_gens, blocks = hit
            if gens is not None and any(
                g < int(gens[j]) for j, g in enumerate(tile_gens)
            ):
                self._drop_tile(key)
                self.invalidations += 1
                return None
            self._tiles.move_to_end(key)
            self.tile_hits += 1
            self.hits += len(key[0])
            return blocks

    def put_tile(self, cids: Sequence[int], s: int,
                 entries: Sequence[DeviceEntry], blocks: Tuple) -> None:
        """Memoizes a freshly composed tile.  Tiles only occupy budget the
        per-cluster entries aren't using (they are derived data — droppable
        without losing the fetch/H2D savings), LRU-evicting older tiles to
        fit; a tile that still doesn't fit simply isn't memoized."""
        nbytes = int(s) * self.entry_nbytes
        key = (tuple(int(c) for c in cids), int(s))
        with self._lock:
            room = (self.budget_bytes
                    - len(self._entries) * self.entry_nbytes)
            if nbytes > room:
                return
            while self._tile_bytes + nbytes > room and self._tiles:
                self._drop_tile(next(iter(self._tiles)))
                self.evictions += 1
            if self._tile_bytes + nbytes > room:
                return
            if key in self._tiles:
                self._drop_tile(key)
            self._tiles[key] = (tuple(e.gen for e in entries), blocks)
            self._tile_bytes += nbytes
            self.tile_puts += 1

    def _drop_tile(self, key) -> None:
        """Removes one memoized tile (lock held)."""
        del self._tiles[key]
        self._tile_bytes -= key[1] * self.entry_nbytes

    def _shrink_tiles_to_room(self) -> None:
        """Evicts LRU tiles until the memo fits in the budget the entries
        left over (lock held) — run after every entry admission so tiles
        always yield to entries."""
        room = self.budget_bytes - len(self._entries) * self.entry_nbytes
        while self._tile_bytes > room and self._tiles:
            self._drop_tile(next(iter(self._tiles)))
            self.evictions += 1

    # ---- insert ----
    def put_records(self, recs: Dict[int, Record]
                    ) -> Dict[int, DeviceEntry]:
        """Device-puts fetched host records and admits them (evicting the
        coldest LRU-tail entries while over budget).  Returns the device
        entries — the caller composes the batch's blocks from these, so a
        record crosses to device exactly once whether or not it survives
        eviction."""
        out: Dict[int, DeviceEntry] = {}
        for cid, rec in recs.items():
            cid = int(cid)
            gen = record_gen(rec)
            with self._lock:
                old = self._entries.get(cid)
            if old is not None and old.gen >= gen:
                out[cid] = old
                continue
            e = self._entry_from_record(gen, rec)
            out[cid] = e
            if self.capacity_records == 0:
                continue  # budget below one entry: compose-only, no admit
            with self._lock:
                self._entries[cid] = e
                self._entries.move_to_end(cid)
                self.puts += 1
                while len(self._entries) > self.capacity_records:
                    self._evict_one()
                self._shrink_tiles_to_room()
        return out

    def _entry_from_record(self, gen: int, rec: Record) -> DeviceEntry:
        # sub-partition records (layout v4) arrive shorter than spec.vpad;
        # compose() stacks fixed-height entries, so pad here with the same
        # fill assemble_blocks uses (zeros, ids −1, unit scales) — padded
        # compositions stay bit-identical to the host path
        rows = int(rec["ids"].shape[0])
        vpad = self.spec.vpad
        if rows < vpad:
            rec = dict(rec)
            pad = vpad - rows
            rec["vectors"] = np.concatenate(
                [rec["vectors"],
                 np.zeros((pad,) + rec["vectors"].shape[1:],
                          rec["vectors"].dtype)], axis=0)
            rec["attrs"] = np.concatenate(
                [rec["attrs"],
                 np.zeros((pad, rec["attrs"].shape[1]),
                          rec["attrs"].dtype)], axis=0)
            rec["ids"] = np.concatenate(
                [rec["ids"], np.full(pad, -1, rec["ids"].dtype)], axis=0)
            if self.spec.has_norms:
                rec["norms"] = np.concatenate(
                    [rec["norms"], np.zeros(pad, rec["norms"].dtype)],
                    axis=0)
            if self.spec.quantized:
                rec["scales"] = np.concatenate(
                    [rec["scales"], np.ones(pad, rec["scales"].dtype)],
                    axis=0)
        return DeviceEntry(
            gen=gen,
            vectors=jax.device_put(rec["vectors"]),
            attrs=jax.device_put(rec["attrs"]),
            ids=jax.device_put(rec["ids"]),
            norms=(jax.device_put(rec["norms"])
                   if self.spec.has_norms else None),
            scales=(jax.device_put(rec["scales"])
                    if self.spec.quantized else None),
        )

    def _evict_one(self):
        """Drops the coldest of the ``HEAT_WINDOW`` LRU-oldest entries
        (lock held)."""
        window = []
        for cid in self._entries:           # insertion order = LRU order
            window.append(cid)
            if len(window) >= self.HEAT_WINDOW:
                break
        victim = min(window, key=self._heat)
        del self._entries[victim]
        self.evictions += 1

    def _heat(self, cid: int) -> float:
        if self.heat_fn is not None:
            try:
                return float(self.heat_fn(cid))
            except Exception:
                pass
        return float(self._requests.get(cid, 0))

    # ---- invalidation ----
    def invalidate_below(self, gens: np.ndarray) -> int:
        """Drops every entry whose generation is below the published vector
        — exactly the clusters a republish rewrote.  Returns the count."""
        g = np.asarray(gens)
        dropped = 0
        with self._lock:
            for cid in [c for c, e in self._entries.items()
                        if c < g.shape[0] and e.gen < int(g[c])]:
                del self._entries[cid]
                dropped += 1
            for key in [k for k, (tgens, _) in self._tiles.items()
                        if any(c < g.shape[0] and tg < int(g[c])
                               for c, tg in zip(k[0], tgens))]:
                self._drop_tile(key)
                dropped += 1
            self.invalidations += dropped
        return dropped

    def clear(self) -> int:
        with self._lock:
            n = len(self._entries) + len(self._tiles)
            self._entries.clear()
            self._tiles.clear()
            self._tile_bytes = 0
        return n

    # ---- composition ----
    def _pad_entry(self) -> DeviceEntry:
        """The never-matching pad row — identical values to
        ``assemble_blocks``'s unfilled slots (zero vectors, ids −1, unit
        scales), so padded device compositions match the host path bitwise."""
        if self._pad is None:
            spec = self.spec
            self._pad = DeviceEntry(
                gen=-1,
                vectors=jnp.zeros((spec.vpad, spec.dim),
                                  dtype=spec.store_dtype),
                attrs=jnp.zeros((spec.vpad, spec.n_attrs), jnp.int16),
                ids=jnp.full((spec.vpad,), -1, jnp.int32),
                norms=(jnp.zeros((spec.vpad,), jnp.float32)
                       if spec.has_norms else None),
                scales=(jnp.ones((spec.vpad,), jnp.float32)
                        if spec.quantized else None),
            )
        return self._pad

    def compose(self, entries: Sequence[DeviceEntry], s: int) -> Tuple:
        """Stacks per-cluster entries (first-need order) into the scan's
        ``[S, Vpad, ...]`` blocks — a device-side copy, no host assembly,
        no H2D.  Pads to ``s`` slots exactly like ``assemble_blocks``."""
        rows = list(entries)
        if len(rows) < s:
            rows.extend([self._pad_entry()] * (s - len(rows)))
        vectors = jnp.stack([e.vectors for e in rows])
        attrs = jnp.stack([e.attrs for e in rows])
        ids = jnp.stack([e.ids for e in rows])
        norms = (jnp.stack([e.norms for e in rows])
                 if self.spec.has_norms else None)
        scales = (jnp.stack([e.scales for e in rows])
                  if self.spec.quantized else None)
        return vectors, attrs, ids, norms, scales

    # ---- observability ----
    @property
    def resident_bytes(self) -> int:
        return len(self._entries) * self.entry_nbytes + self._tile_bytes

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return dict(
                hits=self.hits,
                misses=self.misses,
                puts=self.puts,
                evictions=self.evictions,
                invalidations=self.invalidations,
                tile_hits=self.tile_hits,
                tile_puts=self.tile_puts,
                entries=len(self._entries),
                tiles=len(self._tiles),
                resident_bytes=(len(self._entries) * self.entry_nbytes
                                + self._tile_bytes),
                capacity_records=self.capacity_records,
                budget_bytes=self.budget_bytes,
                hit_rate=self.hit_rate(),
            )
