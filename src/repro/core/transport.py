"""Deadline-bounded block transport: the wire half of the fetch layer.

PR-5's ``SocketTransport`` assumed a healthy peer: one persistent
connection, a 30 s default timeout that a single slow response could hold
for the whole store, and mid-stream failures (peer died between the frame
header and the payload) surfacing as struct/npz decode garbage two layers
up.  Production filtered-search systems (PipeANN's SSD path, the
attribute-filtering study's tail-latency analysis) treat the fetch tier as
an unreliable device behind a deadline-aware client; this module is that
client:

  * every request carries its own deadline (``timeout_s``) — a peer that
    stalls costs one bounded wait, never a hung batch;
  * failures are *typed*: any short read, reset, refusal, or corrupt
    payload raises :class:`TransportError` (a ``ConnectionError`` subclass,
    so pre-existing callers keep working) and the connection is discarded —
    a socket in an unknown mid-stream state is never reused;
  * reconnect-on-broken-pipe with capped exponential backoff + jitter
    (``retries``/``backoff_s``/``backoff_cap_s``);
  * a small connection pool bounds in-flight requests per peer
    (``max_inflight``) so concurrent engines sharing one peer neither
    serialize behind a single socket nor stampede it;
  * request coalescing: concurrent fetches through one transport issue one
    wire fetch per cluster id — followers wait on the leader's in-flight
    holder instead of re-crossing the wire;
  * ``ping()`` — a zero-id request/response round trip — is the health
    layer's lightweight active probe.

The server half (:class:`BlockStoreServer`) and the in-process
:class:`LoopbackTransport` live here too; ``repro.core.blockstore``
re-exports everything for backwards compatibility.

Wire format (both directions): ``[u64 big-endian length][payload]``.
Request payload = raw little-endian int64 cluster ids (empty = ping);
a first value of ``-2`` marks the gen-stamped request variant
``[-2, cid0, gen0, cid1, gen1, ...]`` — each cluster id travels with the
minimum generation the caller will accept, so a peer that lags a
republish reopens its reader instead of answering stale (servers predating
the sentinel see ids only and are caught by the client-side gen check).
Response payload = npz of ``{cid}:{field}`` arrays, never pickled.
"""

from __future__ import annotations

import io
import random
import socket
import struct
import threading
import time
from typing import Dict, List, Optional

import numpy as np

Record = Dict[str, np.ndarray]


class TransportError(ConnectionError):
    """A fetch failed at the transport layer (connect refused, peer closed
    mid-frame, deadline exceeded, corrupt payload).  Subclasses
    ``ConnectionError`` so callers written against the PR-5 transport keep
    catching it; the health layer treats every instance as a passive
    failure signal."""


class TransportTimeout(TransportError):
    """The per-request deadline expired (connect, send, or receive)."""


_FRAME = struct.Struct(">Q")  # 8-byte big-endian payload length

# Frames beyond this are a protocol violation (a desynced stream decoding
# garbage as a length), not a plausible response — fail fast instead of
# trying to recv an exabyte.
_MAX_FRAME = 1 << 40


def _send_frame(sock: socket.socket, payload: bytes):
    sock.sendall(_FRAME.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError(
                f"peer closed mid-frame ({len(buf)}/{n} bytes)"
            )
        buf.extend(chunk)
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _FRAME.unpack(_recv_exact(sock, _FRAME.size))
    if n > _MAX_FRAME:
        raise TransportError(f"frame length {n} exceeds protocol maximum "
                             f"(desynced stream?)")
    return _recv_exact(sock, n)


def _encode_records(recs: Dict[int, Record]) -> bytes:
    """npz-encodes records as ``{cid}:{field}`` arrays — dtype/shape travel
    in the npz header, and decoding never unpickles objects."""
    buf = io.BytesIO()
    np.savez(buf, **{
        f"{cid}:{field}": arr
        for cid, rec in recs.items() for field, arr in rec.items()
    })
    return buf.getvalue()


def _decode_records(payload: bytes) -> Dict[int, Record]:
    out: Dict[int, Record] = {}
    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        for key in z.files:
            cid_s, field = key.split(":", 1)
            out.setdefault(int(cid_s), {})[field] = z[key]
    return out


class LoopbackTransport:
    """In-process peer: requests go straight to the peer store.  The
    test/bench transport — and the honest model of a pod talking to its own
    co-located store."""

    def __init__(self, store):
        self.store = store

    def fetch(self, cluster_ids, gens=None) -> Dict[int, Record]:
        if gens is None:
            return self.store.get(cluster_ids)
        return self.store.get(cluster_ids, gens=gens)

    def ping(self):
        """Active probe: a zero-id fetch (fails iff the store does)."""
        self.store.get(np.asarray([], np.int64))

    def stats(self) -> dict:
        return self.store.stats()

    def close(self):
        pass


class BlockStoreServer:
    """Serves a store's blocks over a length-prefixed socket protocol.

    Wire format (both directions): ``[u64 length][payload]``.  Request
    payload = raw little-endian int64 cluster ids (an empty request is a
    ping and gets an empty npz back); response payload = npz of
    ``{cid}:{field}`` arrays.  One thread per connection; ``port=0`` binds
    an ephemeral port (read it back from ``.port``).

    ``close()`` is idempotent and reliably unblocks the accepter: besides
    closing the listening socket (which wakes ``accept()`` on most
    platforms but is allowed not to), it pokes a throwaway connection at
    the listener so a blocked ``accept()`` always returns and sees the
    stop flag.
    """

    def __init__(self, store, host: str = "127.0.0.1", port: int = 0):
        self.store = store
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.host, self.port = self._sock.getsockname()
        self._stopped = threading.Event()
        self._closed = False
        self._close_lock = threading.Lock()
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._accepter = threading.Thread(target=self._accept_loop,
                                          daemon=True)
        self._accepter.start()

    def _accept_loop(self):
        while not self._stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listening socket closed by close()
            if self._stopped.is_set():
                conn.close()
                return
            with self._conns_lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        try:
            while not self._stopped.is_set():
                try:
                    req = _recv_frame(conn)
                    raw = np.frombuffer(req, dtype="<i8")
                    if raw.size and raw[0] == -2:
                        # gen-stamped request: [-2, cid0, gen0, ...]
                        body = raw[1:]
                        recs = self.store.get(body[0::2], gens=body[1::2])
                    else:
                        recs = self.store.get(raw)
                    _send_frame(conn, _encode_records(recs))
                except (ConnectionError, OSError):
                    # client went away (or close() yanked the socket from
                    # under a mid-request handler) — just drop the conn
                    return
        finally:
            conn.close()
            # drop the tracked handle: long-lived peers see reconnecting
            # clients, and dead sockets must not accumulate until close()
            with self._conns_lock:
                self._conns.discard(conn)

    def close(self):
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        self._stopped.set()
        # Wake a blocked accept() even where closing the listener doesn't:
        # a throwaway connection makes accept() return, and the loop's stop
        # check drops it.  Refusal just means the listener is already dead.
        try:
            poke = socket.create_connection((self.host, self.port),
                                            timeout=0.5)
            poke.close()
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        self._accepter.join(timeout=5)


class SocketTransport:
    """Pooled, deadline-bounded client half of the block protocol.

    Per-request deadline (``timeout_s``), reconnect-on-broken-pipe with
    capped exponential backoff + jitter, at most ``max_inflight`` wire
    requests in flight (a small connection pool — concurrent engines
    sharing a peer fan out without stampeding it), and request coalescing:
    cluster ids another thread is already fetching through this transport
    are not re-requested — the follower waits on the leader's holder.

    Every failure mode raises :class:`TransportError` (deadlines raise
    :class:`TransportTimeout`), and the implicated connection is discarded:
    a socket that timed out or short-read is mid-stream in an unknown
    state, and reusing it is how PR-5 turned one truncated payload into a
    cascade of npz decode errors.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0, *,
                 connect_timeout: Optional[float] = None,
                 max_inflight: int = 4, retries: int = 1,
                 backoff_s: float = 0.05, backoff_cap_s: float = 2.0,
                 jitter: float = 0.5, coalesce: bool = True, seed: int = 0):
        self.host, self.port, self.timeout = host, port, timeout
        self.connect_timeout = connect_timeout or timeout
        self.max_inflight = max(int(max_inflight), 1)
        self.retries = max(int(retries), 0)
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.jitter = jitter
        self.coalesce = coalesce
        self._rng = random.Random(seed)
        self._sem = threading.BoundedSemaphore(self.max_inflight)
        self._idle: List[socket.socket] = []
        self._lock = threading.Lock()
        self._closed = False
        # coalescing: (cid, min_gen) -> [Event, record | exception | None]
        # — keyed on the expected generation too, so a follower that needs
        # a republished block never adopts a pre-republish leader's answer
        self._pending: Dict[tuple, list] = {}
        self._co_lock = threading.Lock()
        # counters (read under/over _lock; exact totals don't matter)
        self.requests = 0
        self.blocks = 0
        self.connects = 0
        self.reconnects = 0
        self.retried = 0
        self.timeouts = 0
        self.errors = 0
        self.coalesced = 0

    @property
    def addr(self) -> str:
        return f"{self.host}:{self.port}"

    # ---- connection pool ----
    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise TransportError(f"transport to {self.addr} is closed")
            if self._idle:
                return self._idle.pop()
            first = self.connects == 0
        try:
            sock = socket.create_connection(
                (self.host, self.port), timeout=self.connect_timeout
            )
        except OSError as e:
            with self._lock:
                self.errors += 1
            if isinstance(e, (socket.timeout, TimeoutError)):
                raise TransportTimeout(
                    f"connect to {self.addr} timed out") from e
            raise TransportError(f"connect to {self.addr} failed: {e}") from e
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._lock:
            self.connects += 1
            if not first:
                self.reconnects += 1
        return sock

    def _checkin(self, sock: socket.socket):
        with self._lock:
            if not self._closed and len(self._idle) < self.max_inflight:
                self._idle.append(sock)
                return
        sock.close()

    @staticmethod
    def _discard(sock: socket.socket):
        try:
            sock.close()
        except OSError:
            pass

    # ---- one wire round trip ----
    def _wire_once(self, payload_req: bytes, n_blocks: int
                   ) -> Dict[int, Record]:
        if not self._sem.acquire(timeout=self.timeout):
            with self._lock:
                self.timeouts += 1
            raise TransportTimeout(
                f"{self.addr}: {self.max_inflight} requests already in "
                f"flight for {self.timeout}s"
            )
        try:
            sock = self._checkout()
            try:
                sock.settimeout(self.timeout)
                _send_frame(sock, payload_req)
                payload = _recv_frame(sock)
                recs = _decode_records(payload) if payload else {}
            except BaseException as e:
                # mid-stream state is unknowable: never reuse this socket
                self._discard(sock)
                with self._lock:
                    self.errors += 1
                if isinstance(e, (socket.timeout, TimeoutError)):
                    with self._lock:
                        self.timeouts += 1
                    raise TransportTimeout(
                        f"{self.addr}: no response within "
                        f"{self.timeout}s") from e
                if isinstance(e, TransportError):
                    raise
                if isinstance(e, (ConnectionError, OSError, struct.error,
                                  ValueError, KeyError, EOFError)):
                    # short read / reset / corrupt npz — one typed error
                    raise TransportError(
                        f"{self.addr}: fetch failed: {e}") from e
                raise
            self._checkin(sock)
            with self._lock:
                self.requests += 1
                self.blocks += n_blocks
            return recs
        finally:
            self._sem.release()

    def _fetch_retry(self, cids: List[int],
                     gens: Optional[List[int]] = None) -> Dict[int, Record]:
        if gens is None:
            payload_req = np.asarray(cids, "<i8").tobytes()
        else:
            inter = np.empty(1 + 2 * len(cids), "<i8")
            inter[0] = -2  # gen-stamped request sentinel
            inter[1::2] = cids
            inter[2::2] = gens
            payload_req = inter.tobytes()
        delay = self.backoff_s
        last: Optional[TransportError] = None
        for attempt in range(self.retries + 1):
            if attempt:
                with self._lock:
                    self.retried += 1
                time.sleep(delay * (1.0 + self.jitter * self._rng.random()))
                delay = min(delay * 2.0, self.backoff_cap_s)
            try:
                return self._wire_once(payload_req, len(cids))
            except TransportError as e:
                last = e
        assert last is not None
        raise last

    # ---- public ----
    def fetch(self, cluster_ids, gens=None) -> Dict[int, Record]:
        flat = np.asarray(cluster_ids, np.int64).reshape(-1)
        cids = [int(c) for c in flat]
        if not cids:
            return {}
        exp: Optional[Dict[int, int]] = None
        if gens is not None:
            exp = {int(c): int(g)
                   for c, g in zip(flat, np.asarray(gens).reshape(-1))}

        def want(cid: int) -> int:
            return 0 if exp is None else exp.get(cid, 0)

        def sub_gens(sub: List[int]) -> Optional[List[int]]:
            return None if exp is None else [want(c) for c in sub]

        if not self.coalesce:
            return self._fetch_retry(cids, sub_gens(cids))
        mine: List[int] = []
        follow: Dict[int, list] = {}
        with self._co_lock:
            for cid in dict.fromkeys(cids):  # unique, first-need order
                key = (cid, want(cid))
                holder = self._pending.get(key)
                if holder is None:
                    self._pending[key] = holder = [threading.Event(), None]
                    mine.append(cid)
                else:
                    follow[cid] = holder
        out: Dict[int, Record] = {}
        if mine:
            try:
                recs = self._fetch_retry(mine, sub_gens(mine))
            except BaseException as e:
                with self._co_lock:
                    for cid in mine:
                        holder = self._pending.pop((cid, want(cid)), None)
                        if holder is not None:
                            holder[1] = e
                            holder[0].set()
                raise
            with self._co_lock:
                for cid in mine:
                    holder = self._pending.pop((cid, want(cid)), None)
                    if holder is not None:
                        holder[1] = recs.get(cid)
                        holder[0].set()
            out.update(recs)
        # the leader's own deadline + backoff budget bounds this wait; the
        # slack keeps a racing leader's bookkeeping from tripping us early
        budget = (self.retries + 1) * self.timeout + 2 * self.backoff_cap_s
        for cid, holder in follow.items():
            got = holder[0].wait(timeout=budget + 5.0)
            rec = holder[1] if got else None
            if rec is None or isinstance(rec, BaseException):
                # leader failed (or stalled): fetch this id ourselves so one
                # bad leader doesn't fail every coalesced follower
                out.update(self._fetch_retry([cid], sub_gens([cid])))
            else:
                with self._lock:
                    self.coalesced += 1
                out[cid] = rec
        return out

    def ping(self):
        """Lightweight active probe: one empty request/response round trip
        (no retries — the health layer decides how often to knock)."""
        self._wire_once(b"", 0)

    def stats(self) -> dict:
        with self._lock:
            return dict(
                kind="socket", addr=self.addr, requests=self.requests,
                blocks=self.blocks, connects=self.connects,
                reconnects=self.reconnects, retries=self.retried,
                timeouts=self.timeouts, errors=self.errors,
                coalesced=self.coalesced,
            )

    def close(self):
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for sock in idle:
            self._discard(sock)
