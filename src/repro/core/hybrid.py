"""Hybrid vectors (paper §3.5, §4.1).

A *hybrid vector* ``h_i = [x_i || a_i]`` concatenates a dense core embedding
``x_i ∈ R^D`` with a discrete attribute row ``a_i ∈ Z^M``.  The paper stores
both in one float row; on TPU we keep the two halves in their natural dtypes
(core: bf16/f32 for the MXU, attributes: int16 for VREG compare ops) but treat
them as one logical record throughout the index.  ``HybridSpec`` is the single
source of truth for that layout.

Attribute values follow the paper's encoding (§3.4, §5.1): fixed-size integers
in [-32768, 32767] — categorical attributes are dictionary-encoded, numeric
attributes are binned/rescaled into the int16 range by the caller (helpers
below).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

ATTR_MIN = -32768
ATTR_MAX = 32767

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HybridSpec:
    """Logical layout of a hybrid vector.

    Attributes:
      dim: D, dimensionality of the dense core embedding.
      n_attrs: M, number of discrete filter attributes.
      core_dtype: storage dtype of the core half (bf16 on TPU).
      attr_dtype: storage dtype of the attribute half (int16 per the paper).
      metric: "dot" (cosine on normalized inputs, maximized) or "l2"
        (Euclidean, internally converted to a maximized score).
    """

    dim: int
    n_attrs: int
    core_dtype: jnp.dtype = jnp.bfloat16
    attr_dtype: jnp.dtype = jnp.int16
    metric: str = "dot"

    def __post_init__(self):
        if self.dim <= 0:
            raise ValueError(f"dim must be positive, got {self.dim}")
        if self.n_attrs < 0:
            raise ValueError(f"n_attrs must be >= 0, got {self.n_attrs}")
        if self.metric not in ("dot", "l2"):
            raise ValueError(f"metric must be 'dot' or 'l2', got {self.metric!r}")

    @property
    def hybrid_dim(self) -> int:
        """D + M, the paper's hybrid dimensionality (778 in the case study)."""
        return self.dim + self.n_attrs


def make_hybrid(
    spec: HybridSpec, core: Array, attrs: Array
) -> Tuple[Array, Array]:
    """Validates and packs a batch of (core, attrs) into index storage dtypes.

    This is the paper's Fig. 1 construction.  We do not physically concatenate
    (mixed dtypes); the pair travels together through the index.
    """
    core = jnp.asarray(core)
    attrs = jnp.asarray(attrs)
    if core.ndim != 2 or core.shape[-1] != spec.dim:
        raise ValueError(f"core must be [N, {spec.dim}], got {core.shape}")
    if attrs.ndim != 2 or attrs.shape[-1] != spec.n_attrs:
        raise ValueError(f"attrs must be [N, {spec.n_attrs}], got {attrs.shape}")
    if core.shape[0] != attrs.shape[0]:
        raise ValueError(
            f"core and attrs disagree on N: {core.shape[0]} vs {attrs.shape[0]}"
        )
    return core.astype(spec.core_dtype), attrs.astype(spec.attr_dtype)


def concat_hybrid(spec: HybridSpec, core: Array, attrs: Array) -> Array:
    """Literal ``[x || a]`` concatenation (paper §4.1), for interop/debug.

    Returns a float array [N, D+M]; the attribute half is cast to the core
    dtype exactly as the paper stores it (float16 in §5.1).
    """
    core, attrs = make_hybrid(spec, core, attrs)
    return jnp.concatenate(
        [core, attrs.astype(spec.core_dtype)], axis=-1
    )


def split_hybrid(spec: HybridSpec, hybrid: Array) -> Tuple[Array, Array]:
    """Inverse of :func:`concat_hybrid`."""
    if hybrid.shape[-1] != spec.hybrid_dim:
        raise ValueError(
            f"hybrid must have trailing dim {spec.hybrid_dim}, got {hybrid.shape}"
        )
    core = hybrid[..., : spec.dim].astype(spec.core_dtype)
    attrs = jnp.round(hybrid[..., spec.dim :].astype(jnp.float32)).astype(
        spec.attr_dtype
    )
    return core, attrs


def encode_numeric_attr(
    values: np.ndarray, lo: float, hi: float
) -> np.ndarray:
    """Adaptive-binning helper (paper §3.4): rescale a numeric column into int16.

    Linearly maps [lo, hi] onto [ATTR_MIN, ATTR_MAX]; out-of-range values are
    clipped.  The same (lo, hi) must be used to encode query ranges.
    """
    if hi <= lo:
        raise ValueError(f"need hi > lo, got [{lo}, {hi}]")
    x = (np.asarray(values, dtype=np.float64) - lo) / (hi - lo)
    x = np.clip(x, 0.0, 1.0)
    return np.round(x * (ATTR_MAX - ATTR_MIN) + ATTR_MIN).astype(np.int16)


def encode_categorical_attr(
    values: np.ndarray, vocabulary: dict
) -> np.ndarray:
    """Dictionary-encode a categorical column into int16 codes."""
    if len(vocabulary) > (ATTR_MAX - ATTR_MIN + 1):
        raise ValueError("categorical vocabulary exceeds int16 code space")
    out = np.empty(len(values), dtype=np.int16)
    for i, v in enumerate(values):
        out[i] = vocabulary[v] + ATTR_MIN
    return out


def l2_normalize(x: Array, eps: float = 1e-12) -> Array:
    """Normalizes rows so dot == cosine (CLIP embeddings in the case study)."""
    n = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), -1, keepdims=True))
    return (x / jnp.maximum(n, eps)).astype(x.dtype)
