"""Per-cluster attribute summaries: the filter-aware side of the planner.

The paper's core claim is that filtering belongs *inside* the index (§3.4,
§4.3), yet a geometry-only probe plan discovers post-hoc — after paying the
full HBM (RAM tier) or mmap-fetch (disk tier) cost — that a streamed cluster
contains zero rows passing the query's filter.  SIEVE's collection-of-indexes
and the attribute-filtering experimental study both observe that cheap
per-partition attribute metadata excludes most partitions under selective
filters.  This module is that metadata for the hybrid IVF index:

  * ``amin/amax [K, M] int16`` — closed per-cluster, per-attribute intervals
    covering every *live* row.  A DNF term whose interval is disjoint from a
    cluster's interval in ANY attribute cannot match any row of that cluster.
  * ``hist [K, M, B] int32`` — fixed-width per-attribute count histograms over
    the global attribute range (``edges_lo/edges_hi [M] int16``).  Two uses:
    a *sound* zero-mass refinement of the interval test (a term whose covered
    bins hold zero rows matches nothing, even inside the interval), and an
    expected-passing-count estimate that ranks surviving probes.

Both tests are conservative by construction: they may only *fail to prune*
(stale-wide intervals after tombstones, partial-bin overcounting), never
prune a cluster that still contains a passing row — so a pruned plan returns
bit-identical ids/scores to an unpruned one.  Maintenance mirrors that
contract: ``add`` widens intervals and adds histogram mass, ``tombstone``
leaves summaries stale (conservative), ``compact`` rebuilds the cluster's
row exactly (see ``core/update.py``).

Summaries are tiny — ``K·M·(2 + 4B)`` bytes plus edges — and always resident:
the disk tier counts them against ``resident_budget_bytes`` and consults them
*before* building the batch's fetch list, which is the whole point.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import ATTR_MAX, ATTR_MIN

Array = jax.Array

DEFAULT_N_BINS = 16


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusterSummaries:
    """Resident per-cluster attribute metadata (shapes above).

    An empty cluster carries the void interval ``[ATTR_MAX, ATTR_MIN]`` and
    zero histogram mass, so it can never match any term — consistent with
    ``counts == 0`` clusters being unprobeable in the centroid top-k.
    """

    amin: Array  # [K, M] int16
    amax: Array  # [K, M] int16
    hist: Array  # [K, M, B] int32 — live-row counts per fixed-width bin
    edges_lo: Array  # [M] int16 — global bin-range lower edge per attribute
    edges_hi: Array  # [M] int16 — global bin-range upper edge per attribute

    @property
    def n_clusters(self) -> int:
        return self.amin.shape[0]

    @property
    def n_attrs(self) -> int:
        return self.amin.shape[1]

    @property
    def n_bins(self) -> int:
        return self.hist.shape[-1]

    def nbytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize
            for a in (self.amin, self.amax, self.hist,
                      self.edges_lo, self.edges_hi)
        )


def attr_bins(attrs: Array, edges_lo: Array, edges_hi: Array,
              n_bins: int) -> Array:
    """Bin index of each attribute value, clipped into ``[0, n_bins)``.

    Values outside the global edge range land in the edge bins — sound for
    the zero-mass test (their mass is visible to any term reaching that edge
    bin, and irrelevant to terms that do not).
    """
    lo = edges_lo.astype(jnp.int32)
    span = jnp.maximum(edges_hi.astype(jnp.int32) - lo + 1, 1)
    b = ((attrs.astype(jnp.int32) - lo) * n_bins) // span
    return jnp.clip(b, 0, n_bins - 1)


def _hist_scatter(bins: Array, live: Array, n_bins: int) -> Array:
    """[..., M] bin indices + [...] live mask → [..., M, B] count histogram.

    Scatter-add over the input rows — peak memory is the *input* size, never
    the ``input × B`` one-hot a comparison-based reduction would build
    (ruinous at billion-row build time).
    """
    *lead, vpad, m = bins.shape
    flat_bins = bins.reshape(-1, vpad, m)
    flat_live = live.reshape(-1, vpad)
    r = flat_bins.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(r)[:, None, None], flat_bins.shape
    )
    cols = jnp.broadcast_to(
        jnp.arange(m)[None, None, :], flat_bins.shape
    )
    add = jnp.broadcast_to(
        flat_live[..., None].astype(jnp.int32), flat_bins.shape
    )
    hist = jnp.zeros((r, m, n_bins), jnp.int32).at[
        rows, cols, flat_bins
    ].add(add)
    return hist.reshape(*lead, m, n_bins)


@jax.jit
def _cluster_rows(attrs: Array, live: Array, edges_lo: Array,
                  edges_hi: Array, hist_width: Array
                  ) -> Tuple[Array, Array, Array]:
    """(amin, amax, hist) over the live rows of ``attrs [K, Vpad, M]``.

    ``hist_width`` is a zeros ``[B]`` template carrying the static bin count
    (jit re-specializes per width).
    """
    n_bins = hist_width.shape[0]
    a_hi = jnp.where(live[..., None], attrs, ATTR_MAX)
    a_lo = jnp.where(live[..., None], attrs, ATTR_MIN)
    amin = jnp.min(a_hi, axis=1).astype(jnp.int16)
    amax = jnp.max(a_lo, axis=1).astype(jnp.int16)
    bins = attr_bins(attrs, edges_lo, edges_hi, n_bins)  # [K, Vpad, M]
    hist = _hist_scatter(bins, live, n_bins)  # [K, M, B]
    return amin, amax, hist


def build_summaries(
    attrs: Array,
    ids: Array,
    *,
    n_bins: int = DEFAULT_N_BINS,
    edges: Optional[Tuple[Array, Array]] = None,
) -> ClusterSummaries:
    """Builds summaries from the index's flat lists (index-build time).

    Args:
      attrs: [K, Vpad, M] int16 attribute lists.
      ids:   [K, Vpad] int32 — rows with ``ids < 0`` (pads, tombstones) are
             excluded.
      n_bins: static histogram width B.
      edges: optional fixed ``(edges_lo, edges_hi)`` per-attribute bin range;
             default = the observed global min/max (so bins spend no width on
             values that never occur).  Pass the old edges when rebuilding a
             subset of clusters so histograms stay comparable.
    """
    live = ids >= 0  # [K, Vpad]
    if edges is None:
        any_live = jnp.any(live)
        a_hi = jnp.where(live[..., None], attrs, ATTR_MAX)
        a_lo = jnp.where(live[..., None], attrs, ATTR_MIN)
        edges_lo = jnp.where(
            any_live, jnp.min(a_hi, axis=(0, 1)), ATTR_MIN
        ).astype(jnp.int16)
        edges_hi = jnp.where(
            any_live, jnp.max(a_lo, axis=(0, 1)), ATTR_MAX
        ).astype(jnp.int16)
    else:
        edges_lo = jnp.asarray(edges[0], jnp.int16)
        edges_hi = jnp.asarray(edges[1], jnp.int16)
    amin, amax, hist = _cluster_rows(
        attrs, live, edges_lo, edges_hi, jnp.zeros((n_bins,), jnp.int32)
    )
    return ClusterSummaries(
        amin=amin, amax=amax, hist=hist, edges_lo=edges_lo, edges_hi=edges_hi
    )


def rebuild_cluster(summaries: ClusterSummaries, attrs_row: Array,
                    ids_row: Array, cluster) -> ClusterSummaries:
    """Recomputes one cluster's summary row exactly (compaction, rebuilds).

    Keeps the existing global edges so the refreshed histogram stays
    comparable with its neighbours.
    """
    live = ids_row >= 0  # [Vpad]
    a_hi = jnp.where(live[:, None], attrs_row, ATTR_MAX)
    a_lo = jnp.where(live[:, None], attrs_row, ATTR_MIN)
    amin = jnp.min(a_hi, axis=0).astype(jnp.int16)
    amax = jnp.max(a_lo, axis=0).astype(jnp.int16)
    bins = attr_bins(attrs_row, summaries.edges_lo, summaries.edges_hi,
                     summaries.n_bins)  # [Vpad, M]
    hist = _hist_scatter(bins[None], live[None], summaries.n_bins)[0]  # [M,B]
    return dataclasses.replace(
        summaries,
        amin=summaries.amin.at[cluster].set(amin),
        amax=summaries.amax.at[cluster].set(amax),
        hist=summaries.hist.at[cluster].set(hist),
    )


def widen_for_add(summaries: ClusterSummaries, assignments: Array,
                  attrs_new: Array, ok: Array) -> ClusterSummaries:
    """Folds a batch of appended rows into the summaries (``add_vectors``).

    Intervals widen via scatter-min/max and histogram mass is added at each
    row's bin; rows with ``ok == False`` (capacity drops) are excluded so the
    summaries keep describing exactly the rows the index holds.
    """
    b, m = attrs_new.shape
    a_hi = jnp.where(ok[:, None], attrs_new, ATTR_MAX).astype(jnp.int16)
    a_lo = jnp.where(ok[:, None], attrs_new, ATTR_MIN).astype(jnp.int16)
    amin = summaries.amin.at[assignments].min(a_hi, mode="drop")
    amax = summaries.amax.at[assignments].max(a_lo, mode="drop")
    bins = attr_bins(attrs_new, summaries.edges_lo, summaries.edges_hi,
                     summaries.n_bins)  # [B_rows, M]
    hist = summaries.hist.at[
        assignments[:, None], jnp.arange(m)[None, :], bins
    ].add(ok[:, None].astype(jnp.int32), mode="drop")
    return dataclasses.replace(summaries, amin=amin, amax=amax, hist=hist)


def pad_clusters(summaries: ClusterSummaries, k_new: int) -> ClusterSummaries:
    """Pads the cluster axis with void (never-matching) summary rows."""
    k, m = summaries.amin.shape
    if k_new < k:
        raise ValueError(f"cannot shrink K: {k} -> {k_new}")
    if k_new == k:
        return summaries
    dk = k_new - k
    return dataclasses.replace(
        summaries,
        amin=jnp.concatenate(
            [summaries.amin, jnp.full((dk, m), ATTR_MAX, jnp.int16)], 0
        ),
        amax=jnp.concatenate(
            [summaries.amax, jnp.full((dk, m), ATTR_MIN, jnp.int16)], 0
        ),
        hist=jnp.concatenate(
            [summaries.hist,
             jnp.zeros((dk, m, summaries.n_bins), jnp.int32)], 0
        ),
    )


# ---------------------------------------------------------------------------
# Per-cluster geometric score bounds (bound-driven early termination)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusterBounds:
    """Resident per-cluster geometric statistics for per-probe score bounds.

    ``radius[c]`` is the max distance from cluster ``c``'s centroid to any
    live *stored* row (SQ8 rows measured after dequantization — the scan
    scores the stored representation, so the bound must cover it, not the
    original floats).  ``slack[c]`` is the max of ``‖x̂‖² − norms_row`` over
    live rows: the l2 kernel scores ``2q·x̂ − norms_row``, and the geometric
    bound on ``2q·x̂ − ‖x̂‖²`` converts to the kernel's score space by adding
    this slack.  Both are conservative the same way the attribute summaries
    are: tombstones leave them stale-wide (a sound over-estimate), a
    compaction rebuilds the row exactly, and an empty cluster carries
    ``radius == slack == 0`` (vacuous — the probe is unprobeable anyway).

    Tiny (``8·K`` bytes) and always resident, like the summaries: the
    terminated executor consults them per batch before any flat list is
    scanned.
    """

    radius: Array  # [K] f32 — max ‖x̂ − c‖ over live stored rows
    slack: Array   # [K] f32 — max (‖x̂‖² − norms_row) over live rows (l2)

    @property
    def n_clusters(self) -> int:
        return self.radius.shape[0]

    def nbytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize for a in (self.radius, self.slack)
        )


@jax.jit
def _bounds_rows(x32: Array, live: Array, centroids: Array,
                 norms: Optional[Array]) -> Tuple[Array, Array]:
    """(radius, slack) over the live rows of ``x32 [K, Vpad, D]`` f32."""
    diff = x32 - centroids.astype(jnp.float32)[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # [K, Vpad]
    # d2 >= 0, so masking dead rows to 0 keeps the max sound and gives an
    # empty cluster radius 0 without a separate any-live branch
    radius = jnp.sqrt(jnp.max(jnp.where(live, d2, 0.0), axis=1))
    if norms is None:
        slack = jnp.zeros_like(radius)
    else:
        sl = jnp.sum(x32 * x32, axis=-1) - norms.astype(jnp.float32)
        any_live = jnp.any(live, axis=1)
        slack = jnp.where(
            any_live, jnp.max(jnp.where(live, sl, -jnp.inf), axis=1), 0.0
        )
    return radius, slack


def _stored_f32(vectors: Array, scales: Optional[Array]) -> Array:
    """The rows as the kernel scores them: dequantized SQ8 / f32-cast."""
    x32 = jnp.asarray(vectors).astype(jnp.float32)
    if scales is not None:
        x32 = x32 * jnp.asarray(scales, jnp.float32)[..., None]
    return x32


def build_bounds(centroids: Array, vectors: Array, ids: Array,
                 norms: Optional[Array] = None,
                 scales: Optional[Array] = None) -> ClusterBounds:
    """Builds the per-cluster score-bound statistics from the flat lists.

    Args mirror the index's resident arrays: ``vectors [K, Vpad, D]`` (store
    dtype; int8 codes with ``scales`` under SQ8), ``ids [K, Vpad]`` (rows
    with ``ids < 0`` excluded), ``norms [K, Vpad]`` for l2.
    """
    live = jnp.asarray(ids) >= 0
    radius, slack = _bounds_rows(
        _stored_f32(vectors, scales), live, jnp.asarray(centroids),
        None if norms is None else jnp.asarray(norms),
    )
    return ClusterBounds(radius=radius, slack=slack)


def rebuild_cluster_bounds(bounds: ClusterBounds, centroid_row: Array,
                           vectors_row: Array, ids_row: Array,
                           norms_row: Optional[Array],
                           scales_row: Optional[Array],
                           cluster) -> ClusterBounds:
    """Recomputes one cluster's bound row exactly (compaction, rebuilds)."""
    radius, slack = _bounds_rows(
        _stored_f32(vectors_row, scales_row)[None],
        (jnp.asarray(ids_row) >= 0)[None],
        jnp.asarray(centroid_row)[None],
        None if norms_row is None else jnp.asarray(norms_row)[None],
    )
    return dataclasses.replace(
        bounds,
        radius=bounds.radius.at[cluster].set(radius[0]),
        slack=bounds.slack.at[cluster].set(slack[0]),
    )


def can_match(summaries: ClusterSummaries, lo: Array, hi: Array) -> Array:
    """[Q, K] bool — can any live row of cluster k pass query q's filter?

    Branch-free and jit-friendly (the planner calls it inside its jitted plan
    stage).  A cluster "can match" iff SOME DNF term overlaps its summary in
    EVERY attribute, where per-attribute overlap requires both

      * interval intersection: ``max(term_lo, amin) <= min(term_hi, amax)``
        (this form is void-term safe — a voided term's ``lo > hi`` can never
        intersect anything), and
      * nonzero histogram mass over the term's covered bins — a sound
        refinement: partial bins overcount, so zero mass proves zero rows.

    False guarantees zero passing rows (prunable); True guarantees nothing.
    """
    amin = summaries.amin.astype(jnp.int32)[None, None]  # [1, 1, K, M]
    amax = summaries.amax.astype(jnp.int32)[None, None]
    tlo = lo.astype(jnp.int32)[:, :, None, :]  # [Q, F, 1, M]
    thi = hi.astype(jnp.int32)[:, :, None, :]
    overlap = jnp.maximum(tlo, amin) <= jnp.minimum(thi, amax)  # [Q, F, K, M]

    n_bins = summaries.n_bins
    # cumulative mass per cluster/attr: cdf[..., b] = rows in bins < b
    cdf = jnp.concatenate(
        [jnp.zeros_like(summaries.hist[..., :1]),
         jnp.cumsum(summaries.hist, axis=-1)], axis=-1
    )  # [K, M, B+1]
    blo = attr_bins(lo, summaries.edges_lo, summaries.edges_hi, n_bins)
    bhi = attr_bins(hi, summaries.edges_lo, summaries.edges_hi, n_bins)
    # mass of bins blo..bhi inclusive = cdf[bhi+1] - cdf[blo], gathered per
    # (cluster, attr) at each term's bin bounds: [Q, F, K, M]
    hi_mass = jnp.take_along_axis(
        cdf[None, None], (bhi + 1)[:, :, None, :, None], axis=-1
    )[..., 0]
    lo_mass = jnp.take_along_axis(
        cdf[None, None], blo[:, :, None, :, None], axis=-1
    )[..., 0]
    nonzero = (hi_mass - lo_mass) > 0
    per_term = jnp.all(jnp.logical_and(overlap, nonzero), axis=-1)  # [Q,F,K]
    return jnp.any(per_term, axis=1)  # [Q, K]


def expected_passing(summaries: ClusterSummaries, lo: Array, hi: Array,
                     counts: Array) -> Array:
    """[Q, K] f32 — histogram-mass estimate of rows passing each filter.

    Per term and attribute, the covered-bin mass (partial bins included, so
    this over-estimates) is turned into a passing fraction; attributes are
    combined under independence and terms are summed (clipped to the live
    count).  Only a *ranking* signal — pruning soundness never rides on it.
    """
    n_bins = summaries.n_bins
    cdf = jnp.concatenate(
        [jnp.zeros_like(summaries.hist[..., :1]),
         jnp.cumsum(summaries.hist, axis=-1)], axis=-1
    )
    total = jnp.maximum(cdf[..., -1], 1)  # [K, M] live rows (per-attr alias)
    blo = attr_bins(lo, summaries.edges_lo, summaries.edges_hi, n_bins)
    bhi = attr_bins(hi, summaries.edges_lo, summaries.edges_hi, n_bins)
    hi_mass = jnp.take_along_axis(
        cdf[None, None], (bhi + 1)[:, :, None, :, None], axis=-1
    )[..., 0]
    lo_mass = jnp.take_along_axis(
        cdf[None, None], blo[:, :, None, :, None], axis=-1
    )[..., 0]
    frac = (hi_mass - lo_mass).astype(jnp.float32) / total[None, None]
    void = (lo > hi).any(axis=-1)  # [Q, F] — voided spare terms pass nothing
    per_term = jnp.where(
        void[:, :, None], 0.0, jnp.prod(frac, axis=-1)
    )  # [Q, F, K]
    est = jnp.sum(per_term, axis=1) * counts[None, :].astype(jnp.float32)
    return jnp.minimum(est, counts[None, :].astype(jnp.float32))
