"""Serving loop: request batching, deadlines, straggler policy (paper §5.4).

The paper flags concurrent searches as a bottleneck for its single-host
design and suggests asynchronous request–reply patterns; this layer is that
pattern for the pod runtime:

  * requests (query vector + FilterSpec row) accumulate in a queue;
  * a micro-batcher drains up to ``max_batch`` requests or waits at most
    ``max_wait_s`` (padding the tail batch to the compiled static Q so the
    jitted search never recompiles);
  * per-batch deadline: chips reported unhealthy by the health tracker are
    excluded from the merge through ``shard_ok`` — the hierarchical top-k is
    an associative monoid, so partial merges return sound (lower-recall)
    results instead of timing out the whole batch;
  * health tracking is EWMA-on-failure with probation, mirroring what a real
    cluster's control plane feeds in.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import FilterSpec, match_all

Array = jax.Array


def make_fused_search_fn(index, *, k: int, n_probes: int, q_block: int = 64,
                         v_block: int = 256, backend: Optional[str] = None,
                         resident_budget_bytes: Optional[int] = None,
                         prune: str = "auto",
                         t_max=None,
                         pipeline: str = "auto",
                         pipeline_depth: int = 2,
                         adaptive_u_cap: Optional[bool] = None,
                         operand_cache: str = "auto",
                         u_cap_ladder: str = "pow2",
                         cache_shards: int = 1,
                         cache_transport: str = "loopback",
                         cache_l1_records: int = 64,
                         cache_fallback: bool = True,
                         peer_timeout_s: float = 30.0,
                         peer_retries: int = 1,
                         breaker_kwargs: Optional[dict] = None,
                         probe_interval_s: Optional[float] = None,
                         delta_budget_mb: Optional[float] = None,
                         delta_quantize: str = "auto",
                         device_cache_mb: Optional[float] = None,
                         termination: Optional[str] = None,
                         epsilon: float = 0.0,
                         partitions: str = "auto",
                         ) -> Callable:
    """The batched server's default search step: the search engine.

    Returns ``search_fn(queries, fspec, shard_ok) -> (scores, ids)`` wired
    to one long-lived :class:`repro.core.engine.SearchEngine` — the
    micro-batcher's whole purpose is assembling a query batch whose probes
    overlap, which is exactly what the engine's per-tile probe dedup
    converts into saved HBM traffic.  ``shard_ok`` is accepted (and ignored)
    so the same server drives the single-host and pod paths.

    ``index`` selects the tier: an in-RAM :class:`IVFFlatIndex`, an already
    open :class:`repro.core.disk.DiskIVFIndex`, or a checkpoint directory
    path (opened disk-resident under ``resident_budget_bytes``, with
    hot-cluster pinning).  Disk-tier batches run through the same kernel via
    the cache's pager and return identical results; the open index is
    exposed as ``search_fn.index`` (and the engine as ``search_fn.engine``)
    so callers can read ``resident_bytes()`` / cache / pipeline stats.

    Engine knobs: ``prune`` selects filter-aware probe pruning (``"auto"``
    = use the index's cluster attribute summaries when present); ``t_max``
    enables adaptive probe widening; ``pipeline`` (``"auto"`` = on for the
    disk tier) double-buffers per-tile cluster fetches against the scan —
    identical results, IO hidden behind compute; ``adaptive_u_cap``
    (default: on) provisions each batch's slot table from the observed
    post-prune unique-cluster counts in power-of-two buckets instead of the
    unpruned worst case — selective filters scan small tables, with at most
    ``len(buckets)`` scan compilations ever.

    Fetch-layer knobs: ``cache_shards > 1`` builds a consistent-hash
    :class:`~repro.core.blockstore.ShardedBlockStore` over that many peer
    caches of the same checkpoint (one index copy per pod) and routes the
    engine's fetch stage through it; ``cache_transport`` selects the peer
    transport (``"loopback"`` in-process, ``"socket"`` the length-prefixed
    wire protocol behind a local server per peer — the pod-topology
    rehearsal).  ``operand_cache`` fetches each cluster block through the
    store once per batch, letting the batch's tiles share the records;
    ``u_cap_ladder="fine"`` adds ×1.5 bucket
    midpoints.  The sharded store is exposed as ``search_fn.blockstore``
    (per-node stats via ``.stats()``) and torn down by
    ``search_fn.close()``.

    Resilience knobs (sharded fetch only): ``cache_fallback`` (default on)
    wires the index's own full-copy pager in as the availability floor —
    an unhealthy peer's clusters are served from local disk, results
    bit-identical, and the batch never fails; ``peer_timeout_s`` /
    ``peer_retries`` bound each socket fetch; ``breaker_kwargs`` tune the
    per-peer circuit breakers; ``probe_interval_s`` starts the active
    health probe.  ``search_fn.degraded()`` reports whether any peer
    circuit is currently open (the server marks responses accordingly).

    Live updates: ``delta_budget_mb`` attaches a RAM
    :class:`~repro.core.delta.DeltaTier` to a disk-tier index — new
    vectors land via ``search_fn.delta.add`` and are searchable in the
    very next batch; deletes via ``search_fn.delta.tombstone`` mask cold
    hits immediately.  ``search_fn.refresh()`` adopts a background
    ``delta.compact_deltas`` republish between batches (commits the
    folded delta rows out of RAM and flips the generation vector — the
    gen-keyed caches invalidate exactly the rewritten clusters).
    Requires a layout-v3 checkpoint (generation-tagged records).

    ``device_cache_mb`` attaches a cross-batch device-resident block cache
    (:class:`~repro.core.devicecache.DeviceBlockCache`) to a disk-tier
    index: hot clusters' fully-assembled operand blocks stay on device
    across batches under the byte budget, keyed ``(cluster_id, gen)`` and
    evicted by observed probe heat — repeat traffic pays no disk read, no
    peer RPC, no host assembly and no H2D transfer, and a republish
    invalidates exactly the rewritten entries via the same ``refresh()``
    handshake.  Stats under ``metrics()``'s ``device_cache.*`` keys; the
    cache is exposed as ``search_fn.device_cache``.

    ``termination`` selects the engine's recall-bounded execution mode:
    ``"exact"`` reorders each tile's probes best-bound-first and drops
    probes that provably cannot enter the top-k (bit-identical results,
    fewer segments scanned on selective streams); ``"bounded"`` with
    ``epsilon`` > 0 additionally drops probes whose probability of
    contributing a top-k hit is ≤ ε (recall ≥ 1−ε in expectation).
    ``delta_quantize="on"`` stores delta-tier rows SQ8-quantized even over
    a float cold tier (~4× capacity per MB; scores agree to quantization
    tolerance, and the next republish dequantizes the rows back into the
    cold tier's dtype).

    ``partitions`` controls filter-specialized sub-partition routing on a
    layout-v4 index (``"auto"`` = route when the index carries a partition
    catalog, ``"off"`` = always scan the flat layout, ``"on"`` = require a
    catalog): routed queries scan the narrowest sub-partition whose
    predicate subsumes their filter — bit-identical results, a fraction of
    the rows.
    """
    from repro.core import blockstore as blockstore_lib
    from repro.core.disk import DiskIVFIndex
    from repro.core.engine import SearchEngine

    owns_index = isinstance(index, str)
    if owns_index:
        index = DiskIVFIndex.open(
            index, resident_budget_bytes=resident_budget_bytes
        )
    delta = None
    if delta_budget_mb is not None:
        from repro.core import delta as delta_lib
        from repro.core import storage

        if not isinstance(index, DiskIVFIndex):
            raise ValueError(
                "delta_budget_mb needs a disk-tier index (a checkpoint "
                "path or an open DiskIVFIndex) — the RAM tier mutates in "
                "place via core.update instead"
            )
        if index.man["layout"] < 3:
            raise storage.GenerationMismatchError(
                f"delta_budget_mb needs a layout-v3 checkpoint "
                f"(generation-tagged cluster records); this one is layout "
                f"v{index.man['layout']} — re-save it with "
                f"storage.save_index(index, dir)"
            )
        delta = delta_lib.DeltaTier.for_index(
            index, delta_budget_mb, quantize=delta_quantize
        )
        index.delta = delta
    store = None
    if cache_shards > 1:
        if not isinstance(index, DiskIVFIndex):
            raise ValueError(
                "cache_shards > 1 needs a disk-tier index (a checkpoint "
                "path or an open DiskIVFIndex) — the RAM tier has no fetch "
                "stage to shard"
            )
        # per-node cache capacity: split the index's own cache budget so N
        # peers together hold what one local cache would have
        cap = max(index.cache.capacity_records // cache_shards, 1)
        # the pod's own full-copy pager (which otherwise idles while the
        # ring serves) is the availability floor: peer failures fetch
        # through it instead of failing the batch — zero extra memory
        store = blockstore_lib.open_sharded(
            index.directory, n_nodes=cache_shards,
            transport=cache_transport, capacity_records=cap,
            l1_records=cache_l1_records,
            fallback=index.blockstore if cache_fallback else None,
            timeout_s=peer_timeout_s, retries=peer_retries,
            breaker_kwargs=breaker_kwargs,
            probe_interval_s=probe_interval_s,
        )
    device_cache = None
    if device_cache_mb is not None:
        from repro.core.devicecache import DeviceBlockCache

        if not isinstance(index, DiskIVFIndex):
            raise ValueError(
                "device_cache_mb needs a disk-tier index (a checkpoint "
                "path or an open DiskIVFIndex) — the RAM tier's operands "
                "are already resident"
            )
        device_cache = DeviceBlockCache(
            blockstore_lib.BlockSpec.from_manifest(index.man),
            int(device_cache_mb * 2**20),
            heat_fn=index.cache.probe_heat,
        )
        index.device_cache = device_cache
    engine = SearchEngine(
        index, k=k, n_probes=n_probes, q_block=q_block, v_block=v_block,
        backend=backend, prune=prune, t_max=t_max, pipeline=pipeline,
        pipeline_depth=pipeline_depth, adaptive_u_cap=adaptive_u_cap,
        blockstore=store, operand_cache=operand_cache,
        u_cap_ladder=u_cap_ladder, device_cache=device_cache,
        termination=termination, epsilon=epsilon,
        partitions=partitions,
    )

    def search_fn(queries, fspec, shard_ok=None):
        del shard_ok  # single host; the pod path lives in core/distributed
        res = engine.search(queries, fspec)
        return res.scores, res.ids

    def close():
        engine.close()
        if store is not None:
            store.close()
        # only tear down an index this factory opened (str path) — a
        # caller-provided DiskIVFIndex may back other search_fns
        if owns_index:
            index.close()

    search_fn.index = index
    search_fn.engine = engine
    search_fn.blockstore = engine.blockstore
    search_fn.degraded = (
        lambda: bool(getattr(engine.blockstore, "degraded", False))
    )
    search_fn.delta = delta
    search_fn.device_cache = device_cache
    search_fn.refresh = engine.refresh
    search_fn.metrics = engine.metrics
    search_fn.metrics_text = engine.metrics_text
    search_fn.close = close
    return search_fn


@dataclasses.dataclass
class Request:
    query: np.ndarray  # [D]
    lo: np.ndarray  # [F, M] int16
    hi: np.ndarray  # [F, M]
    future: "queue.Queue"  # delivery channel (size 1)
    t_enqueue: float = 0.0


@dataclasses.dataclass
class Response:
    scores: np.ndarray  # [k]
    ids: np.ndarray  # [k]
    latency_s: float
    batched_with: int
    degraded: bool  # a shard was dropped from the merge, or the fetch
    #                 layer served around an open peer circuit (the latter
    #                 keeps results bit-identical — it is a health signal,
    #                 not a recall warning)


class ShardHealth:
    """EWMA failure tracker per shard; drops a shard from merges while its
    failure score exceeds the threshold, then lets it back in (probation)."""

    def __init__(self, n_shards: int, threshold: float = 0.5,
                 decay: float = 0.8):
        self.n = n_shards
        self.threshold = threshold
        self.decay = decay
        self.score = np.zeros(n_shards)

    def report(self, shard: int, failed: bool):
        self.score[shard] = self.decay * self.score[shard] + (
            (1 - self.decay) if failed else 0.0
        )

    def ok_mask(self) -> np.ndarray:
        return self.score <= self.threshold

    @property
    def degraded(self) -> bool:
        return bool((~self.ok_mask()).any())


class SearchServer:
    """Micro-batching server around a compiled ``search_fn``.

    search_fn(queries [Q, D], fspec, shard_ok [S]) -> (scores [Q,k], ids [Q,k])
    with STATIC Q — the server pads tail batches.
    """

    def __init__(
        self,
        search_fn: Callable,
        *,
        batch_size: int,
        dim: int,
        n_attrs: int,
        n_terms: int,
        n_shards: int,
        max_wait_s: float = 0.005,
    ):
        self.search_fn = search_fn
        self.batch_size = batch_size
        self.dim = dim
        self.n_attrs = n_attrs
        self.n_terms = n_terms
        self.max_wait_s = max_wait_s
        self.health = ShardHealth(n_shards)
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._refresh = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.stats = dict(batches=0, requests=0, degraded_batches=0,
                          total_latency_s=0.0, refreshes=0)

    # ---- client side ----
    def submit(self, query: np.ndarray, fspec_row: Optional[Tuple] = None
               ) -> "queue.Queue":
        if fspec_row is None:
            wild = match_all(1, self.n_attrs, self.n_terms)
            lo, hi = np.asarray(wild.lo[0]), np.asarray(wild.hi[0])
        else:
            lo, hi = fspec_row
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self._q.put(Request(np.asarray(query), np.asarray(lo),
                            np.asarray(hi), fut, time.monotonic()))
        return fut

    def search_blocking(self, query, fspec_row=None, timeout=60.0) -> Response:
        return self.submit(query, fspec_row).get(timeout=timeout)

    # ---- server side ----
    def start(self):
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def stop(self):
        self._stop.set()
        if self._worker:
            self._worker.join(timeout=30)

    def _drain(self) -> List[Request]:
        """Assembles the next micro-batch.

        The batch deadline is anchored at the *oldest request's enqueue
        time* (``t_enqueue + max_wait_s``), not at drain start: a request
        that aged in the queue while the previous batch was being served,
        or a slow trickle of arrivals each landing just inside the old
        per-``get`` timeout, can no longer stretch batch assembly.  Once
        the deadline passes, only requests already sitting in the queue are
        swept in (they cost no extra latency) and the batch is served.
        """
        batch: List[Request] = []
        deadline = None
        while len(batch) < self.batch_size and not self._stop.is_set():
            now = time.monotonic()
            if deadline is not None and now >= deadline:
                break
            timeout = self.max_wait_s if deadline is None else deadline - now
            try:
                req = self._q.get(timeout=max(timeout, 1e-4))
            except queue.Empty:
                if batch:
                    break
                continue
            batch.append(req)
            if deadline is None:
                deadline = req.t_enqueue + self.max_wait_s
        # Deadline hit or batch full: take whatever is already queued
        # (non-blocking) — free batching, zero added wait.
        while batch and len(batch) < self.batch_size:
            try:
                batch.append(self._q.get_nowait())
            except queue.Empty:
                break
        return batch

    def request_refresh(self):
        """Asks the serving loop to adopt a republished checkpoint.

        Safe from any thread (a background ``compact_deltas`` caller, an
        operator signal): the flag is drained *between* batches, so the
        generation flip never races a batch mid-flight — the atomic
        no-drain handshake of the hot/cold tier.  A no-op for search_fns
        without a ``refresh`` attribute.
        """
        self._refresh.set()

    def _maybe_refresh(self):
        if not self._refresh.is_set():
            return
        self._refresh.clear()
        refresh = getattr(self.search_fn, "refresh", None)
        if callable(refresh):
            refresh()
            self.stats["refreshes"] += 1

    def _run(self):
        while not self._stop.is_set():
            self._maybe_refresh()
            batch = self._drain()
            if not batch:
                continue
            self._serve(batch)

    def _serve(self, batch: List[Request]):
        b = len(batch)
        qsz = self.batch_size
        queries = np.zeros((qsz, self.dim), np.float32)
        lo = np.zeros((qsz, self.n_terms, self.n_attrs), np.int16)
        hi = np.zeros((qsz, self.n_terms, self.n_attrs), np.int16)
        for i, r in enumerate(batch):
            queries[i] = r.query
            lo[i] = r.lo
            hi[i] = r.hi
        ok = self.health.ok_mask()
        fspec = FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))
        t0 = time.monotonic()
        scores, ids = self.search_fn(
            jnp.asarray(queries), fspec, jnp.asarray(ok)
        )
        scores = np.asarray(scores)
        ids = np.asarray(ids)
        t1 = time.monotonic()
        # degraded = a shard dropped from the merge OR the fetch layer
        # routing around an open peer circuit (results stay bit-identical
        # in the latter case; clients still deserve the signal)
        store_degraded = getattr(self.search_fn, "degraded", None)
        degraded = self.health.degraded or bool(
            store_degraded() if callable(store_degraded) else False
        )
        self.stats["batches"] += 1
        self.stats["requests"] += b
        self.stats["degraded_batches"] += int(degraded)
        self.stats["total_latency_s"] += t1 - t0
        for i, r in enumerate(batch):
            r.future.put(
                Response(
                    scores=scores[i],
                    ids=ids[i],
                    latency_s=t1 - r.t_enqueue,
                    batched_with=b,
                    degraded=degraded,
                )
            )
