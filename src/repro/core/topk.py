"""Top-k primitives and the distributed merge tree (paper §4.4 step 5).

The merge of per-list candidate sets is an associative, commutative monoid
((scores, ids) pairs under "keep the k best"), which is what makes the
hierarchical cross-chip merge — and the deadline-based partial merge used for
straggler mitigation — correct by construction.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Finite stand-in for -inf: survives bf16 casts and keeps top_k total-ordered.
NEG_INF = -3.0e38


def masked_topk(
    scores: Array, mask: Optional[Array], k: int, ids: Optional[Array] = None
) -> Tuple[Array, Array]:
    """Top-k over the last axis with invalid entries masked out.

    Returns (values [..., k], idx_or_ids [..., k]).  Masked-out slots that
    survive into the top-k (when fewer than k valid entries exist) carry
    value NEG_INF and id -1.
    """
    s = scores.astype(jnp.float32)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    vals, idx = jax.lax.top_k(s, k)
    if ids is not None:
        out_ids = jnp.take_along_axis(ids, idx, axis=-1)
    else:
        out_ids = idx
    out_ids = jnp.where(vals > NEG_INF / 2, out_ids, -1)
    return vals, out_ids


def merge_topk(
    a: Tuple[Array, Array], b: Tuple[Array, Array], k: int
) -> Tuple[Array, Array]:
    """Monoid combine: best k of the union of two candidate sets."""
    vals = jnp.concatenate([a[0], b[0]], axis=-1)
    ids = jnp.concatenate([a[1], b[1]], axis=-1)
    return masked_topk(vals, None, k, ids=ids)


def merge_topk_many(vals: Array, ids: Array, k: int, axis: int) -> Tuple[Array, Array]:
    """Folds N candidate sets along ``axis`` down to one top-k per row.

    A balanced tree of :func:`merge_topk` combines — the monoid's
    associativity is what lets the tiled search path merge its per-probe
    streaming top-k fragments in log2(N) rounds instead of one wide sort.
    """
    vals = jnp.moveaxis(vals, axis, -2)  # [..., N, k]
    ids = jnp.moveaxis(ids, axis, -2)
    n = vals.shape[-2]
    while n > 1:
        half = n // 2
        a = (vals[..., :half, :], ids[..., :half, :])
        b = (vals[..., half : 2 * half, :], ids[..., half : 2 * half, :])
        mv, mi = merge_topk(a, b, k)
        if n % 2:
            vals = jnp.concatenate([mv, vals[..., -1:, :]], axis=-2)
            ids = jnp.concatenate([mi, ids[..., -1:, :]], axis=-2)
        else:
            vals, ids = mv, mi
        n = vals.shape[-2]
    return vals[..., 0, :], ids[..., 0, :]


def merge_topk_axis(
    vals: Array, ids: Array, k: int, axis_name: str
) -> Tuple[Array, Array]:
    """All-gather along a mesh axis and locally re-select the top k.

    Payload per stage is [axis_size, ..., k] — with k ≪ Vpad this keeps the
    collective term tiny relative to the scan (see EXPERIMENTS §Roofline).
    """
    gv = jax.lax.all_gather(vals, axis_name)  # [axis, ..., k]
    gi = jax.lax.all_gather(ids, axis_name)
    gv = jnp.moveaxis(gv, 0, -2).reshape(*vals.shape[:-1], -1)
    gi = jnp.moveaxis(gi, 0, -2).reshape(*ids.shape[:-1], -1)
    return masked_topk(gv, None, k, ids=gi)


def topk_tree_merge(
    vals: Array, ids: Array, k: int, axis_names: Tuple[str, ...]
) -> Tuple[Array, Array]:
    """Hierarchical merge over mesh axes (model → data → pod)."""
    for name in axis_names:
        vals, ids = merge_topk_axis(vals, ids, k, name)
    return vals, ids
