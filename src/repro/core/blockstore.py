"""Pluggable cluster-block fetch layer: the engine's fetch stage as a protocol.

The paper's disk-based IVF-Flat design is cost-effective because one index
copy can serve heavy traffic — but a fetch path welded to a single-process
``ClusterCache`` forces every serving host to hold its own cache, and every
query tile to re-assemble blocks it shares with sibling tiles.  PipeANN's
SSD-resident pipelining and SIEVE's collection-of-indexes framing both treat
storage access as a first-class, composable layer; this module is that layer
for the search engine:

    BlockStore protocol
        get(cluster_ids)  -> {cid: record}      synchronous fetch
        submit(ids)/wait(h)                     async pair the pipelined
                                                executor drives
        stats()                                 observability

    ResidentBlockStore   RAM tier — slices the resident [K, Vpad, ...]
                         arrays per cluster (trivial; the engine's RAM fast
                         path skips even this and passes the arrays whole).
    LocalBlockStore      today's disk tier — ShardReader + ClusterCache,
                         behavior-identical to the pre-protocol pager.
    ShardedBlockStore    a consistent-hash ring over N peer stores keyed on
                         cluster id: each pod holds ONE index copy, the ring
                         decides whose cache owns each cluster, per-tile
                         fetch lists are split per owner and fetched
                         concurrently, and remote blocks land in a small
                         local L1 so repeat probes don't re-cross the ring.

Transports are pluggable: :class:`LoopbackTransport` keeps peers in-process
(tests, benches, single-host multi-cache experiments); the pooled,
deadline-bounded :class:`SocketTransport` / :class:`BlockStoreServer` pair
(``repro.core.transport``) is the wire path for real pods (npz-encoded
records, no pickle, typed :class:`TransportError` on every failure mode).

The ring is a cache optimization, never a dependency: every pod holds a
full index copy, so a :class:`ShardedBlockStore` built with a ``fallback``
store (the pod's own :class:`LocalBlockStore`) keeps serving when peers
die.  Per-peer circuit breakers (``repro.core.health``) watch the
transports' passive failure/latency signals; an open peer's clusters are
fetched through the local full copy (and optionally adopted into the L1)
until the breaker's half-open probe sees the peer answer again.

Exactness invariant: every store returns the same per-cluster records, so
any store composed with the engine yields results bit-identical to the sync
local path.  Ring membership changes (node added/removed), peer failures,
and failover only change *where* blocks come from — never results.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Record = Dict[str, np.ndarray]


def record_gen(rec: Record) -> int:
    """Generation stamped on a cluster record (0 for pre-v3 records).

    Every fetch layer keys freshness on this: a record whose gen is below
    the caller's published minimum was superseded by a republish and must
    be invalidated, never served.
    """
    g = rec.get("gen")
    return int(g[0]) if g is not None else 0


# ---------------------------------------------------------------------------
# Block geometry + assembly (shared by every store and the engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """Static geometry of one cluster record — everything an assembler needs
    to pack records into the kernel's batch-local ``[S, Vpad, ...]`` blocks."""

    vpad: int
    dim: int
    n_attrs: int
    has_norms: bool
    quantized: bool
    store_dtype: np.dtype

    @classmethod
    def from_index(cls, index) -> "BlockSpec":
        """Derives the spec from any index with the resident surface
        (IVFFlatIndex or DiskIVFIndex)."""
        norms = getattr(index, "norms", None)
        has_norms = (
            index.man["has_norms"] if hasattr(index, "man")
            else norms is not None
        )
        return cls(
            vpad=int(index.vpad), dim=int(index.spec.dim),
            n_attrs=int(index.spec.n_attrs), has_norms=bool(has_norms),
            quantized=bool(index.quantized),
            store_dtype=np.dtype(index.store_dtype),
        )

    @classmethod
    def from_manifest(cls, man: dict) -> "BlockSpec":
        from repro.core import storage

        spec = storage.spec_from_manifest(man)
        return cls(
            vpad=int(man["vpad"]), dim=int(spec.dim),
            n_attrs=int(spec.n_attrs), has_norms=bool(man["has_norms"]),
            quantized=bool(man["quantized"]),
            store_dtype=np.dtype(storage.np_dtype(man["store_dtype"])),
        )

    @property
    def fields(self) -> Tuple[str, ...]:
        f = ["vectors", "attrs", "ids"]
        if self.has_norms:
            f.append("norms")
        if self.quantized:
            f.append("scales")
        return tuple(f)


def first_need_unique(flat: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Unique cluster ids in *first-occurrence* order + inverse map.

    Fetches load (and a cache's prefetch thread streams) clusters in exactly
    the order the scan will first touch them — the same ordering contract as
    :func:`repro.core.probes.fetch_order`.
    """
    uniq_sorted, first, inv_sorted = np.unique(
        flat, return_index=True, return_inverse=True
    )
    order = np.argsort(first, kind="stable")  # sorted-pos → need order
    rank = np.empty_like(order)
    rank[order] = np.arange(order.shape[0])
    return uniq_sorted[order], rank[inv_sorted]


def assemble_blocks(flat: np.ndarray, uniq: np.ndarray, local: np.ndarray,
                    recs: Dict[int, Record], spec: BlockSpec,
                    as_device: bool = False) -> Tuple:
    """Packs per-cluster records into batch-local ``[S, Vpad, ...]`` blocks.

    ``flat`` is the slot list (sets S), ``uniq``/``local`` the first-need
    unique ids and slot→row map from :func:`first_need_unique`, ``recs`` the
    records a :class:`BlockStore` returned.  ``as_device`` additionally moves
    the blocks onto the default device — on an async fetch worker that hides
    the host→device copy behind the previous tile's scan.

    The batch's row height is the *tallest record in this batch*, not
    ``spec.vpad``: sub-partition records (layout v4) are a fraction of their
    parent's height, so a batch of routed probes scans a proportionally
    smaller ``[S, vpad_batch, D]`` block — this is where partition routing's
    scan shrink materializes.  Short records occupy a ``[:rows]`` prefix;
    the tail keeps the dead-row fill (ids −1, scales 1) the kernels mask.
    """
    s = flat.shape[0]
    d, m = spec.dim, spec.n_attrs
    vpad = spec.vpad
    if len(uniq):
        vpad = max(int(recs[int(c)]["ids"].shape[0]) for c in uniq)
    vectors = np.zeros((s, vpad, d), spec.store_dtype)
    attrs = np.zeros((s, vpad, m), np.int16)
    ids = np.full((s, vpad), -1, np.int32)
    norms = np.zeros((s, vpad), np.float32) if spec.has_norms else None
    scales = np.ones((s, vpad), np.float32) if spec.quantized else None
    for i, cid in enumerate(uniq):
        rec = recs[int(cid)]
        rows = int(rec["ids"].shape[0])
        vectors[i, :rows] = rec["vectors"]
        attrs[i, :rows] = rec["attrs"]
        ids[i, :rows] = rec["ids"]
        if norms is not None:
            norms[i, :rows] = rec["norms"]
        if scales is not None:
            scales[i, :rows] = rec["scales"]
    out = (local.astype(np.int32), vectors, attrs, ids, norms, scales)
    if as_device:
        import jax

        out = tuple(None if a is None else jax.device_put(a) for a in out)
        jax.block_until_ready([a for a in out if a is not None])
    return out


def dead_record(spec: BlockSpec) -> Record:
    """A minimal all-dead cluster record (every id −1, neutral fills).

    Stand-in for a cluster the fetch path proved it never needs to read
    (every (query, probe) pair dead at a segment boundary): the assembler
    packs it like any record, the kernels mask every row, and its single
    row never inflates the batch's dynamic height.
    """
    rec: Record = {
        "vectors": np.zeros((1, spec.dim), spec.store_dtype),
        "attrs": np.zeros((1, spec.n_attrs), np.int16),
        "ids": np.full(1, -1, np.int32),
        "gen": np.zeros(1, np.int64),
    }
    if spec.has_norms:
        rec["norms"] = np.zeros(1, np.float32)
    if spec.quantized:
        rec["scales"] = np.ones(1, np.float32)
    return rec


# ---------------------------------------------------------------------------
# Ownership: who serves a cluster
# ---------------------------------------------------------------------------


def _hash_point(key: str) -> int:
    """Stable 64-bit ring point for a (node, replica) label."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big"
    )


def _mix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer: cluster id → ring position."""
    with np.errstate(over="ignore"):
        z = np.asarray(x).astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


class HashRing:
    """Consistent-hash ring over node ids, keyed on cluster id.

    Each node contributes ``replicas`` virtual points; a cluster is owned by
    the first point clockwise from its hash.  Removing a node therefore only
    reassigns *that node's* clusters (its points vanish, everything else
    keeps its owner) — the property that makes ring rebalance a pure
    data-movement event: results never change, only where blocks come from.
    """

    def __init__(self, nodes: Sequence, replicas: int = 64):
        nodes = tuple(nodes)
        if not nodes:
            raise ValueError("HashRing needs at least one node")
        self.nodes = nodes
        self.replicas = replicas
        pts = []
        for n in nodes:
            for r in range(replicas):
                pts.append((_hash_point(f"{n}#{r}"), n))
        pts.sort(key=lambda p: p[0])
        self._hashes = np.asarray([p[0] for p in pts], np.uint64)
        self._owners = np.asarray([nodes.index(p[1]) for p in pts], np.int64)

    def owner_of(self, cluster_ids) -> np.ndarray:
        """Vectorized owner lookup: [n] cluster ids → [n] node ids."""
        h = _mix64(np.asarray(cluster_ids, np.int64))
        idx = np.searchsorted(self._hashes, h, side="right")
        idx = idx % len(self._hashes)
        return np.asarray(self.nodes, object)[self._owners[idx]] \
            if any(not isinstance(n, (int, np.integer)) for n in self.nodes) \
            else np.asarray(self.nodes, np.int64)[self._owners[idx]]

    def without(self, node) -> "HashRing":
        """A new ring with ``node`` removed (its clusters reassigned)."""
        rest = tuple(n for n in self.nodes if n != node)
        return HashRing(rest, replicas=self.replicas)


@dataclasses.dataclass(frozen=True)
class RangeOwnership:
    """Contiguous range sharding: node ``s`` owns ``[s·k_local, (s+1)·k_local)``.

    The same ownership map the pod-scale dispatch uses
    (:func:`repro.core.distributed.dispatch_probes`): handing one instance to
    both the dispatch and a :class:`ShardedBlockStore` makes shard routing
    and cache routing agree — a chip's probes always hit its own pod's cache.
    ``owner_of``/``local_of`` are jnp-compatible (plain integer arithmetic),
    so the dispatch can trace them.
    """

    n_nodes: int
    k_local: int

    @property
    def nodes(self) -> Tuple[int, ...]:
        return tuple(range(self.n_nodes))

    def owner_of(self, cluster_ids):
        return cluster_ids // self.k_local

    def local_of(self, cluster_ids):
        return cluster_ids % self.k_local


# ---------------------------------------------------------------------------
# Stores
# ---------------------------------------------------------------------------


# Guards first-time pool creation for every store instance: pool creation is
# a rare, cheap event, and a shared lock (vs a lazily-created per-instance
# one) closes the check-then-act race when one store is shared by several
# server threads — two racing first submits must not build two pools, or the
# single-worker submission-order guarantee silently breaks.
_POOL_INIT_LOCK = threading.Lock()


class _AsyncStoreMixin:
    """submit/wait over a single-worker pool: handles resolve strictly in
    submission order, which is what keeps the pipelined executor's per-tile
    waits aligned with its per-tile submits."""

    _pool: Optional[ThreadPoolExecutor] = None
    _pool_closed: bool = False

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            with _POOL_INIT_LOCK:
                if self._pool_closed:
                    raise RuntimeError(
                        f"submit on a closed {type(self).__name__}"
                    )
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=1,
                        thread_name_prefix=f"{type(self).__name__}-fetch",
                    )
        return self._pool

    def submit(self, cluster_ids, gens=None) -> Future:
        """Starts fetching ``cluster_ids`` off-thread; returns a handle.
        ``gens`` (parallel minimum generations) rides along to :meth:`get`.
        Raises ``RuntimeError`` after :meth:`close` — a late submit against
        a stopped cache must surface, not quietly leak a fresh pool."""
        if gens is None:
            return self._ensure_pool().submit(self.get, cluster_ids)
        return self._ensure_pool().submit(self.get, cluster_ids, gens=gens)

    def wait(self, handle: Future) -> Dict[int, Record]:
        """Blocks until a :meth:`submit` handle's records are ready."""
        return handle.result()

    def _shutdown_pool(self):
        with _POOL_INIT_LOCK:
            self._pool_closed = True
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)


class ResidentBlockStore(_AsyncStoreMixin):
    """RAM tier: per-cluster views of the resident ``[K, Vpad, ...]`` arrays.

    Trivial by design — it exists so the same engine/test/bench code can
    treat the RAM tier as one more :class:`BlockStore` (e.g. as a loopback
    peer in a sharded ring) without a checkpoint on disk.  The engine's
    resident fast path bypasses it and passes the arrays whole.
    """

    def __init__(self, index):
        self.index = index
        self.spec = BlockSpec.from_index(index)
        self._gets = 0
        self._blocks = 0

    def get(self, cluster_ids, gens=None) -> Dict[int, Record]:
        # gens accepted for protocol uniformity; the resident arrays ARE
        # the current generation, so records are stamped gen 0 and never
        # stale by construction.
        cids = np.asarray(cluster_ids, np.int64).reshape(-1)
        self._gets += 1
        self._blocks += len(cids)
        # attached sub-partitions live in the resident arrays at the parent's
        # full Vpad; trim their records to the sub's own padded height so the
        # assembler's dynamic batch height (and the scan) shrinks with them
        cat = getattr(self.index, "partitions", None)
        out: Dict[int, Record] = {}
        for cid in cids:
            cid = int(cid)
            rows = None
            if cat is not None and cid >= cat.n_base:
                n = max(int(cat.sub_counts[cid - cat.n_base]), 1)
                rows = max(-(-n // 128) * 128, 128)

            def cut(a):
                return a if rows is None or rows >= a.shape[0] else a[:rows]

            rec: Record = {
                "vectors": np.asarray(cut(self.index.vectors[cid])),
                "attrs": np.asarray(cut(self.index.attrs[cid])),
                "ids": np.asarray(cut(self.index.ids[cid])),
                "gen": np.zeros(1, np.int64),
            }
            if self.spec.has_norms:
                rec["norms"] = np.asarray(
                    cut(self.index.norms[cid]), np.float32
                )
            if self.spec.quantized:
                rec["scales"] = np.asarray(
                    cut(self.index.scales[cid]), np.float32
                )
            out[cid] = rec
        return out

    def refresh(self):
        """No-op: the resident arrays are always the current generation."""

    def stats(self) -> dict:
        return dict(kind="resident", gets=self._gets, blocks=self._blocks)

    def close(self):
        self._shutdown_pool()


class LocalBlockStore(_AsyncStoreMixin):
    """One host's disk tier: ShardReader + ClusterCache behind the protocol.

    Behavior-identical to the pre-protocol pager: ``get`` pages records
    through the cache (misses load inline, deduplicated against in-flight
    prefetches), and the gather convenience methods reproduce the old
    ``DiskIVFIndex.gather`` / ``gather_submit`` / ``gather_wait`` contract
    exactly — including assembling + device-putting blocks on the fetch
    worker so the host→device copy hides behind the previous tile's scan.
    """

    def __init__(self, reader, cache, spec: BlockSpec, name: str = "local"):
        self.reader = reader
        self.cache = cache
        self.spec = spec
        self.name = name

    @classmethod
    def open(cls, directory: str, *, capacity_records: Optional[int] = None,
             pin_fraction: float = 0.5, pin_refresh: int = 64,
             name: str = "local") -> "LocalBlockStore":
        """Opens one peer's view of a layout-v2 checkpoint (one index copy
        per pod: every node opens the same directory, the ring decides which
        node's cache serves each cluster)."""
        from repro.core import storage
        from repro.core.disk import ClusterCache, ShardReader

        man = storage.load_manifest(directory)
        storage.check_complete(directory, man)
        reader = ShardReader(directory, man)
        # layout v4: sub-partitions are addressable cluster records past the
        # base id space, so the cache's id range (and default capacity)
        # covers base + subs
        n_total = man["n_clusters"]
        if man.get("has_partitions"):
            n_total += int(man["partitions"]["n_subs"])
        cap = (n_total if capacity_records is None
               else min(int(capacity_records), n_total))
        cache = ClusterCache(
            reader, capacity_records=max(cap, 1),
            n_clusters=n_total, pin_fraction=pin_fraction,
            pin_refresh=pin_refresh,
        )
        return cls(reader, cache, BlockSpec.from_manifest(man), name=name)

    def get(self, cluster_ids, gens=None) -> Dict[int, Record]:
        cids = np.asarray(cluster_ids, np.int64).reshape(-1)
        if len(cids) == 0:
            return {}
        g = None if gens is None else np.asarray(gens).reshape(-1)
        return self.cache.get_many(cids, gens=g)

    def refresh(self):
        """Adopts a republished checkpoint: reopens the shard reader (new
        manifest + fresh mmaps).  Cached records are NOT flushed — the next
        gen-stamped fetch invalidates exactly the rewritten clusters."""
        self.reader.reopen()

    # ---- the old DiskIVFIndex gather surface, now store-backed ----
    def gather(self, slot_cluster) -> Tuple:
        """Synchronous whole-list gather: records → ``[S, Vpad, ...]``
        blocks with slot-local ids (static shapes, no recompiles)."""
        flat = np.asarray(slot_cluster).reshape(-1)
        uniq, local = first_need_unique(flat)
        return assemble_blocks(flat, uniq, local, self.get(uniq), self.spec)

    def gather_submit(self, slot_cluster) -> Future:
        """Async gather: pages + assembles + device-puts off-thread.  The
        worker's misses load inline on its own thread — deliberately NOT
        routed through the cache's ``prefetch``, which would mark every miss
        in-flight an instant before ``get_many`` sees it and turn the hit-
        rate signal into a constant 1.0."""
        flat = np.asarray(slot_cluster).reshape(-1)
        uniq, local = first_need_unique(flat)
        return self._ensure_pool().submit(
            lambda: assemble_blocks(flat, uniq, local, self.get(uniq),
                                    self.spec, as_device=True)
        )

    def gather_wait(self, handle: Future) -> Tuple:
        return handle.result()

    def stats(self) -> dict:
        s = self.cache.stats
        return dict(
            kind="local", name=self.name, hits=s.hits, misses=s.misses,
            evictions=s.evictions, prefetched=s.prefetched, errors=s.errors,
            invalidations=s.invalidations,
            hit_rate=round(self.cache.hit_rate, 4),
            resident_bytes=self.cache.resident_bytes(),
        )

    def close(self):
        self._shutdown_pool()
        self.cache.stop()


# ---------------------------------------------------------------------------
# Transports — implementation lives in repro.core.transport; re-exported
# here because the PR-5 surface (tests, benches, examples) imports them
# from this module
# ---------------------------------------------------------------------------

from repro.core.transport import (  # noqa: E402,F401  (re-export)
    BlockStoreServer,
    LoopbackTransport,
    SocketTransport,
    TransportError,
    TransportTimeout,
    _decode_records,
    _encode_records,
    _recv_frame,
    _send_frame,
)


# ---------------------------------------------------------------------------
# The sharded store
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StoreStats:
    """Degradation accounting for a sharded store — how often the fetch
    path had to route around an unhealthy peer (``launch/serve.py`` and the
    chaos bench surface these)."""

    failovers: int = 0          # peer sub-fetches that failed mid-request
    #                             and were re-served by the fallback
    redirected_blocks: int = 0  # blocks routed straight to the fallback
    #                             because the owner's circuit was open
    fallback_blocks: int = 0    # blocks the local full copy actually served
    stale_answers: int = 0      # peer answers below the published minimum
    #                             generation (peer lagging a republish) —
    #                             treated as misses and re-served fresh,
    #                             never silently accepted
    device_hits: int = 0        # blocks the engine's device cache served —
    #                             fetches this store never saw (avoided
    #                             peer RPCs / disk reads)
    fetches_skipped: int = 0    # clusters dropped from the fetch list
    #                             because every (query, probe) pair on them
    #                             was already dead at a segment boundary —
    #                             remote RPCs never dispatched


class ShardedBlockStore(_AsyncStoreMixin):
    """Consistent-hash sharded cluster fetch over N peer stores.

    ``transports`` maps node id → transport; ``ownership`` (default: a
    :class:`HashRing` over the node ids) decides which peer serves each
    cluster.  ``get`` splits the request per owner
    (:func:`repro.core.probes.split_fetch_by_owner` — per-owner sublists keep
    first-need order) and fetches owners concurrently; fetched blocks land in
    a small local L1 LRU so repeat probes within a host don't re-cross the
    ring.  ``self_node`` marks the co-located peer (its blocks skip the L1 —
    that peer's own cache already holds them — and don't count as remote).

    Ring membership is mutable: :meth:`remove_node` / :meth:`add_node`
    rebuild the ring mid-run.  Only ownership moves; results are
    bit-identical before and after (every peer serves the same records).

    Failover: with a ``fallback`` store (the pod's own full-copy
    :class:`LocalBlockStore`), peer failures are absorbed instead of
    raised.  A per-peer :class:`~repro.core.health.CircuitBreaker`
    (``health``) watches every peer fetch; while a peer's circuit is open
    its clusters are fetched through the fallback (``adopt_fallback``
    additionally lands them in the L1 so repeat probes don't re-read
    disk), and a sub-fetch that fails mid-request is transparently
    re-served by the fallback (``StoreStats.failovers``).  When the
    breaker's cooldown lapses, the next fetch for that peer doubles as the
    half-open probe — recovery needs no restart and no operator.  Without
    a fallback the PR-5 contract is preserved: peer errors raise.
    """

    def __init__(self, transports: Dict[int, object], *,
                 ownership=None, l1_records: int = 64,
                 self_node: Optional[int] = None,
                 owned_stores: Sequence = (), owned_servers: Sequence = (),
                 fallback=None, owns_fallback: bool = False,
                 adopt_fallback: bool = True, health=None,
                 breaker_kwargs: Optional[dict] = None,
                 probe_interval_s: Optional[float] = None):
        from repro.core.health import PeerHealth

        if not transports:
            raise ValueError("ShardedBlockStore needs at least one transport")
        self.transports = dict(transports)
        self.ownership = ownership or HashRing(sorted(self.transports))
        self.self_node = self_node
        self.l1_records = l1_records
        self._l1: "collections.OrderedDict[int, Record]" = (
            collections.OrderedDict()
        )
        self._l1_lock = threading.Lock()
        self._fan = ThreadPoolExecutor(
            max_workers=max(len(self.transports), 1),
            thread_name_prefix="shard-fetch",
        )
        self._stats_lock = threading.Lock()
        self.l1_hits = 0
        self.l1_misses = 0
        self.l1_invalidations = 0
        self.remote_blocks = 0
        self.node_blocks: Dict[int, int] = {n: 0 for n in self.transports}
        # teardown ownership (stores/servers built by open_sharded)
        self._owned_stores = list(owned_stores)
        self._owned_servers = list(owned_servers)
        # availability floor + per-peer health
        self.fallback = fallback
        self._owns_fallback = owns_fallback
        self.adopt_fallback = adopt_fallback
        self.health = health or PeerHealth(
            self.transports, breaker_kwargs=breaker_kwargs
        )
        self.store_stats = StoreStats()
        self.probe_interval_s = probe_interval_s
        self._probe_stop = threading.Event()
        self._prober: Optional[threading.Thread] = None
        if probe_interval_s:
            self._prober = threading.Thread(
                target=self._probe_loop, daemon=True,
                name="shard-health-probe",
            )
            self._prober.start()

    # ---- ring membership ----
    def remove_node(self, node: int):
        """Drops a peer from the ring.  Its clusters re-route to the
        surviving peers (consistent hashing moves only those); results stay
        bit-identical — only where blocks come from changes."""
        if len(self.transports) <= 1:
            raise ValueError("cannot remove the last node")
        if node not in self.transports:
            raise KeyError(node)
        if isinstance(self.ownership, HashRing):
            self.ownership = self.ownership.without(node)
        else:
            raise ValueError(
                "remove_node needs a HashRing ownership (static maps like "
                "RangeOwnership have no rebalance story)"
            )
        t = self.transports.pop(node)
        t.close()
        self.health.drop(node)
        if self.self_node == node:
            self.self_node = None

    def add_node(self, node: int, transport):
        if node in self.transports:
            raise KeyError(f"node {node} already present")
        if not isinstance(self.ownership, HashRing):
            raise ValueError("add_node needs a HashRing ownership")
        self.transports[node] = transport
        self.node_blocks.setdefault(node, 0)
        self.ownership = HashRing(
            sorted(self.transports), replicas=self.ownership.replicas
        )

    # ---- fetch ----
    def _l1_get(self, cids: np.ndarray,
                exp: Optional[Dict[int, int]] = None
                ) -> Tuple[Dict[int, Record], List[int]]:
        found: Dict[int, Record] = {}
        missing: List[int] = []
        invalid = 0
        with self._l1_lock:
            for cid in cids:
                cid = int(cid)
                rec = self._l1.get(cid)
                if rec is not None and exp is not None and \
                        record_gen(rec) < exp.get(cid, 0):
                    del self._l1[cid]  # superseded by a republish
                    invalid += 1
                    rec = None
                if rec is None:
                    missing.append(cid)
                else:
                    self._l1.move_to_end(cid)
                    found[cid] = rec
        with self._stats_lock:
            self.l1_hits += len(found)
            self.l1_misses += len(missing)
            self.l1_invalidations += invalid
        return found, missing

    def _l1_put(self, recs: Dict[int, Record]):
        with self._l1_lock:
            for cid, rec in recs.items():
                self._l1[cid] = rec
                self._l1.move_to_end(cid)
            while len(self._l1) > self.l1_records:
                self._l1.popitem(last=False)

    def get(self, cluster_ids, gens=None, alive=None) -> Dict[int, Record]:
        from repro.core import probes as probes_lib

        cids = np.asarray(cluster_ids, np.int64).reshape(-1)
        if len(cids) == 0:
            return {}
        if alive is not None:
            # segment-boundary shrink: a cluster whose every (query, probe)
            # pair is already dead never leaves the host — drop it before
            # the per-owner split so no peer RPC is dispatched for it
            keep = np.asarray(alive, bool).reshape(-1)
            n_skip = int((~keep).sum())
            if n_skip:
                with self._stats_lock:
                    self.store_stats.fetches_skipped += n_skip
                cids = cids[keep]
                if gens is not None:
                    gens = np.asarray(gens).reshape(-1)[keep]
                if len(cids) == 0:
                    return {}
        exp: Optional[Dict[int, int]] = None
        if gens is not None:
            exp = {int(c): int(g)
                   for c, g in zip(cids, np.asarray(gens).reshape(-1))}
        # self-owned clusters never enter the L1 (the co-located peer's own
        # cache holds them), so they bypass the L1 probe entirely — probing
        # would book a structural miss per lookup and depress the reported
        # hit rate below what any l1_records setting could fix
        if self.self_node is not None:
            owners_all = np.asarray(self.ownership.owner_of(cids))
            self_cids = cids[owners_all == self.self_node]
            peer_cids = cids[owners_all != self.self_node]
        else:
            self_cids = cids[:0]
            peer_cids = cids
        out, missing = self._l1_get(peer_cids, exp)
        missing = list(self_cids) + missing
        if not missing:
            return out
        per_owner = probes_lib.split_fetch_by_owner(
            np.asarray(missing, np.int64), self.ownership.owner_of
        )
        futs = {}
        fallback_cids: List[int] = []
        for owner, sub in per_owner.items():
            if (self.fallback is not None and owner != self.self_node
                    and not self.health.allow(owner)):
                # circuit open and cooldown not lapsed: don't even knock —
                # the local full copy serves this peer's clusters.  (When
                # the cooldown HAS lapsed, allow() grants the half-open
                # probe token and this sub-fetch is the probe.)
                fallback_cids.extend(int(c) for c in sub)
                with self._stats_lock:
                    self.store_stats.redirected_blocks += len(sub)
                continue
            sub_gens = (None if exp is None else
                        np.asarray([exp.get(int(c), 0) for c in sub],
                                   np.int64))
            futs[owner] = (sub, self._fan.submit(self._fetch_peer, owner,
                                                 sub, sub_gens))
        for owner, (sub, fut) in futs.items():
            try:
                recs = fut.result()
            except Exception:
                # _fetch_peer already fed the breaker; without a fallback
                # the PR-5 contract holds (the error surfaces), and the
                # co-located peer failing is a local bug, not a ring event
                if self.fallback is None or owner == self.self_node:
                    raise
                fallback_cids.extend(int(c) for c in sub)
                with self._stats_lock:
                    self.store_stats.failovers += 1
                continue
            if exp is not None and owner != self.self_node:
                # A peer that hasn't adopted the republish yet (reader not
                # reopened, gens not forwarded by an old wire) answers with
                # the superseded record.  Treat those as misses: re-serve
                # through the fallback, never accept them, never L1 them.
                stale = [cid for cid, rec in recs.items()
                         if record_gen(rec) < exp.get(cid, 0)]
                if stale:
                    with self._stats_lock:
                        self.store_stats.stale_answers += len(stale)
                    if self.fallback is None:
                        from repro.core import storage

                        raise storage.GenerationMismatchError(
                            f"peer {owner} served stale generations for "
                            f"clusters {stale[:8]} and no fallback store "
                            f"is configured"
                        )
                    for cid in stale:
                        recs.pop(cid)
                    fallback_cids.extend(stale)
            out.update(recs)
            with self._stats_lock:
                self.node_blocks[owner] = (
                    self.node_blocks.get(owner, 0) + len(recs)
                )
                if owner != self.self_node:
                    self.remote_blocks += len(recs)
            if owner != self.self_node:
                self._l1_put(recs)
        if fallback_cids:
            fb_gens = (None if exp is None else
                       np.asarray([exp.get(int(c), 0)
                                   for c in fallback_cids], np.int64))
            if fb_gens is None:
                recs = self.fallback.get(np.asarray(fallback_cids, np.int64))
            else:
                recs = self.fallback.get(
                    np.asarray(fallback_cids, np.int64), gens=fb_gens
                )
            out.update(recs)
            with self._stats_lock:
                self.store_stats.fallback_blocks += len(recs)
            if self.adopt_fallback:
                self._l1_put(recs)
        return out

    def _fetch_peer(self, owner, sub, gens=None) -> Dict[int, Record]:
        """One peer sub-fetch with passive health signaling: latency feeds
        the breaker's EWMA (brownout detection), any exception is a
        failure vote."""
        t0 = time.monotonic()
        try:
            if gens is None:
                recs = self.transports[owner].fetch(sub)
            else:
                recs = self.transports[owner].fetch(sub, gens=gens)
        except Exception:
            if owner != self.self_node:
                self.health.on_failure(owner)
            raise
        if owner != self.self_node:
            self.health.on_success(owner, time.monotonic() - t0)
        return recs

    def refresh(self):
        """Adopts a republished checkpoint ring-wide: reopens every owned
        peer store and the fallback.  The L1 is deliberately NOT cleared —
        the next gen-stamped fetch invalidates exactly the rewritten
        clusters (``l1_invalidations``), everything else stays hot."""
        for st in self._owned_stores:
            r = getattr(st, "refresh", None)
            if r is not None:
                r()
        if self.fallback is not None:
            r = getattr(self.fallback, "refresh", None)
            if r is not None:
                r()

    def note_device_hits(self, n: int):
        """Counts blocks a device-resident cache served instead of this
        ring — every one is a peer RPC (or local fallback read) that never
        happened (:class:`repro.core.devicecache.DeviceBlockCache`)."""
        with self._stats_lock:
            self.store_stats.device_hits += n

    # ---- health ----
    @property
    def degraded(self) -> bool:
        """True while any peer's circuit is not closed (the engine counts
        batches served in this state)."""
        return self.health.degraded

    def probe_peers(self) -> int:
        """One active-probe pass: pings every non-closed peer whose breaker
        grants a token (``transport.ping`` is a zero-id round trip).
        Returns how many probes succeeded.  Runs periodically when the
        store was built with ``probe_interval_s``; tests call it
        directly."""
        ok = 0
        for node, t in list(self.transports.items()):
            if node == self.self_node:
                continue
            ping = getattr(t, "ping", None)
            if ping is None:
                continue
            ok += int(self.health.probe(node, ping))
        return ok

    def _probe_loop(self):
        while not self._probe_stop.wait(self.probe_interval_s):
            self.probe_peers()

    def stats(self) -> dict:
        with self._stats_lock:
            per_node = {}
            retries = deadline_misses = 0
            for n, t in self.transports.items():
                s = t.stats() if hasattr(t, "stats") else {}
                s = dict(s)
                s["blocks_served"] = self.node_blocks.get(n, 0)
                retries += s.get("retries", 0)
                deadline_misses += s.get("timeouts", 0)
                per_node[n] = s
            return dict(
                kind="sharded", nodes=sorted(self.transports),
                self_node=self.self_node, l1_hits=self.l1_hits,
                l1_misses=self.l1_misses, l1_records=len(self._l1),
                l1_invalidations=self.l1_invalidations,
                remote_blocks=self.remote_blocks, per_node=per_node,
                health={n: s["state"]
                        for n, s in self.health.snapshot().items()},
                failovers=self.store_stats.failovers,
                redirected_blocks=self.store_stats.redirected_blocks,
                fallback_blocks=self.store_stats.fallback_blocks,
                stale_answers=self.store_stats.stale_answers,
                device_hits=self.store_stats.device_hits,
                fetches_skipped=self.store_stats.fetches_skipped,
                retries=retries, deadline_misses=deadline_misses,
                has_fallback=self.fallback is not None,
            )

    def close(self):
        self._probe_stop.set()
        if self._prober is not None:
            self._prober.join(timeout=5)
        self._shutdown_pool()
        self._fan.shutdown(wait=True)
        for t in self.transports.values():
            t.close()
        for s in self._owned_servers:
            s.close()
        for st in self._owned_stores:
            st.close()
        if self._owns_fallback and self.fallback is not None:
            self.fallback.close()


def open_sharded(directory: str, *, n_nodes: int,
                 transport: str = "loopback",
                 capacity_records: Optional[int] = None,
                 l1_records: int = 64, self_node: Optional[int] = 0,
                 pin_fraction: float = 0.5,
                 pin_refresh: int = 64,
                 fallback="open", adopt_fallback: bool = True,
                 timeout_s: float = 30.0, retries: int = 1,
                 breaker_kwargs: Optional[dict] = None,
                 probe_interval_s: Optional[float] = None
                 ) -> ShardedBlockStore:
    """Opens an N-node sharded fetch layer over one checkpoint directory.

    Models the sharded-pod deployment (one index copy per pod, the ring
    splits *cache* ownership): every node opens its own reader + cache over
    the same checkpoint; ``capacity_records`` is the per-node cache cap.
    ``transport="socket"`` additionally runs each peer behind a
    :class:`BlockStoreServer` and talks to it over the deadline-bounded
    wire protocol (``timeout_s``/``retries``) — the in-process rehearsal of
    the real pod topology.  ``self_node`` (the co-located peer whose blocks
    skip the L1) only applies to the loopback transport: behind a socket
    every peer costs a wire round trip, node 0 included, so its blocks
    belong in the L1 like everyone else's.

    ``fallback`` is the availability floor: ``"open"`` (the default) opens
    one more uncached-capacity view of the same checkpoint as the local
    full copy, any BlockStore instance is used as-is (e.g. the pod's own
    ``DiskIVFIndex.blockstore`` — no extra memory), and ``None`` disables
    failover entirely (peer errors raise, the PR-5 contract).
    ``breaker_kwargs`` tune the per-peer circuit breakers
    (:class:`~repro.core.health.CircuitBreaker`); ``probe_interval_s``
    starts the background active-probe thread.  The returned store owns
    its nodes (and servers, and an ``"open"``-ed fallback): ``close()``
    tears everything down.
    """
    if transport not in ("loopback", "socket"):
        raise ValueError(f"transport must be 'loopback'|'socket', got "
                         f"{transport!r}")
    if transport != "loopback":
        self_node = None
    stores = [
        LocalBlockStore.open(
            directory, capacity_records=capacity_records,
            pin_fraction=pin_fraction, pin_refresh=pin_refresh,
            name=f"node{i}",
        )
        for i in range(n_nodes)
    ]
    servers: List[BlockStoreServer] = []
    if transport == "loopback":
        transports = {i: LoopbackTransport(s) for i, s in enumerate(stores)}
    else:
        servers = [BlockStoreServer(s) for s in stores]
        transports = {
            i: SocketTransport(srv.host, srv.port, timeout=timeout_s,
                               retries=retries)
            for i, srv in enumerate(servers)
        }
    owns_fallback = fallback == "open"
    if owns_fallback:
        fallback = LocalBlockStore.open(
            directory, capacity_records=capacity_records,
            pin_fraction=pin_fraction, pin_refresh=pin_refresh,
            name="fallback",
        )
    return ShardedBlockStore(
        transports, ownership=HashRing(range(n_nodes)),
        l1_records=l1_records, self_node=self_node,
        owned_stores=stores, owned_servers=servers,
        fallback=fallback, owns_fallback=owns_fallback,
        adopt_fallback=adopt_fallback, breaker_kwargs=breaker_kwargs,
        probe_interval_s=probe_interval_s,
    )
