"""Pipelined search execution engine: plan → fetch → scan → merge.

The fused search path used to be a monolith (``search_fused_tiled`` ran the
jitted plan, a synchronous whole-batch gather, and one jitted scan/merge
back-to-back).  That serializes disk IO behind device compute — the disk
tier's dominant cost — and provisions every batch's slot tables for the
unpruned worst case.  This module decomposes the path into explicit stages
owned by :class:`SearchEngine`:

    plan   — jitted, resident-state only (:func:`plan_fused_tiled`): centroid
             top-k, filter-aware probe pruning, per-tile probe dedup.  Emits a
             :class:`SearchPlan` carrying per-tile slot tables and first-need
             fetch lists (:class:`TileWork`).
    fetch  — materialize the slots' cluster operands through the pluggable
             :class:`repro.core.blockstore.BlockStore` protocol.  RAM tier:
             the resident ``[K, Vpad, ...]`` arrays (a no-op).  Disk tier: a
             ``LocalBlockStore`` pages the plan's fetch list through the
             cluster cache; a ``ShardedBlockStore`` routes it over a
             consistent-hash ring of peer caches.  Pipelined fetches ride
             the store's ``submit``/``wait`` pair, and a per-batch *operand
             cache* pulls each cluster block through the store once per
             batch, reusing it across every tile of the batch that probes
             the cluster.
    scan   — jitted (:func:`_scan_merge_tiled`): the tiled Pallas/XLA kernel
             over the slot tables, one ``[QB, D] @ [D, VB]`` matmul per
             streamed block, per-probe ``[QB, k]`` fragments.
    merge  — jitted, fused into the scan call: monoid top-k across each
             query's probes, l2 constant fix-up, scan accounting.

Two executors share those stages and return bit-identical results:

  * **sync** (``pipeline="off"``) — the original monolith: one fetch for the
    whole batch, one scan over all ``n_tiles · u_cap`` slots.
  * **pipelined** (``pipeline="on"``) — double-buffered: while tile *i*
    scans on device, a background worker gathers tile *i+1*'s clusters from
    disk (``pipeline_depth`` tiles stay in flight).  Per-tile scans reuse one
    compiled shape, so the pipeline adds no recompiles.

On top of the same plan objects the engine provisions ``u_cap`` adaptively
(``adaptive_u_cap``): the plan runs at the always-sufficient worst-case
table width, the observed post-prune per-tile unique-cluster counts are
bucketed into a fixed power-of-two set of compiled scan shapes
(:func:`u_cap_buckets`), and the slot tables are shrunk host-side to the
smallest sufficient bucket — selective filters scan (and the disk tier
gathers) small slot tables instead of the unpruned worst case, with at most
``len(buckets)`` scan compilations ever (see :func:`scan_compile_count`).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockstore as blockstore_lib
from repro.core import probes as probes_lib
from repro.core import summaries as summaries_lib
from repro.core import topk as topk_lib
from repro.core.filters import FilterSpec
from repro.core.ivf import round_up
from repro.core.search import SearchResult, centroid_scores

Array = jax.Array


# ---------------------------------------------------------------------------
# Stage primitives (jitted).  These are module-level so their jit caches are
# shared by every SearchEngine in the process.
# ---------------------------------------------------------------------------


def tiled_scan_xla(
    slot_cluster, slot_tile, queries, lo, hi, vectors, attrs, ids,
    norms, scales, *, metric: str, k: int, q_block: int, chunk: int = 8,
):
    """XLA streaming executor with the tiled kernel's exact contract.

    Chunked ``lax.map`` over slots: each step gathers ``chunk`` cluster
    blocks, scores them against their query tiles and immediately reduces to
    ``[QB, k]`` — the full per-slot score matrix never exists, matching the
    kernel's memory bound.  This is the fast CPU path (Mosaic needs a real
    TPU to lower non-interpreted).
    """
    d = queries.shape[-1]
    qt = queries.reshape(-1, q_block, d).astype(jnp.float32)
    lot = lo.reshape(-1, q_block, *lo.shape[1:]).astype(jnp.int32)
    hit = hi.reshape(-1, q_block, *hi.shape[1:]).astype(jnp.int32)

    def one(args):
        sc, st = args
        v = jnp.take(vectors, sc, axis=0).astype(jnp.float32)  # [Vpad, D]
        qb = jnp.take(qt, st, axis=0)  # [QB, D]
        scores = qb @ v.T  # [QB, Vpad]
        if scales is not None:
            scores = scores * jnp.take(scales, sc, axis=0)[None, :]
        if metric == "l2":
            scores = 2.0 * scores - jnp.take(norms, sc, axis=0)[None, :]
        a = jnp.take(attrs, sc, axis=0).astype(jnp.int32)  # [Vpad, M]
        qlo = jnp.take(lot, st, axis=0)  # [QB, F, M]
        qhi = jnp.take(hit, st, axis=0)
        inside = jnp.logical_and(
            a[None, :, None, :] >= qlo[:, None],
            a[None, :, None, :] <= qhi[:, None],
        )  # [QB, Vpad, F, M]
        fmask = jnp.any(jnp.all(inside, -1), -1)
        live = jnp.take(ids, sc, axis=0) >= 0
        mask = jnp.logical_and(fmask, live[None, :])
        svals, sids = topk_lib.masked_topk(
            scores, mask, k,
            ids=jnp.broadcast_to(jnp.take(ids, sc, axis=0), scores.shape),
        )
        return svals, sids, jnp.sum(mask.astype(jnp.int32), axis=-1)

    return jax.lax.map(
        one, (slot_cluster, slot_tile), batch_size=min(chunk, slot_cluster.shape[0])
    )


@functools.partial(
    jax.jit,
    static_argnames=("metric", "n_probes", "q_block", "u_cap", "cast_dtype",
                     "t_max"),
)
def plan_fused_tiled(
    centroids: Array,
    counts: Array,
    queries: Array,
    lo: Array,
    hi: Array,
    *,
    metric: str,
    n_probes: int,
    q_block: int,
    u_cap: int,
    cast_dtype,
    summaries=None,
    t_max: Optional[int] = None,
    route_entry=None,
    members=None,
):
    """Plan stage: centroid probe + per-tile dedup over resident state.

    Runs entirely on the *resident* state (centroids + counts + attribute
    summaries), so the disk tier can plan — and hand ``slot_cluster`` to its
    cluster cache as the batch's fetch list — before any flat list is paged
    in.  Returns ``(slot_cluster, slot_tile, slot_of_probe, probe_ok,
    n_unique, queries_pad, lo_pad, hi_pad, n_pruned, geo_probes,
    geo_valid)``; queries/bounds come back padded to whole ``q_block`` tiles
    with edge rows (whose probes dedupe into the last real query's slots, so
    padding adds no scan work).  ``geo_probes``/``geo_valid`` are each
    query's *geometric* top-``n_probes`` candidate clusters (pre-widening,
    pre-pruning): the delta tier masks its RAM rows with exactly this set so
    a delta row only competes for queries whose probe budget would have
    reached its cluster — the condition for bit-parity with a from-scratch
    rebuild at the same logical state.

    With ``summaries`` (a :class:`repro.core.summaries.ClusterSummaries`),
    the plan is filter-aware: a branch-free disjointness test between each
    query's DNF terms and the per-cluster interval/histogram summaries marks
    clusters the filter provably cannot match, and those probes are dropped
    *before* the per-tile dedup — they never get a slot, are never fetched
    by ``probes.fetch_order``, and are never scanned.  Results stay
    bit-identical to the unpruned plan (only zero-passing-row clusters can
    be pruned).

    ``t_max`` (static, > n_probes) additionally enables adaptive probe
    widening (paper §4.3 selectivity-adaptive T): each query's probe set is
    refilled with its next-best *unpruned* centroids from the geometric
    top-``t_max``, so selective filters keep ``n_probes`` productive probes
    instead of silently scanning fewer clusters.  Unfiltered queries prune
    nothing, refill nothing, and plan exactly as before.  Within the refill
    ranking, the summaries' histogram-mass estimate of each cluster's
    expected passing count breaks exact centroid-score ties.

    ``route_entry`` ([Q] int32, −1 = flat) + ``members`` ([E, K_base] int32,
    −1 = scan parent) remap routed queries' probes from base cluster ids to
    the chosen catalog entry's sub-partition ids *after* the centroid top-k
    (probing geometry stays base-only — sub centroids are never scored) and
    *before* the per-tile dedup, so sub ids flow into the slot tables, fetch
    lists and every (cluster_id, gen)-keyed cache below.  ``geo_probes``
    stays base-id (the delta tier's membership mask is defined over base
    assignments).  Subsumption (checked host-side by the catalog's router)
    guarantees the remapped scan is bit-identical to the flat one.
    """
    scores = centroid_scores(centroids, counts, queries, metric=metric)
    q = queries.shape[0]
    if summaries is None:
        cvals, probe_ids = jax.lax.top_k(scores, n_probes)
        probe_ids = probe_ids.astype(jnp.int32)  # [Q, T]
        geo_ids = probe_ids
        geo_ok = cvals > topk_lib.NEG_INF / 2
        probe_valid = None
        n_pruned = jnp.zeros((q,), jnp.int32)
    else:
        cm = summaries_lib.can_match(summaries, lo, hi)  # [Q, K]
        width = n_probes if t_max is None else t_max
        cvals, cand = jax.lax.top_k(scores, width)  # [Q, W] geometric order
        cm_c = jnp.take_along_axis(cm, cand, axis=1)  # [Q, W]
        real = cvals > topk_lib.NEG_INF / 2  # exclude empty/padded clusters
        # geometric top-n_probes, captured before widening re-ranks cand:
        # the delta tier's membership mask must see the same probe set a
        # rebuilt index's planner would produce.
        geo_ids = cand[:, :n_probes].astype(jnp.int32)
        geo_ok = real[:, :n_probes]
        # accounting: probes a geometry-only planner would have scanned (and
        # the disk tier fetched) that the filter proved empty
        n_pruned = jnp.sum(
            jnp.logical_and(~cm_c[:, :n_probes], real[:, :n_probes])
            .astype(jnp.int32), axis=-1,
        )
        if t_max is None:
            # exact mode: the geometric top-T minus its pruned members
            probe_ids = cand.astype(jnp.int32)
            probe_valid = jnp.logical_and(cm_c, real)
        else:
            # widened mode: re-rank candidates by (centroid score, expected
            # passing mass) — the histogram estimate only breaks exact score
            # ties — then keep each query's first n_probes unpruned ones.
            epass = summaries_lib.expected_passing(summaries, lo, hi, counts)
            ep_c = jnp.take_along_axis(epass, cand, axis=1)
            order = jnp.lexsort((-ep_c, -cvals), axis=-1)  # last key primary
            cand = jnp.take_along_axis(cand, order, axis=1)
            cm_c = jnp.take_along_axis(cm_c, order, axis=1)
            real = jnp.take_along_axis(real, order, axis=1)
            ok = jnp.logical_and(cm_c, real)
            rank = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
            probe_ids = cand.astype(jnp.int32)
            probe_valid = jnp.logical_and(ok, rank < n_probes)
    if members is not None:
        # partition remap: routed queries swap each probed base cluster for
        # the entry's sub-partition of it (-1 member = keep the parent)
        ent = jnp.maximum(route_entry, 0)
        sub = members[ent[:, None], probe_ids]  # [Q, W]
        probe_ids = jnp.where(
            jnp.logical_and(route_entry[:, None] >= 0, sub >= 0),
            sub, probe_ids,
        )
    probe_pad = probes_lib.pad_to_tiles(probe_ids, q_block)  # [Qpad, W]
    valid_pad = (
        None if probe_valid is None
        else probes_lib.pad_to_tiles(probe_valid, q_block)
    )
    geo_pad = probes_lib.pad_to_tiles(geo_ids, q_block)  # [Qpad, T]
    geo_ok_pad = probes_lib.pad_to_tiles(geo_ok, q_block)
    queries_pad = probes_lib.pad_to_tiles(queries.astype(cast_dtype), q_block)
    lo_pad = probes_lib.pad_to_tiles(lo, q_block)
    hi_pad = probes_lib.pad_to_tiles(hi, q_block)
    slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique = (
        probes_lib.plan_probe_tiles(probe_pad, q_block=q_block, u_cap=u_cap,
                                    probe_valid=valid_pad)
    )
    return (slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique,
            queries_pad, lo_pad, hi_pad, n_pruned, geo_pad, geo_ok_pad)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "k", "q", "q_block", "v_block", "backend"),
)
def _scan_merge_tiled(
    slot_cluster: Array,
    slot_tile: Array,
    slot_of_probe: Array,
    probe_ok: Array,
    queries: Array,      # [Q, D] original (for the l2 ‖q‖² constant)
    queries_pad: Array,  # [Qpad, D] cast + tile-padded
    lo_pad: Array,
    hi_pad: Array,
    vectors: Array,
    attrs: Array,
    ids: Array,
    norms: Optional[Array],
    scales: Optional[Array],
    *,
    metric: str,
    k: int,
    q: int,
    q_block: int,
    v_block: int,
    backend: str,
) -> SearchResult:
    """Scan + merge stages: scan the planned slots, merge per-probe fragments.

    ``vectors/attrs/ids/...`` are indexed by ``slot_cluster`` rows — either
    the full ``[K, Vpad, ...]`` resident arrays (RAM tier) or batch-local
    gathered ``[S, Vpad, ...]`` blocks with slot-local ids (disk tier).  The
    kernel only ever dereferences rows named in ``slot_cluster``, so the two
    are indistinguishable to it.  The pipelined executor calls this once per
    tile (``q = q_block``, ``slot_tile ≡ 0``) with identical per-slot
    arithmetic, so its results are bit-identical to one whole-batch call.
    """
    from repro.kernels.filtered_scan.filtered_scan import filtered_scan_tiled

    qpad = queries_pad.shape[0]
    if backend in ("pallas", "pallas_interpret"):
        svals, sids, snpass = filtered_scan_tiled(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block, v_block=v_block,
            interpret=backend == "pallas_interpret",
        )
    elif backend == "xla":
        svals, sids, snpass = tiled_scan_xla(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block,
        )
    else:
        raise ValueError(backend)

    # Per-probe candidate fragments, then the monoid merge across T probes.
    # Probes that overflowed an undersized u_cap are dropped soundly (their
    # fragments masked out), mirroring the distributed dispatch's P_cap.
    row = jnp.arange(qpad, dtype=jnp.int32) % q_block  # [Qpad]
    vals_qt = svals[slot_of_probe, row[:, None]]  # [Qpad, T, k]
    ids_qt = sids[slot_of_probe, row[:, None]]
    npass_qt = snpass[slot_of_probe, row[:, None]]  # [Qpad, T]
    vals_qt = jnp.where(probe_ok[..., None], vals_qt, topk_lib.NEG_INF)
    ids_qt = jnp.where(probe_ok[..., None], ids_qt, -1)
    npass_qt = jnp.where(probe_ok, npass_qt, 0)
    vals, out_ids = topk_lib.merge_topk_many(vals_qt, ids_qt, k, axis=1)
    vals, out_ids = vals[:q], out_ids[:q]

    if metric == "l2":
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        vals = jnp.where(
            vals > topk_lib.NEG_INF / 2, vals - q2[:, None], vals
        )

    n_passed = jnp.sum(npass_qt[:q], axis=-1)
    # Scan accounting through the slot tables: a probe's slot scans exactly
    # its cluster, so live-rows-per-slot gathered by slot_of_probe equals the
    # old per-cluster lookup — and works when only gathered rows exist.
    live_per_row = jnp.sum((ids >= 0).astype(jnp.int32), axis=-1)  # [K or S]
    live_per_slot = jnp.take(live_per_row, slot_cluster)  # [S_flat]
    n_scanned = jnp.sum(
        jnp.take(live_per_slot, slot_of_probe[:q])
        * probe_ok[:q].astype(jnp.int32),
        axis=-1,
    )
    return SearchResult(vals, out_ids, n_scanned, n_passed)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "k", "q_block", "v_block", "backend"),
)
def _scan_slots(
    slot_cluster: Array,   # [S] rows into the operand arrays (one segment)
    queries_pad: Array,    # [QB, D] one tile's cast queries
    lo_pad: Array,
    hi_pad: Array,
    vectors: Array,
    attrs: Array,
    ids: Array,
    norms: Optional[Array],
    scales: Optional[Array],
    *,
    metric: str,
    k: int,
    q_block: int,
    v_block: int,
    backend: str,
):
    """Scan stage alone: one slot segment's ``[S, QB, k]`` fragments.

    Exactly :func:`_scan_merge_tiled`'s scan half over a slice of a tile's
    slot table (``slot_tile ≡ 0`` — one query tile).  Per-slot arithmetic is
    independent of which other slots share the call, so fragments from
    segmented scans are bitwise the fragments one whole-table scan produces
    — the bound-driven executor's exactness rides on that.
    """
    from repro.kernels.filtered_scan.filtered_scan import filtered_scan_tiled

    slot_tile = jnp.zeros((slot_cluster.shape[0],), jnp.int32)
    if backend in ("pallas", "pallas_interpret"):
        return filtered_scan_tiled(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block, v_block=v_block,
            interpret=backend == "pallas_interpret",
        )
    elif backend == "xla":
        return tiled_scan_xla(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block,
        )
    raise ValueError(backend)


@functools.partial(jax.jit, static_argnames=("metric", "k", "q"))
def _merge_tile_fragments(
    svals: Array,          # [S_pad, QB, k] per-slot fragments (filler where
    sids: Array,           #   a segment was never scanned)
    snpass: Array,         # [S_pad, QB]
    slot_of_probe: Array,  # [QB, W] tile-local slot pointers
    pair_ok: Array,        # [QB, W] — probe contributes candidates
    scan_ok: Array,        # [QB, W] — probe's slot was actually scanned
    queries: Array,        # [QB, D] original dtype (l2 ‖q‖² constant)
    live_per_slot: Array,  # [S_pad] live rows of each slot's cluster
    *,
    metric: str,
    k: int,
    q: int,
) -> SearchResult:
    """Merge stage for a bound-terminated tile.

    :func:`_scan_merge_tiled`'s merge half with two masks instead of one:
    ``pair_ok`` additionally excludes ε-dropped (query, slot) pairs — their
    fragments may exist (another query kept the segment alive) but the
    bounded-mode contract is that the result equals an exact top-k over the
    surviving probe universe, so they must not leak in.  Provably-dropped
    pairs whose segment was scanned anyway stay *included*: every candidate
    they hold is strictly below the query's final kth, so including them is
    what keeps ``termination="exact"`` bitwise identical to the untruncated
    merge.  ``scan_ok`` keeps ``n_scanned`` honest (terminated slots did no
    scan work).
    """
    row = jnp.arange(svals.shape[1], dtype=jnp.int32)  # [QB]
    vals_qt = svals[slot_of_probe, row[:, None]]  # [QB, W, k]
    ids_qt = sids[slot_of_probe, row[:, None]]
    npass_qt = snpass[slot_of_probe, row[:, None]]  # [QB, W]
    vals_qt = jnp.where(pair_ok[..., None], vals_qt, topk_lib.NEG_INF)
    ids_qt = jnp.where(pair_ok[..., None], ids_qt, -1)
    npass_qt = jnp.where(pair_ok, npass_qt, 0)
    vals, out_ids = topk_lib.merge_topk_many(vals_qt, ids_qt, k, axis=1)
    vals, out_ids = vals[:q], out_ids[:q]

    if metric == "l2":
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)
        vals = jnp.where(
            vals > topk_lib.NEG_INF / 2, vals - q2[:q, None], vals
        )

    n_passed = jnp.sum(npass_qt[:q], axis=-1)
    n_scanned = jnp.sum(
        jnp.take(live_per_slot, slot_of_probe[:q])
        * scan_ok[:q].astype(jnp.int32),
        axis=-1,
    )
    return SearchResult(vals, out_ids, n_scanned, n_passed)


def resolve_prune(index, prune: str):
    """Resolves the ``prune`` knob against an index's summaries.

    Returns the :class:`~repro.core.summaries.ClusterSummaries` to plan with,
    or None for no pruning.  ``"auto"`` prunes iff the index carries
    summaries; ``"on"`` demands them; ``"off"`` never prunes.
    """
    summ = getattr(index, "summaries", None)
    if prune == "off":
        return None
    if prune == "on":
        if summ is None:
            raise ValueError(
                "prune='on' but the index has no cluster summaries — build "
                "with with_summaries=True or re-save the checkpoint (layout "
                "v2.1), or use prune='auto'"
            )
        return summ
    if prune == "auto":
        return summ
    raise ValueError(f"prune must be 'auto'|'on'|'off', got {prune!r}")


@jax.jit
def _batch_pass_fraction(summaries, counts, lo, hi):
    """Per-query expected passing-mass fraction from the resident summaries
    — the cheap, tier-agnostic selectivity estimate (the disk tier has no
    resident attrs to sample)."""
    ep = summaries_lib.expected_passing(summaries, lo, hi, counts)  # [Q, K]
    tot = jnp.maximum(jnp.sum(counts.astype(jnp.float32)), 1.0)
    return jnp.sum(ep, axis=1) / tot


# t_max="auto" widening factors: powers of two over n_probes, so the set of
# distinct plan/scan widths a serving mix can trigger stays bounded (same
# bounded-compile argument as the u_cap buckets).
AUTO_T_FACTORS = (2, 4, 8)


def resolve_auto_t_max(summaries, counts, lo, hi, n_probes: int,
                       n_clusters: int,
                       factors: Tuple[int, ...] = AUTO_T_FACTORS
                       ) -> Optional[int]:
    """Summary-driven per-batch probe widening (``t_max="auto"``).

    Estimates the batch's filter selectivity from the summaries' expected
    passing mass and widens the probe search proportionally: a batch whose
    filters pass ~1/f of the corpus gets its pruned probes refilled from the
    geometric top-``f·n_probes`` (capped at ``factors[-1]``, bucketed into
    powers of two so compiles stay bounded).  Unfiltered batches estimate
    selectivity ~1 and return None — bit-identical to the static plan.
    """
    if summaries is None:
        return None
    sel = float(np.median(np.asarray(
        _batch_pass_fraction(summaries, counts, lo, hi)
    )))
    need = 1.0 / max(sel, 1e-9)
    factor = 1
    for f in factors:
        if need >= f:
            factor = f
    if factor == 1:
        return None
    return min(factor * n_probes, n_clusters)


# ---------------------------------------------------------------------------
# Plan objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TileWork:
    """One query tile's slice of a :class:`SearchPlan` (host-side).

    ``fetch`` is the tile's *novel* cluster list — ids not needed by any
    earlier tile, in first-need (slot) order; concatenating every tile's
    ``fetch`` reproduces ``probes.fetch_order`` for the whole plan, which is
    what a slot-granular pager (or a multi-host cache router) consumes.
    ``release`` is the mirror image — clusters no *later* tile needs — and
    is what lets the per-batch operand cache free each record right after
    its last consumer, keeping reuse inside the disk tier's memory budget.
    """

    tile: int
    slot_cluster: np.ndarray  # [u_cap] int32 — global cluster per slot
    n_unique: int             # live slots (the rest are pads)
    fetch: np.ndarray         # novel clusters, first-need order
    release: np.ndarray       # clusters whose last need is this tile


@dataclasses.dataclass
class TermState:
    """Per-batch bound-driven termination state (host-side numpy).

    Built by :meth:`SearchEngine._prepare_termination` *after* the slot
    tables have been permuted best-bound-first, so every array here indexes
    ``(tile, query-row, slot-position)`` in the order the segmented executor
    scans.  ``ub`` already carries the dtype-aware rounding margin — the
    executor compares it raw against the running kth.
    """

    epsilon: float        # ε-drop threshold (0 in termination="exact")
    seg: int              # slot positions per segment (multiple of 4)
    n_seg: int            # segments per tile
    cap: int              # true table width (cap_pad = seg · n_seg ≥ cap)
    ub: np.ndarray        # [n_tiles, QB, cap_pad] f64 — score upper bound
    lb: np.ndarray        # [n_tiles, QB, cap_pad] f64 — rough lower bound
                          #   (only scales the ε probability model)
    mass: np.ndarray      # [n_tiles, QB, cap_pad] f64 — expected passing
                          #   rows of the pair's cluster (ε model's m)
    valid: np.ndarray     # [n_tiles, QB, cap_pad] bool — real (q, slot) pair


@dataclasses.dataclass
class SearchPlan:
    """Everything the fetch/scan/merge stages need, produced by plan().

    Slot tables are numpy (host) when the executor needs them per tile
    (pipelined mode, disk fetch lists, adaptive shrink) and device arrays on
    the pure-RAM sync fast path — the scan stage accepts either.
    """

    q: int
    q_block: int
    n_tiles: int
    u_cap: int               # provisioned table width (post-bucketing)
    width: int               # probe table width (n_probes or t_max)
    slot_cluster: Any        # [n_tiles·u_cap]
    slot_tile: Any           # [n_tiles·u_cap]
    slot_of_probe: Any       # [Qpad, W]
    probe_ok: Any            # [Qpad, W]
    n_unique: Any            # [n_tiles]
    queries: Array           # [Q, D] original (l2 constant)
    # [Qpad, D] original dtype, tile-padded — only the pipelined per-tile
    # executor reads it, so it is built lazily (None on sync plans)
    queries_orig_pad: Optional[Array]
    queries_pad: Array       # [Qpad, D] cast to the scan dtype
    lo_pad: Array
    hi_pad: Array
    n_pruned: Array          # [Q]
    # Geometric top-n_probes candidate clusters per (padded) query — the
    # delta tier's probe-membership mask.  None when the plan was built
    # without a delta tier attached (zero overhead on frozen serving).
    geo_probes: Optional[Array] = None   # [Qpad, T] int32
    geo_valid: Optional[Array] = None    # [Qpad, T] bool
    # Expected per-cluster generation vector at plan time (layout v3 disk
    # tier) — every fetch of this batch carries it so no cache layer can
    # silently serve a block from before the last republish.
    gens: Optional[np.ndarray] = None    # [K] int64
    # Immutable view of the RAM delta segment captured at plan(): the batch
    # scans exactly this set of delta rows/tombstones regardless of
    # concurrent appends (appends land in the next batch's snapshot).
    delta_snap: Any = None
    # Per-query chosen partition-catalog entry (−1 = flat path); None when
    # the index has no catalog or partitions are off.  Drives the planner's
    # probe remap and the partition/flat scanned-row accounting.
    route: Optional[np.ndarray] = None  # [Q] int32
    # Per-tile work items, built lazily by tile_work() (consumers: the
    # BlockStore fetch stage's per-tile novel-cluster lists, fetch routing
    # diagnostics, multi-host cache sharding).
    tiles: Optional[List[TileWork]] = None
    # Per-batch operand cache (BlockStore fetch path): cluster id → host
    # record, filled as tiles' fetches land; later tiles of the batch that
    # share the cluster assemble from these records instead of re-crossing
    # the store.  Dropped with the plan.
    operands: Optional[Dict[int, dict]] = None
    # Bound-driven termination state (None when the knob is off); built by
    # _prepare_termination before any fetch list exists, so the permuted
    # best-bound-first slot order propagates to fetch/prefetch for free.
    term: Optional[TermState] = None
    # Per-batch (cid, gen) fetch-accounting set: blocks_fetched counts each
    # distinct block once per batch even when an eviction/invalidation race
    # makes a later tile re-pull a block an earlier tile already fetched
    # (the device-cache gap-refetch double-count fix).
    fetched_keys: Optional[set] = None

    def tile_work(self) -> List[TileWork]:
        """Materializes (and caches) the per-tile work items with their
        novel-cluster fetch lists.  Requires a host plan (numpy tables)."""
        if self.tiles is None:
            sc = np.asarray(self.slot_cluster).reshape(
                self.n_tiles, self.u_cap
            )
            nu = np.asarray(self.n_unique)
            fetches = probes_lib.tile_fetch_lists(sc, nu, self.u_cap)
            releases = probes_lib.tile_release_lists(sc, nu, self.u_cap)
            self.tiles = [
                TileWork(tile=i, slot_cluster=sc[i], n_unique=int(nu[i]),
                         fetch=fetches[i], release=releases[i])
                for i in range(self.n_tiles)
            ]
        return self.tiles


@dataclasses.dataclass
class PendingSearch:
    """A batch started by :meth:`SearchEngine.submit` — its plan plus any
    tile gathers already in flight.  Finish with
    :meth:`SearchEngine.result`."""

    plan: SearchPlan
    inflight: Optional[Dict] = None


@dataclasses.dataclass
class EngineStats:
    """Per-engine execution counters (the bench reads these)."""

    batches: int = 0
    pipelined_batches: int = 0
    tiles_scanned: int = 0
    # jit cache misses for the scan stage: +1 whenever this engine dispatches
    # a (shape, backend, ...) scan signature no engine in the process has
    # compiled before — the bench's bounded-recompile gate.
    scan_compilations: int = 0
    # fetch-stage overlap accounting (pipelined disk tier)
    io_wait_s: float = 0.0    # time execute() blocked on gather_wait
    io_total_s: float = 0.0   # submit→completion span of every gather
    last_u_cap: int = 0
    u_cap_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # BlockStore fetch path accounting
    blocks_fetched: int = 0   # per-cluster blocks pulled through the store
    blocks_reused: int = 0    # slots served from the per-batch operand
    #                           cache instead of being re-assembled/re-put
    # degradation accounting: batches completed while the store reported a
    # non-closed peer circuit (results stay bit-identical — the fallback
    # serves the same records — but the fleet should know it ran degraded)
    degraded_batches: int = 0
    # batches whose result folded a non-empty RAM delta segment (live
    # serving); frozen-checkpoint serving keeps this at 0
    delta_folds: int = 0
    # batches that skipped the delta scan because the segment's resident
    # attribute summary proved no live delta row can pass any query's
    # filter (results identical; only the scan is saved)
    delta_skips: int = 0
    # bound-driven termination: (query, slot) pairs dropped before their
    # segment was scanned — provably (upper bound below the running kth) or
    # probabilistically (ε mode) — and whole slot segments skipped because
    # every surviving pair in them was already terminated
    probes_terminated: int = 0
    term_segments_skipped: int = 0
    # partition plane: queries routed to a catalog entry / constrained
    # queries that fell back to the flat layout, and cold-scan row counts
    # split by which path the query took
    partition_hits: int = 0
    partition_fallbacks: int = 0
    partition_rows_scanned: int = 0
    flat_rows_scanned: int = 0
    # delta folds skipped by the per-attribute running interval envelope
    # (satellite of the summary-based delta_skips; also counted there)
    delta_interval_skips: int = 0

    @property
    def overlap_ratio(self) -> float:
        """Fraction of gather time hidden behind compute (1 = fully
        overlapped, 0 = fully serial)."""
        if self.io_total_s <= 0:
            return 0.0
        return max(0.0, 1.0 - self.io_wait_s / self.io_total_s)


def _flatten_metrics(out: Dict[str, Any], prefix: str, obj: Any) -> None:
    """Recursively flattens nested stats into ``prefix.key`` scalar entries
    (dict values recurse; numbers/bools/strings pass through; anything else
    is stringified so the scrape never chokes on a stray object)."""
    if isinstance(obj, dict):
        for key, val in obj.items():
            _flatten_metrics(out, f"{prefix}.{key}", val)
    elif isinstance(obj, (bool, int, float, str)) or obj is None:
        out[prefix] = obj
    elif isinstance(obj, (np.integer, np.floating)):
        out[prefix] = obj.item()
    else:
        out[prefix] = str(obj)


# Metric leaf names that are monotonically increasing counts — rendered as
# Prometheus counters; every other numeric metric is a gauge.
_PROM_COUNTERS = frozenset((
    "batches", "pipelined_batches", "tiles_scanned", "scan_compilations",
    "blocks_fetched", "blocks_reused", "degraded_batches", "delta_folds",
    "delta_skips", "hits", "misses", "puts", "evictions", "invalidations",
    "prefetched", "errors", "stalled_waits", "failovers",
    "redirected_blocks", "fallback_blocks", "stale_answers", "retries",
    "deadline_misses", "device_hits", "tile_hits", "tile_puts", "l1_hits",
    "l1_misses", "l1_invalidations", "remote_blocks", "blocks_served",
    "adds", "tombstoned", "commits", "scan_compile_count",
    "probes_terminated", "term_segments_skipped",
    "partition_hits", "partition_fallbacks", "partition_rows_scanned",
    "flat_rows_scanned", "delta_interval_skips", "fetches_skipped",
))


def _prom_name(key: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in key)
    return out if not out[:1].isdigit() else f"_{out}"


def render_prometheus(metrics: Dict[str, Any],
                      prefix: str = "repro") -> str:
    """Flat dotted-key metrics → Prometheus text exposition format.

    Dots become underscores (``engine.blocks_fetched`` →
    ``repro_engine_blocks_fetched``); booleans render as 0/1 gauges;
    strings become an info-style labeled sample
    (``repro_engine_backend{value="xla"} 1``); None is skipped.  Leaf
    names in :data:`_PROM_COUNTERS` are typed ``counter``, the rest
    ``gauge``.
    """
    lines: List[str] = []
    for key in sorted(metrics):
        val = metrics[key]
        if val is None:
            continue
        name = _prom_name(f"{prefix}.{key}")
        leaf = key.rsplit(".", 1)[-1]
        kind = "counter" if leaf in _PROM_COUNTERS else "gauge"
        if isinstance(val, bool):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {int(val)}")
        elif isinstance(val, (int, float)):
            lines.append(f"# TYPE {name} {kind}")
            lines.append(f"{name} {val}")
        else:
            label = str(val).replace("\\", "\\\\").replace('"', '\\"')
            lines.append(f"# TYPE {name} gauge")
            lines.append(f'{name}{{value="{label}"}} 1')
    return "\n".join(lines) + "\n"


# Fixed latency bucket upper bounds (seconds) for the per-stage histograms.
# Chosen to straddle the measured stage costs from sub-ms RAM-resident plans
# up to multi-second cold disk fetches; fixed so scrapes from different
# processes aggregate.
_LAT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                0.25, 0.5, 1.0, 2.5)


class StageHistogram:
    """Fixed-bucket latency histogram, Prometheus-renderable.

    Buckets are cumulative at render time (classic ``le`` semantics, with
    the implicit ``+Inf`` bucket equal to the total count); observation is
    O(#buckets) with no allocation, cheap enough for per-tile scan timing.
    """

    __slots__ = ("counts", "total", "sum")

    def __init__(self):
        self.counts = [0] * len(_LAT_BUCKETS)
        self.total = 0
        self.sum = 0.0

    def observe(self, seconds: float):
        self.total += 1
        self.sum += seconds
        for i, edge in enumerate(_LAT_BUCKETS):
            if seconds <= edge:
                self.counts[i] += 1
                break

    def render(self, name: str, labels: str) -> List[str]:
        lines = []
        cum = 0
        for edge, n in zip(_LAT_BUCKETS, self.counts):
            cum += n
            lines.append(f'{name}_bucket{{{labels},le="{edge}"}} {cum}')
        lines.append(f'{name}_bucket{{{labels},le="+Inf"}} {self.total}')
        lines.append(f"{name}_sum{{{labels}}} {self.sum}")
        lines.append(f"{name}_count{{{labels}}} {self.total}")
        return lines


def render_stage_histograms(hists: Dict[str, StageHistogram],
                            prefix: str = "repro") -> str:
    """``{stage: histogram}`` → Prometheus exposition text (one metric
    family, ``stage`` label per pipeline stage)."""
    if not hists:
        return ""
    name = f"{prefix}_stage_latency_seconds"
    lines = [f"# TYPE {name} histogram"]
    for stage in sorted(hists):
        lines.extend(hists[stage].render(name, f'stage="{stage}"'))
    return "\n".join(lines) + "\n"


# Process-wide registry of scan-stage signatures that have been dispatched;
# mirrors the underlying jit cache (which is also process-wide), so a new key
# here == a real XLA compilation.
_SCAN_KEYS: set = set()


def scan_compile_count() -> int:
    """Number of distinct scan-stage compilations this process has run."""
    return len(_SCAN_KEYS)


def u_cap_buckets(full_cap: int, lo: int = 8,
                  ladder: str = "pow2") -> Tuple[int, ...]:
    """The fixed u_cap bucket set for ``full_cap``.

    ``ladder="pow2"``: ``(8, 16, 32, ..., full_cap)`` — doubling widths from
    ``lo`` with the exact worst-case cap appended, so every observed unique
    count maps to a bucket and the bucket count (= max scan compilations) is
    ``log2(full_cap/8) + O(1)``.

    ``ladder="fine"`` additionally inserts the ×1.5 midpoint between each
    power-of-two pair (``8, 12, 16, 24, 32, 48, ...``): a batch observing 38
    uniques scans a 48-slot table instead of 64 — the XLA executor's cost is
    linear in table width, so the midpoints buy back up to ~25% of the slot
    scans right above a bucket edge, at the price of ~2× the worst-case
    compile count (still bounded; measured in BENCH_search.json's
    ``u_cap_ladder_ab``).
    """
    if ladder not in ("pow2", "fine"):
        raise ValueError(f"ladder must be 'pow2'|'fine', got {ladder!r}")
    caps = []
    b = lo
    while b < full_cap:
        caps.append(b)
        if ladder == "fine":
            mid = (b * 3) // 2
            if mid < full_cap:
                caps.append(mid)
        b *= 2
    caps.append(full_cap)
    return tuple(sorted(set(caps)))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class SearchEngine:
    """Single entry point for the tiled fused search, both tiers.

    Knobs (latency ↔ throughput):
      * ``pipeline`` — ``"off"``: one whole-batch fetch + one scan (lowest
        per-batch latency when the data is RAM-resident).  ``"on"``: per-tile
        double-buffered fetch/scan overlap (disk-tier throughput; identical
        results).  ``"auto"``: on iff the index pages from disk.
      * ``pipeline_depth`` — gathers kept in flight ahead of the scan
        (2 = classic double buffering; more overlaps deeper but holds more
        gathered tiles in host memory).
      * ``adaptive_u_cap`` — provision the slot table from the observed
        post-prune unique counts (power-of-two buckets, bounded recompiles)
        instead of the worst case.  ``u_cap`` pins the width instead.
      * ``q_block`` — query-tile height: smaller tiles → finer pipeline
        grain (more IO/compute overlap) but more per-tile dispatches.  With
        the operand cache, finer grain no longer pays re-assembly for the
        clusters tiles share.
      * ``operand_cache`` — per-batch reuse of fetched cluster blocks
        (BlockStore path only): each block crosses the store (ring hop,
        cache lock, mmap read) once per batch; tiles that share it assemble
        straight from the batch-local records on the fetch worker
        (``"auto"``/``"on"``/``"off"``; ``blocks_reused`` counts slots
        served from the batch cache).
      * ``u_cap_ladder`` — ``"pow2"`` (default) or ``"fine"`` (×1.5
        midpoints): finer buckets waste fewer pad-slot scans right above a
        bucket edge at ~2× the bounded compile count.
      * ``t_max`` — static widening cap, or ``"auto"`` to pick the per-batch
        cap from the summaries' expected passing mass (bucketed ×2/×4/×8).
      * ``termination`` — bound-driven early termination. ``"exact"``: scan
        each tile's probes best-bound-first in segments and drop remaining
        probes whose score upper bound is provably below the running kth —
        bit-identical results, fewer slot scans. ``"bounded"`` with
        ``epsilon``: additionally drop probes whose probability of
        contributing a top-k row (bound + summary-mass model) is ≤ ε — a
        recall-bounded speed tier (recall@k ≥ 1 − ε per dropped-probe
        model; gated empirically in BENCH_search.json). ``None`` (default)
        keeps the unterminated executors byte-for-byte.

    ``index`` needs the resident surface (``spec / centroids / counts /
    n_clusters / store_dtype / quantized / summaries``) plus one fetch
    source: resident ``vectors/attrs/ids/norms/scales`` (RAM tier), a
    ``blockstore`` (its own, or passed explicitly — e.g. a
    :class:`~repro.core.blockstore.ShardedBlockStore`), or a legacy
    ``gather`` method (``gather_submit``/``gather_wait`` unlock the async
    fetch).
    """

    def __init__(self, index, *, k: int, n_probes: int, q_block: int = 64,
                 v_block: int = 256, u_cap: Optional[int] = None,
                 backend: Optional[str] = None,
                 gather_fn: Optional[Callable] = None,
                 blockstore=None,
                 prune: str = "auto", t_max=None,
                 pipeline: str = "auto", pipeline_depth: int = 2,
                 adaptive_u_cap: Optional[bool] = None,
                 u_cap_bucket_set: Optional[Tuple[int, ...]] = None,
                 u_cap_ladder: str = "pow2",
                 operand_cache: str = "auto",
                 delta=None,
                 device_cache=None,
                 termination: Optional[str] = None,
                 epsilon: float = 0.0,
                 partitions: str = "auto"):
        if termination not in (None, "exact", "bounded"):
            raise ValueError(f"termination must be None|'exact'|'bounded', "
                             f"got {termination!r}")
        if not (0.0 <= float(epsilon) < 1.0):
            raise ValueError(f"epsilon must be in [0, 1), got {epsilon!r}")
        if epsilon > 0.0 and termination != "bounded":
            raise ValueError("epsilon > 0 requires termination='bounded'")
        if pipeline not in ("auto", "on", "off"):
            raise ValueError(f"pipeline must be 'auto'|'on'|'off', got "
                             f"{pipeline!r}")
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if operand_cache not in ("auto", "on", "off"):
            raise ValueError(f"operand_cache must be 'auto'|'on'|'off', got "
                             f"{operand_cache!r}")
        if isinstance(t_max, str) and t_max != "auto":
            raise ValueError(f"t_max must be an int, 'auto' or None, got "
                             f"{t_max!r}")
        if partitions not in ("auto", "on", "off"):
            raise ValueError(f"partitions must be 'auto'|'on'|'off', got "
                             f"{partitions!r}")
        self.partitions = partitions
        # filter-traffic recorder (partition-attribute choice input) and the
        # planner's base-width array views / device members table, built
        # lazily on the first planned batch
        self._traffic = None
        self._base_memo = None
        self._members_memo = None
        self.index = index
        self.k = k
        self.n_probes = n_probes
        self.q_block = q_block
        self.v_block = v_block
        self.u_cap = u_cap
        self.prune = prune
        self.t_max = t_max
        self.pipeline_depth = pipeline_depth
        self.u_cap_bucket_set = u_cap_bucket_set
        if u_cap_ladder not in ("pow2", "fine"):
            raise ValueError(f"u_cap_ladder must be 'pow2'|'fine', got "
                             f"{u_cap_ladder!r}")
        self.u_cap_ladder = u_cap_ladder
        self.operand_cache = operand_cache
        self.backend = backend or (
            "pallas" if jax.default_backend() == "tpu" else "xla"
        )
        # fetch source: explicit gather_fn wins (the pre-BlockStore path,
        # kept as the A/B baseline and for custom pagers); otherwise an
        # explicit or index-provided BlockStore; otherwise the index's own
        # legacy pager; otherwise the resident arrays (RAM tier).
        self._store = None
        if gather_fn is not None:
            self._gather_fn = gather_fn
        else:
            self._store = (blockstore if blockstore is not None
                           else getattr(index, "blockstore", None))
            self._gather_fn = (
                self._store_gather if self._store is not None
                else getattr(index, "gather", None)
            )
        self._bspec = (
            blockstore_lib.BlockSpec.from_index(index)
            if self._store is not None else None
        )
        if operand_cache == "on" and self._store is None:
            raise ValueError("operand_cache='on' needs a BlockStore fetch "
                             "path (disk tier or explicit blockstore=)")
        # cross-batch device-resident block cache: explicit instance or byte
        # budget wins; otherwise the index's attached cache
        # (make_fused_search_fn(device_cache_mb=...) sets index.device_cache)
        dc = (device_cache if device_cache is not None
              else getattr(index, "device_cache", None))
        if isinstance(dc, (int, float)):
            from repro.core.devicecache import DeviceBlockCache

            if self._store is None:
                raise ValueError("device_cache needs a BlockStore fetch "
                                 "path (disk tier or explicit blockstore=)")
            heat = getattr(getattr(index, "cache", None), "probe_heat", None)
            dc = DeviceBlockCache(self._bspec, int(dc), heat_fn=heat)
        if dc is not None and self._store is None:
            raise ValueError("device_cache needs a BlockStore fetch path "
                             "(disk tier or explicit blockstore=)")
        self._device_cache = dc
        # async pair available iff the source IS the index's legacy pager
        self._async_src = (
            index if (self._store is None
                      and self._gather_fn is not None
                      and getattr(index, "gather_submit", None) is not None
                      and self._gather_fn == index.gather)
            else None
        )
        self.pipeline = (
            pipeline if pipeline != "auto"
            else ("on" if self._gather_fn is not None else "off")
        )
        # adaptive provisioning defaults on when the caller didn't pin u_cap
        self.adaptive_u_cap = (
            (u_cap is None) if adaptive_u_cap is None else adaptive_u_cap
        )
        if self.adaptive_u_cap and u_cap is not None:
            raise ValueError("u_cap and adaptive_u_cap are exclusive")
        # RAM delta tier: explicit wins; otherwise the index's attached tier
        # (DiskIVFIndex.delta / make_fused_search_fn(delta_budget_mb=...)).
        self._delta = delta
        # Bound-driven early termination: "exact" drops only provably-losing
        # probes (bitwise-identical results); "bounded" additionally drops
        # probes whose win probability under the bound model is ≤ epsilon.
        self.termination = termination
        self.epsilon = float(epsilon)
        self._bounds_cache = None  # (key, ClusterBounds) lazy-build memo
        # per-stage fixed-bucket latency histograms (plan/fetch/scan/merge/
        # delta_fold), appended to metrics_text() for the Prometheus scrape
        self._stage_hist: Dict[str, StageHistogram] = {}
        self.stats = EngineStats()

    def _observe_stage(self, stage: str, seconds: float):
        hist = self._stage_hist.get(stage)
        if hist is None:
            hist = self._stage_hist[stage] = StageHistogram()
        hist.observe(seconds)

    def _delta_tier(self):
        if self._delta is not None:
            return self._delta
        return getattr(self.index, "delta", None)

    # ---- partition routing (plan-side) ----
    def _resolve_partitions(self):
        """Resolves the ``partitions`` knob against the index's catalog.

        Returns the :class:`~repro.core.partitions.PartitionCatalog` to
        route with, or None for the flat-only planner.  ``"auto"`` routes
        iff the index carries a catalog; ``"on"`` demands one; ``"off"``
        never routes (bit-identical to the pre-partition planner)."""
        cat = getattr(self.index, "partitions", None)
        if self.partitions == "off":
            return None
        if self.partitions == "on" and cat is None:
            raise ValueError(
                "partitions='on' but the index has no partition catalog — "
                "save the checkpoint with layout v4 "
                "(save_index(partitions=build_partitions(...))) or use "
                "partitions='auto'"
            )
        return cat

    def _base_views(self, cat, summ):
        """Base-width planner views of centroids/counts/summaries.

        The disk tier's resident arrays are already base-width; a RAM index
        with attached partitions carries the sub rows inline (scan targets),
        and planning over them would probe duplicated sub centroids — so the
        planner slices to ``[:n_base]``, memoized until the arrays swap."""
        index = self.index
        cents = index.centroids
        nb = cat.n_base
        if int(np.shape(cents)[0]) == nb:
            return cents, index.counts, summ
        memo = self._base_memo
        if memo is not None and memo[0] == id(cents):
            return memo[1], memo[2], (memo[3] if summ is not None else None)
        c = cents[:nb]
        cnt = index.counts[:nb]
        s = None
        if summ is not None:
            s = dataclasses.replace(
                summ, amin=summ.amin[:nb], amax=summ.amax[:nb],
                hist=summ.hist[:nb],
            )
        self._base_memo = (id(cents), c, cnt, s)
        return c, cnt, s

    def _members_device(self, cat):
        """The catalog's [E, K_base] member table as a device array (the
        plan-stage remap operand), memoized per catalog object."""
        memo = self._members_memo
        if memo is not None and memo[0] == id(cat):
            return memo[1]
        m = jnp.asarray(cat.members, jnp.int32)
        self._members_memo = (id(cat), m)
        return m

    def _route_partitions(self, cat, fspec: FilterSpec):
        """Host-side narrowest-subsuming-entry routing + traffic recording.

        Returns ``(route, route_entry, members)`` — the [Q] entry choice
        (−1 = flat) and the remap operands for :func:`plan_fused_tiled` —
        or ``(None, None, None)`` when no catalog is active."""
        lo_np = np.asarray(fspec.lo)
        hi_np = np.asarray(fspec.hi)
        if self.partitions != "off":
            if self._traffic is None:
                from repro.core.partitions import FilterTrafficRecorder

                self._traffic = FilterTrafficRecorder(int(lo_np.shape[-1]))
            self._traffic.observe(lo_np, hi_np)
        if cat is None:
            return None, None, None
        route = cat.route(lo_np, hi_np)  # [Q] int32
        hits = int(np.sum(route >= 0))
        self.stats.partition_hits += hits
        # fallbacks: queries that DO constrain some attribute but no catalog
        # entry subsumes them (unfiltered queries are not "fallbacks" — the
        # flat path is simply their layout)
        nonvoid = np.all(lo_np <= hi_np, axis=-1)  # [Q, T]
        narrowed = np.any(
            (lo_np > summaries_lib.ATTR_MIN)
            | (hi_np < summaries_lib.ATTR_MAX), axis=-1,
        )
        constrained = np.any(nonvoid & narrowed, axis=-1)  # [Q]
        self.stats.partition_fallbacks += int(
            np.sum(constrained & (route < 0))
        )
        if hits == 0:
            return route, None, None  # keep the flat plan signature
        return route, jnp.asarray(route), self._members_device(cat)

    @property
    def traffic(self):
        """The engine's filter-traffic recorder (partition-attribute choice
        input for rebuilds); None until a batch has been planned."""
        return self._traffic

    # ---- plan ----
    def plan(self, queries: Array, fspec: FilterSpec) -> SearchPlan:
        """Plan stage: jitted resident-state plan + host-side provisioning.

        Always plans at the sound worst-case table width (one compile); with
        ``adaptive_u_cap`` the tables are then shrunk to the smallest
        power-of-two bucket covering the observed per-tile unique counts.
        """
        t0 = time.perf_counter()
        index = self.index
        q = queries.shape[0]
        qb = min(self.q_block, round_up(q, 8))
        summ = resolve_prune(index, self.prune)
        # Partition routing: probing geometry (centroid top-k, summaries,
        # widening, bounds) always runs over the BASE clusters — sub ids
        # only enter via the plan-stage probe remap below, so an index with
        # a catalog plans exactly like the flat index for unrouted queries.
        cat = self._resolve_partitions()
        # a RAM index with attached sub-partitions carries them inline in
        # the per-cluster arrays — the planner slices to base width even
        # with routing off, else the centroid top-k would probe the subs'
        # duplicated centroids (not the flat plan)
        cat_any = getattr(index, "partitions", None)
        centroids = index.centroids
        counts = index.counts
        kc = index.n_clusters
        if cat_any is not None:
            kc = cat_any.n_base
            centroids, counts, summ = self._base_views(cat_any, summ)
        route, route_entry, members = self._route_partitions(cat, fspec)
        # Capture an immutable view of the RAM delta segment for this batch,
        # and plan with tombstone/append-adjusted cluster counts: a rebuilt
        # index would see those counts, and centroid_scores masks empty
        # clusters by count — parity requires the live planner to agree.
        tier = self._delta_tier()
        snap = tier.snapshot() if tier is not None else None
        if snap is not None:
            adj = tier.count_adjustment(kc)
            if adj is not None:
                counts = counts + jnp.asarray(adj)
        t_max = self.t_max
        if t_max == "auto":
            # summary-driven widening: bucketed per batch from the expected
            # passing mass, so a selective batch widens and an unfiltered
            # one plans exactly like t_max=None (bit-identical)
            t_max = resolve_auto_t_max(
                summ, counts, fspec.lo, fspec.hi, self.n_probes, kc
            )
        if t_max is not None:
            if t_max < self.n_probes:
                raise ValueError(
                    f"t_max={t_max} < n_probes={self.n_probes}"
                )
            t_max = min(t_max, kc)
            if summ is None or t_max == self.n_probes:
                t_max = None  # widening is only meaningful with pruning
        width = self.n_probes if t_max is None else t_max
        # remapped probes draw from base ∪ sub ids, so the per-tile unique
        # count can exceed the base cluster count — provision for the full
        # id space or the dedup's overflow drop would break parity
        k_total = kc + (cat.n_subs if cat is not None else 0)
        full_cap = min(qb * width, k_total)
        cap = full_cap if self.u_cap is None else self.u_cap
        cast_dtype = (
            np.dtype(np.float32) if index.quantized
            else np.dtype(index.store_dtype)
        )

        (slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique,
         queries_pad, lo_pad, hi_pad, n_pruned, geo_probes,
         geo_valid) = plan_fused_tiled(
            centroids, counts, queries, fspec.lo, fspec.hi,
            metric=index.spec.metric, n_probes=self.n_probes, q_block=qb,
            u_cap=cap, cast_dtype=cast_dtype, summaries=summ, t_max=t_max,
            route_entry=route_entry, members=members,
        )
        qpad = queries_pad.shape[0]
        n_tiles = qpad // qb

        # The sync RAM fast path needs no host view of the tables; the
        # pipelined / disk paths (per-tile slices, fetch lists) do.  The
        # adaptive provisioner alone only needs the tiny [n_tiles] unique
        # counts — the full tables come to host iff a shrink happens.
        need_host = (self.pipeline == "on" or self._gather_fn is not None
                     or self.termination is not None)
        plan = SearchPlan(
            q=q, q_block=qb, n_tiles=n_tiles, u_cap=cap, width=width,
            slot_cluster=slot_cluster, slot_tile=slot_tile,
            slot_of_probe=slot_of_probe, probe_ok=probe_ok,
            n_unique=n_unique, queries=queries,
            queries_orig_pad=(
                probes_lib.pad_to_tiles(queries, qb)
                if self.pipeline == "on" else None
            ),
            queries_pad=queries_pad, lo_pad=lo_pad, hi_pad=hi_pad,
            n_pruned=n_pruned,
            geo_probes=(geo_probes if snap is not None else None),
            geo_valid=(geo_valid if snap is not None else None),
            gens=self._plan_gens(),
            delta_snap=snap,
            route=route,
        )
        if self.adaptive_u_cap:
            self._provision(plan)
        if need_host:
            self._host_tables(plan)
        if self.termination is not None:
            # reorders the slot tables best-bound-first and attaches the
            # TermState; must run before any fetch list / TileWork exists so
            # fetch order and prefetch follow the scan order
            self._prepare_termination(plan, summ, counts)
        self.stats.last_u_cap = plan.u_cap
        self.stats.u_cap_hist[plan.u_cap] = (
            self.stats.u_cap_hist.get(plan.u_cap, 0) + 1
        )
        self._observe_stage("plan", time.perf_counter() - t0)
        return plan

    def _plan_gens(self) -> Optional[np.ndarray]:
        """Per-cluster expected-generation vector for this batch's fetches
        (None on pre-v3 / RAM indexes — every gen is implicitly 0)."""
        g = getattr(self.index, "gens", None)
        return None if g is None else np.asarray(g)

    def _host_tables(self, plan: SearchPlan):
        plan.slot_cluster = np.asarray(plan.slot_cluster)
        plan.slot_tile = np.asarray(plan.slot_tile)
        plan.slot_of_probe = np.asarray(plan.slot_of_probe)
        plan.probe_ok = np.asarray(plan.probe_ok)
        plan.n_unique = np.asarray(plan.n_unique)

    def _provision(self, plan: SearchPlan):
        """Adaptive u_cap: shrink the slot tables to the smallest bucket
        covering the observed per-tile unique counts.

        Sound by construction — the bucket is ≥ every tile's true unique
        count, so no probe is dropped and results stay bit-identical to the
        worst-case table; only pad slots (repeats of each tile's last unique
        id) are cut.  Only the [n_tiles] unique counts are synced to host
        to pick the bucket; the full tables follow only when a shrink
        actually happens (bucket == full leaves a device-only plan alone).
        """
        full = plan.u_cap
        plan.n_unique = np.asarray(plan.n_unique)
        max_u = max(int(plan.n_unique.max(initial=1)), 1)
        buckets = self.u_cap_bucket_set or u_cap_buckets(
            full, ladder=self.u_cap_ladder
        )
        bucket = next((b for b in sorted(buckets) if b >= max_u), full)
        bucket = min(bucket, full)
        if bucket == full:
            return
        self._host_tables(plan)
        sc = plan.slot_cluster.reshape(plan.n_tiles, full)[:, :bucket]
        plan.slot_cluster = np.ascontiguousarray(sc).reshape(-1)
        plan.slot_tile = np.repeat(
            np.arange(plan.n_tiles, dtype=np.int32), bucket
        )
        # re-base flat probe→slot pointers from stride `full` to `bucket`;
        # overflow-clipped junk pointers of not-ok probes stay in range.
        t_idx, s = divmod(plan.slot_of_probe, full)
        plan.slot_of_probe = (
            t_idx * bucket + np.minimum(s, bucket - 1)
        ).astype(np.int32)
        plan.u_cap = bucket

    # ---- bound-driven termination (plan-side) ----
    def _resolve_bounds(self):
        """The per-cluster :class:`~repro.core.summaries.ClusterBounds`:
        the index's precomputed row (disk tier; ``storage.load_bounds``),
        else lazily built from the resident flat lists and memoized until
        the arrays are swapped (refresh)."""
        index = self.index
        b = getattr(index, "bounds", None)
        if b is not None:
            return b
        vectors = getattr(index, "vectors", None)
        if vectors is None:
            raise ValueError(
                "termination needs per-cluster score bounds, but the index "
                "has neither a precomputed `bounds` attribute nor resident "
                "vectors to build one from. Re-save the index with this "
                "version (save_index now writes bounds_radius.npy / "
                "bounds_slack.npy) or attach storage.load_bounds() output."
            )
        key = (id(vectors), id(getattr(index, "scales", None)))
        cached = self._bounds_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        b = summaries_lib.build_bounds(
            index.centroids, vectors, index.ids,
            getattr(index, "norms", None), getattr(index, "scales", None),
        )
        self._bounds_cache = (key, b)
        return b

    def _prepare_termination(self, plan: SearchPlan, summ, counts):
        """Builds the batch's :class:`TermState` and reorders the slot
        tables best-bound-first.

        Per (query, slot) pair an upper bound on any row's kernel-space
        score is derived from resident state only: the centroid inner
        product plus a Cauchy–Schwarz ``‖q‖·radius`` term (dot), or the
        ``‖q‖² − max(d − radius, 0)²`` ball bound shifted by the cluster's
        norm slack (l2, pre-fixup space).  The bound is over the *stored*
        rows (SQ8 measured dequantized), widened by a dtype-aware rounding
        margin so float-accumulation noise can never flip a provable drop.
        Runs after adaptive provisioning (tables at their final width) and
        before any fetch list exists (fetch order follows the permutation).
        """
        index = self.index
        qb, cap, n_tiles = plan.q_block, plan.u_cap, plan.n_tiles
        qpad = qb * n_tiles
        bounds = self._resolve_bounds()
        sc = np.asarray(plan.slot_cluster).reshape(n_tiles, cap)
        cat = self._resolve_partitions()
        if cat is not None:
            # routed slots hold sub-partition ids; centroids / bounds /
            # summary mass are indexed base-width, and a parent's bound
            # soundly covers every sub (subset of its rows, same centroid)
            sc = cat.to_base(sc)

        # which (tile, query-row, slot) pairs are real probes
        sop = np.asarray(plan.slot_of_probe)
        pok = np.asarray(plan.probe_ok)
        tt, ss = np.divmod(sop, cap)
        qi = np.broadcast_to(
            (np.arange(qpad, dtype=np.int32) % qb)[:, None], sop.shape
        )
        valid = np.zeros((n_tiles, qb, cap), bool)
        valid[tt[pok], qi[pok], ss[pok]] = True

        # per-pair score bounds from the CAST queries (the kernel casts to
        # the store dtype before the matmul — bounding the cast query keeps
        # the bound sound for exactly what the kernel scores)
        qt = np.asarray(plan.queries_pad).astype(np.float32)
        qt = qt.reshape(n_tiles, qb, -1)
        C = np.asarray(index.centroids, dtype=np.float32)
        csel = C[sc]                                   # [n_tiles, cap, D]
        rsel = np.asarray(bounds.radius, np.float32)[sc][:, None, :]
        metric = index.spec.metric
        if metric == "dot":
            cs = np.einsum("tqd,tsd->tqs", qt, csel)
            qn = np.linalg.norm(qt, axis=-1)[:, :, None]
            ub = cs + qn * rsel
            lb = cs - qn * rsel
        else:  # l2 — kernel space 2q·x̂ − norms_row (‖q‖² not yet folded)
            qt64 = qt.astype(np.float64)
            c64 = csel.astype(np.float64)
            # ‖q − c‖ in float64: the expanded form cancels catastrophically
            # in f32 when q ≈ c, and an under-estimated d inflates nothing
            # but an OVER-estimated one would break the upper bound
            cs64 = np.einsum("tqd,tsd->tqs", qt64, c64)
            q2 = np.sum(qt64 * qt64, axis=-1)[:, :, None]
            c2 = np.sum(c64 * c64, axis=-1)[:, None, :]
            d = np.sqrt(np.maximum(q2 - 2.0 * cs64 + c2, 0.0))
            near = np.maximum(d - rsel, 0.0)
            ssel = np.asarray(bounds.slack, np.float32)[sc][:, None, :]
            ub = q2 - near * near + ssel
            lb = q2 - (d + rsel) ** 2
        # rounding margin: the kernel accumulates in f32 (operands possibly
        # 16-bit) — widen so accumulation noise can't beat the bound
        itemsize = np.dtype(index.store_dtype).itemsize
        tol = 1e-2 if (not index.quantized and itemsize == 2) else 1e-4
        # f64 state: the ε model subtracts the running kth (NEG_INF when a
        # query's top-k isn't full yet), which overflows in f32
        ub = ub.astype(np.float64) + (1e-3 + tol * np.abs(ub))
        lb = lb.astype(np.float64)

        # ε model's mass: expected passing rows of the pair's cluster under
        # the query's filter (live counts when summaries are off)
        if summ is not None:
            ep = np.asarray(summaries_lib.expected_passing(
                summ, plan.lo_pad, plan.hi_pad, counts
            ))
            mass = np.take_along_axis(
                ep.reshape(n_tiles, qb, -1), sc[:, None, :], axis=2
            )
        else:
            cnt = np.asarray(counts, np.float32)[sc][:, None, :]
            mass = np.broadcast_to(cnt, (n_tiles, qb, cap)).copy()

        # best-bound-first: permute each tile's live slots by descending
        # max-over-queries upper bound, remap probe pointers, co-permute
        slot_bound = np.where(valid, ub, -np.inf).max(axis=1)
        sc_flat, sop_new, perm = probes_lib.bound_order(
            plan.slot_cluster, plan.n_unique, plan.slot_of_probe,
            slot_bound, cap,
        )
        plan.slot_cluster = sc_flat
        plan.slot_of_probe = sop_new
        pq = perm[:, None, :]
        ub = np.take_along_axis(ub, pq, axis=2)
        lb = np.take_along_axis(lb, pq, axis=2)
        mass = np.take_along_axis(mass, pq, axis=2)
        valid = np.take_along_axis(valid, pq, axis=2)

        # segment the slot axis: ~4 segments per tile, widths a multiple of
        # 4 so every (bucket, seg) scan shape comes from a bounded set
        seg = max(4, ((-(-cap // 4) + 3) // 4) * 4)
        n_seg = -(-cap // seg)
        cap_pad = n_seg * seg
        if cap_pad > cap:
            padw = ((0, 0), (0, 0), (0, cap_pad - cap))
            ub = np.pad(ub, padw, constant_values=-np.inf)
            lb = np.pad(lb, padw, constant_values=-np.inf)
            mass = np.pad(mass, padw, constant_values=0.0)
            valid = np.pad(valid, padw, constant_values=False)
        plan.term = TermState(
            epsilon=(self.epsilon if self.termination == "bounded"
                     else 0.0),
            seg=seg, n_seg=n_seg, cap=cap,
            ub=ub, lb=lb, mass=mass, valid=valid,
        )

    # ---- fetch ----
    @property
    def blockstore(self):
        """The BlockStore the fetch stage routes through (None when the
        engine reads resident arrays or a legacy gather_fn)."""
        return self._store

    @property
    def _use_operand_cache(self) -> bool:
        return self._store is not None and self.operand_cache != "off"

    @property
    def device_cache(self):
        """The cross-batch device-resident block cache (None when off)."""
        return self._device_cache

    def _note_device_hits(self, n: int):
        """Tells a sharded store how many blocks the device cache served —
        fetches that never happened, i.e. avoided peer RPCs / disk reads."""
        if n <= 0:
            return
        note = getattr(self._store, "note_device_hits", None)
        if note is not None:
            note(n)

    def _count_fetched(self, plan: Optional[SearchPlan], cids):
        """``blocks_fetched`` accounting on fetch paths with a reuse layer
        (operand / device cache): deduped per batch by ``(cluster, gen)``.
        An eviction or partial invalidation between a tile's submit and its
        assembly makes the gap/missing fallbacks re-pull a block an earlier
        tile of the same batch already fetched — the counter reports
        distinct blocks, so a composed-tile memo hit after a partial
        invalidation no longer double-counts."""
        if plan is None:
            self.stats.blocks_fetched += len(cids)
            return
        if plan.fetched_keys is None:
            plan.fetched_keys = set()
        gens = plan.gens
        for c in cids:
            cid = int(c)
            key = (cid, int(gens[cid]) if gens is not None else 0)
            if key not in plan.fetched_keys:
                plan.fetched_keys.add(key)
                self.stats.blocks_fetched += 1

    def _store_gather(self, slot_cluster, gens: Optional[np.ndarray] = None,
                      plan: Optional[SearchPlan] = None):
        """Whole-list gather through the BlockStore protocol — the sync
        executor's fetch stage (same record ordering, and therefore cache
        behavior, as the pre-protocol pager).  ``gens`` is the full [K]
        expected-generation vector; each fetched cluster carries its entry
        so no cache layer can serve a pre-republish block."""
        flat = np.asarray(slot_cluster).reshape(-1)
        uniq, local = blockstore_lib.first_need_unique(flat)
        g = None if gens is None else gens[uniq]
        if self._device_cache is not None:
            return self._device_gather(flat, uniq, local, gens, plan=plan)
        recs = self._store.get(uniq, gens=g)
        self.stats.blocks_fetched += len(recs)
        return blockstore_lib.assemble_blocks(flat, uniq, local, recs,
                                              self._bspec)

    def _device_gather(self, flat, uniq, local, gens,
                       plan: Optional[SearchPlan] = None):
        """Device-cache-aware gather: resident clusters are served straight
        from the device cache (no store fetch, no host assembly, no H2D);
        only the misses cross the BlockStore, are device-put once and
        admitted.  The batch's blocks are composed on device with the host
        path's exact padding, so results stay bit-identical."""
        dc = self._device_cache
        egens = None if gens is None else gens[uniq]
        s = flat.shape[0]
        tile = dc.get_tile(uniq, s, egens)
        if tile is not None:  # exact repeat: the composed blocks, verbatim
            self._note_device_hits(len(uniq))
            self.stats.blocks_reused += len(uniq)
            return (local.astype(np.int32),) + tile
        hits, missing = dc.get_many(uniq, egens)
        self._note_device_hits(len(hits))
        self.stats.blocks_reused += len(hits)
        if missing:
            marr = np.asarray(missing, np.int64)
            recs = self._store.get(
                marr, gens=None if gens is None else gens[marr]
            )
            self._count_fetched(plan, recs)
            hits.update(dc.put_records(recs))
        entries = [hits[int(c)] for c in uniq]
        blocks = dc.compose(entries, s)
        dc.put_tile(uniq, s, entries, blocks)
        return (local.astype(np.int32),) + blocks

    def _expected_gens(self, plan: SearchPlan,
                       cids) -> Optional[np.ndarray]:
        """Expected generations for a fetch list, from the plan's vector."""
        if plan.gens is None:
            return None
        return plan.gens[np.asarray(cids, np.int64)]

    def fetch(self, plan: SearchPlan):
        """Whole-batch fetch stage (sync executor): resident arrays on the
        RAM tier, one gather over the plan's slot list on the disk tier."""
        index = self.index
        if self._gather_fn is None:
            return (plan.slot_cluster, index.vectors, index.attrs, index.ids,
                    index.norms, index.scales)
        t0 = time.perf_counter()
        if self._store is not None and self._gather_fn == self._store_gather:
            out = self._store_gather(plan.slot_cluster, gens=plan.gens,
                                     plan=plan)
        else:
            out = self._gather_fn(plan.slot_cluster)
        slot_cluster, vectors, attrs, ids, norms, scales = out
        self._observe_stage("fetch", time.perf_counter() - t0)
        return (jnp.asarray(slot_cluster), vectors, attrs, ids, norms,
                scales)

    # ---- scan + merge ----
    def _count_scan(self, key: Tuple):
        if key not in _SCAN_KEYS:
            _SCAN_KEYS.add(key)
            self.stats.scan_compilations += 1

    def _scan_key(self, plan: SearchPlan, *, q: int, qpad: int, s: int,
                  q_block: int, vectors, norms, scales) -> Tuple:
        """The scan stage's jit signature: statics + argument shapes/dtypes
        of :func:`_scan_merge_tiled`.  A whole-batch call over one tile and
        a per-tile call at the same shapes produce the SAME key — they hit
        the same compiled executable, so they must count once."""
        return (
            self.backend, self.index.spec.metric, self.k, q, q_block,
            self.v_block, s, qpad, plan.width,
            np.shape(vectors), str(vectors.dtype),
            str(plan.queries_pad.dtype), tuple(plan.lo_pad.shape[1:]),
            norms is None, scales is None,
        )

    def _mask_tombstones(self, plan: SearchPlan, ids):
        """Masks the snapshot's tombstoned ids out of the cold-tier scan.

        Applied to the ids operand (not the merged result) so the scan's
        masked top-k naturally surfaces the (k+1)-th cold candidate — what a
        rebuild without the deleted rows would return."""
        snap = plan.delta_snap
        if snap is None or snap.tombstones is None:
            return ids
        from repro.core import delta as delta_lib

        return delta_lib.mask_tombstones(jnp.asarray(ids), snap.tombstones)

    def _fold_delta(self, plan: SearchPlan, res: SearchResult) -> SearchResult:
        """Merge stage, tier two: exact scan of the RAM delta segment folded
        into the cold result through the same top-k monoid (cold wins score
        ties, matching concat order in a rebuilt index's merge)."""
        snap = plan.delta_snap
        if snap is None or snap.n_rows == 0:
            return res
        t0 = time.perf_counter()
        from repro.core import delta as delta_lib

        # Per-attribute interval pre-test: the delta tier keeps a running
        # [M] lo/hi envelope over its live rows, refreshed on append — a
        # batch whose every non-void term is disjoint from the envelope on
        # ANY attribute provably matches zero delta rows, skipping even the
        # summary build.  n_scanned keeps the reach count (identical to the
        # unskipped fold's accounting).
        alo = getattr(snap, "attr_lo", None)
        ahi = getattr(snap, "attr_hi", None)
        if alo is not None and ahi is not None:
            lo = np.asarray(plan.lo_pad)
            hi = np.asarray(plan.hi_pad)
            nonvoid = np.all(lo <= hi, axis=-1)  # [Qpad, F]
            overlap = np.all(
                (lo <= ahi[None, None, :]) & (hi >= alo[None, None, :]),
                axis=-1,
            )
            if not bool(np.any(nonvoid & overlap)):
                self.stats.delta_skips += 1
                self.stats.delta_interval_skips += 1
                dscan = delta_lib.snapshot_reach(
                    snap, plan.geo_probes, plan.geo_valid
                )
                q = plan.q
                self._observe_stage("delta_fold", time.perf_counter() - t0)
                return dataclasses.replace(
                    res, n_scanned=res.n_scanned + dscan[:q]
                )

        # Delta-tier scan skip: a tiny resident interval/histogram summary
        # over the segment's live rows (same machinery as the cluster
        # summaries, same soundness contract) proves when a batch's filters
        # can match zero delta rows — then the whole [Qpad, C] scan and its
        # top-k merge are provably all-masked no-ops.  Only the cheap
        # reach count survives, so n_scanned stays bit-identical to the
        # unskipped fold.
        summ = delta_lib.snapshot_summary(snap)
        if summ is None or not bool(np.asarray(
                summaries_lib.can_match(summ, plan.lo_pad, plan.hi_pad)
        ).any()):
            self.stats.delta_skips += 1
            if summ is None:  # no live rows: reach is identically zero
                self._observe_stage("delta_fold",
                                    time.perf_counter() - t0)
                return res
            dscan = delta_lib.snapshot_reach(
                snap, plan.geo_probes, plan.geo_valid
            )
            q = plan.q
            self._observe_stage("delta_fold", time.perf_counter() - t0)
            return dataclasses.replace(
                res, n_scanned=res.n_scanned + dscan[:q]
            )

        dvals, dids, dscan, dpass = delta_lib.scan_snapshot(
            snap, plan.queries, plan.queries_pad, plan.lo_pad, plan.hi_pad,
            plan.geo_probes, plan.geo_valid,
            metric=self.index.spec.metric, k=self.k,
        )
        q = plan.q
        vals, out_ids = topk_lib.merge_topk(
            (res.scores, res.ids), (dvals[:q], dids[:q]), self.k
        )
        self.stats.delta_folds += 1
        self._observe_stage("delta_fold", time.perf_counter() - t0)
        return dataclasses.replace(
            res, scores=vals, ids=out_ids,
            n_scanned=res.n_scanned + dscan[:q],
            n_passed=res.n_passed + dpass[:q],
        )

    def scan_merge(self, plan: SearchPlan, operands) -> SearchResult:
        """Whole-batch scan/merge over fetched operands (sync executor)."""
        t0 = time.perf_counter()
        slot_cluster, vectors, attrs, ids, norms, scales = operands
        ids = self._mask_tombstones(plan, ids)
        metric = self.index.spec.metric
        self._count_scan(self._scan_key(
            plan, q=plan.q, qpad=plan.n_tiles * plan.q_block,
            s=plan.n_tiles * plan.u_cap, q_block=plan.q_block,
            vectors=vectors, norms=norms, scales=scales,
        ))
        res = _scan_merge_tiled(
            jnp.asarray(slot_cluster), jnp.asarray(plan.slot_tile),
            jnp.asarray(plan.slot_of_probe), jnp.asarray(plan.probe_ok),
            plan.queries, plan.queries_pad, plan.lo_pad, plan.hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=self.k, q=plan.q, q_block=plan.q_block,
            v_block=self.v_block, backend=self.backend,
        )
        self._observe_stage("scan", time.perf_counter() - t0)
        return dataclasses.replace(res, n_pruned=plan.n_pruned)

    def _scan_tile(self, plan: SearchPlan, i: int, operands) -> SearchResult:
        """Scan/merge one query tile (pipelined executor).  Same jitted
        stage as the monolith with ``n_tiles=1`` — per-slot arithmetic is
        identical, so tile results concatenate to the sync result bitwise."""
        t0 = time.perf_counter()
        slot_cluster, vectors, attrs, ids, norms, scales = operands
        ids = self._mask_tombstones(plan, ids)
        qb, cap = plan.q_block, plan.u_cap
        metric = self.index.spec.metric
        if plan.queries_orig_pad is None:  # plan was built for a sync run
            plan.queries_orig_pad = probes_lib.pad_to_tiles(plan.queries, qb)
        rows = slice(i * qb, (i + 1) * qb)
        sop = plan.slot_of_probe[rows] - i * cap  # tile-local slot pointers
        self._count_scan(self._scan_key(
            plan, q=qb, qpad=qb, s=cap, q_block=qb,
            vectors=vectors, norms=norms, scales=scales,
        ))
        res = _scan_merge_tiled(
            jnp.asarray(slot_cluster),
            jnp.zeros((cap,), jnp.int32),
            jnp.asarray(sop), jnp.asarray(plan.probe_ok[rows]),
            plan.queries_orig_pad[rows], plan.queries_pad[rows],
            plan.lo_pad[rows], plan.hi_pad[rows],
            vectors, attrs, ids, norms, scales,
            metric=metric, k=self.k, q=qb, q_block=qb,
            v_block=self.v_block, backend=self.backend,
        )
        self._observe_stage("scan", time.perf_counter() - t0)
        return res

    def _fetch_segment(self, plan: SearchPlan, seg_sc: np.ndarray,
                       alive_seg: np.ndarray, ops: Dict[int, dict]):
        """Per-segment lazy fetch for the sharded terminated executor.

        Clusters first needed by this segment whose every (query, probe)
        pair is already dead at the boundary are dropped from the per-owner
        fetch list before dispatch (the store counts ``fetches_skipped``)
        and scanned as all-masked zero blocks — every candidate they might
        have held is provably below the final kth, so results stay exact
        while the ring never sees the fetch.  Live records are kept in the
        batch-scoped ``ops`` cache; skipped clusters are NOT cached, so a
        later tile where they are alive fetches them for real."""
        spec = self._bspec
        uniq, local = blockstore_lib.first_need_unique(seg_sc)
        slot_alive = alive_seg.any(axis=0)  # [seg]
        cid_alive = np.zeros(len(uniq), bool)
        np.logical_or.at(cid_alive, local, slot_alive)
        need = np.asarray(
            [j for j, c in enumerate(uniq) if int(c) not in ops], np.int64
        )
        if need.size:
            need_ids = uniq[need]
            recs = self._store.get(
                need_ids,
                gens=(plan.gens[need_ids] if plan.gens is not None
                      else None),
                alive=cid_alive[need],
            )
            self._count_fetched(plan, recs)
            for c, r in recs.items():
                ops[int(c)] = r
        dead = None
        view = {}
        for c in uniq:
            r = ops.get(int(c))
            if r is None:  # skipped this segment: all-masked zero block
                if dead is None:
                    dead = blockstore_lib.dead_record(spec)
                r = dead
            view[int(c)] = r
        # pad the unique list to the fixed segment width so segment scans
        # share one operand shape per (bucket, record vpad)
        seg_w = int(seg_sc.shape[0])
        if len(uniq) < seg_w:
            uniq = np.concatenate(
                [uniq, np.repeat(uniq[-1:], seg_w - len(uniq))]
            )
        return blockstore_lib.assemble_blocks(seg_sc, uniq, local, view,
                                              spec, as_device=True)

    def _scan_tile_terminated(self, plan: SearchPlan, i: int,
                              operands, ops: Optional[Dict[int, dict]] = None
                              ) -> SearchResult:
        """Bound-driven scan of one query tile: best-bound-first segments,
        running top-k folded after each, remaining (query, slot) pairs
        dropped when their score upper bound provably (or, in ε mode,
        probably) cannot reach the query's top-k.

        Exactness: a pair dropped under the provable rule scores strictly
        below the query's *running* kth, which only rises — so it is
        strictly below the final kth and its fragments could never surface
        in the merged top-k.  Pairs whose segment WAS scanned (for another
        query) keep their fragments in the merge, so ``termination="exact"``
        reproduces the unterminated scan bitwise.  ε-dropped pairs are
        always masked — the result is the exact top-k over the surviving
        probe set, which shrinks monotonically with ε.

        ``operands=None`` runs the *segmented-fetch* mode (sharded ring):
        each segment's clusters are fetched right before its scan through
        :meth:`_fetch_segment`, so boundary drops shrink the remote fetch
        lists; ``ops`` is the batch-scoped record cache.
        """
        from repro.kernels.filtered_scan.filtered_scan import (
            fold_running_topk,
        )

        t_start = time.perf_counter()
        term = plan.term
        qb, cap, k = plan.q_block, plan.u_cap, self.k
        seg, n_seg = term.seg, term.n_seg
        cap_pad = n_seg * seg
        metric = self.index.spec.metric
        if plan.queries_orig_pad is None:
            plan.queries_orig_pad = probes_lib.pad_to_tiles(plan.queries, qb)
        rows = slice(i * qb, (i + 1) * qb)
        sop = np.asarray(plan.slot_of_probe[rows]) - i * cap
        pok = np.asarray(plan.probe_ok[rows])
        q_pad = plan.queries_pad[rows]
        lo_pad = plan.lo_pad[rows]
        hi_pad = plan.hi_pad[rows]
        segmented = operands is None
        if segmented:
            sc = np.asarray(plan.slot_cluster).reshape(
                plan.n_tiles, cap
            )[i].astype(np.int64)
            vectors = attrs = ids = norms = scales = None
            live_np = None  # filled per scanned segment
        else:
            slot_cluster, vectors, attrs, ids, norms, scales = operands
            ids = self._mask_tombstones(plan, ids)
            sc = np.asarray(slot_cluster).reshape(-1)
        # pad the tile's slot list to the segmented width with the standard
        # repeat-last-slot convention (scanned only if its segment is)
        if cap_pad > cap:
            sc = np.concatenate([sc, np.repeat(sc[-1:], cap_pad - cap)])
        if segmented:
            live_np = np.zeros((cap_pad,), np.int32)
            live_per_slot = None
            sc_dev = None
        else:
            sc_dev = jnp.asarray(sc, jnp.int32)
            live_per_row = jnp.sum((ids >= 0).astype(jnp.int32), axis=-1)
            live_per_slot = jnp.take(live_per_row, sc_dev)

        alive = term.valid[i].copy()              # [qb, cap_pad]
        eps_dropped = np.zeros((qb, cap_pad), bool)
        scanned = np.zeros((n_seg,), bool)
        run_vals = jnp.full((qb, k), topk_lib.NEG_INF, jnp.float32)
        run_ids = jnp.full((qb, k), -1, jnp.int32)
        frags: List[Optional[Tuple]] = []
        for si in range(n_seg):
            p0, p1 = si * seg, (si + 1) * seg
            alive_seg = alive[:, p0:p1]
            if not alive_seg.any():
                self.stats.term_segments_skipped += 1
                frags.append(None)
            else:
                scanned[si] = True
                if segmented:
                    t_f = time.perf_counter()
                    (seg_local, vectors, attrs, ids, norms,
                     scales) = self._fetch_segment(
                        plan, sc[p0:p1], alive_seg, ops
                    )
                    self._observe_stage("fetch", time.perf_counter() - t_f)
                    ids = self._mask_tombstones(plan, ids)
                    live_row = np.asarray(
                        jnp.sum((ids >= 0).astype(jnp.int32), axis=-1)
                    )
                    seg_local = np.asarray(seg_local)
                    live_np[p0:p1] = live_row[seg_local]
                    scan_sc = jnp.asarray(seg_local, jnp.int32)
                else:
                    scan_sc = sc_dev[p0:p1]
                self._count_scan((
                    "term", self.backend, metric, k, qb, self.v_block, seg,
                    np.shape(vectors), str(vectors.dtype),
                    str(q_pad.dtype), tuple(lo_pad.shape[1:]),
                    norms is None, scales is None,
                ))
                svals, sids, snpass = _scan_slots(
                    scan_sc, q_pad, lo_pad, hi_pad,
                    vectors, attrs, ids, norms, scales,
                    metric=metric, k=k, q_block=qb, v_block=self.v_block,
                    backend=self.backend,
                )
                frags.append((svals, sids, snpass))
                run_vals, run_ids = fold_running_topk(
                    run_vals, run_ids, svals, sids, jnp.asarray(alive_seg),
                    k=k,
                )
            if si + 1 >= n_seg:
                break
            # boundary: compare remaining pairs' upper bounds against the
            # running kth (one host sync per boundary, n_seg − 1 per tile)
            kth = np.asarray(run_vals)[:, k - 1]
            kth_real = kth > topk_lib.NEG_INF / 2
            rest = np.s_[:, p1:]
            drop = (alive[rest] & kth_real[:, None]
                    & (term.ub[i][rest] < kth[:, None]))
            if si == 0 and term.epsilon > 0.0:
                # the ε decision is made exactly once, at the first
                # boundary, from an ε-independent kth — so higher ε drops a
                # superset of lower ε's pairs and recall is monotone in ε
                ub_r, lb_r = term.ub[i][rest], term.lb[i][rest]
                m_r = term.mass[i][rest]
                p_hit = np.clip(
                    (ub_r - kth[:, None])
                    / np.maximum(ub_r - lb_r, 1e-12),
                    0.0, 1.0,
                )
                p_hit = np.where(kth_real[:, None], p_hit, 1.0)
                p_any = 1.0 - np.power(
                    1.0 - np.minimum(p_hit, 1.0 - 1e-12), m_r
                )
                edrop = alive[rest] & (p_any <= term.epsilon)
                eps_dropped[rest] |= edrop
                drop = drop | edrop
            self.stats.probes_terminated += int(drop.sum())
            alive[rest] &= ~drop
        # never-scanned segments contribute all-masked filler fragments so
        # the merge sees one fixed [cap_pad, QB, k] shape per bucket
        filler = None
        for si in range(n_seg):
            if frags[si] is None:
                if filler is None:
                    filler = (
                        jnp.full((seg, qb, k), topk_lib.NEG_INF,
                                 jnp.float32),
                        jnp.full((seg, qb, k), -1, jnp.int32),
                        jnp.zeros((seg, qb), jnp.int32),
                    )
                frags[si] = filler
        svals_all = jnp.concatenate([f[0] for f in frags], axis=0)
        sids_all = jnp.concatenate([f[1] for f in frags], axis=0)
        snpass_all = jnp.concatenate([f[2] for f in frags], axis=0)
        # a probe's fragments enter the merge iff its segment was scanned
        # and it was not ε-dropped; provably-dropped pairs of a scanned
        # segment stay in (their rows are strictly below the final kth —
        # keeping them preserves bitwise identity with the full scan)
        scanned_pos = np.repeat(scanned, seg)
        qi = np.broadcast_to(np.arange(qb)[:, None], sop.shape)
        scan_ok = pok & scanned_pos[sop]
        pair_ok = scan_ok & ~eps_dropped[qi, sop]
        if segmented:
            live_per_slot = jnp.asarray(live_np)
        res = _merge_tile_fragments(
            svals_all, sids_all, snpass_all, jnp.asarray(sop),
            jnp.asarray(pair_ok), jnp.asarray(scan_ok),
            plan.queries_orig_pad[rows], live_per_slot,
            metric=metric, k=k, q=qb,
        )
        self._observe_stage("scan", time.perf_counter() - t_start)
        return res

    def _execute_terminated_sync(self, plan: SearchPlan) -> SearchResult:
        """Sync executor, termination active: one whole-batch fetch, then
        per-tile segmented scans (the early-termination decisions need the
        per-tile running kth, so the monolithic all-tiles scan is replaced
        by a loop over the same compiled per-segment stage)."""
        if (self._store is not None and self._device_cache is None
                and isinstance(self._store,
                               blockstore_lib.ShardedBlockStore)):
            return self._execute_terminated_segmented(plan)
        operands = self.fetch(plan)
        slot_cluster = np.asarray(operands[0]).reshape(
            plan.n_tiles, plan.u_cap
        )
        parts: List[SearchResult] = []
        for i in range(plan.n_tiles):
            parts.append(self._scan_tile_terminated(
                plan, i, (slot_cluster[i],) + tuple(operands[1:])
            ))
            self.stats.tiles_scanned += 1
        return self._merge_parts(plan, parts)

    def _execute_terminated_segmented(self, plan: SearchPlan
                                      ) -> SearchResult:
        """Terminated executor over a sharded ring: per-segment lazy fetch
        instead of one whole-batch gather, so a cluster every query has
        already dropped at a segment boundary is never dispatched to its
        owning peer (the sharded-ring fetch shrink;
        ``StoreStats.fetches_skipped``).  Scores/ids stay exact — a skipped
        cluster's candidates are all provably below the final kth —
        while ``n_scanned`` counts only actually-fetched rows."""
        ops: Dict[int, dict] = {}
        parts: List[SearchResult] = []
        for i in range(plan.n_tiles):
            parts.append(self._scan_tile_terminated(plan, i, None, ops=ops))
            self.stats.tiles_scanned += 1
        return self._merge_parts(plan, parts)

    def _note_partition_rows(self, plan: SearchPlan, res: SearchResult):
        """Splits the batch's cold-scan row accounting by routing outcome
        (partition vs flat path) — the partition plane's effectiveness
        gauge.  No-op (and no host sync) without an active catalog."""
        if plan.route is None:
            return
        ns = np.asarray(res.n_scanned)
        hit = plan.route >= 0
        self.stats.partition_rows_scanned += int(ns[hit].sum())
        self.stats.flat_rows_scanned += int(ns[~hit].sum())

    # ---- executors ----
    def execute(self, plan: SearchPlan) -> SearchResult:
        self.stats.batches += 1
        if self.pipeline == "on":
            res = self._execute_pipelined(plan)
        elif plan.term is not None:
            res = self._execute_terminated_sync(plan)
        else:
            res = self.scan_merge(plan, self.fetch(plan))
        self._note_partition_rows(plan, res)
        res = self._fold_delta(plan, res)
        self._note_degraded()
        return res

    def _note_degraded(self):
        """Counts batches served while the fetch store was routing around
        an unhealthy peer (failover keeps results bit-identical, so this
        counter is the only visible trace)."""
        if self._store is not None and getattr(self._store, "degraded",
                                               False):
            self.stats.degraded_batches += 1

    # ---- cross-batch software pipeline ----
    def submit(self, queries: Array, fspec: FilterSpec) -> "PendingSearch":
        """Starts a batch: plans it and (pipelined, disk tier) launches its
        first ``pipeline_depth`` tile gathers immediately.

        With :meth:`result` this software-pipelines *across batches*: submit
        batch *i+1* while batch *i* scans, and batch *i+1*'s clusters page
        in + transfer behind batch *i*'s compute.  At serving batch sizes
        of one tile (``Q ≤ q_block``) this is the only place IO/compute
        overlap can come from — within-batch double buffering needs ≥ 2
        tiles.  Multi-tile batches pipeline best with ``pipeline_depth ≥
        n_tiles`` when batches are interleaved through submit/result (the
        single fetch worker serves gathers strictly in submission order).
        """
        plan = self.plan(queries, fspec)
        self.stats.batches += 1
        if self.pipeline != "on" or self._gather_fn is None:
            return PendingSearch(plan=plan, inflight=None)
        depth = min(self.pipeline_depth, plan.n_tiles)
        inflight = self._start_inflight(plan, depth)
        return PendingSearch(plan=plan, inflight=inflight)

    def result(self, pending: "PendingSearch") -> SearchResult:
        """Finishes a :meth:`submit`-started batch (scan + merge)."""
        plan = pending.plan
        if pending.inflight is None:
            if self.pipeline == "on":
                res = self._execute_pipelined(plan)
            elif plan.term is not None:
                res = self._execute_terminated_sync(plan)
            else:
                res = self.scan_merge(plan, self.fetch(plan))
        else:
            res = self._run_tiles(plan, pending.inflight)
        self._note_partition_rows(plan, res)
        res = self._fold_delta(plan, res)
        self._note_degraded()
        return res

    def _tile_operands(self, plan: SearchPlan, i: int):
        """RAM-tier per-tile operands: resident arrays + the tile's global
        slot ids (no fetch needed)."""
        index = self.index
        sc = plan.slot_cluster.reshape(plan.n_tiles, plan.u_cap)[i]
        return (sc, index.vectors, index.attrs, index.ids, index.norms,
                index.scales)

    def _ensure_pool(self):
        """The engine's single fetch/assembly worker: tasks run strictly in
        submission order, keeping per-tile waits aligned with submits."""
        from concurrent.futures import ThreadPoolExecutor

        if getattr(self, "_pool", None) is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="engine-fetch"
            )
        return self._pool

    def _start_inflight(self, plan: SearchPlan, depth: int) -> Dict:
        """Prepares a pipelined batch (operand cache + per-tile novel fetch
        lists when the BlockStore path is active) and launches the first
        ``depth`` tile fetches."""
        if self._device_cache is not None:
            # the device cache subsumes the per-batch operand cache: the
            # per-tile novel lists still bound what crosses the store, but
            # in-batch reuse rides the same cross-batch device entries
            plan.tile_work()
        elif self._use_operand_cache:
            plan.operands = {}
            plan.tile_work()  # per-tile novel-cluster lists (host tables)
        return {i: self._submit(plan, i) for i in range(depth)}

    def _assemble_tile(self, plan: SearchPlan, i: int, h_store):
        """Engine-worker half of the BlockStore fetch: wait the store's
        records, merge them into the batch operand cache (when enabled),
        assemble tile *i*'s ``[u_cap, ...]`` blocks and move them on-device
        — all off the scan thread, so both IO (store worker) and assembly +
        host→device copy (this worker) hide behind the previous tile's
        scan.  With the operand cache, a cluster several tiles share is
        fetched through the store once per batch; later tiles assemble it
        straight from the batch-local records (``blocks_reused``)."""
        recs = self._store.wait(h_store)
        if self._device_cache is not None or plan.operands is not None:
            self._count_fetched(plan, recs)
        else:
            self.stats.blocks_fetched += len(recs)
        sc = plan.slot_cluster.reshape(plan.n_tiles, plan.u_cap)[i]
        uniq, local = blockstore_lib.first_need_unique(sc)
        if self._device_cache is not None:
            return self._assemble_tile_device(plan, uniq, local, recs,
                                              sc.shape[0])
        if plan.operands is not None:  # per-batch reuse on
            # the operand cache keys on (cluster_id, gen) like every other
            # cache layer — plan.gens is fixed for the batch, so this is a
            # pure re-keying, but it keeps the invalidation contract uniform
            gens = plan.gens

            def gkey(c):
                cid = int(c)
                return (cid, int(gens[cid]) if gens is not None else 0)

            ops = plan.operands
            for c, r in recs.items():
                ops[gkey(c)] = r
            # fetch lists and slot tables always agree; tolerate a gap by
            # fetching inline rather than scanning stale rows
            missing = [int(c) for c in uniq if gkey(c) not in ops]
            if missing:
                more = self._store.get(
                    np.asarray(missing, np.int64),
                    gens=self._expected_gens(plan, missing),
                )
                self._count_fetched(plan, more)
                for c, r in more.items():
                    ops[gkey(c)] = r
            self.stats.blocks_reused += max(
                len(uniq) - len(recs) - len(missing), 0
            )
            view = {int(c): ops[gkey(c)] for c in uniq}
            out = blockstore_lib.assemble_blocks(sc, uniq, local, view,
                                                 self._bspec, as_device=True)
            # free records whose last consuming tile is this one: the
            # batch cache's footprint tracks live overlap ranges, not the
            # batch's whole unique set — an evicted-under-budget record
            # must not be kept alive past its last use (a later surprise
            # consumer re-fetches via the `missing` fallback above)
            if plan.tiles is not None:
                for c in plan.tiles[i].release:
                    ops.pop(gkey(c), None)
            return out
        return blockstore_lib.assemble_blocks(sc, uniq, local, recs,
                                              self._bspec, as_device=True)

    def _assemble_tile_device(self, plan: SearchPlan, uniq, local, recs,
                              s: int):
        """Device-cache half of :meth:`_assemble_tile`: the tile's blocks
        are composed from resident device entries (cross-batch hits) plus
        this tile's store fetches, which are device-put once and admitted
        — so a cluster several tiles (or batches) share never re-crosses
        the store, the host assembler, or the H2D bus.  A resident entry
        evicted between submit and assembly is re-fetched inline (same
        fallback the operand cache uses), never scanned stale."""
        dc = self._device_cache
        egens = self._expected_gens(plan, uniq)
        tile = dc.get_tile(uniq, s, egens)
        if tile is not None:  # exact repeat: the composed blocks, verbatim
            self._note_device_hits(len(uniq))
            self.stats.blocks_reused += len(uniq)
            dc.put_records(recs)  # admit this tile's fetches regardless
            return (local.astype(np.int32),) + tile
        hits, missing = dc.get_many(uniq, egens)
        self._note_device_hits(len(hits))
        self.stats.blocks_reused += len(hits)
        entries = dict(hits)
        entries.update(dc.put_records(recs))
        gap = [c for c in missing if c not in entries]
        if gap:
            more = self._store.get(
                np.asarray(gap, np.int64),
                gens=self._expected_gens(plan, gap),
            )
            self._count_fetched(plan, more)
            entries.update(dc.put_records(more))
        ordered = [entries[int(c)] for c in uniq]
        blocks = dc.compose(ordered, s)
        dc.put_tile(uniq, s, ordered, blocks)
        return (local.astype(np.int32),) + blocks

    def _submit(self, plan: SearchPlan, i: int):
        """Starts tile *i*'s fetch; returns (handle, t_submit, done_box).
        The waited handle always yields assembled, device-resident
        ``(local_ids, vectors, attrs, ids, norms, scales)`` operands."""
        t0 = time.monotonic()
        done = [None]  # completion timestamp, set by the done-callback
        if self._store is not None:
            if self._device_cache is not None:
                # fetch only this tile's novel clusters that are not already
                # device-resident — on a device hit the store worker never
                # sees the cluster (no disk read, no peer RPC); an entry
                # evicted before assembly is re-fetched inline there
                novel = plan.tile_work()[i].fetch
                fetch_ids = self._device_cache.filter_missing(
                    novel, self._expected_gens(plan, novel)
                )
            elif self._use_operand_cache:
                # fetch only clusters no earlier tile of this batch needed;
                # everything else is already (or will be) in plan.operands
                fetch_ids = plan.tile_work()[i].fetch
            else:
                sc = plan.slot_cluster.reshape(plan.n_tiles, plan.u_cap)[i]
                fetch_ids, _ = blockstore_lib.first_need_unique(sc)
            h_store = self._store.submit(
                fetch_ids, gens=self._expected_gens(plan, fetch_ids)
            )  # IO on the store worker
            h = self._ensure_pool().submit(self._assemble_tile, plan, i,
                                           h_store)
        elif self._async_src is not None:
            sc = plan.slot_cluster.reshape(plan.n_tiles, plan.u_cap)[i]
            h = self._async_src.gather_submit(sc)
        else:
            # generic sync gather_fn: run it on the engine's own worker so
            # the pipeline still overlaps IO with the device scan
            sc = plan.slot_cluster.reshape(plan.n_tiles, plan.u_cap)[i]
            h = self._ensure_pool().submit(self._gather_fn, sc)
        h.add_done_callback(lambda _: done.__setitem__(0, time.monotonic()))
        return h, t0, done

    def _wait(self, handle_rec):
        handle, t_submit, done = handle_rec
        t0 = time.monotonic()
        if self._async_src is not None:
            out = self._async_src.gather_wait(handle)
        else:
            out = handle.result()
        t1 = time.monotonic()
        self.stats.io_wait_s += t1 - t0
        self._observe_stage("fetch", t1 - t0)
        # submit→completion span; a gather that finished long before this
        # wait counts its true (short) duration, not the time it sat done —
        # the callback timestamp may lag result() by a beat, so fall back
        # to t1 when it hasn't landed yet
        t_done = done[0] if done[0] is not None else t1
        self.stats.io_total_s += max(t_done - t_submit, 0.0)
        slot_cluster, vectors, attrs, ids, norms, scales = out
        return (jnp.asarray(slot_cluster), vectors, attrs, ids, norms,
                scales)

    def _execute_pipelined(self, plan: SearchPlan) -> SearchResult:
        """Double-buffered executor: scan tile *i* while tiles
        *i+1 … i+depth* gather in the background.  RAM tier degenerates to
        per-tile scans over the resident arrays (same results, no fetch).

        A serially-executed single-tile batch has nothing to overlap with —
        the pipelined path would only add a thread hop — so it falls back
        to the sync fetch+scan (identical results, sync latency).  Cross-
        batch overlap for single-tile batches comes from
        :meth:`submit`/:meth:`result`, whose gathers are already in flight
        when the result is drained.
        """
        if plan.n_tiles < 2 and self._gather_fn is not None:
            if plan.term is not None:
                return self._execute_terminated_sync(plan)
            return self.scan_merge(plan, self.fetch(plan))
        scan = (self._scan_tile_terminated if plan.term is not None
                else self._scan_tile)
        if self._gather_fn is None:
            self.stats.pipelined_batches += 1
            parts: List[SearchResult] = []
            for i in range(plan.n_tiles):
                parts.append(
                    scan(plan, i, self._tile_operands(plan, i))
                )
                self.stats.tiles_scanned += 1
            return self._merge_parts(plan, parts)
        depth = min(self.pipeline_depth, plan.n_tiles)
        inflight = self._start_inflight(plan, depth)
        return self._run_tiles(plan, inflight)

    def _run_tiles(self, plan: SearchPlan, inflight: Dict) -> SearchResult:
        """Drains a pipelined batch: wait tile i's fetch, keep ``depth``
        fetches in flight, scan, concatenate.  On any failure the remaining
        in-flight handles are still waited (exceptions swallowed) — every
        submit gets its wait, so no future exception goes unretrieved and
        the cache ends consistent — then the original error propagates."""
        self.stats.pipelined_batches += 1
        n = plan.n_tiles
        depth = max(len(inflight), 1)
        scan = (self._scan_tile_terminated if plan.term is not None
                else self._scan_tile)
        parts: List[SearchResult] = []
        try:
            for i in range(n):
                operands = self._wait(inflight.pop(i))
                if i + depth < n:
                    inflight[i + depth] = self._submit(plan, i + depth)
                parts.append(scan(plan, i, operands))
                self.stats.tiles_scanned += 1
        except BaseException:
            for handle_rec in inflight.values():
                try:
                    handle_rec[0].result()
                except BaseException:
                    pass
            raise
        return self._merge_parts(plan, parts)

    def _merge_parts(self, plan: SearchPlan,
                     parts: List[SearchResult]) -> SearchResult:
        t0 = time.perf_counter()
        if len(parts) == 1:
            res = parts[0]
            res = SearchResult(res.scores[: plan.q], res.ids[: plan.q],
                               res.n_scanned[: plan.q],
                               res.n_passed[: plan.q])
        else:
            res = SearchResult(
                jnp.concatenate([p.scores for p in parts])[: plan.q],
                jnp.concatenate([p.ids for p in parts])[: plan.q],
                jnp.concatenate([p.n_scanned for p in parts])[: plan.q],
                jnp.concatenate([p.n_passed for p in parts])[: plan.q],
            )
        self._observe_stage("merge", time.perf_counter() - t0)
        return dataclasses.replace(res, n_pruned=plan.n_pruned)

    # ---- the whole pipeline ----
    def search(self, queries: Array, fspec: FilterSpec) -> SearchResult:
        return self.execute(self.plan(queries, fspec))

    # ---- live-update handshake ----
    def refresh(self) -> bool:
        """Atomically flips the engine to the latest published generation.

        Call strictly *between* batches (SearchServer does this on
        ``request_refresh``): reopens the fetch stores' readers, reloads the
        index's resident state (counts / summaries / gens) and commits any
        pending delta freeze.  Gen-keyed caches need no flush — the next
        batch's fetches carry the new expected generations, so exactly the
        rewritten clusters miss and re-page.  Returns True when a new
        generation was picked up."""
        if self._store is not None:
            store_refresh = getattr(self._store, "refresh", None)
            if store_refresh is not None:
                store_refresh()
        idx_refresh = getattr(self.index, "refresh", None)
        changed = bool(idx_refresh()) if idx_refresh is not None else False
        if self._device_cache is not None:
            # same precision contract as the host caches: the new generation
            # vector names exactly the clusters the republish rewrote, and
            # only their device entries (gen below the new minimum) drop —
            # untouched hot clusters stay resident through the flip
            gens = self._plan_gens()
            if gens is not None:
                self._device_cache.invalidate_below(gens)
        return changed

    # ---- observability ----
    def metrics(self) -> Dict[str, Any]:
        """One flat scrape-able dict: engine + store + cache + health +
        delta-tier counters under stable dotted keys (``engine.batches``,
        ``store.per_node.0.hits``, ``cache.invalidations``,
        ``delta.rows``, ...).  Values are scalars (numbers / bools /
        strings) — ready for a metrics exporter, no nesting to unpack."""
        out: Dict[str, Any] = {}
        eng = dataclasses.asdict(self.stats)
        eng["overlap_ratio"] = self.stats.overlap_ratio
        eng["pipeline"] = self.pipeline
        eng["backend"] = self.backend
        eng["scan_compile_count"] = scan_compile_count()
        _flatten_metrics(out, "engine", eng)
        if self._store is not None:
            store_stats = getattr(self._store, "stats", None)
            if callable(store_stats):
                _flatten_metrics(out, "store", store_stats())
        cache = getattr(self.index, "cache", None)
        cstats = getattr(cache, "stats", None) if cache is not None else None
        if cstats is not None:
            c = dataclasses.asdict(cstats)
            hit_rate = getattr(cache, "hit_rate", None)
            c["hit_rate"] = hit_rate() if callable(hit_rate) else hit_rate
            _flatten_metrics(out, "cache", c)
        if self._device_cache is not None:
            _flatten_metrics(out, "device_cache", self._device_cache.stats())
        tier = self._delta_tier()
        if tier is not None:
            _flatten_metrics(out, "delta", tier.stats())
        cat = getattr(self.index, "partitions", None)
        if cat is not None:
            _flatten_metrics(out, "partitions", dict(
                entries=cat.n_entries, subs=cat.n_subs,
                catalog_bytes=cat.nbytes(),
            ))
        if self._traffic is not None:
            _flatten_metrics(out, "filter_traffic", self._traffic.stats())
        return out

    def metrics_text(self) -> str:
        """:meth:`metrics` rendered in Prometheus text exposition format,
        plus the per-stage fixed-bucket latency histograms
        (``launch/serve.py --metrics-port`` serves this)."""
        return (render_prometheus(self.metrics())
                + render_stage_histograms(self._stage_hist))

    def close(self):
        pool = getattr(self, "_pool", None)
        if pool is not None:
            pool.shutdown(wait=True)
            self._pool = None


def search_fused_tiled(
    index,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    q_block: int = 64,
    v_block: int = 256,
    u_cap: Optional[int] = None,
    backend: Optional[str] = None,
    gather_fn=None,
    blockstore=None,
    prune: str = "auto",
    t_max=None,
    pipeline: str = "off",
    pipeline_depth: int = 2,
    adaptive_u_cap: bool = False,
    u_cap_ladder: str = "pow2",
    operand_cache: str = "auto",
    termination: Optional[str] = None,
    epsilon: float = 0.0,
    partitions: str = "auto",
) -> SearchResult:
    """Query-tiled, probe-deduplicated fused search with streaming top-k.

    Thin wrapper over :class:`SearchEngine` kept as the functional entry
    point — same contract as :func:`repro.core.search.search_reference`
    (identical ids/scores modulo tie order).  Defaults reproduce the classic
    synchronous path exactly: ``u_cap=None`` provisions the always-sufficient
    worst case (``min(q_block·W, K)``), ``pipeline="off"`` runs one fetch +
    one scan.  ``pipeline="on"`` double-buffers per-tile fetches against the
    scan; ``adaptive_u_cap=True`` buckets the slot-table width from the
    observed post-prune unique counts.  Long-lived callers (servers, benches)
    should hold a :class:`SearchEngine` instead to keep its stats.

    With ``gather_fn=None`` the scan reads ``index``'s in-RAM
    ``[K, Vpad, ...]`` arrays.  A disk-resident index supplies its cluster
    cache's pager (``index.gather`` is picked up automatically by the
    engine): the hook receives the plan's ``slot_cluster`` fetch list and
    returns ``(local_ids, vectors, attrs, ids, norms, scales)`` batch-local
    blocks, which the same kernel scans for bit-identical results.

    ``prune``: ``"auto"`` (default) consults the index's cluster attribute
    summaries when present and drops probes whose clusters provably contain
    no row passing the query's filter — same ids/scores, fewer slots, fewer
    disk fetches.  ``"on"`` requires summaries, ``"off"`` disables.
    ``t_max`` (static, ≥ n_probes; needs pruning active) widens: pruned
    probes are refilled from the query's next-best unpruned centroids within
    the geometric top-``t_max``, trading bit-identity for recovered recall
    under selective filters (every surfaced hit remains exact).
    """
    eng = SearchEngine(
        index, k=k, n_probes=n_probes, q_block=q_block, v_block=v_block,
        u_cap=u_cap, backend=backend, gather_fn=gather_fn,
        blockstore=blockstore, prune=prune, t_max=t_max, pipeline=pipeline,
        pipeline_depth=pipeline_depth, adaptive_u_cap=adaptive_u_cap,
        u_cap_ladder=u_cap_ladder, operand_cache=operand_cache,
        termination=termination, epsilon=epsilon, partitions=partitions,
    )
    try:
        return eng.search(queries, fspec)
    finally:
        eng.close()
