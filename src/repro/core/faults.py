"""Deterministic fault injection for the fetch path (tests + ``--chaos``).

Chaos that can't be replayed can't gate a CI job, so the harness is a
*schedule*, not a dice roll: every wrapper consults a shared
:class:`FaultSchedule` that decides — purely from a per-target operation
counter and the rule list — whether this fetch fails, stalls, or passes
through.  The same schedule object therefore produces the same fault
sequence on every run, and tests can assert exact failover counts.

Fault classes (the ways a real peer dies, as seen from the client):

  ``refuse``      connection refused / peer process gone — the fetch fails
                  immediately with a :class:`TransportError`.
  ``disconnect``  peer closed mid-payload — short read, typed error.
  ``truncate``    full-length but corrupt payload — decode-level error.
  ``latency``     the fetch completes but only after ``latency_s`` — a
                  latency spike when ``count`` bounds it, a slow-peer
                  brownout when it doesn't.

``refuse``/``disconnect``/``truncate`` all surface as the transport's
typed :class:`TransportError` (what the real client raises after
detecting each condition on the wire — the socket-level detection itself
is exercised separately by the rogue-server tests); what distinguishes
them downstream is *when* they fire relative to the request, which the
schedule controls via ``after``/``count``.  Latency faults sleep and then
pass through, so the brownout path exercises the health layer's EWMA
tripwire rather than its failure counter.

:class:`FaultyTransport` wraps any transport (loopback or socket) and is
what ``ShardedBlockStore`` peers are wrapped with under ``--chaos``;
:class:`FaultyBlockStore` wraps any store (e.g. behind a
``BlockStoreServer`` to make a *server* slow or crashy, which drives real
wire-level timeouts at the client).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core.transport import TransportError

FAULT_KINDS = ("refuse", "disconnect", "truncate", "latency")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One line of the chaos schedule.

    The rule arms after ``after`` operations on a target (each wrapper's
    fetch/ping is one operation), fires on at most ``count`` operations
    (``None`` = forever — a killed peer stays dead, a brownout persists),
    and for latency faults sleeps ``latency_s`` before passing through.
    """

    kind: str
    after: int = 0
    count: Optional[int] = None
    latency_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")


class FaultSchedule:
    """Deterministic per-target fault sequencing.

    One schedule can drive many wrappers: each wrapper names a ``target``
    (e.g. the peer's node id) and the schedule keeps an independent
    operation counter per target, so "node 1 dies at its 3rd fetch" means
    exactly that regardless of how other peers interleave.  ``seed`` is
    recorded for provenance (the schedule itself is counter-driven and
    needs no randomness; benches stamp it into their output).
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules: Tuple[FaultRule, ...] = tuple(rules)
        self.seed = seed
        self._lock = threading.Lock()
        self._ops: Dict = collections.defaultdict(int)
        self._fired: collections.Counter = collections.Counter()
        self.injected: collections.Counter = collections.Counter()

    def next(self, target) -> Optional[FaultRule]:
        """Advances ``target``'s operation counter and returns the fault to
        inject on this operation (first matching rule), if any."""
        with self._lock:
            op = self._ops[target]
            self._ops[target] = op + 1
            for i, rule in enumerate(self.rules):
                if op < rule.after:
                    continue
                if rule.count is not None and self._fired[(target, i)] >= rule.count:
                    continue
                self._fired[(target, i)] += 1
                self.injected[rule.kind] += 1
                return rule
        return None

    def injected_total(self) -> int:
        with self._lock:
            return sum(self.injected.values())


def kill_peer(after: int = 0) -> Tuple[FaultRule, ...]:
    """A peer that dies at its ``after``-th operation and never comes back
    (the ``--chaos kill-one-peer`` schedule)."""
    return (FaultRule("refuse", after=after),)


def brownout_peer(latency_s: float = 0.2, after: int = 0,
                  count: Optional[int] = None) -> Tuple[FaultRule, ...]:
    """A peer that still answers, ``latency_s`` late — forever or for
    ``count`` operations (the ``--chaos brownout`` schedule)."""
    return (FaultRule("latency", after=after, count=count,
                      latency_s=latency_s),)


_FAULT_MSG = {
    "refuse": "connection refused",
    "disconnect": "peer closed mid-frame",
    "truncate": "corrupt response payload",
}


class FaultyTransport:
    """Chaos wrapper around any transport.  Error faults raise before the
    wire is touched; latency faults sleep and pass through.  Drop-in for
    ``ShardedBlockStore.transports[node]``."""

    def __init__(self, inner, schedule: FaultSchedule, target="peer"):
        self.inner = inner
        self.schedule = schedule
        self.target = target

    def _maybe_fault(self):
        rule = self.schedule.next(self.target)
        if rule is None:
            return
        if rule.kind == "latency":
            time.sleep(rule.latency_s)
            return
        raise TransportError(
            f"injected {rule.kind} on {self.target}: {_FAULT_MSG[rule.kind]}"
        )

    def fetch(self, cluster_ids, gens=None):
        self._maybe_fault()
        if gens is None:
            return self.inner.fetch(cluster_ids)
        return self.inner.fetch(cluster_ids, gens=gens)

    def ping(self):
        self._maybe_fault()
        ping = getattr(self.inner, "ping", None)
        if ping is not None:
            ping()
        else:
            self.inner.fetch(np.asarray([], np.int64))

    def stats(self) -> dict:
        s = dict(self.inner.stats()) if hasattr(self.inner, "stats") else {}
        s["injected"] = dict(self.schedule.injected)
        return s

    def close(self):
        self.inner.close()


class FaultyBlockStore:
    """Chaos wrapper around any BlockStore — e.g. behind a
    :class:`~repro.core.transport.BlockStoreServer` so the *server* is the
    slow/crashy party and the client's deadline + typed-error paths are
    exercised over a real socket.  ``submit``/``wait`` delegate to the
    inner store's pool so pipelined callers work unchanged."""

    def __init__(self, inner, schedule: FaultSchedule, target="store"):
        self.inner = inner
        self.schedule = schedule
        self.target = target

    @property
    def spec(self):
        return self.inner.spec

    def get(self, cluster_ids, gens=None):
        rule = self.schedule.next(self.target)
        if rule is not None:
            if rule.kind == "latency":
                time.sleep(rule.latency_s)
            else:
                raise ConnectionError(
                    f"injected {rule.kind} on {self.target}: "
                    f"{_FAULT_MSG[rule.kind]}"
                )
        if gens is None:
            return self.inner.get(cluster_ids)
        return self.inner.get(cluster_ids, gens=gens)

    def submit(self, cluster_ids, gens=None):
        if gens is None:
            return self.inner._ensure_pool().submit(self.get, cluster_ids)
        return self.inner._ensure_pool().submit(self.get, cluster_ids,
                                                gens=gens)

    def wait(self, handle):
        return handle.result()

    def stats(self) -> dict:
        s = dict(self.inner.stats())
        s["injected"] = dict(self.schedule.injected)
        return s

    def close(self):
        self.inner.close()


def inject(store, node, rules: Iterable[FaultRule],
           seed: int = 0) -> FaultSchedule:
    """Wraps one peer of a :class:`ShardedBlockStore` in a
    :class:`FaultyTransport` driven by a fresh schedule; returns the
    schedule (for ``injected`` accounting).  The wrapper is installed
    in-place — the store's next fetch routed to ``node`` sees the faults."""
    schedule = FaultSchedule(tuple(rules), seed=seed)
    store.transports[node] = FaultyTransport(
        store.transports[node], schedule, target=node
    )
    return schedule
