"""Pod-scale filtered search: probe dispatch + hierarchical merge (DESIGN §4).

Sharding model
--------------
The index's cluster axis is contiguously range-sharded over every mesh axis
(``pod × data × model`` flattened): chip ``s`` of ``S`` owns clusters
``[s·K/S, (s+1)·K/S)``.  Queries, centroids and filters are replicated (the
query batch is KiB-scale; the lists are TB-scale — replicating the small side
makes every chip able to compute the dispatch locally with zero
communication).

A probe (q, t) is owned by exactly one chip.  Dispatch mirrors MoE
token→expert routing: sort probes by owner, rank within owner, scatter into a
static ``[S, P_cap]`` slot table.  ``P_cap`` is the per-chip probe capacity
(E[load] = Q·T/S); overflow is *counted*, not silent — an overflowing dispatch
degrades recall and must be observable (SearchResult.n_scanned carries it).

Per chip: the fused Pallas scan streams each slot's cluster block-by-block
(HBM→VMEM — the paper's "load only the probed lists"), then per-slot top-k →
per-query top-k, then a tree merge over ``model → data → pod``.  Each merge
stage moves only ``[axis, Q, k]`` — the collective term stays orders of
magnitude below the scan term (EXPERIMENTS §Roofline).

Tiled backends (``*_tiled``) additionally deduplicate each chip's probes per
(query tile, local cluster) pair before scanning — see ``core/probes.py`` —
so a popular cluster probed by many queries in the batch is streamed from the
chip's HBM exactly once, and the scan runs the query-tiled kernel
(``[QB, D] @ [D, VB]`` matmuls with in-kernel streaming top-k) instead of
per-probe matvecs over a materialized ``[P_cap, Vpad]`` score matrix.

Straggler mitigation: the merge is an associative monoid, so any chip's
contribution can be dropped (deadline expiry, preemption) and the result
remains a valid, slightly-lower-recall answer.  ``shard_ok`` implements the
drop; serving.py owns the deadline policy.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import ivf as ivf_lib
from repro.core import probes as probes_lib
from repro.core import summaries as summaries_lib
from repro.core import topk as topk_lib
from repro.core.filters import FilterSpec
from repro.core.ivf import IVFFlatIndex
from repro.core.search import SearchResult
from repro.kernels.centroid_topk.ops import probe_centroids
from repro.kernels.filtered_scan.filtered_scan import (
    filtered_scan,
    filtered_scan_tiled,
)
from repro.core.engine import tiled_scan_xla

TILED_BACKENDS = ("pallas_tiled", "pallas_tiled_interpret", "xla_tiled")

Array = jax.Array
NEG_INF = topk_lib.NEG_INF


def probe_capacity(q: int, t: int, n_shards: int, slack: float = 2.0) -> int:
    """Static P_cap: expected load × slack, multiple of 8, at least 8."""
    expect = (q * t + n_shards - 1) // n_shards
    cap = int(expect * slack) + 1
    return max(8, ((cap + 7) // 8) * 8)


def dispatch_probes(
    probe_ids: Array, *, n_shards: int, k_local: int, p_cap: int,
    probe_valid: Optional[Array] = None,
    ownership=None,
) -> Tuple[Array, Array, Array, Array]:
    """Builds the probe slot table (replicated computation).

    Args:
      probe_ids: [Q, T] global cluster ids.
      n_shards: S, total chips holding index shards.
      k_local: clusters per shard (K/S, contiguous ranges).
      p_cap: static per-shard slot capacity.
      probe_valid: optional [Q, T] bool — probes the filter-aware planner
        pruned (the cluster's attribute summaries prove no row can pass the
        query's filter).  Pruned probes are dispatched to a sentinel owner
        past every shard: they consume no P_cap slot on any chip, are never
        scanned, and never count toward overflow — the pod-scale analogue of
        the single-host plan dropping them before the per-tile dedup.
      ownership: optional owner/local map with jnp-compatible ``owner_of``/
        ``local_of`` (default: ``blockstore.RangeOwnership(n_shards,
        k_local)``, the contiguous range map).  The SAME object can be
        handed to a :class:`repro.core.blockstore.ShardedBlockStore` so
        shard routing and cache routing agree — a chip's probes always land
        on its own pod's cache (``make_sharded_search`` exposes it in its
        info dict).

    Returns:
      slot_cluster [S, P_cap] int32 — local cluster id per slot (0 for pads),
      slot_query   [S, P_cap] int32 — query row per slot (0 for pads),
      slot_valid   [S, P_cap] bool,
      n_overflowed scalar int32 — live probes dropped by capacity.
    """
    from repro.core.blockstore import RangeOwnership

    if ownership is None:
        ownership = RangeOwnership(n_shards, k_local)
    q, t = probe_ids.shape
    flat = probe_ids.reshape(-1)  # [Q*T]
    owner = ownership.owner_of(flat)
    local = ownership.local_of(flat)
    query = jnp.repeat(jnp.arange(q, dtype=jnp.int32), t)
    if probe_valid is not None:
        # sentinel owner sorts after every real shard; its scatter rows are
        # out of range and dropped, so pruned probes vanish from the table
        owner = jnp.where(probe_valid.reshape(-1), owner, n_shards)

    order = jnp.argsort(owner)
    owner_s = jnp.take(owner, order)
    starts = jnp.searchsorted(owner_s, jnp.arange(n_shards), side="left")
    rank = jnp.arange(q * t) - jnp.take(starts, owner_s)

    sc = jnp.zeros((n_shards, p_cap), jnp.int32)
    sq = jnp.zeros((n_shards, p_cap), jnp.int32)
    sv = jnp.zeros((n_shards, p_cap), jnp.bool_)
    sc = sc.at[owner_s, rank].set(
        jnp.take(local, order).astype(jnp.int32), mode="drop"
    )
    sq = sq.at[owner_s, rank].set(
        jnp.take(query, order).astype(jnp.int32), mode="drop"
    )
    sv = sv.at[owner_s, rank].set(True, mode="drop")
    n_overflowed = jnp.sum(
        jnp.logical_and(rank >= p_cap, owner_s < n_shards).astype(jnp.int32)
    )
    return sc, sq, sv, n_overflowed


def dispatch_probes_tiled(
    probe_ids: Array, *, n_shards: int, k_local: int, p_cap: int,
    u_cap: int, q_block: int, probe_valid: Optional[Array] = None,
    ownership=None,
):
    """Probe dispatch + per-shard (query tile, cluster) deduplication.

    Extends :func:`dispatch_probes` with the tiled kernel's slot tables:
    per shard, the valid probes are deduplicated by ``(query_tile,
    local_cluster)`` so a cluster probed by many queries of a tile is
    scanned once on its owner chip.  ``probe_valid`` threads the planner's
    summary prune mask through: pruned probes take no P_cap slot, no unique
    slot, and no scan on any shard (results stay bit-identical — only
    zero-passing-row clusters are ever pruned).

    Returns the four :func:`dispatch_probes` outputs plus:
      u_cluster [S, u_cap] int32 — local cluster per unique slot (pads
                repeat the last unique id → Pallas skips their re-DMA),
      u_tile    [S, u_cap] int32 — query tile per unique slot,
      slot_of   [S, P_cap] int32 — unique-slot index of each probe,
      u_count   [S] int32 — live unique slots per shard.
    """
    sc, sq, sv, n_overflowed = dispatch_probes(
        probe_ids, n_shards=n_shards, k_local=k_local, p_cap=p_cap,
        probe_valid=probe_valid, ownership=ownership,
    )
    tile = sq // q_block
    key = tile * k_local + sc  # [S, P_cap]
    table, slot_of, u_count = probes_lib.dedup_rows(key, sv, u_cap)
    # u_cap = min(p_cap, k_local·n_tiles) can never overflow; clip anyway.
    slot_of = jnp.minimum(slot_of, u_cap - 1)
    u_cluster = table % k_local
    u_tile = table // k_local
    return sc, sq, sv, n_overflowed, u_cluster, u_tile, slot_of, u_count


def _rank_within_query(slot_query: Array, slot_valid: Array, t: int) -> Array:
    """Rank of each slot among the valid slots serving the same query.

    Bounded by T (a query has exactly T probes globally), so the scatter
    destination [Q, T, k] never overflows.
    """
    p = slot_query.shape[0]
    key = jnp.where(slot_valid, slot_query, jnp.int32(2**30))
    order = jnp.argsort(key)
    key_s = jnp.take(key, order)
    first = jnp.searchsorted(key_s, key_s, side="left")
    rank_s = jnp.arange(p) - first
    rank = jnp.zeros((p,), jnp.int32).at[order].set(rank_s.astype(jnp.int32))
    return jnp.minimum(rank, t - 1)


def _scan_slots_xla(
    vectors, attrs, ids, norms, scales, queries, lo, hi, slot_cluster,
    slot_query, *, metric: str, use_vmap: bool,
) -> Array:
    """XLA-native equivalent of the Pallas scan (identical contract).

    Used for the CPU dry-run lowering (Mosaic kernels need a real TPU to
    lower non-interpreted).  ``use_vmap=False`` streams one slot at a time
    (lax.map — bounded [Vpad, D] live gather, the exec variant);
    ``use_vmap=True`` materializes all slots (accurate while-free HLO for
    cost_analysis — the cost variant).
    """
    from repro.kernels.filtered_scan.ref import filtered_scan_ref

    def one(args):
        sc, sq = args
        return filtered_scan_ref(
            sc[None], sq[None], queries, lo, hi, vectors, attrs, ids,
            norms, scales, metric=metric,
        )[0]

    if use_vmap:
        return jax.vmap(lambda sc, sq: one((sc, sq)))(slot_cluster, slot_query)
    return jax.lax.map(one, (slot_cluster, slot_query))


def _local_shard_search(
    vectors: Array,  # [K_local, Vpad, D]
    attrs: Array,
    ids: Array,
    norms: Optional[Array],
    scales: Optional[Array],
    queries: Array,  # [Q, D] replicated
    lo: Array,
    hi: Array,
    slot_cluster: Array,  # [P_cap]
    slot_query: Array,  # [P_cap]
    slot_valid: Array,  # [P_cap] bool (already gated by shard_ok)
    u_cluster: Optional[Array] = None,  # [U] (tiled backends)
    u_tile: Optional[Array] = None,  # [U]
    slot_of: Optional[Array] = None,  # [P_cap] → index into U
    *,
    metric: str,
    k: int,
    t: int,
    q_block: int,
    v_block: int,
    backend: str,
) -> Tuple[Array, Array]:
    """One chip's contribution: fused scan over its slots → per-query top-k."""
    q = queries.shape[0]
    if backend in TILED_BACKENDS:
        # deduped scan → per-slot [QB, k] fragments → per-probe gather
        if backend == "xla_tiled":
            uvals, uids, _ = tiled_scan_xla(
                u_cluster, u_tile, queries, lo, hi, vectors, attrs, ids,
                norms, scales, metric=metric, k=k, q_block=q_block,
            )
        else:
            uvals, uids, _ = filtered_scan_tiled(
                u_cluster, u_tile, queries, lo, hi, vectors, attrs, ids,
                norms, scales, metric=metric, k=k, q_block=q_block,
                v_block=v_block,
                interpret=backend == "pallas_tiled_interpret",
            )
        row = slot_query % q_block  # [P_cap]
        svals = uvals[slot_of, row]  # [P_cap, k]
        sids = uids[slot_of, row]
        svals = jnp.where(slot_valid[:, None], svals, NEG_INF)
        sids = jnp.where(slot_valid[:, None], sids, -1)
    elif backend in ("pallas", "pallas_interpret", "xla_map", "xla_vmap"):
        if backend in ("pallas", "pallas_interpret"):
            scores = filtered_scan(
                slot_cluster, slot_query, queries, lo, hi, vectors, attrs,
                ids, norms, scales, metric=metric, v_block=v_block,
                interpret=backend == "pallas_interpret",
            )  # [P_cap, Vpad]
        else:
            scores = _scan_slots_xla(
                vectors, attrs, ids, norms, scales, queries, lo, hi,
                slot_cluster, slot_query, metric=metric,
                use_vmap=backend == "xla_vmap",
            )
        scores = jnp.where(slot_valid[:, None], scores, NEG_INF)
        slot_ids = jnp.take(ids, slot_cluster, axis=0)  # [P_cap, Vpad]
        svals, sids = topk_lib.masked_topk(
            scores, None, k, ids=slot_ids
        )  # [P,k]
    else:
        raise ValueError(backend)

    rank = _rank_within_query(slot_query, slot_valid, t)
    qvals = jnp.full((q, t, k), NEG_INF, jnp.float32)
    qids = jnp.full((q, t, k), -1, jnp.int32)
    safe_q = jnp.where(slot_valid, slot_query, q)  # pads scatter out of range
    qvals = qvals.at[safe_q, rank].set(svals, mode="drop")
    qids = qids.at[safe_q, rank].set(sids, mode="drop")
    vals, out_ids = topk_lib.masked_topk(
        qvals.reshape(q, t * k), None, k, ids=qids.reshape(q, t * k)
    )
    return vals, out_ids


@dataclasses.dataclass(frozen=True)
class ShardedSearchConfig:
    k: int = 100
    n_probes: int = 7  # paper's T
    p_cap_slack: float = 2.0
    v_block: int = 256
    q_block: int = 128  # centroid-topk tiles
    k_block: int = 512
    scan_q_block: int = 64  # query-tile height QB for the tiled backends
    use_centroid_kernel: bool = False  # XLA path on CPU; kernel on TPU
    # Per-probe scans: "pallas" (TPU), "pallas_interpret" (CPU tests),
    # "xla_map" (dry-run exec variant), "xla_vmap" (dry-run cost variant).
    # Tiled, probe-deduplicated scans with streaming top-k: "pallas_tiled"
    # (TPU), "pallas_tiled_interpret" (CPU tests), "xla_tiled" (fast CPU).
    backend: str = "pallas_interpret"
    quantized: bool = False  # SQ8 lists (see ivf.quantize_index)
    # Filter-aware probe pruning from the index's resident cluster attribute
    # summaries (core/summaries.py), replicated like the centroids: "auto"
    # prunes iff the index carries summaries, "on" requires them, "off"
    # disables.  Pruned probes never consume P_cap slots on their owner
    # shard; ids/scores stay bit-identical to the unpruned dispatch.
    prune: str = "auto"


def make_sharded_search(
    mesh: Mesh,
    metric: str,
    *,
    q_total: int,
    n_clusters: int,
    cfg: ShardedSearchConfig,
    axis_names: Optional[Sequence[str]] = None,
):
    """Builds the pod-scale search step for a given mesh.

    Returns ``(search_fn, shardings)``: ``search_fn(index, queries, fspec,
    shard_ok) -> SearchResult`` (jit-compatible), and a dict mapping index
    leaf names to NamedShardings (cluster axis split over all mesh axes).
    """
    axes = tuple(axis_names or mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if n_clusters % n_shards:
        raise ValueError(
            f"K={n_clusters} must divide over {n_shards} shards; pad K at "
            f"build time (storage.reshard handles this)."
        )
    k_local = n_clusters // n_shards
    p_cap = probe_capacity(q_total, cfg.n_probes, n_shards, cfg.p_cap_slack)
    merge_axes = tuple(reversed(axes))  # model → data → pod
    needs_norms = metric == "l2"
    tiled = cfg.backend in TILED_BACKENDS
    scan_qb = min(cfg.scan_q_block, ivf_lib.round_up(q_total, 8))
    q_pad_total = ivf_lib.round_up(q_total, scan_qb)
    n_tiles = q_pad_total // scan_qb
    u_cap = max(1, min(p_cap, k_local * n_tiles))

    shard_spec = P(axes)  # leading (cluster) axis split over all mesh axes
    repl = P()

    def _local(vec, att, idl, nrm, scl, ok, sc, sq, sv, uc, ut, uslot,
               queries, lo, hi):
        sid = jnp.int32(0)
        for a in axes:
            sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
        my_sc = jax.lax.dynamic_index_in_dim(sc, sid, keepdims=False)
        my_sq = jax.lax.dynamic_index_in_dim(sq, sid, keepdims=False)
        my_sv = jax.lax.dynamic_index_in_dim(sv, sid, keepdims=False)
        my_uc = jax.lax.dynamic_index_in_dim(uc, sid, keepdims=False)
        my_ut = jax.lax.dynamic_index_in_dim(ut, sid, keepdims=False)
        my_us = jax.lax.dynamic_index_in_dim(uslot, sid, keepdims=False)
        my_sv = jnp.logical_and(my_sv, ok[0])
        vals, out_ids = _local_shard_search(
            vec, att, idl, nrm if needs_norms else None,
            scl if quantized else None, queries, lo, hi,
            my_sc, my_sq, my_sv, my_uc, my_ut, my_us,
            metric=metric, k=cfg.k, t=cfg.n_probes, q_block=scan_qb,
            v_block=cfg.v_block, backend=cfg.backend,
        )
        return topk_lib.topk_tree_merge(vals, out_ids, cfg.k, merge_axes)

    quantized = cfg.quantized
    sharded_local = compat.shard_map(
        _local,
        mesh=mesh,
        in_specs=(shard_spec, shard_spec, shard_spec, shard_spec, shard_spec,
                  shard_spec, repl, repl, repl, repl, repl, repl, repl, repl,
                  repl),
        out_specs=(repl, repl),
        # pallas_call's out_shape carries no varying-mesh-axes annotation;
        # VMA/replication checking cannot see through it, so it is disabled.
        check=False,
    )

    def search_fn(index: IVFFlatIndex, queries: Array, fspec: FilterSpec,
                  shard_ok: Optional[Array] = None) -> SearchResult:
        if shard_ok is None:
            shard_ok = jnp.ones((n_shards,), jnp.bool_)
        # ---- §4.4 step 2: probe centroids (replicated) ----
        _, probe_ids = probe_centroids(
            queries, index.centroids, t=cfg.n_probes,
            q_block=min(cfg.q_block, queries.shape[0]),
            k_block=min(cfg.k_block, n_clusters),
            metric=metric, use_kernel=cfg.use_centroid_kernel,
            interpret=cfg.backend not in ("pallas", "pallas_tiled"),
        )
        # ---- filter-aware prune mask (replicated, like the plan stage) ----
        from repro.core.engine import resolve_prune

        summ = resolve_prune(index, cfg.prune)
        if summ is not None:
            cm = summaries_lib.can_match(summ, fspec.lo, fspec.hi)  # [Q, K]
            probe_valid = jnp.take_along_axis(cm, probe_ids, axis=1)
        else:
            probe_valid = None
        # ---- dispatch (replicated compute; each chip consumes its row) ----
        if tiled:
            sc, sq, sv, n_drop, uc, ut, uslot, _ = dispatch_probes_tiled(
                probe_ids, n_shards=n_shards, k_local=k_local, p_cap=p_cap,
                u_cap=u_cap, q_block=scan_qb, probe_valid=probe_valid,
            )
            queries_in = probes_lib.pad_to_tiles(queries, scan_qb)
            lo_in = probes_lib.pad_to_tiles(fspec.lo, scan_qb)
            hi_in = probes_lib.pad_to_tiles(fspec.hi, scan_qb)
        else:
            sc, sq, sv, n_drop = dispatch_probes(
                probe_ids, n_shards=n_shards, k_local=k_local, p_cap=p_cap,
                probe_valid=probe_valid,
            )
            uc = jnp.zeros((n_shards, 1), jnp.int32)
            ut = jnp.zeros((n_shards, 1), jnp.int32)
            uslot = jnp.zeros((n_shards, p_cap), jnp.int32)
            queries_in, lo_in, hi_in = queries, fspec.lo, fspec.hi
        norms = index.norms if needs_norms else jnp.zeros(
            (n_clusters, 1), jnp.float32
        )
        scales = index.scales if quantized else jnp.zeros(
            (n_clusters, 1), jnp.float32
        )
        vals, out_ids = sharded_local(
            index.vectors, index.attrs, index.ids, norms, scales, shard_ok,
            sc, sq, sv, uc, ut, uslot, queries_in, lo_in, hi_in,
        )
        q = queries.shape[0]
        vals, out_ids = vals[:q], out_ids[:q]
        if needs_norms:
            q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1, keepdims=True)
            vals = jnp.where(vals > NEG_INF / 2, vals - q2, vals)
        zero = jnp.zeros((q,), jnp.int32)
        return SearchResult(vals, out_ids, zero + n_drop, zero)

    shardings = {
        "centroids": NamedSharding(mesh, repl),
        "vectors": NamedSharding(mesh, shard_spec),
        "attrs": NamedSharding(mesh, shard_spec),
        "ids": NamedSharding(mesh, shard_spec),
        "norms": NamedSharding(mesh, shard_spec),
        "scales": NamedSharding(mesh, shard_spec),
        "counts": NamedSharding(mesh, shard_spec),
    }
    from repro.core.blockstore import RangeOwnership

    # The dispatch's ownership map, exposed so the serving layer can hand
    # the SAME map to a ShardedBlockStore — cache routing then agrees with
    # shard routing (a chip's probes are always its own pod's cache load).
    return search_fn, shardings, dict(p_cap=p_cap, k_local=k_local,
                                      n_shards=n_shards,
                                      ownership=RangeOwnership(n_shards,
                                                               k_local))
