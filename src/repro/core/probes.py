"""Probe planning: query tiling and per-batch probe deduplication.

Under real traffic a batch of Q queries probing T lists each hits far fewer
than Q·T *distinct* lists — popular clusters are probed by many queries at
once (the batch-sharing observation in SIEVE and the filtered-ANNS
experimental study).  The per-(query, probe) slot layout the original fused
scan used re-streams a duplicated cluster's blocks HBM→VMEM once per
duplicate.  This module builds the slot tables that let the tiled kernel
stream every (query-tile, cluster) pair exactly once:

  * queries are grouped into static tiles of ``q_block`` rows;
  * per tile, the Q·T probe ids are sorted and deduplicated into a
    static-size table of ``u_cap`` unique-cluster slots (padded by repeating
    the last unique id, so consecutive padded slots hit the Pallas
    revisiting fast path and cost no extra HBM traffic);
  * every original (query, t) probe keeps a pointer into the table so the
    per-probe top-k candidates can be gathered back after the scan.

All shapes are static (sort + cumsum + scatter, no data-dependent sizes), so
the whole plan jits and shards.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# int32 max: sorts after every real key, so invalid entries sink to the end.
_SENTINEL = jnp.int32(2**31 - 1)


def dedup_rows(
    keys: Array, valid: Optional[Array], cap: int
) -> Tuple[Array, Array, Array]:
    """Row-wise sorted dedup into a static-size unique table.

    Args:
      keys:  [R, L] int32 (each row deduped independently).
      valid: [R, L] bool or None; invalid entries are excluded.
      cap:   static table width; callers must size it so the true unique
             count never exceeds it (e.g. ``min(L, key_space)``).

    Returns:
      table   [R, cap] int32 — unique keys, ascending; tail slots are padded
              with the row's last unique key (0 for all-invalid rows).
      slot_of [R, L] int32 — UNCAPPED unique index of each entry's key (junk,
              but ≥ 0, where ``valid`` is False).  Values ≥ cap mark keys
              that overflowed the table — callers must mask or clip them.
      count   [R] int32 — number of unique valid keys per row.
    """
    r, l = keys.shape
    k = keys if valid is None else jnp.where(valid, keys, _SENTINEL)
    order = jnp.argsort(k, axis=1)
    ks = jnp.take_along_axis(k, order, axis=1)  # [R, L] ascending
    vs = ks != _SENTINEL
    first = jnp.logical_and(
        vs,
        jnp.concatenate(
            [jnp.ones((r, 1), bool), ks[:, 1:] != ks[:, :-1]], axis=1
        ),
    )
    slot_sorted = jnp.maximum(
        jnp.cumsum(first.astype(jnp.int32), axis=1) - 1, 0
    )
    count = jnp.sum(first.astype(jnp.int32), axis=1)

    rows = jnp.arange(r)[:, None]
    dest = jnp.where(first, slot_sorted, cap)  # ≥ cap ⇒ dropped
    table = jnp.zeros((r, cap), jnp.int32).at[rows, dest].set(
        ks.astype(jnp.int32), mode="drop"
    )
    capped = jnp.minimum(count, cap)
    last = jnp.take_along_axis(table, jnp.maximum(capped - 1, 0)[:, None], 1)
    table = jnp.where(
        jnp.arange(cap)[None, :] < jnp.maximum(capped, 1)[:, None],
        table, last,
    )

    slot_of = jnp.zeros((r, l), jnp.int32).at[rows, order].set(slot_sorted)
    return table, slot_of, count


def plan_probe_tiles(
    probe_ids: Array, *, q_block: int, u_cap: int,
    probe_valid: Optional[Array] = None,
) -> Tuple[Array, Array, Array, Array, Array]:
    """Builds the tiled kernel's slot tables for a single-host batch.

    Args:
      probe_ids: [Qpad, T] int32 cluster ids, Qpad a multiple of q_block.
      q_block:   query-tile height QB.
      u_cap:     static unique-probe capacity per tile.
                 ``min(q_block·T, n_clusters)`` is always sufficient; smaller
                 values trade recall for speed under overlap-heavy traffic —
                 overflowed probes are reported via ``probe_ok`` and their
                 candidates dropped (sound degradation, like the distributed
                 dispatch's P_cap).
      probe_valid: optional [Qpad, T] bool — probes the planner pruned (e.g.
                 the filter-aware summary test proved the cluster holds no
                 passing row).  Invalid probes never enter the slot tables:
                 they are not scanned, not fetched by ``fetch_order``, and
                 report ``probe_ok=False``.

    Returns:
      slot_cluster  [n_tiles·u_cap] int32 — cluster scanned by each slot.
      slot_tile     [n_tiles·u_cap] int32 — query tile each slot serves.
      slot_of_probe [Qpad, T] int32 — flat slot index of each original probe
                    (clipped in-range; check probe_ok).
      probe_ok      [Qpad, T] bool — False where the probe overflowed u_cap
                    or was pruned via ``probe_valid``.
      n_unique      [n_tiles] int32 — live slots per tile (rest are pads).
    """
    qpad, t = probe_ids.shape
    if qpad % q_block:
        raise ValueError(f"Qpad={qpad} not a multiple of q_block={q_block}")
    n_tiles = qpad // q_block
    flat = probe_ids.reshape(n_tiles, q_block * t).astype(jnp.int32)
    valid = (
        None if probe_valid is None
        else probe_valid.reshape(n_tiles, q_block * t)
    )
    table, slot_of, count = dedup_rows(flat, valid, u_cap)
    slot_cluster = table.reshape(-1)
    slot_tile = jnp.repeat(
        jnp.arange(n_tiles, dtype=jnp.int32), u_cap, total_repeat_length=n_tiles * u_cap
    )
    probe_ok = (slot_of < u_cap).reshape(qpad, t)
    if probe_valid is not None:
        probe_ok = jnp.logical_and(probe_ok, probe_valid)
    slot_of_probe = (
        jnp.minimum(slot_of, u_cap - 1)
        + jnp.arange(n_tiles, dtype=jnp.int32)[:, None] * u_cap
    ).reshape(qpad, t)
    return slot_cluster, slot_tile, slot_of_probe, probe_ok, count


def fetch_order(slot_cluster, n_unique, u_cap: int):
    """The disk tier's cache fetch list from a probe plan (host-side).

    Flattens the per-tile unique-probe tables into one duplicate-free list of
    cluster ids in *first-need order* — tile 0's unique clusters first, then
    tile 1's novel ones, and so on.  Feeding this to the cluster cache's
    prefetch thread loads clusters in exactly the order the scan will consume
    them, so the earliest tiles unblock first.

    Args:
      slot_cluster: [n_tiles·u_cap] int32 (``plan_probe_tiles`` output),
                    array-like (host numpy or device array).
      n_unique:     [n_tiles] int32 live-slot counts (pads excluded).
      u_cap:        static per-tile slot capacity.

    Returns a 1-D int64 numpy array of distinct cluster ids.

    Vectorized (mask → flatten row-major → first-seen unique): the old
    Python double loop over ``n_tiles × u_cap`` ran per batch on the serving
    hot path and dominated plan time at large batch×probe products.
    """
    import numpy as np

    sc = np.asarray(slot_cluster).reshape(-1, u_cap).astype(np.int64)
    nu = np.asarray(n_unique)
    live = np.arange(u_cap)[None, :] < nu[:, None]  # [n_tiles, u_cap]
    flat = sc[live]  # row-major ⇒ tile 0's slots first, then tile 1's, ...
    uniq, first = np.unique(flat, return_index=True)
    return uniq[np.argsort(first, kind="stable")]


def _live_flat(slot_cluster, n_unique, u_cap: int):
    """Flattens live slots row-major (tile 0 first) with their tile ids."""
    import numpy as np

    sc = np.asarray(slot_cluster).reshape(-1, u_cap).astype(np.int64)
    nu = np.asarray(n_unique)
    n_tiles = sc.shape[0]
    live = np.arange(u_cap)[None, :] < nu[:, None]  # [n_tiles, u_cap]
    tile_of = np.broadcast_to(
        np.arange(n_tiles)[:, None], sc.shape
    )
    return n_tiles, sc[live], tile_of[live]


def tile_fetch_lists(slot_cluster, n_unique, u_cap: int):
    """Per-tile *novel*-cluster fetch lists (host-side).

    Splits :func:`fetch_order`'s flat first-need list back into per-tile
    units: tile i's list holds the clusters it needs that no earlier tile
    already fetched, in slot order.  Concatenating every tile's list
    reproduces ``fetch_order`` exactly — these are the routing units a
    slot-granular pager (the pipelined engine's fetch stage) or a
    multi-host cache shard consumes per tile.

    Returns a list of 1-D int64 numpy arrays, one per tile.

    Vectorized like :func:`fetch_order` (mask → flatten row-major →
    first-seen unique, then one split by first-need tile): the engine's
    operand-cache fetch stage calls this per batch on the serving hot
    path, where the old per-element Python double loop dominated plan
    time at large batch×probe products.
    """
    import numpy as np

    n_tiles, flat, flat_tile = _live_flat(slot_cluster, n_unique, u_cap)
    uniq, first = np.unique(flat, return_index=True)
    order = np.argsort(first, kind="stable")  # first-need (slot) order
    uniq = uniq[order]
    first_tile = flat_tile[first][order]
    return [uniq[first_tile == t] for t in range(n_tiles)]


def tile_release_lists(slot_cluster, n_unique, u_cap: int):
    """Per-tile *last-need* cluster lists (host-side).

    The complement of :func:`tile_fetch_lists`: tile i's list holds the
    clusters no tile after i needs, in slot order.  A per-batch operand
    cache frees a cluster's record right after its last consuming tile is
    assembled, so the cache's footprint tracks the batch's live overlap
    ranges instead of its whole unique set — what keeps batch-level reuse
    compatible with the disk tier's bounded-memory budget.

    The lists partition the batch's unique clusters (every fetched cluster
    is released by exactly one tile).
    """
    import numpy as np

    n_tiles, flat, flat_tile = _live_flat(slot_cluster, n_unique, u_cap)
    rev = flat[::-1]
    uniq, first_rev = np.unique(rev, return_index=True)
    last = flat.shape[0] - 1 - first_rev  # last occurrence in need order
    order = np.argsort(last, kind="stable")
    uniq = uniq[order]
    last_tile = flat_tile[last][order]
    return [uniq[last_tile == t] for t in range(n_tiles)]


def bound_order(slot_cluster, n_unique, slot_of_probe, slot_bound,
                u_cap: int):
    """Permutes each tile's live slots best-bound-first (host-side).

    The dedup tables come out in ascending-cluster-id order (a sort
    artifact); the bound-driven executor instead wants to scan the slots
    most likely to hold top-k candidates first, so the running kth score
    rises as fast as possible and later slots can be dropped on a bound.
    This reorders each tile's live region ``[0, u)`` by descending
    ``slot_bound`` and rewrites the pad region to repeat the *new* last
    live slot (preserving the consecutive-pad revisiting fast path), then
    remaps every probe pointer through the permutation.  Must run before
    any fetch list is built from the tables — fetch/prefetch then follow
    the new order for free.

    Args:
      slot_cluster:  [n_tiles·u_cap] int32 (``plan_probe_tiles`` output).
      n_unique:      [n_tiles] live-slot counts.
      slot_of_probe: [Qpad, T] int32 flat slot pointers.
      slot_bound:    [n_tiles, u_cap] f32 per-slot priority (e.g. the max
                     score upper bound over the tile's queries).
      u_cap:         static per-tile slot capacity.

    Returns ``(slot_cluster', slot_of_probe', perm)`` as host numpy arrays,
    where ``perm [n_tiles, u_cap]`` maps new slot position → old position
    (identity on pads), so callers can co-permute per-slot state with
    ``np.take_along_axis(x, perm, ...)``.
    """
    import numpy as np

    sc = np.array(np.asarray(slot_cluster).reshape(-1, u_cap), np.int32)
    nu = np.asarray(n_unique)
    bound = np.asarray(slot_bound)
    n_tiles = sc.shape[0]
    perm = np.broadcast_to(
        np.arange(u_cap, dtype=np.int32), (n_tiles, u_cap)
    ).copy()
    inv = perm.copy()
    for t in range(n_tiles):
        u = min(int(nu[t]), u_cap)
        if u <= 1:
            continue
        order = np.argsort(-bound[t, :u], kind="stable").astype(np.int32)
        perm[t, :u] = order
        sc[t, :u] = sc[t, order]
        sc[t, u:] = sc[t, u - 1]  # pads repeat the new last live slot
        inv_t = np.empty(u, np.int32)
        inv_t[order] = np.arange(u, dtype=np.int32)
        inv[t, :u] = inv_t  # positions ≥ u keep identity (clipped pads)
    t_idx, s = np.divmod(np.asarray(slot_of_probe, np.int32), u_cap)
    sop = (t_idx * u_cap + inv[t_idx, s]).astype(np.int32)
    return sc.reshape(-1), sop, perm


def split_fetch_by_owner(fetch, owner_of, alive=None):
    """Splits a first-need fetch list per owning node (host-side).

    ``fetch`` is any fetch-list unit — a whole-plan :func:`fetch_order`, or
    one tile's :func:`tile_fetch_lists` entry — and ``owner_of`` maps cluster
    ids to node ids (a ``blockstore.HashRing``/``RangeOwnership``, or the
    distributed dispatch's range map).  Each owner's sublist preserves the
    input's first-need order, so every peer streams its share of the tile in
    exactly the order the scan will consume it; the sublists partition the
    input (concatenating them in any order recovers the same set).

    ``alive`` (parallel bool mask) drops entries whose every (query, probe)
    pair is already dead before the split, so no peer sees a fetch for a
    cluster the scan provably won't read.

    Returns ``{node_id: 1-D int64 array}`` for the owners that appear.
    """
    import numpy as np

    fetch = np.asarray(fetch, dtype=np.int64).reshape(-1)
    if alive is not None:
        fetch = fetch[np.asarray(alive, dtype=bool).reshape(-1)]
    if fetch.size == 0:
        return {}
    owners = np.asarray(owner_of(fetch))
    return {
        int(o): fetch[owners == o] for o in np.unique(owners)
    }


def pad_to_tiles(x: Array, q_block: int) -> Array:
    """Pads the leading (query) axis up to a q_block multiple with edge rows.

    Edge rows (copies of the last real query) dedupe into the real queries'
    probe slots, so padding adds no scan work.
    """
    q = x.shape[0]
    pad = (-q) % q_block
    if pad == 0:
        return x
    cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, cfg, mode="edge")
