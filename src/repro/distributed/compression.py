"""Gradient compression for DP all-reduce: int8 quantization + error
feedback (1-bit-Adam-style residual correction).

At multi-pod scale the "pod" axis crosses DCN (slow links); compressing the
cross-pod gradient all-reduce 4× (f32→int8 with per-tensor scale) trades a
little optimizer noise for 4× less DCN traffic.  Error feedback keeps the
quantization bias out of the training trajectory: the residual (g − Q(g)) is
carried into the next step, so the *accumulated* applied gradient is unbiased.

Usage inside a shard_map'd train step::

    g_q, scale = quantize(g + err)
    g_mean = psum(dequantize(g_q, scale), "pod") / n_pods   # int8 on the wire
    err = (g + err) - dequantize(g_q, scale)

(The psum here is on the dequantized value for jax-semantics simplicity; on
real hardware the int8 payload rides the wire and dequantization happens
post-reduce — the traffic accounting in §Roofline uses the int8 width.)
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(
    grads: Any,
    err: Any,
    axis_name: Optional[str],
    n_replicas: int,
) -> Tuple[Any, Any]:
    """Quantize (grad + residual), all-reduce, return (mean grad, residual').

    With axis_name=None (single replica) this degrades to the identity-plus-
    quantization path so tests can check the error-feedback algebra exactly.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        new_err = corrected - deq
        if axis_name is not None:
            deq = jax.lax.psum(deq, axis_name) / n_replicas
        return deq.astype(g.dtype), new_err

    out = jax.tree.map(one, grads, err)
    g_out = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    e_out = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_out, e_out


def compression_ratio(params) -> float:
    """Wire-bytes ratio of int8+scale vs f32 for the given tree."""
    f32 = sum(p.size * 4 for p in jax.tree.leaves(params))
    i8 = sum(p.size * 1 + 4 for p in jax.tree.leaves(params))
    return f32 / i8
