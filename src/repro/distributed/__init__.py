from repro.distributed.compression import (
    compressed_psum_tree,
    compression_ratio,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)

__all__ = [
    "compressed_psum_tree", "compression_ratio", "dequantize_int8",
    "init_error_feedback", "quantize_int8",
]
