"""Version tolerance for the handful of JAX APIs that moved between releases.

The repo pins no JAX version; the container ships one.  Three APIs this
codebase leans on were renamed across the 0.4 → 0.6 line:

  * ``pltpu.TPUCompilerParams``  →  ``pltpu.CompilerParams``
  * ``jax.experimental.shard_map.shard_map(check_rep=...)``
                                 →  ``jax.shard_map(check_vma=...)``
  * ``with mesh:``               →  ``with jax.set_mesh(mesh):``

Every call site imports the spelling-stable wrappers below instead of
guessing which JAX it is running under.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# pltpu.CompilerParams (new) vs pltpu.TPUCompilerParams (old).
CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check=True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check=True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check,
        )


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient for PartitionSpec resolution."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # older JAX: Mesh is itself the context manager
