"""Jit'd wrapper: pads Q/K to block multiples and dispatches kernel vs ref.

On CPU (tests, examples) the XLA reference is faster than interpret mode, so
``probe_centroids`` picks the path via ``use_kernel``; the launch layer sets
it per backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centroid_topk.centroid_topk import centroid_topk
from repro.kernels.centroid_topk.ref import centroid_topk_ref


@functools.partial(
    jax.jit,
    static_argnames=("t", "q_block", "k_block", "metric", "use_kernel",
                     "interpret"),
)
def probe_centroids(
    queries: jax.Array,
    centroids: jax.Array,
    *,
    t: int,
    q_block: int = 128,
    k_block: int = 512,
    metric: str = "dot",
    use_kernel: bool = True,
    interpret: bool = False,
):
    """Returns (values [Q, T] f32, probe_ids [Q, T] int32), padding-safe."""
    q, _ = queries.shape
    k = centroids.shape[0]
    if not use_kernel:
        return centroid_topk_ref(queries, centroids, t=t, metric=metric)

    qb = min(q_block, q)
    q_pad = (-q) % qb
    k_pad = (-k) % k_block
    qp = jnp.pad(queries, ((0, q_pad), (0, 0)))
    cp = jnp.pad(centroids, ((0, k_pad), (0, 0)))
    if k_pad and metric == "dot":
        # padded centroids are zero ⇒ score 0 could win over negatives; push
        # them out of reach instead.
        cp = cp.at[k:].set(0.0)
    vals, ids = centroid_topk(
        qp, cp, t=t, q_block=qb, k_block=min(k_block, k + k_pad),
        metric=metric, interpret=interpret,
    )
    if k_pad:
        # mask any padded-centroid wins (score from zero rows)
        bad = ids >= k
        vals = jnp.where(bad, -3.0e38, vals)
        ids = jnp.where(bad, -1, ids)
    return vals[:q], ids[:q]
