from repro.kernels.centroid_topk.centroid_topk import centroid_topk
from repro.kernels.centroid_topk.ops import probe_centroids
from repro.kernels.centroid_topk.ref import centroid_topk_ref

__all__ = ["centroid_topk", "centroid_topk_ref", "probe_centroids"]
