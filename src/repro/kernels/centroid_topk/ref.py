"""Pure-jnp oracle for the streaming centroid top-T kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def centroid_topk_ref(
    queries: jax.Array, centroids: jax.Array, *, t: int, metric: str = "dot"
):
    q32 = queries.astype(jnp.float32)
    c32 = centroids.astype(jnp.float32)
    scores = q32 @ c32.T
    if metric == "l2":
        scores = 2.0 * scores - jnp.sum(c32 * c32, -1)[None, :]
    vals, ids = jax.lax.top_k(scores, t)
    return vals, ids.astype(jnp.int32)
