"""Streaming centroid top-T (paper §4.4 step 2) as a Pallas kernel.

Computes ``top-T_k( score(q, c_k) )`` for a batch of queries against the full
centroid table without ever writing the [Q, K] score matrix to HBM: each grid
step scores one (query-block × centroid-block) tile on the MXU and folds it
into a running top-T held in VMEM scratch.  At K=32 768, Q=1024 that removes a
128 MiB HBM round-trip per batch.

The in-kernel selection is iterative max-extraction (T static iterations of
max/argmax over the tile ∪ running set) — branch-free, Mosaic-friendly, and
exact; no reliance on sort lowering inside the kernel.

Grid: (Q//q_block, K//k_block), centroid axis innermost so the running state
for a query block sees every centroid tile before the output write.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -3.0e38


def _kernel(q_ref, c_ref, ov_ref, oi_ref, rv_ref, ri_ref, *, t, k_block,
            metric):
    ki = pl.program_id(1)
    nkb = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        rv_ref[...] = jnp.full_like(rv_ref, NEG_INF)
        ri_ref[...] = jnp.full_like(ri_ref, -1)

    q = q_ref[...].astype(jnp.float32)  # [QB, D]
    c = c_ref[...].astype(jnp.float32)  # [KB, D]
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [QB, KB]
    if metric == "l2":
        scores = 2.0 * scores - jnp.sum(c * c, axis=-1)[None, :]

    qb = scores.shape[0]
    base = ki * k_block
    tile_ids = jax.lax.broadcasted_iota(jnp.int32, (qb, k_block), 1) + base

    cand_v = jnp.concatenate([rv_ref[...], scores], axis=1)  # [QB, T+KB]
    cand_i = jnp.concatenate([ri_ref[...], tile_ids], axis=1)

    new_v = []
    new_i = []
    for _ in range(t):  # static T-step extraction
        m = jnp.max(cand_v, axis=1)  # [QB]
        am = jnp.argmax(cand_v, axis=1)  # [QB]
        picked = jnp.take_along_axis(cand_i, am[:, None], axis=1)[:, 0]
        new_v.append(m)
        new_i.append(jnp.where(m > NEG_INF / 2, picked, -1))
        hit = (
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
            == am[:, None]
        )
        cand_v = jnp.where(hit, NEG_INF, cand_v)
    rv_ref[...] = jnp.stack(new_v, axis=1)
    ri_ref[...] = jnp.stack(new_i, axis=1)

    @pl.when(ki == nkb - 1)
    def _emit():
        ov_ref[...] = rv_ref[...]
        oi_ref[...] = ri_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("t", "q_block", "k_block", "metric", "interpret"),
)
def centroid_topk(
    queries: jax.Array,  # [Q, D]
    centroids: jax.Array,  # [K, D]
    *,
    t: int,
    q_block: int = 128,
    k_block: int = 512,
    metric: str = "dot",
    interpret: bool = False,
):
    """Returns (values [Q, T] f32, ids [Q, T] int32)."""
    q, d = queries.shape
    k = centroids.shape[0]
    if q % q_block != 0:
        raise ValueError(f"Q={q} not a multiple of q_block={q_block}")
    if k % k_block != 0:
        raise ValueError(f"K={k} not a multiple of k_block={k_block}")
    if metric not in ("dot", "l2"):
        raise ValueError(metric)

    grid = (q // q_block, k // k_block)
    kern = functools.partial(_kernel, t=t, k_block=k_block, metric=metric)
    vals, ids = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_block, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((k_block, d), lambda qi, ki: (ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((q_block, t), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((q_block, t), lambda qi, ki: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, t), jnp.float32),
            jax.ShapeDtypeStruct((q, t), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_block, t), jnp.float32),
            pltpu.VMEM((q_block, t), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(queries, centroids)
    return vals, ids
