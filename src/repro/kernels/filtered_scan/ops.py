"""Jit'd wrappers: index-level fused filtered search built on the Pallas scans.

Two entry points share :class:`repro.core.search.SearchResult`'s contract:

  * :func:`search_fused`       — the original per-(query, probe) slot path.
    Still materializes a ``[Q·T, Vpad]`` score matrix on the way to top-k.
  * :func:`search_fused_tiled` — the batched successor, now owned by the
    search execution engine (:mod:`repro.core.engine`): a jitted plan stage
    (centroid top-k + filter-aware probe pruning + per-tile probe dedup), a
    fetch stage (resident arrays or the disk tier's cluster cache), and a
    jitted scan/merge stage (query-tiled kernel + streaming top-k + monoid
    merge).  Re-exported here for backward compatibility, together with the
    engine's stage primitives (``plan_fused_tiled``, ``tiled_scan_xla``,
    ``resolve_prune``) that used to live in this module.

Backends for the tiled path: ``"pallas"`` (compiled, TPU), ``"pallas_interpret"``
(CPU debugging/tests), ``"xla"`` (pure-jnp streaming executor — the fast CPU
path).  ``backend=None`` picks ``"pallas"`` on TPU and ``"xla"`` elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import topk as topk_lib
from repro.core.engine import (  # noqa: F401  (back-compat re-exports)
    SearchEngine,
    plan_fused_tiled,
    resolve_prune,
    search_fused_tiled,
    tiled_scan_xla,
    _scan_merge_tiled,
)
from repro.core.filters import FilterSpec
from repro.core.ivf import IVFFlatIndex
from repro.core.search import SearchResult, search_centroids
from repro.kernels.filtered_scan.filtered_scan import (  # noqa: F401
    filtered_scan,
    fold_running_topk,
)

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("k", "n_probes", "v_block", "interpret")
)
def search_fused(
    index: IVFFlatIndex,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    v_block: int = 256,
    interpret: Optional[bool] = None,
) -> SearchResult:
    """Single-device fused search (paper §4.4 via the Pallas kernel).

    interpret=None auto-detects the backend: the compiled kernel on TPU,
    interpret mode everywhere else (CPU tests, GPU dry-runs).  Pass an
    explicit bool to pin the mode (tests do).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = queries.shape[0]
    probe_ids, _ = search_centroids(index, queries, n_probes)  # [Q, T]

    slot_cluster = probe_ids.reshape(-1)  # [Q*T]
    slot_query = jnp.repeat(
        jnp.arange(q, dtype=jnp.int32), n_probes
    )  # [Q*T]

    scores = filtered_scan(
        slot_cluster,
        slot_query,
        queries.astype(jnp.float32 if index.quantized
                       else index.vectors.dtype),
        fspec.lo,
        fspec.hi,
        index.vectors,
        index.attrs,
        index.ids,
        index.norms,
        index.scales,
        metric=index.spec.metric,
        v_block=v_block,
        interpret=interpret,
    )  # [Q*T, Vpad]

    if index.spec.metric == "l2":
        # add back the per-query -||q||^2 so scores match the oracle
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        scores = jnp.where(
            scores > topk_lib.NEG_INF / 2,
            scores - jnp.take(q2, slot_query)[:, None],
            scores,
        )

    out_ids = jnp.take(index.ids, slot_cluster, axis=0)  # [Q*T, Vpad]
    vpad = scores.shape[-1]
    flat_scores = scores.reshape(q, n_probes * vpad)
    flat_ids = out_ids.reshape(q, n_probes * vpad)
    vals, ids = topk_lib.masked_topk(flat_scores, None, k, ids=flat_ids)

    passed = scores > topk_lib.NEG_INF / 2  # [Q*T, Vpad]
    n_passed = jnp.sum(
        passed.reshape(q, -1).astype(jnp.int32), axis=-1
    )
    live = (out_ids >= 0).reshape(q, -1)
    n_scanned = jnp.sum(live.astype(jnp.int32), axis=-1)
    return SearchResult(vals, ids, n_scanned, n_passed)
