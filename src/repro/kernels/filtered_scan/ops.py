"""Jit'd wrappers: index-level fused filtered search built on the Pallas scans.

Two entry points share :class:`repro.core.search.SearchResult`'s contract:

  * :func:`search_fused`       — the original per-(query, probe) slot path.
    Still materializes a ``[Q·T, Vpad]`` score matrix on the way to top-k.
  * :func:`search_fused_tiled` — the batched successor.  Queries are tiled,
    probes are deduplicated per tile (``core/probes.py``), the kernel scores
    a whole ``[QB, D]`` query tile per streamed block and reduces it to a
    running ``[QB, k]`` on the fly, and the per-probe fragments are merged
    with the ``merge_topk`` monoid — peak memory ``O(slots·QB·k)``, never
    ``O(Q·T·Vpad)``, and a cluster probed by many queries of a tile is
    streamed HBM→VMEM exactly once.

Backends for the tiled path: ``"pallas"`` (compiled, TPU), ``"pallas_interpret"``
(CPU debugging/tests), ``"xla"`` (pure-jnp streaming executor — the fast CPU
path, chunked ``lax.map`` over slots so the same never-materialize bound
holds).  ``backend=None`` picks ``"pallas"`` on TPU and ``"xla"`` elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.core import probes as probes_lib
from repro.core import summaries as summaries_lib
from repro.core import topk as topk_lib
from repro.core.filters import FilterSpec
from repro.core.ivf import IVFFlatIndex, round_up
from repro.core.search import SearchResult, centroid_scores, search_centroids
from repro.kernels.filtered_scan.filtered_scan import (
    filtered_scan,
    filtered_scan_tiled,
)

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("k", "n_probes", "v_block", "interpret")
)
def search_fused(
    index: IVFFlatIndex,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    v_block: int = 256,
    interpret: Optional[bool] = None,
) -> SearchResult:
    """Single-device fused search (paper §4.4 via the Pallas kernel).

    interpret=None auto-detects the backend: the compiled kernel on TPU,
    interpret mode everywhere else (CPU tests, GPU dry-runs).  Pass an
    explicit bool to pin the mode (tests do).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = queries.shape[0]
    probe_ids, _ = search_centroids(index, queries, n_probes)  # [Q, T]

    slot_cluster = probe_ids.reshape(-1)  # [Q*T]
    slot_query = jnp.repeat(
        jnp.arange(q, dtype=jnp.int32), n_probes
    )  # [Q*T]

    scores = filtered_scan(
        slot_cluster,
        slot_query,
        queries.astype(jnp.float32 if index.quantized
                       else index.vectors.dtype),
        fspec.lo,
        fspec.hi,
        index.vectors,
        index.attrs,
        index.ids,
        index.norms,
        index.scales,
        metric=index.spec.metric,
        v_block=v_block,
        interpret=interpret,
    )  # [Q*T, Vpad]

    if index.spec.metric == "l2":
        # add back the per-query -||q||^2 so scores match the oracle
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        scores = jnp.where(
            scores > topk_lib.NEG_INF / 2,
            scores - jnp.take(q2, slot_query)[:, None],
            scores,
        )

    out_ids = jnp.take(index.ids, slot_cluster, axis=0)  # [Q*T, Vpad]
    vpad = scores.shape[-1]
    flat_scores = scores.reshape(q, n_probes * vpad)
    flat_ids = out_ids.reshape(q, n_probes * vpad)
    vals, ids = topk_lib.masked_topk(flat_scores, None, k, ids=flat_ids)

    passed = scores > topk_lib.NEG_INF / 2  # [Q*T, Vpad]
    n_passed = jnp.sum(
        passed.reshape(q, -1).astype(jnp.int32), axis=-1
    )
    live = (out_ids >= 0).reshape(q, -1)
    n_scanned = jnp.sum(live.astype(jnp.int32), axis=-1)
    return SearchResult(vals, ids, n_scanned, n_passed)


def tiled_scan_xla(
    slot_cluster, slot_tile, queries, lo, hi, vectors, attrs, ids,
    norms, scales, *, metric: str, k: int, q_block: int, chunk: int = 8,
):
    """XLA streaming executor with the tiled kernel's exact contract.

    Chunked ``lax.map`` over slots: each step gathers ``chunk`` cluster
    blocks, scores them against their query tiles and immediately reduces to
    ``[QB, k]`` — the full per-slot score matrix never exists, matching the
    kernel's memory bound.  This is the fast CPU path (Mosaic needs a real
    TPU to lower non-interpreted).
    """
    d = queries.shape[-1]
    qt = queries.reshape(-1, q_block, d).astype(jnp.float32)
    lot = lo.reshape(-1, q_block, *lo.shape[1:]).astype(jnp.int32)
    hit = hi.reshape(-1, q_block, *hi.shape[1:]).astype(jnp.int32)

    def one(args):
        sc, st = args
        v = jnp.take(vectors, sc, axis=0).astype(jnp.float32)  # [Vpad, D]
        qb = jnp.take(qt, st, axis=0)  # [QB, D]
        scores = qb @ v.T  # [QB, Vpad]
        if scales is not None:
            scores = scores * jnp.take(scales, sc, axis=0)[None, :]
        if metric == "l2":
            scores = 2.0 * scores - jnp.take(norms, sc, axis=0)[None, :]
        a = jnp.take(attrs, sc, axis=0).astype(jnp.int32)  # [Vpad, M]
        qlo = jnp.take(lot, st, axis=0)  # [QB, F, M]
        qhi = jnp.take(hit, st, axis=0)
        inside = jnp.logical_and(
            a[None, :, None, :] >= qlo[:, None],
            a[None, :, None, :] <= qhi[:, None],
        )  # [QB, Vpad, F, M]
        fmask = jnp.any(jnp.all(inside, -1), -1)
        live = jnp.take(ids, sc, axis=0) >= 0
        mask = jnp.logical_and(fmask, live[None, :])
        svals, sids = topk_lib.masked_topk(
            scores, mask, k,
            ids=jnp.broadcast_to(jnp.take(ids, sc, axis=0), scores.shape),
        )
        return svals, sids, jnp.sum(mask.astype(jnp.int32), axis=-1)

    return jax.lax.map(
        one, (slot_cluster, slot_tile), batch_size=min(chunk, slot_cluster.shape[0])
    )


@functools.partial(
    jax.jit,
    static_argnames=("metric", "n_probes", "q_block", "u_cap", "cast_dtype",
                     "t_max"),
)
def plan_fused_tiled(
    centroids: Array,
    counts: Array,
    queries: Array,
    lo: Array,
    hi: Array,
    *,
    metric: str,
    n_probes: int,
    q_block: int,
    u_cap: int,
    cast_dtype,
    summaries=None,
    t_max: Optional[int] = None,
):
    """Stage 1 of the tiled search: centroid probe + per-tile dedup plan.

    Runs entirely on the *resident* state (centroids + counts + attribute
    summaries), so the disk tier can plan — and hand ``slot_cluster`` to its
    cluster cache as the batch's fetch list — before any flat list is paged
    in.  Returns ``(slot_cluster, slot_tile, slot_of_probe, probe_ok,
    n_unique, queries_pad, lo_pad, hi_pad, n_pruned)``; queries/bounds come
    back padded to whole ``q_block`` tiles with edge rows (whose probes
    dedupe into the last real query's slots, so padding adds no scan work).

    With ``summaries`` (a :class:`repro.core.summaries.ClusterSummaries`),
    the plan is filter-aware: a branch-free disjointness test between each
    query's DNF terms and the per-cluster interval/histogram summaries marks
    clusters the filter provably cannot match, and those probes are dropped
    *before* the per-tile dedup — they never get a slot, are never fetched
    by ``probes.fetch_order``, and are never scanned.  Results stay
    bit-identical to the unpruned plan (only zero-passing-row clusters can
    be pruned).

    ``t_max`` (static, > n_probes) additionally enables adaptive probe
    widening (paper §4.3 selectivity-adaptive T): each query's probe set is
    refilled with its next-best *unpruned* centroids from the geometric
    top-``t_max``, so selective filters keep ``n_probes`` productive probes
    instead of silently scanning fewer clusters.  Unfiltered queries prune
    nothing, refill nothing, and plan exactly as before.  Within the refill
    ranking, the summaries' histogram-mass estimate of each cluster's
    expected passing count breaks exact centroid-score ties.
    """
    scores = centroid_scores(centroids, counts, queries, metric=metric)
    q = queries.shape[0]
    if summaries is None:
        _, probe_ids = jax.lax.top_k(scores, n_probes)
        probe_ids = probe_ids.astype(jnp.int32)  # [Q, T]
        probe_valid = None
        n_pruned = jnp.zeros((q,), jnp.int32)
    else:
        cm = summaries_lib.can_match(summaries, lo, hi)  # [Q, K]
        width = n_probes if t_max is None else t_max
        cvals, cand = jax.lax.top_k(scores, width)  # [Q, W] geometric order
        cm_c = jnp.take_along_axis(cm, cand, axis=1)  # [Q, W]
        real = cvals > topk_lib.NEG_INF / 2  # exclude empty/padded clusters
        # accounting: probes a geometry-only planner would have scanned (and
        # the disk tier fetched) that the filter proved empty
        n_pruned = jnp.sum(
            jnp.logical_and(~cm_c[:, :n_probes], real[:, :n_probes])
            .astype(jnp.int32), axis=-1,
        )
        if t_max is None:
            # exact mode: the geometric top-T minus its pruned members
            probe_ids = cand.astype(jnp.int32)
            probe_valid = jnp.logical_and(cm_c, real)
        else:
            # widened mode: re-rank candidates by (centroid score, expected
            # passing mass) — the histogram estimate only breaks exact score
            # ties — then keep each query's first n_probes unpruned ones.
            epass = summaries_lib.expected_passing(summaries, lo, hi, counts)
            ep_c = jnp.take_along_axis(epass, cand, axis=1)
            order = jnp.lexsort((-ep_c, -cvals), axis=-1)  # last key primary
            cand = jnp.take_along_axis(cand, order, axis=1)
            cm_c = jnp.take_along_axis(cm_c, order, axis=1)
            real = jnp.take_along_axis(real, order, axis=1)
            ok = jnp.logical_and(cm_c, real)
            rank = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
            probe_ids = cand.astype(jnp.int32)
            probe_valid = jnp.logical_and(ok, rank < n_probes)
    probe_pad = probes_lib.pad_to_tiles(probe_ids, q_block)  # [Qpad, W]
    valid_pad = (
        None if probe_valid is None
        else probes_lib.pad_to_tiles(probe_valid, q_block)
    )
    queries_pad = probes_lib.pad_to_tiles(queries.astype(cast_dtype), q_block)
    lo_pad = probes_lib.pad_to_tiles(lo, q_block)
    hi_pad = probes_lib.pad_to_tiles(hi, q_block)
    slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique = (
        probes_lib.plan_probe_tiles(probe_pad, q_block=q_block, u_cap=u_cap,
                                    probe_valid=valid_pad)
    )
    return (slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique,
            queries_pad, lo_pad, hi_pad, n_pruned)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "k", "q", "q_block", "v_block", "backend"),
)
def _scan_merge_tiled(
    slot_cluster: Array,
    slot_tile: Array,
    slot_of_probe: Array,
    probe_ok: Array,
    queries: Array,      # [Q, D] original (for the l2 ‖q‖² constant)
    queries_pad: Array,  # [Qpad, D] cast + tile-padded
    lo_pad: Array,
    hi_pad: Array,
    vectors: Array,
    attrs: Array,
    ids: Array,
    norms: Optional[Array],
    scales: Optional[Array],
    *,
    metric: str,
    k: int,
    q: int,
    q_block: int,
    v_block: int,
    backend: str,
) -> SearchResult:
    """Stage 2: scan the planned slots and merge per-probe fragments.

    ``vectors/attrs/ids/...`` are indexed by ``slot_cluster`` rows — either
    the full ``[K, Vpad, ...]`` resident arrays (RAM tier) or batch-local
    gathered ``[S, Vpad, ...]`` blocks with slot-local ids (disk tier).  The
    kernel only ever dereferences rows named in ``slot_cluster``, so the two
    are indistinguishable to it.
    """
    qpad = queries_pad.shape[0]
    if backend in ("pallas", "pallas_interpret"):
        svals, sids, snpass = filtered_scan_tiled(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block, v_block=v_block,
            interpret=backend == "pallas_interpret",
        )
    elif backend == "xla":
        svals, sids, snpass = tiled_scan_xla(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            vectors, attrs, ids, norms, scales,
            metric=metric, k=k, q_block=q_block,
        )
    else:
        raise ValueError(backend)

    # Per-probe candidate fragments, then the monoid merge across T probes.
    # Probes that overflowed an undersized u_cap are dropped soundly (their
    # fragments masked out), mirroring the distributed dispatch's P_cap.
    row = jnp.arange(qpad, dtype=jnp.int32) % q_block  # [Qpad]
    vals_qt = svals[slot_of_probe, row[:, None]]  # [Qpad, T, k]
    ids_qt = sids[slot_of_probe, row[:, None]]
    npass_qt = snpass[slot_of_probe, row[:, None]]  # [Qpad, T]
    vals_qt = jnp.where(probe_ok[..., None], vals_qt, topk_lib.NEG_INF)
    ids_qt = jnp.where(probe_ok[..., None], ids_qt, -1)
    npass_qt = jnp.where(probe_ok, npass_qt, 0)
    vals, out_ids = topk_lib.merge_topk_many(vals_qt, ids_qt, k, axis=1)
    vals, out_ids = vals[:q], out_ids[:q]

    if metric == "l2":
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        vals = jnp.where(
            vals > topk_lib.NEG_INF / 2, vals - q2[:, None], vals
        )

    n_passed = jnp.sum(npass_qt[:q], axis=-1)
    # Scan accounting through the slot tables: a probe's slot scans exactly
    # its cluster, so live-rows-per-slot gathered by slot_of_probe equals the
    # old per-cluster lookup — and works when only gathered rows exist.
    live_per_row = jnp.sum((ids >= 0).astype(jnp.int32), axis=-1)  # [K or S]
    live_per_slot = jnp.take(live_per_row, slot_cluster)  # [S_flat]
    n_scanned = jnp.sum(
        jnp.take(live_per_slot, slot_of_probe[:q])
        * probe_ok[:q].astype(jnp.int32),
        axis=-1,
    )
    return SearchResult(vals, out_ids, n_scanned, n_passed)


def resolve_prune(index, prune: str):
    """Resolves the ``prune`` knob against an index's summaries.

    Returns the :class:`~repro.core.summaries.ClusterSummaries` to plan with,
    or None for no pruning.  ``"auto"`` prunes iff the index carries
    summaries; ``"on"`` demands them; ``"off"`` never prunes.
    """
    summ = getattr(index, "summaries", None)
    if prune == "off":
        return None
    if prune == "on":
        if summ is None:
            raise ValueError(
                "prune='on' but the index has no cluster summaries — build "
                "with with_summaries=True or re-save the checkpoint (layout "
                "v2.1), or use prune='auto'"
            )
        return summ
    if prune == "auto":
        return summ
    raise ValueError(f"prune must be 'auto'|'on'|'off', got {prune!r}")


def search_fused_tiled(
    index,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    q_block: int = 64,
    v_block: int = 256,
    u_cap: Optional[int] = None,
    backend: Optional[str] = None,
    gather_fn=None,
    prune: str = "auto",
    t_max: Optional[int] = None,
) -> SearchResult:
    """Query-tiled, probe-deduplicated fused search with streaming top-k.

    Same contract as :func:`repro.core.search.search_reference` (identical
    ids/scores modulo tie order).  q_block is the query-tile height QB;
    u_cap bounds unique probes per tile (default ``min(QB·W, K)`` for probe
    table width W — always sufficient, since a tile cannot probe more than K
    distinct clusters).

    Two jitted stages: a *plan* over the resident state (centroid top-k +
    filter-aware probe pruning + per-tile probe dedup) and a *scan/merge*
    over the flat lists.  With ``gather_fn=None`` the scan reads ``index``'s
    in-RAM ``[K, Vpad, ...]`` arrays.  A disk-resident index passes
    ``gather_fn`` (its cluster cache's pager): the hook receives the plan's
    ``slot_cluster`` fetch list and returns ``(local_ids, vectors, attrs,
    ids, norms, scales)`` batch-local blocks, which the same kernel scans
    for bit-identical results.  ``index`` then only needs the resident
    surface (``spec / centroids / counts / store_dtype / quantized /
    summaries``), e.g. :class:`repro.core.disk.DiskIVFIndex`.

    ``prune``: ``"auto"`` (default) consults the index's cluster attribute
    summaries when present and drops probes whose clusters provably contain
    no row passing the query's filter — same ids/scores, fewer slots, fewer
    disk fetches.  ``"on"`` requires summaries, ``"off"`` disables.
    ``t_max`` (static, ≥ n_probes; needs pruning active) widens: pruned
    probes are refilled from the query's next-best unpruned centroids within
    the geometric top-``t_max``, trading bit-identity for recovered recall
    under selective filters (every surfaced hit remains exact).
    """
    q, _ = queries.shape
    qb = min(q_block, round_up(q, 8))
    kc = index.n_clusters
    summ = resolve_prune(index, prune)
    if t_max is not None:
        if t_max < n_probes:
            raise ValueError(f"t_max={t_max} < n_probes={n_probes}")
        t_max = min(t_max, kc)
        if summ is None or t_max == n_probes:
            t_max = None  # widening is only meaningful with pruning active
    width = n_probes if t_max is None else t_max
    cap = min(qb * width, kc) if u_cap is None else u_cap
    cast_dtype = np.dtype(np.float32) if index.quantized else np.dtype(
        index.store_dtype
    )
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"

    (slot_cluster, slot_tile, slot_of_probe, probe_ok, _, queries_pad,
     lo_pad, hi_pad, n_pruned) = plan_fused_tiled(
        index.centroids, index.counts, queries, fspec.lo, fspec.hi,
        metric=index.spec.metric, n_probes=n_probes, q_block=qb, u_cap=cap,
        cast_dtype=cast_dtype, summaries=summ, t_max=t_max,
    )

    if gather_fn is None:
        vectors, attrs, ids = index.vectors, index.attrs, index.ids
        norms, scales = index.norms, index.scales
    else:
        slot_cluster, vectors, attrs, ids, norms, scales = gather_fn(
            slot_cluster
        )
        slot_cluster = jnp.asarray(slot_cluster)

    res = _scan_merge_tiled(
        slot_cluster, slot_tile, slot_of_probe, probe_ok, queries,
        queries_pad, lo_pad, hi_pad, vectors, attrs, ids, norms, scales,
        metric=index.spec.metric, k=k, q=q, q_block=qb, v_block=v_block,
        backend=backend,
    )
    return dataclasses.replace(res, n_pruned=n_pruned)
