"""Jit'd wrappers: index-level fused filtered search built on the Pallas scans.

Two entry points share :class:`repro.core.search.SearchResult`'s contract:

  * :func:`search_fused`       — the original per-(query, probe) slot path.
    Still materializes a ``[Q·T, Vpad]`` score matrix on the way to top-k.
  * :func:`search_fused_tiled` — the batched successor.  Queries are tiled,
    probes are deduplicated per tile (``core/probes.py``), the kernel scores
    a whole ``[QB, D]`` query tile per streamed block and reduces it to a
    running ``[QB, k]`` on the fly, and the per-probe fragments are merged
    with the ``merge_topk`` monoid — peak memory ``O(slots·QB·k)``, never
    ``O(Q·T·Vpad)``, and a cluster probed by many queries of a tile is
    streamed HBM→VMEM exactly once.

Backends for the tiled path: ``"pallas"`` (compiled, TPU), ``"pallas_interpret"``
(CPU debugging/tests), ``"xla"`` (pure-jnp streaming executor — the fast CPU
path, chunked ``lax.map`` over slots so the same never-materialize bound
holds).  ``backend=None`` picks ``"pallas"`` on TPU and ``"xla"`` elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import probes as probes_lib
from repro.core import topk as topk_lib
from repro.core.filters import FilterSpec
from repro.core.ivf import IVFFlatIndex, round_up
from repro.core.search import SearchResult, search_centroids
from repro.kernels.filtered_scan.filtered_scan import (
    filtered_scan,
    filtered_scan_tiled,
)

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("k", "n_probes", "v_block", "interpret")
)
def search_fused(
    index: IVFFlatIndex,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    v_block: int = 256,
    interpret: Optional[bool] = None,
) -> SearchResult:
    """Single-device fused search (paper §4.4 via the Pallas kernel).

    interpret=None auto-detects the backend: the compiled kernel on TPU,
    interpret mode everywhere else (CPU tests, GPU dry-runs).  Pass an
    explicit bool to pin the mode (tests do).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = queries.shape[0]
    probe_ids, _ = search_centroids(index, queries, n_probes)  # [Q, T]

    slot_cluster = probe_ids.reshape(-1)  # [Q*T]
    slot_query = jnp.repeat(
        jnp.arange(q, dtype=jnp.int32), n_probes
    )  # [Q*T]

    scores = filtered_scan(
        slot_cluster,
        slot_query,
        queries.astype(jnp.float32 if index.quantized
                       else index.vectors.dtype),
        fspec.lo,
        fspec.hi,
        index.vectors,
        index.attrs,
        index.ids,
        index.norms,
        index.scales,
        metric=index.spec.metric,
        v_block=v_block,
        interpret=interpret,
    )  # [Q*T, Vpad]

    if index.spec.metric == "l2":
        # add back the per-query -||q||^2 so scores match the oracle
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        scores = jnp.where(
            scores > topk_lib.NEG_INF / 2,
            scores - jnp.take(q2, slot_query)[:, None],
            scores,
        )

    out_ids = jnp.take(index.ids, slot_cluster, axis=0)  # [Q*T, Vpad]
    vpad = scores.shape[-1]
    flat_scores = scores.reshape(q, n_probes * vpad)
    flat_ids = out_ids.reshape(q, n_probes * vpad)
    vals, ids = topk_lib.masked_topk(flat_scores, None, k, ids=flat_ids)

    passed = scores > topk_lib.NEG_INF / 2  # [Q*T, Vpad]
    n_passed = jnp.sum(
        passed.reshape(q, -1).astype(jnp.int32), axis=-1
    )
    live = (out_ids >= 0).reshape(q, -1)
    n_scanned = jnp.sum(live.astype(jnp.int32), axis=-1)
    return SearchResult(vals, ids, n_scanned, n_passed)


def tiled_scan_xla(
    slot_cluster, slot_tile, queries, lo, hi, vectors, attrs, ids,
    norms, scales, *, metric: str, k: int, q_block: int, chunk: int = 8,
):
    """XLA streaming executor with the tiled kernel's exact contract.

    Chunked ``lax.map`` over slots: each step gathers ``chunk`` cluster
    blocks, scores them against their query tiles and immediately reduces to
    ``[QB, k]`` — the full per-slot score matrix never exists, matching the
    kernel's memory bound.  This is the fast CPU path (Mosaic needs a real
    TPU to lower non-interpreted).
    """
    d = queries.shape[-1]
    qt = queries.reshape(-1, q_block, d).astype(jnp.float32)
    lot = lo.reshape(-1, q_block, *lo.shape[1:]).astype(jnp.int32)
    hit = hi.reshape(-1, q_block, *hi.shape[1:]).astype(jnp.int32)

    def one(args):
        sc, st = args
        v = jnp.take(vectors, sc, axis=0).astype(jnp.float32)  # [Vpad, D]
        qb = jnp.take(qt, st, axis=0)  # [QB, D]
        scores = qb @ v.T  # [QB, Vpad]
        if scales is not None:
            scores = scores * jnp.take(scales, sc, axis=0)[None, :]
        if metric == "l2":
            scores = 2.0 * scores - jnp.take(norms, sc, axis=0)[None, :]
        a = jnp.take(attrs, sc, axis=0).astype(jnp.int32)  # [Vpad, M]
        qlo = jnp.take(lot, st, axis=0)  # [QB, F, M]
        qhi = jnp.take(hit, st, axis=0)
        inside = jnp.logical_and(
            a[None, :, None, :] >= qlo[:, None],
            a[None, :, None, :] <= qhi[:, None],
        )  # [QB, Vpad, F, M]
        fmask = jnp.any(jnp.all(inside, -1), -1)
        live = jnp.take(ids, sc, axis=0) >= 0
        mask = jnp.logical_and(fmask, live[None, :])
        svals, sids = topk_lib.masked_topk(
            scores, mask, k,
            ids=jnp.broadcast_to(jnp.take(ids, sc, axis=0), scores.shape),
        )
        return svals, sids, jnp.sum(mask.astype(jnp.int32), axis=-1)

    return jax.lax.map(
        one, (slot_cluster, slot_tile), batch_size=min(chunk, slot_cluster.shape[0])
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probes", "q_block", "v_block", "u_cap",
                     "backend"),
)
def search_fused_tiled(
    index: IVFFlatIndex,
    queries: Array,
    fspec: FilterSpec,
    *,
    k: int,
    n_probes: int,
    q_block: int = 64,
    v_block: int = 256,
    u_cap: Optional[int] = None,
    backend: Optional[str] = None,
) -> SearchResult:
    """Query-tiled, probe-deduplicated fused search with streaming top-k.

    Same contract as :func:`repro.core.search.search_reference` (identical
    ids/scores modulo tie order).  q_block is the query-tile height QB;
    u_cap bounds unique probes per tile (default ``min(QB·T, K)`` — always
    sufficient, since a tile cannot probe more than K distinct clusters).
    """
    q, d = queries.shape
    qb = min(q_block, round_up(q, 8))
    metric = index.spec.metric
    kc = index.n_clusters

    probe_ids, _ = search_centroids(index, queries, n_probes)  # [Q, T]

    # Pad the batch to whole tiles with edge rows; their probes dedupe into
    # the last real query's slots, so padding adds no scan work.
    probe_pad = probes_lib.pad_to_tiles(probe_ids, qb)  # [Qpad, T]
    queries_pad = probes_lib.pad_to_tiles(
        queries.astype(jnp.float32 if index.quantized
                       else index.vectors.dtype),
        qb,
    )
    lo_pad = probes_lib.pad_to_tiles(fspec.lo, qb)
    hi_pad = probes_lib.pad_to_tiles(fspec.hi, qb)
    qpad = queries_pad.shape[0]

    cap = min(qb * n_probes, kc) if u_cap is None else u_cap
    slot_cluster, slot_tile, slot_of_probe, probe_ok, _ = (
        probes_lib.plan_probe_tiles(probe_pad, q_block=qb, u_cap=cap)
    )

    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend in ("pallas", "pallas_interpret"):
        svals, sids, snpass = filtered_scan_tiled(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            index.vectors, index.attrs, index.ids, index.norms, index.scales,
            metric=metric, k=k, q_block=qb, v_block=v_block,
            interpret=backend == "pallas_interpret",
        )
    elif backend == "xla":
        svals, sids, snpass = tiled_scan_xla(
            slot_cluster, slot_tile, queries_pad, lo_pad, hi_pad,
            index.vectors, index.attrs, index.ids, index.norms, index.scales,
            metric=metric, k=k, q_block=qb,
        )
    else:
        raise ValueError(backend)

    # Per-probe candidate fragments, then the monoid merge across T probes.
    # Probes that overflowed an undersized u_cap are dropped soundly (their
    # fragments masked out), mirroring the distributed dispatch's P_cap.
    row = jnp.arange(qpad, dtype=jnp.int32) % qb  # [Qpad]
    vals_qt = svals[slot_of_probe, row[:, None]]  # [Qpad, T, k]
    ids_qt = sids[slot_of_probe, row[:, None]]
    npass_qt = snpass[slot_of_probe, row[:, None]]  # [Qpad, T]
    vals_qt = jnp.where(probe_ok[..., None], vals_qt, topk_lib.NEG_INF)
    ids_qt = jnp.where(probe_ok[..., None], ids_qt, -1)
    npass_qt = jnp.where(probe_ok, npass_qt, 0)
    vals, out_ids = topk_lib.merge_topk_many(vals_qt, ids_qt, k, axis=1)
    vals, out_ids = vals[:q], out_ids[:q]

    if metric == "l2":
        q2 = jnp.sum(queries.astype(jnp.float32) ** 2, -1)  # [Q]
        vals = jnp.where(
            vals > topk_lib.NEG_INF / 2, vals - q2[:, None], vals
        )

    n_passed = jnp.sum(npass_qt[:q], axis=-1)
    live_per_cluster = jnp.sum(
        (index.ids >= 0).astype(jnp.int32), axis=-1
    )  # [K]
    # probes dropped by an undersized u_cap were never scanned — keep the
    # perf-accounting stats consistent with what actually ran
    n_scanned = jnp.sum(
        jnp.take(live_per_cluster, probe_ids)
        * probe_ok[:q].astype(jnp.int32),
        axis=-1,
    )
    return SearchResult(vals, out_ids, n_scanned, n_passed)
