"""Fused filtered IVF scans — the paper's §4.4 steps 3+4 as Pallas kernels.

Two kernel generations live here:

  * :func:`filtered_scan` — the original per-(query, probe) slot kernel.
    Grid ``(P, Vpad // v_block)``; each step is a ``[VB, D] @ [D, 1]``
    matvec, so the MXU runs ~1/128 utilized and a cluster probed by many
    queries is re-streamed HBM→VMEM once per duplicate slot.
  * :func:`filtered_scan_tiled` — the batched successor.  Queries are tiled
    ``q_block`` at a time, probes are deduplicated per tile (see
    ``core/probes.py``), and the grid becomes ``(unique_slots, Vpad //
    v_block)``: each step scores a whole query tile against the streamed
    block in one ``[QB, D] @ [D, VB]`` matmul and folds the masked scores
    into a running per-slot top-k held in the revisited output block — the
    ``[P, Vpad]`` score matrix is never materialized, and peak memory drops
    from ``O(Q·T·Vpad)`` to ``O(slots·QB·k)``.


The paper's measured bottleneck is the *filtering pass* (1.09 s of 1.428 s):
a separate sweep over the probed lists' attribute rows before any distance is
computed.  On TPU we eliminate that pass instead of accelerating it: the
attribute interval test runs in VREGs on the same VMEM-resident block that the
MXU is scoring, so filtering adds zero extra HBM traffic.

The paper's *dynamic memory loading* ("only the probed lists are loaded into
RAM") maps onto scalar-prefetch block indexing: the probe table
``slot_cluster [P]`` is prefetched into SMEM, and the ``index_map`` of the
database operands selects which cluster's block the next grid step DMAs
HBM→VMEM — the same indirection pattern paged attention uses for KV blocks.
Only probed clusters are ever touched; everything else stays cold in HBM,
exactly like the paper's cold lists stay on disk.

Grid: ``(P, Vpad // v_block)`` — probe slots × intra-list blocks.
Operands (scalar prefetch first, per PrefetchScalarGridSpec):
  slot_cluster [P] int32   — cluster id each slot scans   (SMEM)
  slot_query   [P] int32   — query row each slot serves   (SMEM)
  queries  [Q, D]    f32/bf16
  lo, hi   [Q, F, M] int16 — DNF interval bounds per query
  vectors  [K, Vpad, D]    — flat lists (the big operand, block-streamed)
  attrs    [K, Vpad, M] int16
  ids      [K, Vpad] int32 — liveness: id < 0 ⇒ dead/padded slot
Output:
  scores [P, Vpad] f32 — masked to NEG_INF where the filter/liveness fails.

A "l2" variant additionally streams ``norms [K, Vpad] f32`` and emits
``2·q·v − ‖v‖²`` (the per-query −‖q‖² constant is rank-free and added by the
wrapper for score fidelity).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -3.0e38


def _mask_from_attrs(attrs_i32, lo_i32, hi_i32):
    """[V, M] attrs vs [F, M] bounds → [V] bool (OR over F of AND over M)."""
    a = attrs_i32[:, None, :]  # [V, 1, M]
    inside = jnp.logical_and(a >= lo_i32[None], a <= hi_i32[None])  # [V, F, M]
    return jnp.any(jnp.all(inside, axis=-1), axis=-1)  # [V]


def _scan_kernel_dot(
    slot_cluster_ref,  # scalar prefetch (unused in body; drives index_maps)
    slot_query_ref,
    q_ref,  # [1, D]
    lo_ref,  # [1, F, M]
    hi_ref,  # [1, F, M]
    v_ref,  # [1, VB, D]
    a_ref,  # [1, VB, M]
    id_ref,  # [1, VB]
    o_ref,  # [1, VB]
):
    del slot_cluster_ref, slot_query_ref
    q = q_ref[0].astype(jnp.float32)  # [D]
    v = v_ref[0].astype(jnp.float32)  # [VB, D]
    # MXU: [VB, D] @ [D, 1] → [VB, 1]; fp32 accumulation.
    dots = jax.lax.dot_general(
        v, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]
    a = a_ref[0].astype(jnp.int32)  # [VB, M] — int32 compares on the VPU
    fmask = _mask_from_attrs(
        a, lo_ref[0].astype(jnp.int32), hi_ref[0].astype(jnp.int32)
    )
    live = id_ref[0] >= 0
    o_ref[0] = jnp.where(jnp.logical_and(fmask, live), dots, NEG_INF)


def _scan_kernel_dot_q8(
    slot_cluster_ref,
    slot_query_ref,
    q_ref,  # [1, D]
    lo_ref,
    hi_ref,
    v_ref,  # [1, VB, D] int8
    a_ref,
    id_ref,
    s_ref,  # [1, VB] f32 per-vector SQ8 scale
    o_ref,
):
    """SQ8 variant: int8 rows stream from HBM (half the traffic of bf16);
    the dequant is one VPU multiply on the [VB] dot-product column."""
    del slot_cluster_ref, slot_query_ref
    q = q_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # int8 → f32 in VREGs
    dots = jax.lax.dot_general(
        v, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * s_ref[0]
    a = a_ref[0].astype(jnp.int32)
    fmask = _mask_from_attrs(
        a, lo_ref[0].astype(jnp.int32), hi_ref[0].astype(jnp.int32)
    )
    live = id_ref[0] >= 0
    o_ref[0] = jnp.where(jnp.logical_and(fmask, live), dots, NEG_INF)


def _scan_kernel_l2(
    slot_cluster_ref,
    slot_query_ref,
    q_ref,
    lo_ref,
    hi_ref,
    v_ref,
    a_ref,
    id_ref,
    n_ref,  # [1, VB] f32 ‖v‖²
    o_ref,
):
    del slot_cluster_ref, slot_query_ref
    q = q_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dots = jax.lax.dot_general(
        v, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]
    score = 2.0 * dots - n_ref[0]
    a = a_ref[0].astype(jnp.int32)
    fmask = _mask_from_attrs(
        a, lo_ref[0].astype(jnp.int32), hi_ref[0].astype(jnp.int32)
    )
    live = id_ref[0] >= 0
    o_ref[0] = jnp.where(jnp.logical_and(fmask, live), score, NEG_INF)


@functools.partial(
    jax.jit,
    static_argnames=("v_block", "interpret", "metric"),
)
def filtered_scan(
    slot_cluster: jax.Array,
    slot_query: jax.Array,
    queries: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    vectors: jax.Array,
    attrs: jax.Array,
    ids: jax.Array,
    norms: Optional[jax.Array] = None,
    scales: Optional[jax.Array] = None,
    *,
    metric: str = "dot",
    v_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Runs the fused scan. Returns masked scores [P, Vpad] f32.

    v_block: intra-list block length; VMEM working set per step is
    ``v_block·(D·bytes(core) + M·2 + 8)`` — 256×768 bf16 ≈ 384 KiB, well
    inside the ~16 MiB v5e VMEM budget, leaving room for double buffering.
    """
    p = slot_cluster.shape[0]
    k, vpad, d = vectors.shape
    m = attrs.shape[-1]
    f = lo.shape[1]
    v_block = min(v_block, vpad)
    while vpad % v_block != 0 and v_block > 8:
        v_block //= 2  # builds pad Vpad to ×128, so 128 always divides
    if vpad % v_block != 0:
        raise ValueError(f"vpad={vpad} has no usable v_block ≤ requested")
    if metric not in ("dot", "l2"):
        raise ValueError(metric)
    if metric == "l2" and norms is None:
        raise ValueError("metric='l2' requires norms")

    nvb = vpad // v_block
    grid = (p, nvb)

    # index_maps receive (grid idxs..., *scalar_prefetch_refs)
    def im_query(pi, vi, sc, sq):
        del vi, sc
        return (sq[pi], 0)

    def im_bounds(pi, vi, sc, sq):
        del vi, sc
        return (sq[pi], 0, 0)

    def im_vec(pi, vi, sc, sq):
        del sq
        return (sc[pi], vi, 0)

    def im_rows(pi, vi, sc, sq):
        del sq
        return (sc[pi], vi)

    def im_out(pi, vi, sc, sq):
        del sc, sq
        return (pi, vi)

    in_specs = [
        pl.BlockSpec((1, d), im_query),
        pl.BlockSpec((1, f, m), im_bounds),
        pl.BlockSpec((1, f, m), im_bounds),
        pl.BlockSpec((1, v_block, d), im_vec),
        pl.BlockSpec((1, v_block, m), im_vec),
        pl.BlockSpec((1, v_block), im_rows),
    ]
    operands = [queries, lo, hi, vectors, attrs, ids]
    if metric == "l2":
        if scales is not None:
            raise NotImplementedError("SQ8 + l2 not wired (norms suffice)")
        in_specs.append(pl.BlockSpec((1, v_block), im_rows))
        operands.append(norms)
        kernel = _scan_kernel_l2
    elif scales is not None:
        in_specs.append(pl.BlockSpec((1, v_block), im_rows))
        operands.append(scales)
        kernel = _scan_kernel_dot_q8
    else:
        kernel = _scan_kernel_dot

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, v_block), im_out),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, vpad), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(slot_cluster.astype(jnp.int32), slot_query.astype(jnp.int32), *operands)
    return out


# ---------------------------------------------------------------------------
# Tiled, probe-deduplicated variant with in-kernel streaming top-k
# ---------------------------------------------------------------------------


def _fold_topk(run_v, run_i, scores, ids_blk, k):
    """Monoid fold: best k of (running set ∪ block), by iterative extraction.

    Branch-free static-k max-extraction (the centroid_topk idiom) — no
    reliance on sort/top_k lowering inside the kernel.  Ties resolve to the
    earliest candidate position, which (running set first, then the block in
    slot order) reproduces ``lax.top_k``'s first-index tie order over the
    flat list.
    """
    cand_v = jnp.concatenate([run_v, scores], axis=1)  # [QB, k+VB]
    cand_i = jnp.concatenate([run_i, ids_blk], axis=1)
    new_v = []
    new_i = []
    for _ in range(k):
        m = jnp.max(cand_v, axis=1)  # [QB]
        am = jnp.argmax(cand_v, axis=1)
        picked = jnp.take_along_axis(cand_i, am[:, None], axis=1)[:, 0]
        new_v.append(m)
        new_i.append(jnp.where(m > NEG_INF / 2, picked, -1))
        hit = (
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
            == am[:, None]
        )
        cand_v = jnp.where(hit, NEG_INF, cand_v)
    return jnp.stack(new_v, axis=1), jnp.stack(new_i, axis=1)


def _tiled_kernel(
    slot_cluster_ref,  # scalar prefetch (drives index_maps)
    slot_tile_ref,
    q_ref,  # [QB, D]
    lo_ref,  # [QB, F, M]
    hi_ref,  # [QB, F, M]
    v_ref,  # [1, VB, D]
    a_ref,  # [1, VB, M]
    id_ref,  # [1, VB]
    *rest,  # ([aux_ref [1, VB]], ov_ref [1,QB,k], oi_ref [1,QB,k], op_ref [1,QB])
    k: int,
    metric: str,
    quantized: bool,
):
    del slot_cluster_ref, slot_tile_ref
    if metric == "l2" or quantized:
        aux_ref, ov_ref, oi_ref, op_ref = rest
    else:
        aux_ref = None
        ov_ref, oi_ref, op_ref = rest
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        ov_ref[...] = jnp.full_like(ov_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)
        op_ref[...] = jnp.zeros_like(op_ref)

    q = q_ref[...].astype(jnp.float32)  # [QB, D]
    v = v_ref[0].astype(jnp.float32)  # [VB, D]
    # MXU: one [QB, D] @ [D, VB] matmul scores the whole query tile against
    # the streamed block — compute-dense where the matvec kernel was ~1/QB
    # utilized.  fp32 accumulation.
    scores = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [QB, VB]
    if quantized:
        scores = scores * aux_ref[0][None, :]  # SQ8 dequant on the VPU
    if metric == "l2":
        scores = 2.0 * scores - aux_ref[0][None, :]  # ‖q‖² added by wrapper

    a = a_ref[0].astype(jnp.int32)  # [VB, M]
    lo = lo_ref[...].astype(jnp.int32)  # [QB, F, M]
    hi = hi_ref[...].astype(jnp.int32)
    fmask = None  # per-query DNF interval test, [QB, VB] in VREGs
    for fi in range(lo.shape[1]):
        term = jnp.all(
            jnp.logical_and(
                a[None] >= lo[:, fi][:, None], a[None] <= hi[:, fi][:, None]
            ),
            axis=-1,
        )
        fmask = term if fmask is None else jnp.logical_or(fmask, term)
    live = id_ref[0] >= 0  # [VB]
    mask = jnp.logical_and(fmask, live[None, :])
    scores = jnp.where(mask, scores, NEG_INF)
    op_ref[0] = op_ref[0] + jnp.sum(mask.astype(jnp.int32), axis=1)

    ids_blk = jnp.broadcast_to(id_ref[0][None, :], scores.shape)
    new_v, new_i = _fold_topk(ov_ref[0], oi_ref[0], scores, ids_blk, k)
    ov_ref[0] = new_v
    oi_ref[0] = new_i


@functools.partial(
    jax.jit,
    static_argnames=("metric", "k", "q_block", "v_block", "interpret"),
)
def filtered_scan_tiled(
    slot_cluster: jax.Array,
    slot_tile: jax.Array,
    queries: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    vectors: jax.Array,
    attrs: jax.Array,
    ids: jax.Array,
    norms: Optional[jax.Array] = None,
    scales: Optional[jax.Array] = None,
    *,
    metric: str = "dot",
    k: int = 10,
    q_block: int = 64,
    v_block: int = 256,
    interpret: bool = False,
):
    """Tiled fused scan with streaming per-slot top-k.

    Grid: ``(S, Vpad // v_block)`` — unique-probe slots × intra-list blocks.
    Operands (scalar prefetch first):
      slot_cluster [S] int32      — cluster each slot scans          (SMEM)
      slot_tile    [S] int32      — query tile each slot serves      (SMEM)
      queries  [Qpad, D]          — Qpad a multiple of q_block; tile t is
                                    rows ``[t·QB, (t+1)·QB)``
      lo, hi   [Qpad, F, M] int16 — DNF interval bounds per query
      vectors  [K, Vpad, D], attrs [K, Vpad, M], ids [K, Vpad] — flat lists
      norms / scales [K, Vpad] f32 — l2 / SQ8 row constants

    Returns:
      vals  [S, QB, k] f32 — per-slot streaming top-k (NEG_INF pads)
      ids   [S, QB, k] int32 — original vector ids (-1 pads)
      npass [S, QB] int32 — candidates passing filter ∧ liveness per slot

    VMEM working set per step is ``QB·D + 4·QB·F·M + v_block·(D·bytes +
    M·2 + 8) + 2·QB·k`` — 64×768 queries + 256×768 bf16 block ≈ 0.6 MiB,
    far inside the ~16 MiB v5e budget, leaving room for double buffering.
    """
    s = slot_cluster.shape[0]
    qpad, d = queries.shape
    _, vpad, _ = vectors.shape
    m = attrs.shape[-1]
    f = lo.shape[1]
    if qpad % q_block:
        raise ValueError(f"Qpad={qpad} not a multiple of q_block={q_block}")
    v_block = min(v_block, vpad)
    while vpad % v_block != 0 and v_block > 8:
        v_block //= 2
    if vpad % v_block != 0:
        raise ValueError(f"vpad={vpad} has no usable v_block ≤ requested")
    if metric not in ("dot", "l2"):
        raise ValueError(metric)
    if metric == "l2":
        if norms is None:
            raise ValueError("metric='l2' requires norms")
        if scales is not None:
            raise NotImplementedError("SQ8 + l2 not wired (norms suffice)")

    nvb = vpad // v_block
    grid = (s, nvb)

    def im_query(si, vi, sc, st):
        del vi, sc
        return (st[si], 0)

    def im_bounds(si, vi, sc, st):
        del vi, sc
        return (st[si], 0, 0)

    def im_vec(si, vi, sc, st):
        del st
        return (sc[si], vi, 0)

    def im_rows(si, vi, sc, st):
        del st
        return (sc[si], vi)

    def im_out3(si, vi, sc, st):
        del vi, sc, st
        return (si, 0, 0)

    def im_out2(si, vi, sc, st):
        del vi, sc, st
        return (si, 0)

    in_specs = [
        pl.BlockSpec((q_block, d), im_query),
        pl.BlockSpec((q_block, f, m), im_bounds),
        pl.BlockSpec((q_block, f, m), im_bounds),
        pl.BlockSpec((1, v_block, d), im_vec),
        pl.BlockSpec((1, v_block, m), im_vec),
        pl.BlockSpec((1, v_block), im_rows),
    ]
    operands = [queries, lo, hi, vectors, attrs, ids]
    quantized = scales is not None
    if metric == "l2":
        in_specs.append(pl.BlockSpec((1, v_block), im_rows))
        operands.append(norms)
    elif quantized:
        in_specs.append(pl.BlockSpec((1, v_block), im_rows))
        operands.append(scales)

    kernel = functools.partial(
        _tiled_kernel, k=k, metric=metric, quantized=quantized
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, q_block, k), im_out3),
            pl.BlockSpec((1, q_block, k), im_out3),
            pl.BlockSpec((1, q_block), im_out2),
        ],
    )
    vals, out_ids, npass = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, q_block, k), jnp.float32),
            jax.ShapeDtypeStruct((s, q_block, k), jnp.int32),
            jax.ShapeDtypeStruct((s, q_block), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(slot_cluster.astype(jnp.int32), slot_tile.astype(jnp.int32), *operands)
    return vals, out_ids, npass


@functools.partial(jax.jit, static_argnames=("k",))
def fold_running_topk(
    run_vals: jax.Array,   # [QB, k] f32 running per-query top-k values
    run_ids: jax.Array,    # [QB, k] int32 running ids
    svals: jax.Array,      # [S, QB, k] f32 per-slot fragments (a segment)
    sids: jax.Array,       # [S, QB, k] int32
    alive: jax.Array,      # [QB, S] bool — (query, slot) pairs scheduled
    *,
    k: int,
):
    """Folds one scanned slot segment into the per-query running top-k.

    The bound-driven executor scans a tile's slot table in segments and
    compares the running kth score against the remaining slots' upper
    bounds; this is the device-side fold that keeps that running state —
    only the ``[QB, k]`` result crosses to host at segment boundaries, never
    the per-slot fragments (no host sync per tile/slot).  ``alive`` masks
    pairs that were dropped (or never scheduled), so the running kth can
    only reflect the surviving probe universe — folding a dropped pair's
    candidates could raise the kth above what that universe's full scan
    would produce and make a later drop unsound.
    """
    qb = svals.shape[1]
    live = alive.T[:, :, None]  # [S, QB, 1]
    vals = jnp.where(live, svals, NEG_INF)
    ids = jnp.where(live, sids, -1)
    vals = jnp.moveaxis(vals, 0, 1).reshape(qb, -1)  # [QB, S·k]
    ids = jnp.moveaxis(ids, 0, 1).reshape(qb, -1)
    vals = jnp.concatenate([run_vals, vals], axis=1)
    ids = jnp.concatenate([run_ids, ids], axis=1)
    new_vals, idx = jax.lax.top_k(vals, k)
    return new_vals, jnp.take_along_axis(ids, idx, axis=1)
