from repro.kernels.filtered_scan.filtered_scan import (
    filtered_scan,
    filtered_scan_tiled,
)
from repro.kernels.filtered_scan.ops import search_fused, search_fused_tiled
from repro.kernels.filtered_scan.ref import (
    filtered_scan_ref,
    filtered_scan_tiled_ref,
)

__all__ = [
    "filtered_scan",
    "filtered_scan_ref",
    "filtered_scan_tiled",
    "filtered_scan_tiled_ref",
    "search_fused",
    "search_fused_tiled",
]
