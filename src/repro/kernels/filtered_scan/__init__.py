from repro.kernels.filtered_scan.filtered_scan import filtered_scan
from repro.kernels.filtered_scan.ops import search_fused
from repro.kernels.filtered_scan.ref import filtered_scan_ref

__all__ = ["filtered_scan", "filtered_scan_ref", "search_fused"]
