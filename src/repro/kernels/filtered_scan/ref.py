"""Pure-jnp oracle for the fused filtered scan kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -3.0e38


def filtered_scan_ref(
    slot_cluster: jax.Array,  # [P] int32
    slot_query: jax.Array,  # [P] int32
    queries: jax.Array,  # [Q, D]
    lo: jax.Array,  # [Q, F, M] int16
    hi: jax.Array,  # [Q, F, M] int16
    vectors: jax.Array,  # [K, Vpad, D]
    attrs: jax.Array,  # [K, Vpad, M] int16
    ids: jax.Array,  # [K, Vpad] int32
    norms: Optional[jax.Array] = None,  # [K, Vpad] f32
    scales: Optional[jax.Array] = None,  # [K, Vpad] f32 (SQ8)
    *,
    metric: str = "dot",
) -> jax.Array:
    """Returns masked scores [P, Vpad] f32 — the kernel's contract."""
    v = jnp.take(vectors, slot_cluster, axis=0).astype(jnp.float32)  # [P,V,D]
    a = jnp.take(attrs, slot_cluster, axis=0).astype(jnp.int32)  # [P,V,M]
    iv = jnp.take(ids, slot_cluster, axis=0)  # [P,V]
    q = jnp.take(queries, slot_query, axis=0).astype(jnp.float32)  # [P,D]
    qlo = jnp.take(lo, slot_query, axis=0).astype(jnp.int32)  # [P,F,M]
    qhi = jnp.take(hi, slot_query, axis=0).astype(jnp.int32)

    dots = jnp.einsum("pvd,pd->pv", v, q)
    if scales is not None:
        dots = dots * jnp.take(scales, slot_cluster, axis=0)
    if metric == "dot":
        score = dots
    else:
        nn = jnp.take(norms, slot_cluster, axis=0)
        score = 2.0 * dots - nn

    inside = jnp.logical_and(
        a[:, :, None, :] >= qlo[:, None, :, :],
        a[:, :, None, :] <= qhi[:, None, :, :],
    )  # [P, V, F, M]
    fmask = jnp.any(jnp.all(inside, -1), -1)  # [P, V]
    live = iv >= 0
    return jnp.where(jnp.logical_and(fmask, live), score, NEG_INF)
