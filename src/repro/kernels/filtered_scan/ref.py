"""Pure-jnp oracle for the fused filtered scan kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -3.0e38


def filtered_scan_ref(
    slot_cluster: jax.Array,  # [P] int32
    slot_query: jax.Array,  # [P] int32
    queries: jax.Array,  # [Q, D]
    lo: jax.Array,  # [Q, F, M] int16
    hi: jax.Array,  # [Q, F, M] int16
    vectors: jax.Array,  # [K, Vpad, D]
    attrs: jax.Array,  # [K, Vpad, M] int16
    ids: jax.Array,  # [K, Vpad] int32
    norms: Optional[jax.Array] = None,  # [K, Vpad] f32
    scales: Optional[jax.Array] = None,  # [K, Vpad] f32 (SQ8)
    *,
    metric: str = "dot",
) -> jax.Array:
    """Returns masked scores [P, Vpad] f32 — the kernel's contract."""
    v = jnp.take(vectors, slot_cluster, axis=0).astype(jnp.float32)  # [P,V,D]
    a = jnp.take(attrs, slot_cluster, axis=0).astype(jnp.int32)  # [P,V,M]
    iv = jnp.take(ids, slot_cluster, axis=0)  # [P,V]
    q = jnp.take(queries, slot_query, axis=0).astype(jnp.float32)  # [P,D]
    qlo = jnp.take(lo, slot_query, axis=0).astype(jnp.int32)  # [P,F,M]
    qhi = jnp.take(hi, slot_query, axis=0).astype(jnp.int32)

    dots = jnp.einsum("pvd,pd->pv", v, q)
    if scales is not None:
        dots = dots * jnp.take(scales, slot_cluster, axis=0)
    if metric == "dot":
        score = dots
    else:
        nn = jnp.take(norms, slot_cluster, axis=0)
        score = 2.0 * dots - nn

    inside = jnp.logical_and(
        a[:, :, None, :] >= qlo[:, None, :, :],
        a[:, :, None, :] <= qhi[:, None, :, :],
    )  # [P, V, F, M]
    fmask = jnp.any(jnp.all(inside, -1), -1)  # [P, V]
    live = iv >= 0
    return jnp.where(jnp.logical_and(fmask, live), score, NEG_INF)


def filtered_scan_tiled_ref(
    slot_cluster: jax.Array,  # [S] int32
    slot_tile: jax.Array,  # [S] int32
    queries: jax.Array,  # [Qpad, D], Qpad a multiple of q_block
    lo: jax.Array,  # [Qpad, F, M] int16
    hi: jax.Array,  # [Qpad, F, M] int16
    vectors: jax.Array,  # [K, Vpad, D]
    attrs: jax.Array,  # [K, Vpad, M] int16
    ids: jax.Array,  # [K, Vpad] int32
    norms: Optional[jax.Array] = None,
    scales: Optional[jax.Array] = None,
    *,
    metric: str = "dot",
    k: int = 10,
    q_block: int = 64,
):
    """Gather-based oracle for the tiled kernel's (vals, ids, npass) contract."""
    d = queries.shape[-1]
    qt = queries.reshape(-1, q_block, d).astype(jnp.float32)
    lot = lo.reshape(-1, q_block, *lo.shape[1:]).astype(jnp.int32)
    hit = hi.reshape(-1, q_block, *hi.shape[1:]).astype(jnp.int32)

    v = jnp.take(vectors, slot_cluster, axis=0).astype(jnp.float32)  # [S,V,D]
    a = jnp.take(attrs, slot_cluster, axis=0).astype(jnp.int32)  # [S,V,M]
    iv = jnp.take(ids, slot_cluster, axis=0)  # [S,V]
    q = jnp.take(qt, slot_tile, axis=0)  # [S,QB,D]
    qlo = jnp.take(lot, slot_tile, axis=0)  # [S,QB,F,M]
    qhi = jnp.take(hit, slot_tile, axis=0)

    scores = jnp.einsum("sqd,svd->sqv", q, v)
    if scales is not None:
        scores = scores * jnp.take(scales, slot_cluster, axis=0)[:, None, :]
    if metric == "l2":
        scores = 2.0 * scores - jnp.take(norms, slot_cluster, 0)[:, None, :]

    inside = jnp.logical_and(
        a[:, None, :, None, :] >= qlo[:, :, None, :, :],
        a[:, None, :, None, :] <= qhi[:, :, None, :, :],
    )  # [S, QB, V, F, M]
    fmask = jnp.any(jnp.all(inside, -1), -1)  # [S, QB, V]
    mask = jnp.logical_and(fmask, (iv >= 0)[:, None, :])
    scores = jnp.where(mask, scores, NEG_INF)
    npass = jnp.sum(mask.astype(jnp.int32), axis=-1)  # [S, QB]

    vals, idx = jax.lax.top_k(scores, k)  # [S, QB, k]
    out_ids = jnp.take_along_axis(
        jnp.broadcast_to(iv[:, None, :], scores.shape), idx, axis=-1
    )
    out_ids = jnp.where(vals > NEG_INF / 2, out_ids, -1)
    return vals, out_ids, npass
