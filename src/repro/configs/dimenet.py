"""dimenet [arXiv:2003.03123; unverified tier].

n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6.
Shapes: full_graph_sm (Cora-like), minibatch_lg (Reddit-like, sampled),
ogb_products (full-batch large), molecule (batched small graphs).
The paper's IVF technique is inapplicable inside this arch (DESIGN.md §5).
"""

from repro.models.gnn.dimenet import DimeNetConfig, scaled_down_gnn

ARCH_ID = "dimenet"
FAMILY = "gnn"


def config(d_feat: int = 128, d_out: int = 32, readout: str = "node"
           ) -> DimeNetConfig:
    return DimeNetConfig(
        name=ARCH_ID,
        n_blocks=6,
        d_hidden=128,
        n_bilinear=8,
        n_spherical=7,
        n_radial=6,
        d_feat=d_feat,
        d_out=d_out,
        readout=readout,
    )


def smoke_config() -> DimeNetConfig:
    return scaled_down_gnn(config())
