"""din [arXiv:1706.06978; paper tier].

embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80, target-attention
interaction.  The paper's IVF index serves this arch's candidate-generation
stage (retrieval_cand) — DESIGN.md §5.
"""

import dataclasses

from repro.models.recsys.models import RecsysConfig

ARCH_ID = "din"
FAMILY = "recsys"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        arch="din",
        embed_dim=18,
        seq_len=100,
        n_dense=13,
        attn_mlp_dims=(80, 40),
        mlp_dims=(200, 80),
        vocab_items=1_048_576,
    )


def smoke_config() -> RecsysConfig:
    return dataclasses.replace(
        config(), vocab_items=1000, seq_len=12,
    )
