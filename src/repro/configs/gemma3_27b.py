"""gemma3-27b [hf:google/gemma-3-27b-pt; unverified tier].

62L d_model=5376 32H (GQA kv=16, d_head 128) d_ff=21504 vocab=262144,
5:1 local:global sliding window, dual RoPE theta, qk-norm, sandwich norms.
Hybrid local/global ⇒ long_500k RUNS for this arch.
"""

from repro.models.config import TransformerConfig, scaled_down

ARCH_ID = "gemma3-27b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=21504,
        vocab_size=262144,
        rope_theta=1e4,
        rope_theta_global=1e6,
        window=1024,
        global_every=6,
        act="gelu",
        qk_norm=True,
        sandwich_norm=True,
        scale_embed=True,
        tie_embeddings=True,
    )


def smoke_config() -> TransformerConfig:
    return scaled_down(config(), global_every=2)
