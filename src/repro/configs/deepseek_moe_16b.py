"""deepseek-moe-16b [arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA), 2 shared + 64 routed top-6 fine-grained experts
(d_ff 1408), first layer dense (d_ff 10944), vocab 102 400.  Pure full
attention ⇒ long_500k skipped per DESIGN.md §6.
"""

from repro.models.config import MoEConfig, TransformerConfig, scaled_down

ARCH_ID = "deepseek-moe-16b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=10944,
        vocab_size=102400,
        rope_theta=1e4,
        moe=MoEConfig(
            n_routed=64,
            top_k=6,
            n_shared=2,
            d_ff_expert=1408,
            first_dense_layers=1,
            d_ff_dense=10944,
            capacity_factor=1.25,
            router_score="softmax",
            aux_loss_coef=0.001,
        ),
        tie_embeddings=False,
    )


def smoke_config() -> TransformerConfig:
    return scaled_down(config())
