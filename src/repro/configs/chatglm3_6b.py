"""chatglm3-6b [arXiv:2406.12793; hf].

28L d_model=4096 32H (2-group MQA, kv=2) d_ff=13696 vocab=65024,
partial rotary 0.5 ("RoPE 2d"), qkv bias, SwiGLU.  Pure full attention ⇒
long_500k skipped per DESIGN.md §6.
"""

from repro.models.config import TransformerConfig, scaled_down

ARCH_ID = "chatglm3-6b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_head=128,
        d_ff=13696,
        vocab_size=65024,
        rope_theta=1e4,
        rotary_pct=0.5,
        qkv_bias=True,
        act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> TransformerConfig:
    return scaled_down(config(), n_kv_heads=2)
