"""sasrec [arXiv:1808.09781; paper tier].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, causal self-attention over the
item history, dot-product next-item scoring (natively retrieval-friendly).
"""

import dataclasses

from repro.models.recsys.models import RecsysConfig

ARCH_ID = "sasrec"
FAMILY = "recsys"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        arch="sasrec",
        embed_dim=50,
        seq_len=50,
        n_dense=13,
        n_blocks=2,
        n_heads=1,
        vocab_items=1_048_576,
    )


def smoke_config() -> RecsysConfig:
    return dataclasses.replace(config(), vocab_items=1000, seq_len=12)
