"""wide-deep [arXiv:1606.07792; paper tier].

n_sparse=40 embed_dim=32 mlp=1024-512-256, concat interaction; linear wide
path over the fused sparse-field table.
"""

import dataclasses

from repro.models.recsys.models import RecsysConfig

ARCH_ID = "wide-deep"
FAMILY = "recsys"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        arch="wide_deep",
        embed_dim=32,
        n_sparse=40,
        n_dense=13,
        mlp_dims=(1024, 512, 256),
        vocab_items=1_048_576,
        vocab_sparse=1_048_576,
        seq_len=0,
    )


def smoke_config() -> RecsysConfig:
    return dataclasses.replace(
        config(), vocab_items=1000, vocab_sparse=500, n_sparse=6,
        mlp_dims=(64, 32),
    )
