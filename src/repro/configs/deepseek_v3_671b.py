"""deepseek-v3-671b [arXiv:2412.19437; hf].

61L d_model=7168 128H, MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
v 128), 1 shared + 256 routed top-8 experts (sigmoid router, aux-loss-free
bias), expert d_ff 2048, first 3 layers dense (d_ff 18432), vocab 129 280,
MTP depth 1.  Pure full attention on every layer (MLA compresses KV *width*,
not length) ⇒ long_500k is skipped per DESIGN.md §6.
"""

from repro.models.config import MLAConfig, MoEConfig, TransformerConfig, scaled_down

ARCH_ID = "deepseek-v3-671b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_head=192,  # qk_nope + qk_rope
        d_ff=18432,
        vocab_size=129280,
        rope_theta=1e4,
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed=256,
            top_k=8,
            n_shared=1,
            d_ff_expert=2048,
            first_dense_layers=3,
            d_ff_dense=18432,
            capacity_factor=1.25,
            router_score="sigmoid_norm",
            use_routing_bias=True,
        ),
        mtp_depth=1,
        tie_embeddings=False,
    )


def smoke_config() -> TransformerConfig:
    return scaled_down(config())
