"""gemma3-12b [hf:google/gemma-3-12b-pt; unverified tier].

48L d_model=3840 16H (GQA kv=8, d_head 256) d_ff=15360 vocab=262144,
5:1 local:global sliding window (1024), dual RoPE theta (10k local / 1M
global), qk-norm, sandwich norms, tied embeddings, 128k context.
Hybrid local/global ⇒ long_500k RUNS for this arch (local layers cache only
their 1024-token window).
"""

from repro.models.config import TransformerConfig, scaled_down

ARCH_ID = "gemma3-12b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_head=256,
        d_ff=15360,
        vocab_size=262144,
        rope_theta=1e4,
        rope_theta_global=1e6,
        window=1024,
        global_every=6,  # 5 local : 1 global
        act="gelu",
        qk_norm=True,
        sandwich_norm=True,
        scale_embed=True,
        tie_embeddings=True,
    )


def smoke_config() -> TransformerConfig:
    return scaled_down(config(), global_every=2)
