"""One config module per assigned architecture + the paper case study."""
