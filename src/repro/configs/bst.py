"""bst [arXiv:1905.06874; paper tier] — Behavior Sequence Transformer.

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256; transformer
over [history ‖ target] then MLP CTR head.
"""

import dataclasses

from repro.models.recsys.models import RecsysConfig

ARCH_ID = "bst"
FAMILY = "recsys"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        arch="bst",
        embed_dim=32,
        seq_len=20,
        n_dense=13,
        n_blocks=1,
        n_heads=8,
        mlp_dims=(1024, 512, 256),
        vocab_items=1_048_576,
    )


def smoke_config() -> RecsysConfig:
    return dataclasses.replace(
        config(), vocab_items=1000, seq_len=8, mlp_dims=(64, 32),
    )
