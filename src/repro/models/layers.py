"""Shared transformer layers: norms, RoPE, attention (GQA/windowed/flash,
MLA latent), gated MLPs.

Design notes (TPU):
  * ``flash_attention`` is a pure-JAX blockwise-softmax scan over KV blocks —
    O(S·blk) live memory instead of O(S²), which is what lets 32k-prefill
    lower inside a 16 GB HBM budget.  (A Pallas flash kernel is a further
    step; the XLA fusion of this formulation is already block-streaming.)
  * Sliding windows are a *mask parameter*, not a code path: local and global
    layers share one HLO shape so the layer stack stays lax.scan-able
    (gemma3's 5:1 pattern scans with a per-layer window array).
  * ``decode_attention`` is written as plain einsum+softmax so XLA SPMD can
    partition the KV-length axis across the ``model`` mesh axis
    (sequence-parallel decode for 500k contexts): max/sum reductions over the
    sharded axis become all-reduces automatically.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

BIG_WINDOW = jnp.int32(2**30)


# ---------------------------------------------------------------- norms ----
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope_tables(positions: Array, dim: int, theta: float) -> Tuple[Array, Array]:
    """Returns (sin, cos) tables [*, dim/2] f32 for given positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [*, dim/2]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array, rotary_dim: Optional[int] = None
               ) -> Array:
    """Rotates the first ``rotary_dim`` dims of x [..., S, H, dh] (pairwise,
    NEOX-style split halves). sin/cos: [S, rotary_dim/2]."""
    dh = x.shape[-1]
    rd = rotary_dim or dh
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    s = sin[..., None, :]  # [S, 1, rd/2] broadcast over heads
    c = cos[..., None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rd < dh else out


# ------------------------------------------------------------ attention ----
def _block_mask(q_pos: Array, k_pos: Array, window: Array, causal: bool
                ) -> Array:
    """[Sq, Sk] bool; window<=0 means unbounded (global layer)."""
    w = jnp.where(window > 0, window, BIG_WINDOW)
    d = q_pos[:, None] - k_pos[None, :]
    m = d < w
    if causal:
        m = jnp.logical_and(m, d >= 0)
    return m


def flash_attention(
    q: Array,  # [B, Sq, H, dh]
    k: Array,  # [B, Sk, Hkv, dh]
    v: Array,  # [B, Sk, Hkv, dhv]
    *,
    window: Array | int = 0,
    causal: bool = True,
    q_offset: Array | int = 0,
    block_k: int = 1024,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Blockwise-softmax attention (GQA-aware). Returns [B, Sq, H, dhv].

    One online-softmax pass over KV blocks; [B, Sq, H, block_k] live scores.
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dhv = v.shape[-1]
    g = h // hkv
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    blk = min(block_k, sk)
    if sk % blk:
        raise ValueError(f"Sk={sk} must be divisible by block_k={blk}")
    nblk = sk // blk

    qg = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32) * scale
    kb = k.reshape(b, nblk, blk, hkv, dh)
    vb = v.reshape(b, nblk, blk, hkv, dhv)
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    window = jnp.asarray(window)

    def body(carry, blk_in):
        m_prev, l_prev, acc = carry
        kblk, vblk, bi = blk_in
        s = jnp.einsum(
            "bqkgd,bjkd->bqkgj", qg, kblk.astype(jnp.float32)
        )  # [B,Sq,Hkv,G,blk]
        k_pos = bi * blk + jnp.arange(blk)
        mask = _block_mask(q_pos, k_pos, window, causal)  # [Sq, blk]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgj,bjkd->bqkgd", p, vblk.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, sq, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dhv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
         jnp.arange(nblk)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dhv).astype(q.dtype)


def decode_attention(
    q: Array,  # [B, 1, H, dh]
    k_cache: Array,  # [B, S, Hkv, dh]
    v_cache: Array,  # [B, S, Hkv, dhv]
    *,
    position: Array,  # [B] current write position (attend to < position+1)
    window: Array | int = 0,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache."""
    b, _, h, dh = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    logits = jnp.einsum(
        "bkgd,bjkd->bkgj", qg, k_cache.astype(jnp.float32)
    )  # [B,Hkv,G,S]
    pos_k = jnp.arange(s)[None, :]  # [1, S]
    w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), BIG_WINDOW)
    dist = position[:, None] - pos_k
    valid = jnp.logical_and(dist >= 0, dist < w)  # [B, S]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgj,bjkd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, -1).astype(q.dtype)


# ---------------------------------------------------------------- mlps -----
def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": functools.partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def gated_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array,
              act: str = "silu") -> Array:
    """SwiGLU/GeGLU: down( act(x·gate) ⊙ (x·up) )."""
    h = act_fn(act)(x @ w_gate) * (x @ w_up)
    return h @ w_down
