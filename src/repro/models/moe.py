"""Mixture-of-Experts FFN with sort-based dispatch and expert parallelism.

Dispatch is the probe-dispatch pattern from ``core/distributed.py`` turned
inward: (token, choice) pairs are sorted by expert, ranked within expert, and
scattered into a static ``[E_local, C]`` slot table — no one-hot dispatch
matmuls, so ``cost_analysis`` FLOPs stay ≈ active-parameter FLOPs × capacity
factor rather than the GShard einsum blow-up.

Expert parallelism: experts are sharded over the ``model`` mesh axis while
activations enter replicated over it (the Megatron TP layout at the FFN
boundary).  Each chip routes ALL its tokens, serves only its local experts,
and a single psum over ``model`` combines expert outputs — same collective
volume as the dense-TP FFN it replaces, zero all_to_alls on the critical
path.  Capacity overflow drops (token, choice) pairs, never whole tokens
(top-k>1 gives redundancy), and the drop count is returned for monitoring.

Single-device path (smoke tests): identical math with E_local = E and the
psum elided.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import act_fn

Array = jax.Array


def init_moe_params(key: Array, d_model: int, cfg: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    fe = cfg.d_ff_expert
    e = cfg.n_routed
    init = jax.nn.initializers.truncated_normal(stddev=0.02)
    p = {
        "router": init(ks[0], (d_model, e), jnp.float32),
        "router_bias": jnp.zeros((e,), jnp.float32),
        "wg": init(ks[1], (e, d_model, fe), dtype),
        "wu": init(ks[2], (e, d_model, fe), dtype),
        "wd": init(ks[3], (e, fe, d_model), dtype),
    }
    if cfg.n_shared:
        fs = fe * cfg.n_shared
        p["shared_wg"] = init(ks[4], (d_model, fs), dtype)
        p["shared_wu"] = init(ks[5], (d_model, fs), dtype)
        p["shared_wd"] = init(ks[6], (fs, d_model), dtype)
    return p


def router_scores(x: Array, router_w: Array, bias: Array, cfg: MoEConfig
                  ) -> Tuple[Array, Array, Array]:
    """Returns (top-k weights [N,k], top-k ids [N,k], full probs [N,E])."""
    logits = x.astype(jnp.float32) @ router_w  # [N, E]
    if cfg.router_score == "sigmoid_norm":  # DeepSeek-V3
        scores = jax.nn.sigmoid(logits)
        sel = scores + (bias if cfg.use_routing_bias else 0.0)
        _, ids = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        sel = probs + (bias if cfg.use_routing_bias else 0.0)
        w, ids = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(probs, ids, axis=-1)
    return w.astype(jnp.float32), ids.astype(jnp.int32), probs


def aux_load_balance_loss(probs: Array, ids: Array, n_experts: int) -> Array:
    """Switch-style load-balancing loss: E · Σ_e f_e · P_e."""
    n, k = ids.shape
    counts = jnp.zeros((n_experts,), jnp.float32)
    counts = counts.at[ids.reshape(-1)].add(1.0)
    f = counts / (n * k)
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def _dispatch_table(
    ids: Array,  # [N, k] global expert ids
    weights: Array,  # [N, k]
    *,
    e_lo: Array,  # scalar: first local expert id
    e_local: int,
    capacity: int,
) -> Tuple[Array, Array, Array, Array]:
    """Builds [E_local, C] (token_idx, weight, valid) tables + drop count."""
    n, k = ids.shape
    flat_e = ids.reshape(-1)  # [N*k]
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    local_e = flat_e - e_lo
    mine = jnp.logical_and(local_e >= 0, local_e < e_local)
    sort_key = jnp.where(mine, local_e, e_local)  # foreign → tail bucket
    order = jnp.argsort(sort_key)
    key_s = jnp.take(sort_key, order)
    starts = jnp.searchsorted(key_s, jnp.arange(e_local), side="left")
    rank = jnp.arange(n * k) - jnp.take(starts, jnp.minimum(key_s, e_local - 1))
    ok = jnp.logical_and(key_s < e_local, rank < capacity)

    tok_tab = jnp.zeros((e_local, capacity), jnp.int32)
    w_tab = jnp.zeros((e_local, capacity), jnp.float32)
    v_tab = jnp.zeros((e_local, capacity), jnp.bool_)
    # not-ok entries scatter OUT of range (mode="drop"), never to (0, 0) —
    # they must not clobber a legitimate slot.
    dst_e = jnp.where(ok, key_s, e_local)
    dst_c = jnp.where(ok, rank, 0)
    src_t = jnp.take(flat_t, order)
    src_w = jnp.take(flat_w, order)
    tok_tab = tok_tab.at[dst_e, dst_c].set(jnp.where(ok, src_t, 0), mode="drop")
    w_tab = w_tab.at[dst_e, dst_c].set(jnp.where(ok, src_w, 0.0), mode="drop")
    v_tab = v_tab.at[dst_e, dst_c].set(ok, mode="drop")
    n_dropped = jnp.sum(
        jnp.logical_and(key_s < e_local, rank >= capacity).astype(jnp.int32)
    )
    return tok_tab, w_tab, v_tab, n_dropped


def moe_ffn_local(
    x: Array,  # [N, D] local tokens (replicated over the EP axes)
    params: dict,  # expert weights already LOCAL: wg/wu/wd [E_local, ...]
    cfg: MoEConfig,
    *,
    ep_axes: Tuple[str, ...] = (),
    act: str = "silu",
    capacity: Optional[int] = None,
    combine: bool = True,  # False: caller combines (e.g. reduce-scatter)
) -> Tuple[Array, dict]:
    """Routed-experts FFN. Caller adds the shared-expert branch.

    Returns (out [N, D], metrics{aux_loss, n_dropped}).
    """
    n, d = x.shape
    e = cfg.n_routed
    e_local = params["wg"].shape[0]
    if not ep_axes:
        e_lo = jnp.int32(0)
    else:
        idx = jnp.int32(0)
        for a in ep_axes:  # linearized shard index, major axis first
            # psum(1) == axis size, spelled portably across JAX versions
            idx = idx * jax.lax.psum(jnp.int32(1), a) + jax.lax.axis_index(a)
        e_lo = idx * e_local
    if capacity is None:
        capacity = max(8, int(cfg.capacity_factor * n * cfg.top_k / e + 0.999))
        capacity = ((capacity + 7) // 8) * 8

    w, ids, probs = router_scores(
        x, params["router"], params["router_bias"], cfg
    )
    tok_tab, w_tab, v_tab, n_dropped = _dispatch_table(
        ids, w, e_lo=e_lo, e_local=e_local, capacity=capacity
    )

    xg = jnp.take(x, tok_tab.reshape(-1), axis=0).reshape(
        e_local, capacity, d
    )  # [E_local, C, D]
    h = act_fn(act)(
        jnp.einsum("ecd,edf->ecf", xg, params["wg"])
    ) * jnp.einsum("ecd,edf->ecf", xg, params["wu"])
    y = jnp.einsum("ecf,efd->ecd", h, params["wd"])  # [E_local, C, D]
    y = y * jnp.where(v_tab, w_tab, 0.0)[..., None].astype(y.dtype)

    out = jnp.zeros((n, d), y.dtype)
    out = out.at[tok_tab.reshape(-1)].add(y.reshape(-1, d))
    if ep_axes and combine:
        out = jax.lax.psum(out, ep_axes)
        n_dropped = jax.lax.psum(n_dropped, ep_axes)

    aux = aux_load_balance_loss(probs, ids, e)
    return out, dict(aux_loss=aux, n_dropped=n_dropped)


def shared_expert_ffn(x: Array, params: dict, act: str = "silu") -> Array:
    h = act_fn(act)(x @ params["shared_wg"]) * (x @ params["shared_wu"])
    return h @ params["shared_wd"]
