"""The four assigned recsys architectures over shared embedding substrate.

  din        [arXiv:1706.06978] — target-attention over user history
  sasrec     [arXiv:1808.09781] — causal self-attention next-item model
  bst        [arXiv:1905.06874] — transformer over [history ‖ target]
  wide-deep  [arXiv:1606.07792] — linear wide path + deep MLP on embeddings

All four share: huge vocab-sharded item/field tables (the hot path), an
interaction module, a small MLP head.  ``user_embedding`` exposes each
model's retrieval vector so `retrieval_cand` can score 1M candidates as a
batched dot / via the paper's IVF index (two-stage retrieval; DESIGN.md §5).

Batch contract (RecsysBatch):
  dense [B, n_dense] f32 · sparse [B, n_sparse] int32 · hist [B, L] int32
  (-1 pad) · target [B] int32 · label [B] f32
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.recsys.embedding import embedding_bag, init_table

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    arch: str  # "din" | "sasrec" | "bst" | "wide_deep"
    embed_dim: int
    seq_len: int = 0
    n_dense: int = 13
    n_sparse: int = 0
    vocab_items: int = 1_000_000
    vocab_sparse: int = 100_000
    mlp_dims: Tuple[int, ...] = (200, 80)
    attn_mlp_dims: Tuple[int, ...] = (80, 40)  # DIN attention MLP
    n_blocks: int = 0
    n_heads: int = 1
    dtype: Any = jnp.float32

    def n_params(self) -> int:
        total = self.vocab_items * self.embed_dim
        total += self.n_sparse * self.vocab_sparse * self.embed_dim
        prev = self.embed_dim * 4 + self.n_dense  # rough head input
        for h in self.mlp_dims:
            total += prev * h
            prev = h
        return total


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RecsysBatch:
    dense: Array
    sparse: Array
    hist: Array
    target: Array
    label: Array


def _mlp(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.nn.initializers.glorot_normal()(
                ks[i], (dims[i], dims[i + 1]), dtype
            ),
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i in range(len(dims) - 1)
    ]


def _apply_mlp(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if final_act or i < len(layers) - 1:
            x = act(x)
    return x


def _tiny_attn_params(key, d, n_heads, dtype):
    ks = jax.random.split(key, 4)
    ini = jax.nn.initializers.glorot_normal()
    return {
        "wqkv": ini(ks[0], (d, 3 * d), dtype),
        "wo": ini(ks[1], (d, d), dtype),
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "ff1": ini(ks[2], (d, 4 * d), dtype),
        "ff2": ini(ks[3], (4 * d, d), dtype),
    }


def _tiny_block(p, x, n_heads, causal, mask=None):
    """Minimal pre-LN transformer block for sasrec/bst."""
    from repro.models.layers import rms_norm

    b, s, d = x.shape
    dh = d // n_heads
    h = rms_norm(x, p["ln1"])
    qkv = h @ p["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, n_heads, dh)
    k = k.reshape(b, s, n_heads, dh)
    v = v.reshape(b, s, n_heads, dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (dh ** -0.5)
    if causal:
        cm = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(cm[None, None], logits, -1e30)
    if mask is not None:  # [B, S] key validity
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    a = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
    x = x + o @ p["wo"]
    h = rms_norm(x, p["ln2"])
    return x + jax.nn.relu(h @ p["ff1"]) @ p["ff2"]


# ------------------------------------------------------------------ init ---
def init_params(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 16))
    d = cfg.embed_dim
    p: Dict[str, Any] = {"item_table": init_table(next(ks), cfg.vocab_items,
                                                  d, cfg.dtype)}
    if cfg.n_sparse:
        p["field_tables"] = init_table(
            next(ks), cfg.n_sparse * cfg.vocab_sparse, d, cfg.dtype
        )  # one fused [F·V, D] table (quotient indexing) — single big gather
    if cfg.arch == "din":
        p["attn_mlp"] = _mlp(
            next(ks), (4 * d,) + tuple(cfg.attn_mlp_dims) + (1,), cfg.dtype
        )
        head_in = 3 * d + cfg.n_dense
        p["head"] = _mlp(next(ks), (head_in,) + tuple(cfg.mlp_dims) + (1,),
                         cfg.dtype)
    elif cfg.arch == "sasrec":
        p["pos_embed"] = init_table(next(ks), cfg.seq_len, d, cfg.dtype)
        p["blocks"] = [
            _tiny_attn_params(next(ks), d, cfg.n_heads, cfg.dtype)
            for _ in range(cfg.n_blocks)
        ]
    elif cfg.arch == "bst":
        p["pos_embed"] = init_table(next(ks), cfg.seq_len + 1, d, cfg.dtype)
        p["blocks"] = [
            _tiny_attn_params(next(ks), d, cfg.n_heads, cfg.dtype)
            for _ in range(cfg.n_blocks)
        ]
        head_in = (cfg.seq_len + 1) * d + cfg.n_dense
        p["head"] = _mlp(next(ks), (head_in,) + tuple(cfg.mlp_dims) + (1,),
                         cfg.dtype)
    elif cfg.arch == "wide_deep":
        head_in = cfg.n_sparse * d + cfg.n_dense
        p["head"] = _mlp(next(ks), (head_in,) + tuple(cfg.mlp_dims) + (1,),
                         cfg.dtype)
        p["wide"] = init_table(
            next(ks), cfg.n_sparse * cfg.vocab_sparse, 1, cfg.dtype
        )
        p["wide_bias"] = jnp.zeros((), cfg.dtype)
    else:
        raise ValueError(cfg.arch)
    return p


# ------------------------------------------------------------- forwards ---
def _field_lookup(p, cfg, sparse_ids):
    """[B, F] ids → [B, F, D] via the fused field table (id + F·offset)."""
    f = cfg.n_sparse
    offs = jnp.arange(f, dtype=jnp.int32) * cfg.vocab_sparse
    fused = jnp.where(sparse_ids >= 0, sparse_ids + offs[None, :], -1)
    rows = embedding_bag(
        p["field_tables"], fused[..., None], mode="sum"
    )  # [B, F, D]
    return rows


def user_embedding(params, cfg: RecsysConfig, batch: RecsysBatch) -> Array:
    """The retrieval vector (for `retrieval_cand` / IVF candidate gen)."""
    if cfg.arch in ("din", "wide_deep"):
        return embedding_bag(params["item_table"], batch.hist, mode="mean")
    # sequence models: hidden state at the last valid position
    h = _seq_hidden(params, cfg, batch)
    last = jnp.maximum(jnp.sum((batch.hist >= 0).astype(jnp.int32), -1) - 1, 0)
    return jnp.take_along_axis(
        h, last[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]


def _seq_hidden(params, cfg, batch) -> Array:
    e = embedding_bag(params["item_table"], batch.hist[..., None])  # [B,L,D]
    s = e.shape[1]
    e = e + params["pos_embed"][None, :s]
    mask = batch.hist >= 0
    for blk in params["blocks"]:
        e = _tiny_block(blk, e, cfg.n_heads, causal=True, mask=mask)
    return e


def forward(params, cfg: RecsysConfig, batch: RecsysBatch) -> Array:
    """Pointwise CTR logit [B] (din/bst/wide_deep) or next-item score [B]
    against the batch target (sasrec)."""
    b = batch.target.shape[0]
    tgt = embedding_bag(params["item_table"], batch.target[:, None])  # [B,D]

    if cfg.arch == "din":
        hist = embedding_bag(params["item_table"], batch.hist[..., None])
        mask = (batch.hist >= 0)[..., None]  # [B, L, 1]
        tq = jnp.broadcast_to(tgt[:, None], hist.shape)
        a_in = jnp.concatenate(
            [hist, tq, hist - tq, hist * tq], axis=-1
        )  # [B, L, 4D]
        w = _apply_mlp(params["attn_mlp"], a_in, act=jax.nn.sigmoid)  # [B,L,1]
        w = jnp.where(mask, w, 0.0)
        interest = jnp.sum(hist * w, axis=1)  # [B, D] (no softmax, per paper)
        x = jnp.concatenate([interest, tgt, interest * tgt,
                             batch.dense.astype(tgt.dtype)], -1)
        return _apply_mlp(params["head"], x)[:, 0]

    if cfg.arch == "sasrec":
        h = _seq_hidden(params, cfg, batch)
        last = jnp.maximum(
            jnp.sum((batch.hist >= 0).astype(jnp.int32), -1) - 1, 0
        )
        u = jnp.take_along_axis(
            h, last[:, None, None].astype(jnp.int32), axis=1
        )[:, 0]
        return jnp.sum(u * tgt, -1)  # dot score

    if cfg.arch == "bst":
        e = embedding_bag(params["item_table"], batch.hist[..., None])
        seq = jnp.concatenate([e, tgt[:, None]], axis=1)  # [B, L+1, D]
        s = seq.shape[1]
        seq = seq + params["pos_embed"][None, :s]
        mask = jnp.concatenate(
            [batch.hist >= 0, jnp.ones((b, 1), bool)], axis=1
        )
        for blk in params["blocks"]:
            seq = _tiny_block(blk, seq, cfg.n_heads, causal=False, mask=mask)
        x = jnp.concatenate(
            [seq.reshape(b, -1), batch.dense.astype(seq.dtype)], -1
        )
        return _apply_mlp(params["head"], x)[:, 0]

    if cfg.arch == "wide_deep":
        fields = _field_lookup(params, cfg, batch.sparse)  # [B, F, D]
        deep_in = jnp.concatenate(
            [fields.reshape(b, -1), batch.dense.astype(fields.dtype)], -1
        )
        deep = _apply_mlp(params["head"], deep_in)[:, 0]
        offs = jnp.arange(cfg.n_sparse, dtype=jnp.int32) * cfg.vocab_sparse
        fused = jnp.where(batch.sparse >= 0, batch.sparse + offs[None], -1)
        wide = embedding_bag(params["wide"], fused, mode="sum")[:, 0]
        return deep + wide + params["wide_bias"]

    raise ValueError(cfg.arch)


def loss_fn(params, cfg: RecsysConfig, batch: RecsysBatch
            ) -> Tuple[Array, Dict[str, Array]]:
    logit = forward(params, cfg, batch).astype(jnp.float32)
    y = batch.label.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    acc = jnp.mean(((logit > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"bce": loss, "acc": acc}


def retrieval_scores(params, cfg: RecsysConfig, batch: RecsysBatch,
                     candidates: Array, k: int = 100
                     ) -> Tuple[Array, Array]:
    """`retrieval_cand`: score user vs [N_cand, D] item rows — one batched
    matmul + top-k, never a loop. The IVF-index path for the same operation
    lives in examples/recsys_retrieval.py."""
    u = user_embedding(params, cfg, batch)  # [B, D]
    scores = u.astype(jnp.float32) @ candidates.astype(jnp.float32).T
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids
