"""EmbeddingBag for JAX (the brief's explicit gap): ``jnp.take`` +
``jax.ops.segment_sum``, with a vocab-sharded variant for pod-scale tables.

Two layouts:
  * fixed-width bags [B, L] with -1 padding (recsys histories) —
    :func:`embedding_bag`;
  * ragged multi-hot bags (flat ids + bag ids) — :func:`embedding_bag_ragged`
    via segment_sum, torch ``nn.EmbeddingBag`` semantics.

Sharding: tables are vocab-range-sharded over the ``model`` axis
(:func:`sharded_embedding_bag`, shard_map): each chip looks up only ids in
its range (out-of-range → 0 rows) and a psum over ``model`` assembles the
bag sums — the classic vocab-parallel embedding, with traffic [B, D] instead
of gathering table rows across chips.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

Array = jax.Array


def init_table(key: Array, vocab: int, dim: int, dtype=jnp.float32,
               stddev: float = 0.02) -> Array:
    return jax.nn.initializers.truncated_normal(stddev=stddev)(
        key, (vocab, dim), dtype
    )


def embedding_bag(
    table: Array,  # [V, D]
    ids: Array,  # [..., L] int32, -1 = padding
    *,
    mode: str = "sum",
    weights: Optional[Array] = None,  # [..., L]
) -> Array:
    """Fixed-width bag lookup+reduce. Returns [..., D]."""
    mask = (ids >= 0).astype(table.dtype)[..., None]
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)  # [..., L, D]
    if weights is not None:
        rows = rows * weights[..., None].astype(table.dtype)
    rows = rows * mask
    s = jnp.sum(rows, axis=-2)
    if mode == "sum":
        return s
    if mode == "mean":
        n = jnp.maximum(jnp.sum(mask, axis=-2), 1.0)
        return s / n
    if mode == "max":
        neg = jnp.where(mask > 0, rows, -jnp.inf)
        return jnp.max(neg, axis=-2)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: Array,  # [V, D]
    flat_ids: Array,  # [NNZ] int32
    bag_ids: Array,  # [NNZ] int32 — which bag each id belongs to
    n_bags: int,
    *,
    mode: str = "sum",
    weights: Optional[Array] = None,  # [NNZ]
) -> Array:
    """Ragged (true multi-hot) bags via segment_sum. Returns [n_bags, D]."""
    rows = jnp.take(table, jnp.maximum(flat_ids, 0), axis=0)
    valid = (flat_ids >= 0).astype(table.dtype)[:, None]
    if weights is not None:
        rows = rows * weights[:, None].astype(table.dtype)
    rows = rows * valid
    s = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "sum":
        return s
    if mode == "mean":
        n = jax.ops.segment_sum(valid, bag_ids, num_segments=n_bags)
        return s / jnp.maximum(n, 1.0)
    raise ValueError(mode)


def sharded_embedding_bag(
    table: Array,  # [V, D] — sharded P("model", None)
    ids: Array,  # [..., L] — replicated over "model"
    mesh: Mesh,
    *,
    mode: str = "sum",
    dp_axes: Tuple[str, ...] = ("data",),
) -> Array:
    """Vocab-parallel bag lookup: local-range take + psum over 'model'."""
    v = table.shape[0]
    n_model = mesh.shape["model"]
    v_local = v // n_model

    def local(tab, idl):
        me = jax.lax.axis_index("model")
        lo = me.astype(jnp.int32) * v_local
        rel = idl - lo
        inrange = jnp.logical_and(rel >= 0, rel < v_local)
        valid = jnp.logical_and(inrange, idl >= 0)
        rows = jnp.take(tab, jnp.clip(rel, 0, v_local - 1), axis=0)
        rows = rows * valid[..., None].astype(rows.dtype)
        out = jnp.sum(rows, axis=-2)
        out = jax.lax.psum(out, "model")
        if mode == "mean":
            n = jax.lax.psum(
                jnp.sum(valid.astype(rows.dtype), -1, keepdims=True), "model"
            )
            out = out / jnp.maximum(n, 1.0)
        return out

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P(dp_axes, *([None] * (ids.ndim - 1)))),
        out_specs=P(dp_axes, *([None] * (ids.ndim - 2)), None),
        check=False,
    )(table, ids)
