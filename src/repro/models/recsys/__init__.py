from repro.models.recsys.embedding import (
    embedding_bag,
    embedding_bag_ragged,
    init_table,
    sharded_embedding_bag,
)
from repro.models.recsys.models import (
    RecsysBatch,
    RecsysConfig,
    forward,
    init_params,
    loss_fn,
    retrieval_scores,
    user_embedding,
)

__all__ = [
    "RecsysBatch", "RecsysConfig", "embedding_bag", "embedding_bag_ragged",
    "forward", "init_params", "init_table", "loss_fn", "retrieval_scores",
    "sharded_embedding_bag", "user_embedding",
]
