"""LM family: one parameterized decoder covering the five assigned archs.

  deepseek-v3-671b  — MLA attention, 1 shared + 256 routed top-8 (sigmoid
                      router, aux-loss-free bias), 3 dense lead layers, MTP
  deepseek-moe-16b  — MHA, 2 shared + 64 routed top-6, 1 dense lead layer
  gemma3-12b/27b    — GQA, 5:1 local:global sliding window, dual RoPE theta,
                      qk-norm, sandwich norms, tied embeddings
  chatglm3-6b       — 2-group MQA, partial rotary (0.5), SwiGLU, qkv bias

Layer stacks are lax.scan'ed over stacked parameters; the local/global
pattern is a per-layer *window array* (one HLO shape for both kinds), and the
gemma3 dual-theta RoPE is a per-layer select between two precomputed tables.
MoE layers run expert-parallel via shard_map when a mesh is supplied and
single-device otherwise (same math; see models/moe.py).

Sharding (Megatron TP on "model", DP on ("pod","data")):
  embed [V, D]            P(model, -)     vocab-parallel
  wq/wk/wv, w_gate/w_up   P(-, model)     column-parallel
  wo, w_down              P(model, -)     row-parallel
  experts [E, ...]        P(model, -, -)  expert-parallel
  activations [B, S, D]   P(dp, -, -)
  logits [B, S, V]        P(dp, -, model) (loss reduces over sharded V)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.models import moe as moe_lib
from repro.models.config import TransformerConfig
from repro.models.layers import (
    apply_rope,
    flash_attention,
    gated_mlp,
    rms_norm,
    rope_tables,
)

Array = jax.Array


# ------------------------------------------------------------------ init ---
def _init(key, shape, dtype, stddev=0.02):
    return jax.nn.initializers.truncated_normal(stddev=stddev)(
        key, shape, dtype
    )


def _init_attn(key, cfg: TransformerConfig, n_layers: int) -> Dict[str, Array]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = iter(jax.random.split(key, 16))
    dt = cfg.dtype
    L = n_layers
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        p = {
            "wq_a": _init(next(ks), (L, d, m.q_lora_rank), dt),
            "q_ln": jnp.zeros((L, m.q_lora_rank), dt),
            "wq_b": _init(next(ks), (L, m.q_lora_rank, h * qk), dt),
            "wkv_a": _init(next(ks), (L, d, m.kv_lora_rank + m.qk_rope_dim), dt),
            "kv_ln": jnp.zeros((L, m.kv_lora_rank), dt),
            "wk_b": _init(next(ks), (L, m.kv_lora_rank, h, m.qk_nope_dim), dt),
            "wv_b": _init(next(ks), (L, m.kv_lora_rank, h, m.v_head_dim), dt),
            "wo": _init(next(ks), (L, h * m.v_head_dim, d), dt),
        }
    else:
        p = {
            "wq": _init(next(ks), (L, d, h * dh), dt),
            "wk": _init(next(ks), (L, d, hkv * dh), dt),
            "wv": _init(next(ks), (L, d, hkv * dh), dt),
            "wo": _init(next(ks), (L, h * dh, d), dt),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((L, h * dh), dt)
            p["bk"] = jnp.zeros((L, hkv * dh), dt)
            p["bv"] = jnp.zeros((L, hkv * dh), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((L, cfg.d_head if cfg.mla is None else
                                 cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim), dt)
        p["k_norm"] = jnp.zeros_like(p["q_norm"])
    return p


def _init_block(key, cfg: TransformerConfig, n_layers: int, d_ff: int,
                is_moe: bool) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 8))
    d, dt, L = cfg.d_model, cfg.dtype, n_layers
    blk: Dict[str, Any] = {
        "ln1": jnp.zeros((L, d), dt),
        "ln2": jnp.zeros((L, d), dt),
        "attn": _init_attn(next(ks), cfg, L),
    }
    if cfg.sandwich_norm:
        blk["ln1_post"] = jnp.zeros((L, d), dt)
        blk["ln2_post"] = jnp.zeros((L, d), dt)
    if is_moe:
        moe_keys = jax.random.split(next(ks), L)
        per_layer = [
            moe_lib.init_moe_params(k, d, cfg.moe, dt) for k in moe_keys
        ]
        blk["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        blk["mlp"] = {
            "wg": _init(next(ks), (L, d, d_ff), dt),
            "wu": _init(next(ks), (L, d, d_ff), dt),
            "wd": _init(next(ks), (L, d_ff, d), dt),
        }
    return blk


def init_params(key: Array, cfg: TransformerConfig) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 8))
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": _init(next(ks), (cfg.vocab_size, d), cfg.dtype),
        "final_norm": jnp.zeros((d,), cfg.dtype),
    }
    d_ff_dense = cfg.moe.d_ff_dense if cfg.moe else cfg.d_ff
    if cfg.n_dense_layers:
        params["blocks"] = _init_block(
            next(ks), cfg, cfg.n_dense_layers, d_ff_dense, is_moe=False
        )
    if cfg.n_moe_layers:
        params["moe_blocks"] = _init_block(
            next(ks), cfg, cfg.n_moe_layers, 0, is_moe=True
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(next(ks), (d, cfg.vocab_size), cfg.dtype)
    if cfg.mtp_depth:
        mtp_blk = _init_block(next(ks), cfg, 1, d_ff_dense, is_moe=False)
        params["mtp"] = {
            "norm_h": jnp.zeros((d,), cfg.dtype),
            "norm_e": jnp.zeros((d,), cfg.dtype),
            "proj": _init(next(ks), (2 * d, d), cfg.dtype),
            "block": mtp_blk,
            "final_norm": jnp.zeros((d,), cfg.dtype),
        }
    return params


# ------------------------------------------------------------- shardings ---
def param_pspecs(cfg: TransformerConfig) -> Dict[str, Any]:
    """PartitionSpec tree matching init_params (leading L axis unsharded).

    TP ("model") on head/ff/expert dims; FSDP (``cfg.fsdp_axis``) on the
    other weight dim so per-chip parameter bytes scale 1/(TP·FSDP) — without
    it a 671B model stores 84 GB/chip (model-sharding only) and cannot fit
    v5e.  XLA re-gathers the FSDP shards per layer inside the scan (the
    classic ZeRO-3 all-gather, visible in the collective term).  Experts
    shard over ``cfg.moe_ep_axes``.  Multi-pod keeps one replica per pod
    ("pod" carries pure DP).
    """
    f = cfg.fsdp_axis
    ep = cfg.moe_ep_axes
    col = P(None, f, "model")  # [L, D, F]
    row = P(None, "model", f)  # [L, F, D]
    rep1 = P(None, None)  # [L, D]
    if cfg.mla is not None:
        attn = {
            "wq_a": P(None, f, None),
            "q_ln": rep1,
            "wq_b": P(None, f, "model"),
            "wkv_a": P(None, f, None),
            "kv_ln": rep1,
            "wk_b": P(None, f, "model", None),
            "wv_b": P(None, f, "model", None),
            "wo": row,
        }
    else:
        attn = {"wq": col, "wk": col, "wv": col, "wo": row}
        if cfg.qkv_bias:
            attn.update({"bq": P(None, "model"), "bk": P(None, "model"),
                         "bv": P(None, "model")})
    if cfg.qk_norm:
        attn["q_norm"] = rep1
        attn["k_norm"] = rep1

    def block_specs(is_moe):
        b = {"ln1": rep1, "ln2": rep1, "attn": dict(attn)}
        if cfg.sandwich_norm:
            b["ln1_post"] = rep1
            b["ln2_post"] = rep1
        if is_moe:
            b["moe"] = {
                "router": P(None, f, None),
                "router_bias": P(None, None),
                "wg": P(None, ep, f, None),
                "wu": P(None, ep, f, None),
                "wd": P(None, ep, None, f),
            }
            if cfg.moe.n_shared:
                b["moe"].update({
                    "shared_wg": col, "shared_wu": col, "shared_wd": row,
                })
        else:
            b["mlp"] = {"wg": col, "wu": col, "wd": row}
        return b

    specs: Dict[str, Any] = {
        "embed": P("model", f),
        "final_norm": P(None),
    }
    if cfg.n_dense_layers:
        specs["blocks"] = block_specs(False)
    if cfg.n_moe_layers:
        specs["moe_blocks"] = block_specs(True)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(f, "model")
    if cfg.mtp_depth:
        specs["mtp"] = {
            "norm_h": P(None),
            "norm_e": P(None),
            "proj": P(f, None),
            "block": block_specs(False),
            "final_norm": P(None),
        }
    return specs


# --------------------------------------------------------------- forward ---
def _head_constrain(t, mesh, dp_axes, n_heads):
    """Pin expanded q/k/v to the head-sharded TP layout.

    Without this, XLA resolving the SP (S-sharded) ↔ TP (head-sharded)
    boundary can replicate the EXPANDED attention tensors — measured 62
    GB/layer/chip of f32 full-head all-gathers on deepseek-v3 (EXPERIMENTS
    §Perf iter 1). KV heads that don't divide the axis stay replicated.
    """
    if mesh is None or n_heads % mesh.shape["model"] != 0:
        return t
    return jax.lax.with_sharding_constraint(
        t, P(dp_axes, None, "model", None)
    )


def _gqa_attention(x, p, cfg: TransformerConfig, sin, cos, window,
                   q_offset=0, mesh=None, dp_axes=("data",)):
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _head_constrain(q.reshape(b, s, h, dh), mesh, dp_axes, h)
    k = _head_constrain(k.reshape(b, s, hkv, dh), mesh, dp_axes, hkv)
    v = _head_constrain(v.reshape(b, s, hkv, dh), mesh, dp_axes, hkv)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    rd = int(dh * cfg.rotary_pct)
    q = apply_rope(q, sin, cos, rd)
    k = apply_rope(k, sin, cos, rd)
    out = flash_attention(
        q, k, v, window=window, q_offset=q_offset,
        block_k=min(cfg.attn_block_k, s),
    )
    return out.reshape(b, s, h * dh) @ p["wo"], (k, v)


def _mla_attention(x, p, cfg: TransformerConfig, sin, cos, window,
                   q_offset=0, mesh=None, dp_axes=("data",)):
    """MLA training/prefill path (expanded); decode uses the absorbed path."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    cq = rms_norm(x @ p["wq_a"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]

    ckv_full = x @ p["wkv_a"]  # [B,S,kvr+rope]
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # [B,S,1,rope]

    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope, sin, cos)

    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["wv_b"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))], -1
    )
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    # keep the EXPANDED tensors head-sharded (they are 128-head wide; letting
    # XLA replicate them costs tens of GB/layer — §Perf iter 1)
    q_full = _head_constrain(q_full, mesh, dp_axes, h)
    k = _head_constrain(k, mesh, dp_axes, h)
    v = _head_constrain(v, mesh, dp_axes, h)
    out = flash_attention(
        q_full, k, v, window=window, q_offset=q_offset,
        block_k=min(cfg.attn_block_k, s), softmax_scale=qk ** -0.5,
    )
    out = out.reshape(b, s, h * m.v_head_dim) @ p["wo"]
    # cache payload for prefill: the latent pair (what MLA stores)
    return out, (c_kv, k_rope[:, :, 0, :])


def _moe_ffn(x, moe_params, cfg: TransformerConfig,
             mesh: Optional[Mesh], dp_axes: Tuple[str, ...]):
    """Expert FFN + shared expert. x: [B, S, D].

    Parallelism plan from cfg: experts sharded over ``moe_ep_axes`` (EP);
    when ``fsdp_axis`` is set the expert weights are additionally stored
    FSDP-sharded and all-gathered INSIDE the scan/remat body so the gather
    can never be hoisted into a whole-stack materialization (ZeRO-3: a
    layer's gathered weights live only for that layer).  If EP uses an axis
    that also carries data parallelism ("data" at decode), activations are
    replicated over it (token batches at decode are KiB-scale).
    """
    b, s, d = x.shape
    mcfg = cfg.moe
    ep_axes = cfg.moe_ep_axes if mesh is not None else ()
    fsdp = cfg.fsdp_axis if mesh is not None else None
    # reduce-scatter combine is valid when EP is the single "model" axis and
    # the sequence divides it (not decode S=1, not multi-axis EP)
    use_scatter = (
        cfg.moe_combine == "scatter" and mesh is not None
        and ep_axes == ("model",) and s % mesh.shape["model"] == 0
    )

    def local(xl, wg, wu, wd, router, rbias):
        if fsdp is not None:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        n = xl.shape[0] * xl.shape[1]
        flat = xl.reshape(n, d)
        p_local = {"wg": wg, "wu": wu, "wd": wd, "router": router,
                   "router_bias": rbias}
        out, metrics = moe_lib.moe_ffn_local(
            flat, p_local, mcfg,
            ep_axes=ep_axes if mesh is not None else (),
            act=cfg.act,
            combine=not use_scatter,
        )
        if use_scatter:  # combine partial expert outputs into the SP layout
            out = out.reshape(xl.shape)
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                       tiled=True)
            metrics["n_dropped"] = jax.lax.psum(metrics["n_dropped"], "model")
            return out, metrics["aux_loss"], metrics["n_dropped"]
        return out.reshape(xl.shape), metrics["aux_loss"], metrics["n_dropped"]

    if mesh is None:
        out, aux, dropped = local(
            x, moe_params["wg"], moe_params["wu"], moe_params["wd"],
            moe_params["router"], moe_params["router_bias"],
        )
    else:
        # tokens must be replicated over any EP axis that is also a dp axis
        dp_eff = tuple(a for a in dp_axes if a not in ep_axes)
        dp = P(dp_eff if dp_eff else None, None, None)
        out_spec = (P(dp_eff if dp_eff else None, "model", None)
                    if use_scatter else dp)
        out, aux, dropped = compat.shard_map(
            local,
            mesh=mesh,
            in_specs=(dp, P(ep_axes, fsdp, None), P(ep_axes, fsdp, None),
                      P(ep_axes, None, fsdp), P(None, None), P(None)),
            out_specs=(out_spec, P(), P()),
            check=False,
        )(x, moe_params["wg"], moe_params["wu"], moe_params["wd"],
          moe_params["router"], moe_params["router_bias"])
    if mcfg.n_shared:
        out = out + gated_mlp(
            x, moe_params["shared_wg"], moe_params["shared_wu"],
            moe_params["shared_wd"], cfg.act,
        )
    return out, aux, dropped


def _block_apply(h, blk_params, cfg: TransformerConfig, sin, cos, window,
                 is_moe: bool, mesh, dp_axes, q_offset=0):
    """One transformer block. Returns (h, kv_payload, aux, dropped).

    Under a mesh the carry is kept SEQUENCE-SHARDED over "model" (Megatron
    SP): the per-layer residual the remat policy must keep alive shrinks by
    the TP width (61 × 470 MB → 61 × 29 MB for deepseek-v3 train_4k), and
    XLA inserts the all-gather (entering attention) / reduce-scatter
    (leaving wo / w_down) pairs around each block.  Sq=1 decode skips SP.
    """
    if mesh is not None and h.shape[1] % mesh.shape["model"] == 0:
        h = jax.lax.with_sharding_constraint(h, P(dp_axes, "model", None))
    attn_in = rms_norm(h, blk_params["ln1"], cfg.norm_eps)
    attn_fn = _mla_attention if cfg.mla is not None else _gqa_attention
    attn_out, kv = attn_fn(attn_in, blk_params["attn"], cfg, sin, cos,
                           window, q_offset, mesh, dp_axes)
    if cfg.sandwich_norm:
        attn_out = rms_norm(attn_out, blk_params["ln1_post"], cfg.norm_eps)
    h = h + attn_out

    mlp_in = rms_norm(h, blk_params["ln2"], cfg.norm_eps)
    if is_moe:
        mlp_out, aux, dropped = _moe_ffn(mlp_in, blk_params["moe"], cfg,
                                         mesh, dp_axes)
    else:
        mlp_out = gated_mlp(mlp_in, blk_params["mlp"]["wg"],
                            blk_params["mlp"]["wu"],
                            blk_params["mlp"]["wd"], cfg.act)
        aux = jnp.zeros((), jnp.float32)
        dropped = jnp.zeros((), jnp.int32)
    if cfg.sandwich_norm:
        mlp_out = rms_norm(mlp_out, blk_params["ln2_post"], cfg.norm_eps)
    return h + mlp_out, kv, aux, dropped


def _scan_stack(h, stack, cfg, windows, sin_l, cos_l, sin_g, cos_g,
                is_moe, mesh, dp_axes, collect_kv=False, q_offset=0):
    """lax.scan over a stacked block. windows: [L] int32 per-layer."""

    def apply(hc, blk, sin, cos, w):
        return _block_apply(hc, blk, cfg, sin, cos, w, is_moe, mesh,
                            dp_axes, q_offset)

    if cfg.remat:
        apply = jax.checkpoint(
            apply, policy=jax.checkpoint_policies.nothing_saveable
        )

    def body(carry, xs):
        hc = carry
        blk, w = xs
        is_global = w == 0
        sin = jnp.where(is_global, sin_g, sin_l)
        cos = jnp.where(is_global, cos_g, cos_l)
        h2, kv, aux, dropped = apply(hc, blk, sin, cos, w)
        ys = (kv if collect_kv else None, aux, dropped)
        return h2, ys

    h, (kv, aux, dropped) = jax.lax.scan(
        body, h, (stack, windows),
        unroll=windows.shape[0] if cfg.scan_unroll else 1,
    )
    return h, kv, jnp.sum(aux), jnp.sum(dropped)


def forward(
    params: Dict[str, Any],
    cfg: TransformerConfig,
    tokens: Array,  # [B, S] int32
    *,
    mesh: Optional[Mesh] = None,
    dp_axes: Tuple[str, ...] = ("data",),
    collect_kv: bool = False,
    q_offset: int = 0,
) -> Tuple[Array, Dict[str, Any]]:
    """Full-sequence forward. Returns (hidden [B,S,D], aux dict).

    aux carries moe metrics and (if collect_kv) the per-layer cache payloads
    for prefill.
    """
    b, s = tokens.shape
    constrain = (
        (lambda x, spec: jax.lax.with_sharding_constraint(x, P(*spec)))
        if mesh is not None else (lambda x, spec: x)
    )
    h = jnp.take(params["embed"], tokens, axis=0)  # [B,S,D]
    if cfg.scale_embed:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    sp_ok = mesh is not None and s % mesh.shape["model"] == 0
    h = constrain(h, (dp_axes, "model" if sp_ok else None, None))

    positions = q_offset + jnp.arange(s)
    rd = (cfg.mla.qk_rope_dim if cfg.mla is not None
          else int(cfg.d_head * cfg.rotary_pct))
    sin_l, cos_l = rope_tables(positions, rd, cfg.rope_theta)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    sin_g, cos_g = rope_tables(positions, rd, theta_g)

    wp = cfg.window_pattern()
    aux: Dict[str, Any] = {}
    kv_all = []
    if cfg.n_dense_layers:
        w_dense = jnp.asarray(wp[: cfg.n_dense_layers])
        h, kv, aux_l, drop = _scan_stack(
            h, params["blocks"], cfg, w_dense, sin_l, cos_l, sin_g, cos_g,
            False, mesh, dp_axes, collect_kv, q_offset,
        )
        kv_all.append(kv)
        aux["moe_aux_loss"] = aux_l
        aux["moe_dropped"] = drop
    if cfg.n_moe_layers:
        w_moe = jnp.asarray(wp[cfg.n_dense_layers :])
        h, kv, aux_l, drop = _scan_stack(
            h, params["moe_blocks"], cfg, w_moe, sin_l, cos_l, sin_g, cos_g,
            True, mesh, dp_axes, collect_kv, q_offset,
        )
        kv_all.append(kv)
        aux["moe_aux_loss"] = aux.get("moe_aux_loss", 0.0) + aux_l
        aux["moe_dropped"] = aux.get("moe_dropped", 0) + drop
    h = constrain(h, (dp_axes, "model" if sp_ok else None, None))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if collect_kv:
        aux["kv"] = kv_all
    return h, aux


def logits_from_hidden(params, cfg, h, constrain=None):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = h @ head.astype(h.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if constrain is not None:
        logits = constrain(logits)
    return logits


def lm_loss(
    params: Dict[str, Any],
    cfg: TransformerConfig,
    tokens: Array,  # [B, S]
    labels: Array,  # [B, S] (-1 = ignore)
    *,
    mesh: Optional[Mesh] = None,
    dp_axes: Tuple[str, ...] = ("data",),
) -> Tuple[Array, Dict[str, Any]]:
    """Causal LM loss (+ MTP auxiliary loss + MoE balance loss)."""
    sp_ok = mesh is not None and tokens.shape[1] % mesh.shape["model"] == 0
    constrain = (
        (lambda x: jax.lax.with_sharding_constraint(
            x, P(dp_axes, "model" if sp_ok else None, None)))
        if mesh is not None else None
    )
    h, aux = forward(params, cfg, tokens, mesh=mesh, dp_axes=dp_axes)

    def ce(hid, lab):
        lg = logits_from_hidden(params, cfg, hid, constrain)
        lg = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    total, denom = ce(h, labels)
    metrics = {"ce_tokens": denom}

    if cfg.mtp_depth:
        # predict t+2: combine h_t with embedding of token t+1 (=labels_t)
        mtp = params["mtp"]
        nxt = jnp.maximum(labels, 0)
        e_next = jnp.take(params["embed"], nxt, axis=0)
        comb = jnp.concatenate(
            [rms_norm(h, mtp["norm_h"], cfg.norm_eps),
             rms_norm(e_next, mtp["norm_e"], cfg.norm_eps)], -1
        ) @ mtp["proj"]
        blk = jax.tree.map(lambda x: x[0], mtp["block"])  # unstack L=1
        s = comb.shape[1]
        rd = (cfg.mla.qk_rope_dim if cfg.mla is not None
              else int(cfg.d_head * cfg.rotary_pct))
        sin, cos = rope_tables(jnp.arange(s), rd, cfg.rope_theta)
        h2, _kv, _aux, _drop = _block_apply(
            comb, blk, cfg, sin, cos, jnp.int32(0), False, mesh, dp_axes
        )
        h2 = rms_norm(h2, mtp["final_norm"], cfg.norm_eps)
        labels_mtp = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1
        )
        mtp_total, mtp_denom = ce(h2, labels_mtp)
        total = total + cfg.mtp_loss_weight * mtp_total
        denom = denom  # main-token normalization
        metrics["mtp_tokens"] = mtp_denom

    loss = total / jnp.maximum(denom, 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_coef * aux.get("moe_aux_loss", 0.0)
        metrics["moe_aux_loss"] = aux.get("moe_aux_loss", 0.0)
        metrics["moe_dropped"] = aux.get("moe_dropped", 0)
    return loss, metrics
