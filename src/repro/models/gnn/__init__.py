from repro.models.gnn.dimenet import (
    DimeNetConfig,
    GraphBatch,
    forward,
    init_params,
    loss_fn,
    scaled_down_gnn,
)

__all__ = [
    "DimeNetConfig", "GraphBatch", "forward", "init_params", "loss_fn",
    "scaled_down_gnn",
]
