"""DimeNet [arXiv:2003.03123] — directional message passing with triplet
(k→j→i) angular features, adapted per the brief's GNN kernel-regime notes.

Message passing is built entirely on ``jnp.take`` + ``jax.ops.segment_sum``
over explicit edge/triplet index lists (JAX has no CSR SpMM; the gather/
scatter IS the system).  Distribution: edges and triplets are sharded over
chips; cross-shard gathers (a triplet's in-message may live elsewhere) are
plain sharded ``take`` ops that XLA SPMD lowers to collectives — this arch is
the designated *collective-bound* roofline specimen (EXPERIMENTS §Roofline).

Faithfulness notes (DESIGN.md §Arch-applicability):
  * The assigned shapes include citation/product graphs without 3-D
    coordinates; ``input_specs`` supplies synthetic positions and the node
    featurizer is an MLP on ``d_feat`` features (DimeNet's atom-type embed
    generalized).  The molecule shape uses the model exactly as published.
  * The 2-D spherical basis uses sine-radial × Legendre-angular functions
    with the paper's p=6 smooth envelope — the m=0 Fourier-Bessel surrogate
    (exact Bessel roots add nothing structural on TPU).
  * Triplets are capped per edge (static shapes); the cap is a config knob
    and the assigned molecular cutoff graphs sit well under it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 128  # input node feature width (varies per shape)
    d_out: int = 32  # classes (node tasks) or 1 (graph regression)
    cutoff: float = 5.0
    envelope_p: int = 6
    readout: str = "node"  # "node" | "graph"
    dtype: Any = jnp.float32
    scan_unroll: bool = False  # dry-run cost variant (see launch/specs.py)

    def n_params(self) -> int:
        d = self.d_hidden
        per_block = (
            d * d * 4  # message MLPs
            + self.n_bilinear * d * d  # bilinear tensor
            + self.n_spherical * self.n_radial * self.n_bilinear
            + self.n_radial * d
            + d * d * 2  # output block
        )
        return self.d_feat * d + 3 * d * d + self.n_blocks * per_block \
            + d * self.d_out


def scaled_down_gnn(cfg: DimeNetConfig, **overrides) -> DimeNetConfig:
    small = dict(n_blocks=2, d_hidden=32, n_bilinear=2, n_spherical=3,
                 n_radial=4, d_feat=16, d_out=4)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


# ------------------------------------------------------------- the bases ---
def envelope(d: Array, cutoff: float, p: int) -> Array:
    """Smooth polynomial cutoff u(d) (paper eq. 8), zero at d=cutoff."""
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2)
    c = -p * (p + 1) / 2.0
    return 1.0 + a * x**p + b * x ** (p + 1) + c * x ** (p + 2)


def radial_basis(d: Array, n_radial: int, cutoff: float, p: int) -> Array:
    """e_RBF(d) [.., n_radial]: envelope · sin(nπ d/c)/d (paper eq. 7)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    ds = jnp.maximum(d[..., None], 1e-6)
    env = envelope(d, cutoff, p)[..., None]
    return env * jnp.sin(n * jnp.pi * ds / cutoff) / ds * jnp.sqrt(
        2.0 / cutoff
    )


def _legendre(cos_t: Array, n: int) -> Array:
    """P_0..P_{n-1}(cosθ) via the recurrence. [.., n]."""
    outs = [jnp.ones_like(cos_t), cos_t]
    for l in range(2, n):
        outs.append(
            ((2 * l - 1) * cos_t * outs[-1] - (l - 1) * outs[-2]) / l
        )
    return jnp.stack(outs[:n], axis=-1)


def spherical_basis(d: Array, cos_angle: Array, n_spherical: int,
                    n_radial: int, cutoff: float, p: int) -> Array:
    """e_SBF(d_kj, θ) [.., n_spherical · n_radial]."""
    rad = radial_basis(d, n_radial, cutoff, p)  # [.., R]
    ang = _legendre(cos_angle, n_spherical)  # [.., S]
    out = rad[..., None, :] * ang[..., :, None]  # [.., S, R]
    return out.reshape(out.shape[:-2] + (n_spherical * n_radial,))


# ----------------------------------------------------------------- init ----
def _dense(key, din, dout, dtype):
    return jax.nn.initializers.glorot_normal()(key, (din, dout), dtype)


def init_params(key: Array, cfg: DimeNetConfig) -> Dict[str, Any]:
    d = cfg.d_hidden
    nsr = cfg.n_spherical * cfg.n_radial
    ks = iter(jax.random.split(key, 8 + cfg.n_blocks * 8))
    p: Dict[str, Any] = {
        "feat_proj": _dense(next(ks), cfg.d_feat, d, cfg.dtype),
        "rbf_embed": _dense(next(ks), cfg.n_radial, d, cfg.dtype),
        "msg_embed": _dense(next(ks), 3 * d, d, cfg.dtype),
        "out_proj": _dense(next(ks), d, cfg.d_out, cfg.dtype),
    }
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "w_msg": _dense(next(ks), d, d, cfg.dtype),
            "w_src": _dense(next(ks), d, d, cfg.dtype),
            "w_sbf": _dense(next(ks), nsr, cfg.n_bilinear, cfg.dtype),
            "w_bil": jax.nn.initializers.normal(0.02)(
                next(ks), (cfg.n_bilinear, d, d), cfg.dtype
            ),
            "w_res1": _dense(next(ks), d, d, cfg.dtype),
            "w_res2": _dense(next(ks), d, d, cfg.dtype),
            "w_rbf_out": _dense(next(ks), cfg.n_radial, d, cfg.dtype),
            "w_out": _dense(next(ks), d, d, cfg.dtype),
        })
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return p


# --------------------------------------------------------------- forward ---
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Static-shape graph with explicit triplets.

    node_feat   [N, d_feat]
    positions   [N, 3]
    edge_src    [E] int32 (j of message j→i)      edge_dst [E] int32 (i)
    edge_mask   [E] bool (padding)
    trip_in     [T] int32 — edge index of (k→j)   trip_out [T] int32 — (j→i)
    trip_mask   [T] bool
    graph_id    [N] int32 (graph readout; zeros for single graph)
    n_graphs    int (static)
    """

    node_feat: Array
    positions: Array
    edge_src: Array
    edge_dst: Array
    edge_mask: Array
    trip_in: Array
    trip_out: Array
    trip_mask: Array
    graph_id: Array
    n_graphs: int = dataclasses.field(metadata=dict(static=True))


def forward(params: Dict[str, Any], cfg: DimeNetConfig, g: GraphBatch
            ) -> Array:
    """Returns [N, d_out] (node readout) or [n_graphs, d_out] (graph)."""
    act = jax.nn.silu
    n = g.node_feat.shape[0]
    e = g.edge_src.shape[0]

    h = act(g.node_feat.astype(cfg.dtype) @ params["feat_proj"])  # [N, d]

    # geometry
    dvec = jnp.take(g.positions, g.edge_dst, 0) - jnp.take(
        g.positions, g.edge_src, 0
    )  # [E, 3]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(dvec * dvec, -1), 1e-12))
    rbf = radial_basis(dist, cfg.n_radial, cfg.cutoff, cfg.envelope_p)
    # bases are evaluated in f32 (trig/envelope precision) then cast to the
    # working dtype so the scan carry stays uniform under bf16 configs
    rbf = jnp.where(g.edge_mask[:, None], rbf, 0.0).astype(cfg.dtype)

    # triplet angle at j between (k→j) and (j→i): cosθ = -d_kj·d_ji/(|..||..|)
    v_in = jnp.take(dvec, g.trip_in, 0)  # k→j
    v_out = jnp.take(dvec, g.trip_out, 0)  # j→i
    num = jnp.sum(v_in * v_out, -1)
    den = jnp.maximum(
        jnp.linalg.norm(v_in, axis=-1) * jnp.linalg.norm(v_out, axis=-1),
        1e-12,
    )
    cos_t = jnp.clip(num / den, -1.0, 1.0)
    d_in = jnp.take(dist, g.trip_in, 0)
    sbf = spherical_basis(d_in, cos_t, cfg.n_spherical, cfg.n_radial,
                          cfg.cutoff, cfg.envelope_p)
    sbf = jnp.where(g.trip_mask[:, None], sbf, 0.0).astype(cfg.dtype)

    # embedding block: m_ji = σ(W [h_j ‖ h_i ‖ rbf_emb])
    m = act(
        jnp.concatenate(
            [jnp.take(h, g.edge_src, 0), jnp.take(h, g.edge_dst, 0),
             act(rbf @ params["rbf_embed"])], axis=-1,
        ) @ params["msg_embed"]
    )  # [E, d]
    m = jnp.where(g.edge_mask[:, None], m, 0.0)

    def block(m, bp):
        # directional aggregation over triplets
        m_kj = jnp.take(act(m @ bp["w_src"]), g.trip_in, 0)  # [T, d]
        sbf_emb = sbf @ bp["w_sbf"]  # [T, n_bilinear]
        inter = jnp.einsum("td,bdf->tbf", m_kj, bp["w_bil"])  # [T, B, d]
        inter = jnp.einsum("tbf,tb->tf", inter, sbf_emb)  # [T, d]
        inter = jnp.where(g.trip_mask[:, None], inter, 0.0)
        agg = jax.ops.segment_sum(inter, g.trip_out, num_segments=e)
        m2 = act(m @ bp["w_msg"]) + agg
        m2 = m2 + act(act(m2 @ bp["w_res1"]) @ bp["w_res2"])  # residual MLP
        m2 = jnp.where(g.edge_mask[:, None], m2, 0.0)
        # output block: per-node contribution
        t_i = jax.ops.segment_sum(
            m2 * (rbf @ bp["w_rbf_out"]), g.edge_dst, num_segments=n
        )
        return m2, act(t_i @ bp["w_out"])

    def body(carry, bp):
        m, acc = carry
        m, contrib = block(m, bp)
        return (m, acc + contrib), None

    acc0 = jnp.zeros((n, cfg.d_hidden), cfg.dtype)
    (_, node_repr), _ = jax.lax.scan(
        body, (m, acc0), params["blocks"],
        unroll=cfg.n_blocks if cfg.scan_unroll else 1,
    )

    out = node_repr @ params["out_proj"]  # [N, d_out]
    if cfg.readout == "graph":
        out = jax.ops.segment_sum(out, g.graph_id, num_segments=g.n_graphs)
    return out


def loss_fn(params, cfg: DimeNetConfig, g: GraphBatch, labels: Array,
            label_mask: Optional[Array] = None) -> Tuple[Array, Dict]:
    """Node tasks: masked softmax CE. Graph tasks: MSE regression."""
    out = forward(params, cfg, g)
    if cfg.readout == "graph":
        err = (out[..., 0] - labels.astype(jnp.float32)) ** 2
        return jnp.mean(err), {"mse": jnp.mean(err)}
    logits = out.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[:, None], axis=-1
    )[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    if label_mask is not None:
        mask = mask * label_mask.astype(jnp.float32)
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"n_labeled": jnp.sum(mask)}
