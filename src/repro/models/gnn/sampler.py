"""Layered fanout neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

Host-side numpy: samples a K-hop neighborhood with per-hop fanouts from a CSR
adjacency, remaps to compact local ids, pads to static shapes, and emits the
triplet lists DimeNet's directional aggregation needs.  The jitted train step
only ever sees fixed-shape GraphBatch arrays — the sampler is the ragged→
static boundary of the system.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    node_feat: np.ndarray  # [N, d]
    positions: np.ndarray  # [N, 3]
    labels: np.ndarray  # [N]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1


def random_graph(rng: np.random.Generator, n_nodes: int, avg_degree: int,
                 d_feat: int, n_classes: int = 8) -> CSRGraph:
    """Synthetic power-law-ish graph for tests/benchmarks."""
    deg = np.minimum(
        rng.zipf(1.7, n_nodes) + avg_degree // 2, avg_degree * 8
    )
    deg = np.minimum(deg, n_nodes - 1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, indptr[-1]).astype(np.int32)
    return CSRGraph(
        indptr=indptr,
        indices=indices,
        node_feat=rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        positions=rng.standard_normal((n_nodes, 3)).astype(np.float32),
        labels=rng.integers(0, n_classes, n_nodes).astype(np.int32),
    )


def sample_subgraph(
    rng: np.random.Generator,
    g: CSRGraph,
    seed_nodes: np.ndarray,
    fanouts: Sequence[int],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (nodes [M], edge_src, edge_dst) in LOCAL ids; nodes[0:len(seed)]
    are the seeds.  Edges point hop-(h+1) → hop-h (message flow to seeds)."""
    local = {int(v): i for i, v in enumerate(seed_nodes)}
    nodes = list(int(v) for v in seed_nodes)
    frontier = list(int(v) for v in seed_nodes)
    esrc, edst = [], []
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            if hi <= lo:
                continue
            nbrs = g.indices[lo:hi]
            take = min(f, len(nbrs))
            chosen = rng.choice(nbrs, take, replace=False)
            for u in chosen:
                u = int(u)
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                esrc.append(local[u])
                edst.append(local[v])
        frontier = nxt
    return (
        np.asarray(nodes, np.int32),
        np.asarray(esrc, np.int32),
        np.asarray(edst, np.int32),
    )


def build_triplets(
    edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int,
    max_per_edge: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """(trip_in, trip_out): for each edge e=(j→i), up to ``max_per_edge``
    incoming edges (k→j), k≠i."""
    in_edges = [[] for _ in range(n_nodes)]
    for eid, dst in enumerate(edge_dst):
        in_edges[int(dst)].append(eid)
    t_in, t_out = [], []
    for eid in range(len(edge_src)):
        j, i = int(edge_src[eid]), int(edge_dst[eid])
        cnt = 0
        for kj in in_edges[j]:
            if int(edge_src[kj]) == i:
                continue  # exclude the back-edge k == i
            t_in.append(kj)
            t_out.append(eid)
            cnt += 1
            if cnt >= max_per_edge:
                break
    return np.asarray(t_in, np.int32), np.asarray(t_out, np.int32)


def pad_to(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    if len(x) >= n:
        return x[:n]
    pad = np.full((n - len(x),) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], 0)


def make_graph_batch_arrays(
    g: CSRGraph,
    nodes: np.ndarray,
    edge_src: np.ndarray,
    edge_dst: np.ndarray,
    *,
    n_pad: int,
    e_pad: int,
    t_pad: int,
    max_trip_per_edge: int = 16,
):
    """Pads a sampled subgraph into the static GraphBatch arrays (numpy)."""
    t_in, t_out = build_triplets(
        edge_src, edge_dst, len(nodes), max_trip_per_edge
    )
    ne, nt = len(edge_src), len(t_in)
    return dict(
        node_feat=pad_to(g.node_feat[nodes], n_pad),
        positions=pad_to(g.positions[nodes], n_pad),
        edge_src=pad_to(edge_src, e_pad),
        edge_dst=pad_to(edge_dst, e_pad),
        edge_mask=pad_to(np.ones(ne, bool), e_pad, False),
        trip_in=pad_to(t_in, t_pad),
        trip_out=pad_to(t_out, t_pad),
        trip_mask=pad_to(np.ones(nt, bool), t_pad, False),
        labels=pad_to(g.labels[nodes], n_pad, -1),
        graph_id=np.zeros(n_pad, np.int32),
    )
