"""Serving paths: prefill, KV caches, single-token decode.

Cache layouts (chosen for pod-scale decode, DESIGN §4):

  * uniform mode (no sliding windows — deepseek/chatglm): caches stacked per
    layer ``[L, B, S, ...]``; decode scans layers with the cache threaded as
    scan xs→ys.  The KV-length axis S is sharded over the ``model`` mesh axis
    (sequence-parallel decode) — plain einsum+softmax lets XLA SPMD turn the
    S-reductions into all-reduces.
  * gemma mode (window + global_every): layers are processed in *rounds* of
    (G−1 local + 1 global).  Local layers keep **ring buffers of length W**
    (window) — for long_500k this is the sub-quadratic memory story: 52 of 62
    layers hold 1024 positions instead of 524 288.

  * MLA decode uses the absorbed formulation: scores are taken directly
    against the latent cache (``q̃ = q_nope·W_uk``), and the attention output
    is computed in latent space then expanded through ``W_uv`` — the cache
    stays [S, kv_lora + rope] wide (576 for deepseek-v3) instead of
    [S, H·(dh+dv)] (32 768 wide): a 57× cache-bandwidth saving at decode.

The decode step assumes a shared scalar position (synchronous batch decode,
the standard throughput-benchmark setting); continuous batching would carry
per-sequence positions and a paged cache — out of scope, noted in DESIGN.md.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import TransformerConfig
from repro.models.layers import apply_rope, rms_norm, rope_tables, gated_mlp
from repro.models.transformer import _moe_ffn, logits_from_hidden

Array = jax.Array


# ------------------------------------------------------------ utilities ---
def _rope_at(pos: Array, dim: int, theta: float) -> Tuple[Array, Array]:
    """(sin, cos) [1, dim/2] at a scalar position (broadcasts over batch)."""
    return rope_tables(pos[None], dim, theta)


def _ring_positions(pos: Array, w: int) -> Tuple[Array, Array]:
    """True positions stored in each ring slot + validity, at write-time pos."""
    slots = jnp.arange(w)
    delta = jnp.mod(pos - slots, w)
    k_pos = pos - delta
    return k_pos, k_pos >= 0


def _attend_cache(q, k_cache, v_cache, k_pos, valid, scale):
    """q [B,1,H,dh] vs cache [B,S,Hkv,dh(v)] with explicit key positions."""
    b, _, h, dh = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    logits = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache.astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgj,bjkd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, -1)


# ----------------------------------------------------- per-layer decodes ---
def _gqa_decode(x, p, cfg: TransformerConfig, kc, vc, pos, theta,
                ring_w: int = 0):
    """x [B,1,D]; kc/vc [B,S,Hkv,dh]. Returns (out, kc', vc')."""
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, h, dh)
    k = k.reshape(b, 1, hkv, dh)
    v = v.reshape(b, 1, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    rd = int(dh * cfg.rotary_pct)
    sin, cos = _rope_at(pos, rd, theta)
    q = apply_rope(q, sin, cos, rd)
    k = apply_rope(k, sin, cos, rd)

    if ring_w:
        slot = jnp.mod(pos, ring_w)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, slot, 0, 0))
        k_pos, valid = _ring_positions(pos, ring_w)
    else:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, pos, 0, 0))
        k_pos = jnp.arange(kc.shape[1])
        valid = k_pos <= pos
    out = _attend_cache(q, kc, vc, k_pos, valid, dh ** -0.5)
    out = out.reshape(b, 1, h * dh).astype(x.dtype) @ p["wo"]
    return out, kc, vc


def _mla_decode(x, p, cfg: TransformerConfig, ckv_c, kr_c, pos, theta):
    """Absorbed MLA decode. ckv_c [B,S,kvr], kr_c [B,S,rope]."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim

    cq = rms_norm(x @ p["wq_a"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, 1, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    sin, cos = _rope_at(pos, m.qk_rope_dim, theta)
    q_rope = apply_rope(q_rope, sin, cos)

    ckv_full = x @ p["wkv_a"]  # [B,1,kvr+rope]
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(
        ckv_full[..., m.kv_lora_rank :][:, :, None, :], sin, cos
    )[:, :, 0, :]
    ckv_c = jax.lax.dynamic_update_slice(
        ckv_c, c_kv.astype(ckv_c.dtype), (0, pos, 0)
    )
    kr_c = jax.lax.dynamic_update_slice(
        kr_c, k_rope.astype(kr_c.dtype), (0, pos, 0)
    )

    # absorbed scores: q̃_h = W_uk,hᵀ q_nope  →  [B,H,kvr]
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    logits = (
        jnp.einsum("bhr,bsr->bhs", q_abs, ckv_c.astype(jnp.float32))
        + jnp.einsum("bhe,bse->bhs", q_rope[:, 0].astype(jnp.float32),
                     kr_c.astype(jnp.float32))
    ) * (qk ** -0.5)
    valid = jnp.arange(ckv_c.shape[1]) <= pos
    logits = jnp.where(valid[None, None, :], logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    pr = jnp.exp(logits - mx)
    pr = pr / jnp.maximum(jnp.sum(pr, -1, keepdims=True), 1e-30)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, ckv_c.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", o_lat, p["wv_b"].astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, ckv_c, kr_c


def _block_decode(h, blk, cfg: TransformerConfig, cache, pos, theta,
                  is_moe, ring_w, mesh, dp_axes):
    attn_in = rms_norm(h, blk["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, c0, c1 = _mla_decode(attn_in, blk["attn"], cfg, cache[0],
                                       cache[1], pos, theta)
    else:
        attn_out, c0, c1 = _gqa_decode(attn_in, blk["attn"], cfg, cache[0],
                                       cache[1], pos, theta, ring_w)
    if cfg.sandwich_norm:
        attn_out = rms_norm(attn_out, blk["ln1_post"], cfg.norm_eps)
    h = h + attn_out
    mlp_in = rms_norm(h, blk["ln2"], cfg.norm_eps)
    if is_moe:
        mlp_out, _, _ = _moe_ffn(mlp_in, blk["moe"], cfg, mesh, dp_axes)
    else:
        mlp_out = gated_mlp(mlp_in, blk["mlp"]["wg"], blk["mlp"]["wu"],
                            blk["mlp"]["wd"], cfg.act)
    if cfg.sandwich_norm:
        mlp_out = rms_norm(mlp_out, blk["ln2_post"], cfg.norm_eps)
    return h + mlp_out, (c0, c1)


# -------------------------------------------------------- cache factory ---
def cache_spec(cfg: TransformerConfig, batch: int, s_max: int
               ) -> Dict[str, Any]:
    """Shapes/dtypes of the decode cache (ShapeDtypeStructs for the dry-run,
    zeros for runtime via init_cache)."""
    dt = cfg.dtype
    m = cfg.mla

    def kv_shapes(n, s):
        if m is not None:
            return (
                jax.ShapeDtypeStruct((n, batch, s, m.kv_lora_rank), dt),
                jax.ShapeDtypeStruct((n, batch, s, m.qk_rope_dim), dt),
            )
        return (
            jax.ShapeDtypeStruct((n, batch, s, cfg.n_kv_heads, cfg.d_head), dt),
            jax.ShapeDtypeStruct((n, batch, s, cfg.n_kv_heads, cfg.d_head), dt),
        )

    if not cfg.sub_quadratic:
        spec: Dict[str, Any] = {}
        if cfg.n_dense_layers:
            spec["dense"] = kv_shapes(cfg.n_dense_layers, s_max)
        if cfg.n_moe_layers:
            spec["moe"] = kv_shapes(cfg.n_moe_layers, s_max)
        return spec

    g = cfg.global_every
    n_rounds = cfg.n_layers // g
    n_tail = cfg.n_layers - n_rounds * g  # trailing local layers
    w = min(cfg.window, s_max)
    spec = {
        "local": kv_shapes(n_rounds * (g - 1), w),
        "global": kv_shapes(n_rounds, s_max),
    }
    if n_tail:
        spec["tail"] = kv_shapes(n_tail, w)
    return spec


def init_cache(cfg: TransformerConfig, batch: int, s_max: int
               ) -> Dict[str, Any]:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_spec(cfg, batch, s_max),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def decode_layout(params: Dict[str, Any], cfg: TransformerConfig
                  ) -> Dict[str, Any]:
    """Re-lays stacked block params for decode.

    Uniform archs: identity.  Gemma mode: blocks [L, ...] →
    {"local": [R·(G−1), ...], "global": [R, ...], "tail": [L_rem, ...]}
    in round-execution order.  A one-time host-side copy at server start —
    never part of the lowered per-token step.
    """
    if not cfg.sub_quadratic:
        return params
    g = cfg.global_every
    n_rounds = cfg.n_layers // g
    local_idx = np.asarray(
        [r * g + j for r in range(n_rounds) for j in range(g - 1)]
    )
    global_idx = np.asarray([r * g + (g - 1) for r in range(n_rounds)])
    tail_idx = np.arange(n_rounds * g, cfg.n_layers)
    blocks = params["blocks"]
    take = lambda idx: jax.tree.map(lambda x: jnp.take(x, idx, axis=0), blocks)
    out = dict(params)
    out["blocks_local"] = take(local_idx)
    out["blocks_global"] = take(global_idx)
    if len(tail_idx):
        out["blocks_tail"] = take(tail_idx)
    del out["blocks"]
    return out


# ------------------------------------------------------------ the steps ---
def decode_step(
    params: Dict[str, Any],
    cfg: TransformerConfig,
    cache: Dict[str, Any],
    tokens: Array,  # [B] int32
    pos: Array,  # scalar int32 — current write position
    *,
    mesh=None,
    dp_axes: Tuple[str, ...] = ("data",),
) -> Tuple[Array, Dict[str, Any]]:
    """One token for the whole batch. Returns (logits [B, V], cache')."""
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :]  # [B,1,D]
    if cfg.scale_embed:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    theta_l = cfg.rope_theta
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    new_cache: Dict[str, Any] = {}

    def scan_uniform(h, stack, cache_pair, is_moe, theta):
        def body(hc, xs):
            blk, c0, c1 = xs
            h2, (n0, n1) = _block_decode(hc, blk, cfg, (c0, c1), pos, theta,
                                         is_moe, 0, mesh, dp_axes)
            return h2, (n0, n1)

        return jax.lax.scan(body, h, (stack, *cache_pair))

    if not cfg.sub_quadratic:
        if cfg.n_dense_layers:
            h, new_cache["dense"] = scan_uniform(
                h, params["blocks"], cache["dense"], False, theta_l
            )
        if cfg.n_moe_layers:
            h, new_cache["moe"] = scan_uniform(
                h, params["moe_blocks"], cache["moe"], True, theta_l
            )
    else:
        g = cfg.global_every
        n_rounds = cfg.n_layers // g
        gm1 = g - 1
        loc_stack = jax.tree.map(
            lambda x: x.reshape((n_rounds, gm1) + x.shape[1:]),
            params["blocks_local"],
        )
        loc_cache = jax.tree.map(
            lambda x: x.reshape((n_rounds, gm1) + x.shape[1:]),
            cache["local"],
        )

        def round_body(hc, xs):
            lblk, lc0, lc1, gblk, gc0, gc1 = xs

            def local_body(hh, ys):
                blk, c0, c1 = ys
                h2, (n0, n1) = _block_decode(
                    hh, blk, cfg, (c0, c1), pos, theta_l, False,
                    cfg.window, mesh, dp_axes,
                )
                return h2, (n0, n1)

            hc, (nl0, nl1) = jax.lax.scan(local_body, hc, (lblk, lc0, lc1))
            hc, (ng0, ng1) = _block_decode(
                hc, gblk, cfg, (gc0, gc1), pos, theta_g, False, 0,
                mesh, dp_axes,
            )
            return hc, (nl0, nl1, ng0, ng1)

        h, (nl0, nl1, ng0, ng1) = jax.lax.scan(
            round_body, h,
            (loc_stack, *loc_cache, params["blocks_global"],
             *cache["global"]),
        )
        new_cache["local"] = tuple(
            x.reshape((n_rounds * gm1,) + x.shape[2:]) for x in (nl0, nl1)
        )
        new_cache["global"] = (ng0, ng1)
        if "blocks_tail" in params:
            def tail_body(hh, ys):
                blk, c0, c1 = ys
                h2, (n0, n1) = _block_decode(
                    hh, blk, cfg, (c0, c1), pos, theta_l, False,
                    cfg.window, mesh, dp_axes,
                )
                return h2, (n0, n1)

            h, new_cache["tail"] = jax.lax.scan(
                tail_body, h, (params["blocks_tail"], *cache["tail"])
            )

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, h)[:, 0, :]  # [B, V]
    return logits, new_cache


def prefill(
    params: Dict[str, Any],
    cfg: TransformerConfig,
    tokens: Array,  # [B, S]
    s_max: int,
    *,
    mesh=None,
    dp_axes: Tuple[str, ...] = ("data",),
) -> Tuple[Array, Dict[str, Any]]:
    """Full-prompt pass; returns (logits [B, S, V], decode cache @ s_max)."""
    from repro.models.transformer import forward

    b, s = tokens.shape
    h, aux = forward(params, cfg, tokens, mesh=mesh, dp_axes=dp_axes,
                     collect_kv=True)
    logits = logits_from_hidden(params, cfg, h)
    kv_stacks = aux["kv"]  # list per stack: (k|ckv [L,B,S,...], v|kr)

    def to_cache(pair, s_cache):
        def pad_or_ring(x):
            if s_cache >= s:  # linear cache, pad tail
                padding = [(0, 0)] * x.ndim
                padding[2] = (0, s_cache - s)
                return jnp.pad(x, padding)
            # ring: keep the last s_cache positions at slot p % W
            w = s_cache
            keep = x[:, :, s - w :]
            slots = jnp.mod(jnp.arange(s - w, s), w)
            out = jnp.zeros(x.shape[:2] + (w,) + x.shape[3:], x.dtype)
            return out.at[:, :, slots].set(keep)

        return tuple(pad_or_ring(x) for x in pair)

    cache: Dict[str, Any] = {}
    if not cfg.sub_quadratic:
        i = 0
        if cfg.n_dense_layers:
            cache["dense"] = to_cache(kv_stacks[i], s_max)
            i += 1
        if cfg.n_moe_layers:
            cache["moe"] = to_cache(kv_stacks[i], s_max)
    else:
        g = cfg.window  # ring length
        pair = kv_stacks[0]  # single dense stack [L, ...]
        gi = cfg.global_every
        n_rounds = cfg.n_layers // gi
        local_idx = np.asarray(
            [r * gi + j for r in range(n_rounds) for j in range(gi - 1)]
        )
        global_idx = np.asarray([r * gi + (gi - 1) for r in range(n_rounds)])
        tail_idx = np.arange(n_rounds * gi, cfg.n_layers)
        pick = lambda idx: tuple(jnp.take(x, idx, axis=0) for x in pair)
        cache["local"] = to_cache(pick(local_idx), min(g, s_max))
        cache["global"] = to_cache(pick(global_idx), s_max)
        if len(tail_idx):
            cache["tail"] = to_cache(pick(tail_idx), min(g, s_max))
    return logits, cache


def greedy_generate(params, cfg, prompt: Array, n_new: int, s_max: int,
                    *, mesh=None) -> Array:
    """Reference sampler for tests/examples (prefill + greedy decode loop)."""
    b, s = prompt.shape
    dparams = decode_layout(params, cfg)
    logits, cache = prefill(params, cfg, prompt, s_max, mesh=mesh)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    outs = [tok]
    step = jax.jit(
        functools.partial(decode_step, cfg=cfg, mesh=mesh)
    ) if mesh is None else functools.partial(decode_step, cfg=cfg, mesh=mesh)
    for i in range(n_new - 1):
        logits_i, cache = step(dparams, cache=cache, tokens=tok,
                               pos=jnp.int32(s + i))
        tok = jnp.argmax(logits_i, -1).astype(jnp.int32)
        outs.append(tok)
    return jnp.stack(outs, axis=1)  # [B, n_new]
