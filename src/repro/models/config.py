"""Model configuration dataclasses shared by the LM family."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    n_shared: int
    d_ff_expert: int
    first_dense_layers: int = 0  # leading dense layers (DeepSeek: 1 or 3)
    d_ff_dense: int = 0  # d_ff of those leading dense layers
    capacity_factor: float = 1.25
    router_score: str = "softmax"  # "softmax" | "sigmoid_norm" (DeepSeek-V3)
    aux_loss_coef: float = 0.001
    # DeepSeek-V3 aux-loss-free balancing keeps a per-expert bias added to
    # routing scores (updated out-of-band by the trainer, not by grads).
    use_routing_bias: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None  # gemma3: 1e6 on global layers
    rotary_pct: float = 1.0  # chatglm3: 0.5 ("RoPE 2d" partial rotary)
    window: int = 0  # sliding window width for local layers (0 = none)
    global_every: int = 0  # every Nth layer is global (gemma3: 6 → 5:1)
    act: str = "silu"
    qk_norm: bool = False  # gemma3
    sandwich_norm: bool = False  # gemma3: post-attn/post-ffn norms too
    scale_embed: bool = False  # gemma: embed × sqrt(d_model)
    qkv_bias: bool = False  # chatglm3
    mtp_loss_weight: float = 0.1
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mtp_depth: int = 0  # DeepSeek-V3 multi-token prediction modules
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_block_k: int = 1024
    logit_softcap: float = 0.0
    # Dry-run cost-variant: fully unroll layer scans so XLA cost_analysis
    # counts every layer (while-loop bodies are otherwise counted once).
    scan_unroll: bool = False
    # Parallelism plan (overridden per lowering, e.g. decode drops FSDP and
    # widens EP so 671B weights fit without per-step regathers).
    fsdp_axis: Optional[str] = "data"
    moe_ep_axes: Tuple[str, ...] = ("model",)
    # §Perf: "scatter" replaces the MoE output psum over `model` with a
    # reduce-scatter straight into the sequence-parallel layout — halves the
    # combine traffic AND deletes the next block's re-scatter.
    moe_combine: str = "psum"  # "psum" | "scatter"

    # ----- derived -----
    @property
    def n_dense_layers(self) -> int:
        return self.moe.first_dense_layers if self.moe else self.n_layers

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.moe else 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch has a bounded-window component (long_500k eligible)."""
        return self.window > 0 and self.global_every > 1

    def window_pattern(self) -> np.ndarray:
        """[n_layers] int32 — per-layer window (0 = global/full attention)."""
        w = np.zeros(self.n_layers, np.int32)
        if self.window > 0:
            w[:] = self.window
            if self.global_every > 0:
                # every global_every-th layer is global (gemma3: layers
                # 5, 11, ... full attention; 5 local before each)
                w[self.global_every - 1 :: self.global_every] = 0
            else:
                w[:] = self.window
        return w

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, dh = self.d_model, self.d_head
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
        dense_mlp = 3 * d * (self.moe.d_ff_dense if self.moe else self.d_ff)
        total = self.n_dense_layers * (attn + dense_mlp)
        if self.moe:
            e = self.moe
            expert = 3 * d * e.d_ff_expert
            per_moe = attn + e.n_routed * expert + e.n_shared * expert \
                + d * e.n_routed
            total += self.n_moe_layers * per_moe
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        e = self.moe
        expert = 3 * d * e.d_ff_expert
        full = self.n_params()
        inactive = self.n_moe_layers * (e.n_routed - e.top_k) * expert
        return full - inactive


def scaled_down(cfg: TransformerConfig, **overrides) -> TransformerConfig:
    """Smoke-test reduction: same family/topology, tiny dims."""
    small = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_head=32,
        d_ff=256,
        vocab_size=512,
        dtype=jnp.float32,
        remat=False,
        attn_block_k=64,
    )
    if cfg.window:
        small["window"] = 16
    if cfg.moe:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            n_routed=8,
            top_k=min(cfg.moe.top_k, 2),
            n_shared=min(cfg.moe.n_shared, 1),
            d_ff_expert=64,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_ff_dense=256,
            # generous capacity: batch-independent routing makes smoke tests
            # (prefill == forward) deterministic; full configs keep 1.25
            capacity_factor=8.0,
        )
    if cfg.mla:
        small["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
            v_head_dim=32,
        )
        small["d_head"] = 48  # nope+rope
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
