"""Model zoo: LM family, GNN, recsys (see configs/)."""
