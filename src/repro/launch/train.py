"""Training launcher: ``--arch <id>`` selects any assigned architecture at
its smoke scale (CPU container) or full scale (TPU pod, with the production
mesh and the dry-run's shardings).

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch dimenet --steps 10
    PYTHONPATH=src python -m repro.launch.train --arch din --steps 20
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp


def lm_runner(arch: str, args):
    from repro.launch.specs import LM_ARCHS
    from repro.models.transformer import init_params, lm_loss
    from repro.data import lm_batch
    from repro.train.train_loop import Trainer, TrainLoopConfig
    from repro.data import ShardedFeeder

    cfg = LM_ARCHS[arch].smoke_config() if args.smoke else \
        LM_ARCHS[arch].config()
    params = init_params(jax.random.key(args.seed), cfg)

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch["tokens"], batch["labels"])

    tl = TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, lr=args.lr,
                         warmup=max(2, args.steps // 10),
                         log_every=max(1, args.steps // 10))
    trainer = Trainer(loss_fn, params, tl)
    feeder = ShardedFeeder(
        lambda s, i: lm_batch(s, i, args.batch, args.seq, cfg.vocab_size),
        seed=args.seed,
    )
    hist = trainer.run(feeder)
    feeder.close()
    return hist


def gnn_runner(arch: str, args):
    from repro.configs import dimenet as dimenet_cfg
    from repro.models.gnn import GraphBatch, init_params, loss_fn
    from repro.models.gnn.sampler import (
        make_graph_batch_arrays, random_graph, sample_subgraph,
    )
    from repro.train.train_loop import Trainer, TrainLoopConfig

    cfg = dimenet_cfg.smoke_config()
    rng = np.random.default_rng(args.seed)
    g = random_graph(rng, 2000, 8, cfg.d_feat, cfg.d_out)
    params = init_params(jax.random.key(args.seed), cfg)
    n_pad, e_pad, t_pad = 2048, 4096, 16384

    def gen(seed, step):
        r = np.random.default_rng((seed, step))
        seeds = r.choice(g.n_nodes, 64, replace=False).astype(np.int32)
        nodes, esrc, edst = sample_subgraph(r, g, seeds, [6, 4])
        return make_graph_batch_arrays(
            g, nodes, esrc, edst, n_pad=n_pad, e_pad=e_pad, t_pad=t_pad,
        )

    def loss_wrap(p, arrs):
        batch = GraphBatch(
            node_feat=arrs["node_feat"], positions=arrs["positions"],
            edge_src=arrs["edge_src"], edge_dst=arrs["edge_dst"],
            edge_mask=arrs["edge_mask"], trip_in=arrs["trip_in"],
            trip_out=arrs["trip_out"], trip_mask=arrs["trip_mask"],
            graph_id=arrs["graph_id"], n_graphs=1,
        )
        return loss_fn(p, cfg, batch, arrs["labels"])

    from repro.data import ShardedFeeder

    tl = TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, lr=args.lr,
                         log_every=max(1, args.steps // 10))
    trainer = Trainer(loss_wrap, params, tl)
    feeder = ShardedFeeder(gen, seed=args.seed)
    hist = trainer.run(feeder)
    feeder.close()
    return hist


def recsys_runner(arch: str, args):
    from repro.launch.specs import RECSYS_ARCHS
    from repro.models.recsys import RecsysBatch, init_params, loss_fn
    from repro.data import ShardedFeeder, recsys_batch
    from repro.train.train_loop import Trainer, TrainLoopConfig

    cfg = RECSYS_ARCHS[arch].smoke_config() if args.smoke else \
        RECSYS_ARCHS[arch].config()
    params = init_params(jax.random.key(args.seed), cfg)

    def gen(seed, step):
        return recsys_batch(seed, step, args.batch, cfg.seq_len,
                            cfg.n_dense, cfg.n_sparse, cfg.vocab_items,
                            cfg.vocab_sparse)

    def loss_wrap(p, b):
        batch = RecsysBatch(
            dense=b["dense"], sparse=b["sparse"], hist=b["hist"],
            target=b["target"], label=b["label"],
        )
        return loss_fn(p, cfg, batch)

    tl = TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, lr=args.lr,
                         log_every=max(1, args.steps // 10))
    trainer = Trainer(loss_wrap, params, tl)
    feeder = ShardedFeeder(gen, seed=args.seed)
    hist = trainer.run(feeder)
    feeder.close()
    return hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=1000)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    from repro.launch.specs import LM_ARCHS, RECSYS_ARCHS

    if args.arch in LM_ARCHS:
        hist = lm_runner(args.arch, args)
    elif args.arch == "dimenet":
        hist = gnn_runner(args.arch, args)
    elif args.arch in RECSYS_ARCHS:
        hist = recsys_runner(args.arch, args)
    else:
        raise SystemExit(f"unknown arch {args.arch}")
    print(f"final loss {hist['loss'][-1]:.4f} after {len(hist['loss'])} steps")


if __name__ == "__main__":
    main()
