"""Production mesh factory (DESIGN §4).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the "pod"
axis carries data parallelism across the slower inter-pod links (DCN);
"model" carries TP/EP over fast intra-pod ICI.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the fake device count before first use).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before any jax import"
        )
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
