import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable (e)).

For every assigned (architecture × input-shape) cell and the paper's own
search step, on BOTH production meshes (single-pod 16×16 and multi-pod
2×16×16):

    with mesh:
        lowered  = jax.jit(step, ...).lower(*abstract_args)
        compiled = lowered.compile()
        memory_analysis() / cost_analysis() / collective parse

Two variants per cell (see launch/specs.py): ``exec`` (scanned — the memory
proof) and ``cost`` (unrolled — exact FLOPs/bytes/collective counts).
Results are cached as JSON per (cell × mesh × variant) under
``results/dryrun/`` so reruns only compile what changed.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b
    PYTHONPATH=src python -m repro.launch.dryrun --arch paper-ivf --list
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import compat

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
RESULTS_DIR = os.path.abspath(RESULTS_DIR)

# matches e.g. `%ag.5 = f32[16,1024,100]{2,1,0} all-gather(%x), ...`
COLLECTIVE_RE = re.compile(
    r"=\s*(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1}


def _group_size(line: str) -> int:
    m = GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[n_groups,group_size]<=[N]
        return int(m.group(2))
    return 1


def parse_collectives(hlo_text: str, loop_trip_counts=None):
    """Sums PER-DEVICE link bytes of collective ops in post-SPMD HLO.

    Per-op traffic model (ring algorithms, within ~2× of exact):
      all-gather / all-to-all / collective-permute → result bytes,
      all-reduce → 2 × result bytes,
      reduce-scatter → result bytes × group size (the pre-scatter input).

    Ops inside while bodies appear once in the text; the cost variant is
    fully unrolled so its sums are exact.  For the exec variant we also
    report a loop-corrected estimate using the known scan trip counts.
    """
    per_kind = {}
    total = 0
    in_loop_total = 0
    current_comp_is_loop = False
    for line in hlo_text.splitlines():
        if line.startswith("%") or line.startswith("ENTRY"):
            name = line.split(" ", 1)[0]
            current_comp_is_loop = ("while" in name or "body" in name
                                    or "cond" in name)
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        nbytes = size * DTYPE_BYTES[dt]
        if kind == "all-reduce":
            nbytes *= 2
        elif kind == "reduce-scatter":
            nbytes *= _group_size(line)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        total += nbytes
        if current_comp_is_loop:
            in_loop_total += nbytes
    max_trip = max(loop_trip_counts.values()) if loop_trip_counts else 1
    corrected = total + in_loop_total * max(0, max_trip - 1)
    return dict(per_kind=per_kind, total_bytes=total,
                in_loop_bytes=in_loop_total,
                loop_corrected_bytes=corrected)


def _compile_cell(cell, mesh, trip_counts):
    from repro.launch.mesh import n_chips

    t0 = time.time()
    with compat.use_mesh(mesh):
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    coll = parse_collectives(hlo, trip_counts)
    return dict(
        chips=n_chips(mesh),
        lower_s=round(t_lower - t0, 2),
        compile_s=round(t_compile - t_lower, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
        ),
        flops=cost.get("flops", 0.0),
        bytes_accessed=cost.get("bytes accessed", 0.0),
        collectives=coll,
    )


def run_cell(arch: str, shape: str, multi_pod: bool, variant: str,
             force: bool = False):
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import LM_ARCHS, build_cell, lm_probe_plan

    mesh_tag = "multipod512" if multi_pod else "pod256"
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}__{variant}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = time.time()
    record = dict(arch=arch, shape=shape, mesh=mesh_tag, variant=variant)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if variant == "cost" and arch in LM_ARCHS:
            # Fully unrolling a 61-layer 512-way module is a multi-hour
            # compile; reported cost is LINEAR in layer counts (while bodies
            # once + per-layer elementwise param ops), so a few small
            # unrolled probes solve for exact full-depth totals.
            probes, solve = lm_probe_plan(arch, shape)
            results = []
            for p in probes:
                cell = build_cell(arch, shape, mesh, "cost", layers=p)
                results.append(
                    _compile_cell(cell, mesh, cell.meta["loop_trip_counts"])
                )
            full = build_cell(arch, shape, mesh, "exec")  # meta only
            pick = lambda key, sub=None: [
                (r[key][sub] if sub else r[key]) for r in results
            ]
            flops = solve(*pick("flops"))
            nbytes = solve(*pick("bytes_accessed"))
            coll_total = solve(
                *[r["collectives"]["total_bytes"] for r in results]
            )
            record.update(
                ok=True,
                chips=results[0]["chips"],
                compile_s=sum(r["compile_s"] for r in results),
                memory=results[-1]["memory"],  # probe memory; exec is truth
                flops=float(flops),
                bytes_accessed=float(nbytes),
                collectives=dict(
                    per_kind={}, total_bytes=float(max(coll_total, 0.0)),
                    in_loop_bytes=0,
                    loop_corrected_bytes=float(max(coll_total, 0.0)),
                ),
                synthesized_from_probes=[list(p) for p in probes],
                probe_results=[
                    dict(flops=r["flops"], bytes=r["bytes_accessed"],
                         coll=r["collectives"]["total_bytes"])
                    for r in results
                ],
                meta={k: v for k, v in full.meta.items()
                      if isinstance(v, (int, float, str, dict, list, tuple))},
            )
        else:
            cell = build_cell(arch, shape, mesh, variant)
            res = _compile_cell(cell, mesh, cell.meta.get("loop_trip_counts"))
            record.update(
                ok=True,
                meta={k: v for k, v in cell.meta.items()
                      if isinstance(v, (int, float, str, dict, list, tuple))},
                **res,
            )
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    status = "OK " if record.get("ok") else "FAIL"
    print(f"[{status}] {arch} × {shape} × {mesh_tag} × {variant} "
          f"({time.time()-t0:.0f}s)", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod256", "multipod512", "both"],
                    default="both")
    ap.add_argument("--variant", choices=["exec", "cost", "both"],
                    default="both")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.specs import list_cells

    cells = list_cells()
    if args.list:
        for a, s, skip in cells:
            print(f"{a:20s} {s:15s} {'SKIP: ' + skip if skip else ''}")
        return

    meshes = {"pod256": [False], "multipod512": [True],
              "both": [False, True]}[args.mesh]
    variants = {"exec": ["exec"], "cost": ["cost"],
                "both": ["exec", "cost"]}[args.variant]

    n_ok = n_fail = n_skip = 0
    for arch, shape, skip in cells:
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        if skip:
            n_skip += 1
            print(f"[SKIP] {arch} × {shape}: {skip}")
            continue
        for mp in meshes:
            for v in variants:
                rec = run_cell(arch, shape, mp, v, force=args.force)
                if rec.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed, "
          f"{n_skip} cells skipped (documented)")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
