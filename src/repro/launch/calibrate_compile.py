"""Compile-time calibration: lower+compile the heaviest cell
(deepseek-v3-671b × train_4k × 512-chip mesh), exec + cost variants.
Run:  PYTHONPATH=src python -m repro.launch.calibrate_compile [cost]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys  # noqa: E402
import time  # noqa: E402
t0 = time.time()

import dataclasses  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import deepseek_v3_671b  # noqa: E402
from repro.launch.mesh import make_production_mesh, dp_axes  # noqa: E402
from repro.models.transformer import init_params, lm_loss, param_pspecs  # noqa: E402
from repro import compat  # noqa: E402
from repro.train.optimizer import (  # noqa: E402
    OptimizerConfig, adafactor_state_pspecs, clip_by_global_norm,
    make_optimizer,
)


def main():
    cost_variant = "cost" in sys.argv[1:]
    cfg = deepseek_v3_671b.config()
    if cost_variant:
        cfg = dataclasses.replace(cfg, scan_unroll=True, attn_block_k=4096,
                                  remat=False)
    mesh = make_production_mesh(multi_pod=True)
    dp = dp_axes(mesh)
    print(f"mesh={mesh.shape} dp={dp} cost_variant={cost_variant} "
          f"import: {time.time()-t0:.1f}s")

    opt_cfg = OptimizerConfig(name="adafactor", lr=1e-4, weight_decay=0.0)
    opt_init, opt_update = make_optimizer(opt_cfg)

    def train_step(params, opt_state, tokens, labels):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels, mesh=mesh, dp_axes=dp),
            has_aux=True,
        )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_state = opt_update(grads, opt_state, params,
                                           jnp.float32(1e-4))
        return new_params, new_state, loss, gnorm

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))
    opt_shape = jax.eval_shape(opt_init, params_shape)
    pspecs = param_pspecs(cfg)
    opt_pspecs = adafactor_state_pspecs(pspecs, params_shape, opt_cfg)

    as_abs = lambda shapes, specs: jax.tree.map(
        lambda sh, spec: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, spec)
        ),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    params_abs = as_abs(params_shape, pspecs)
    opt_abs = as_abs(opt_shape, opt_pspecs)

    b, s = 256, 4096
    tok = jax.ShapeDtypeStruct(
        (b, s), jnp.int32, sharding=NamedSharding(mesh, P(dp, None))
    )

    t1 = time.time()
    jitted = jax.jit(train_step, donate_argnums=(0, 1))
    with compat.use_mesh(mesh):
        lowered = jitted.lower(params_abs, opt_abs, tok, tok)
        t2 = time.time()
        print(f"lower: {t2-t1:.1f}s")
        compiled = lowered.compile()
    t3 = time.time()
    print(f"compile: {t3-t2:.1f}s")
    mem = compiled.memory_analysis()
    gib = 1 << 30
    print(f"per-device: args {mem.argument_size_in_bytes/gib:.2f} GiB, "
          f"out {mem.output_size_in_bytes/gib:.2f} GiB, "
          f"temp {mem.temp_size_in_bytes/gib:.2f} GiB, "
          f"alias {mem.alias_size_in_bytes/gib:.2f} GiB")
    cost = compiled.cost_analysis()
    print("flops:", cost.get("flops"), "bytes:", cost.get("bytes accessed"))
    print(f"TOTAL {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
