"""Serving launcher: builds (or loads) a hybrid index and serves batched
filtered queries through the micro-batching server.

Two tiers:

  * ``--tier ram``  — the whole index lives in host/device memory.
  * ``--tier disk`` — only centroids + counts stay resident; flat lists page
    in from a layout-v2 checkpoint through the probe-driven cluster cache,
    capped by ``--resident-budget-mb`` (hot clusters are pinned).

    PYTHONPATH=src python -m repro.launch.serve --n 100000 --requests 128
    PYTHONPATH=src python -m repro.launch.serve --load <index_dir>
    PYTHONPATH=src python -m repro.launch.serve --load <index_dir> \\
        --tier disk --resident-budget-mb 64
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp


def _sample_queries(disk_index, max_clusters: int = 4) -> np.ndarray:
    """Demo query pool from a few paged-in clusters — O(clusters) memory,
    never the whole index."""
    rows = []
    for cid in range(min(max_clusters, disk_index.n_clusters)):
        rec = disk_index.reader.read(cid)
        live = rec["ids"] >= 0
        v = rec["vectors"][live].astype(np.float32)
        if disk_index.quantized:
            v = v * rec["scales"][live][:, None]
        rows.append(v)
    return np.concatenate(rows, 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--n-attrs", type=int, default=6)
    ap.add_argument("--clusters", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--probes", type=int, default=7)
    ap.add_argument("--load", default=None, help="index dir to restore")
    ap.add_argument("--save", default=None, help="index dir to persist")
    ap.add_argument("--tier", choices=("ram", "disk"), default="ram",
                    help="disk = page clusters from the checkpoint on demand")
    ap.add_argument("--resident-budget-mb", type=int, default=None,
                    help="disk tier: cap on resident bytes (centroids + "
                         "counts + summaries + cluster cache); default = "
                         "unbounded cache")
    ap.add_argument("--prune", choices=("auto", "on", "off"), default="auto",
                    help="filter-aware probe pruning from the resident "
                         "cluster attribute summaries (layout v2.1); "
                         "auto = prune when the index carries summaries")
    ap.add_argument("--t-max", default=None,
                    help="adaptive probe widening cap: refill pruned probes "
                         "from next-best unpruned centroids up to this rank "
                         "(an int, or 'auto' to pick the per-batch cap from "
                         "the summaries' expected passing mass)")
    ap.add_argument("--pipeline", choices=("auto", "on", "off"),
                    default="auto",
                    help="double-buffered executor: scan tile i while tile "
                         "i+1's clusters gather in the background (auto = "
                         "on for the disk tier).  Identical results; "
                         "improves throughput whenever fetches cost "
                         "anything, costs nothing when they don't")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="cluster gathers kept in flight ahead of the scan "
                         "(2 = classic double buffering; deeper overlaps "
                         "more IO at the cost of gathered-tile host memory)")
    ap.add_argument("--cache-shards", type=int, default=1,
                    help="disk tier: shard the cluster cache over this many "
                         "peer stores on a consistent-hash ring (one index "
                         "copy per pod; 1 = the classic local cache)")
    ap.add_argument("--cache-transport", choices=("loopback", "socket"),
                    default="loopback",
                    help="sharded-cache peer transport: in-process loopback "
                         "or the length-prefixed socket protocol (each peer "
                         "behind a local BlockStoreServer)")
    ap.add_argument("--operand-cache", choices=("auto", "on", "off"),
                    default="auto",
                    help="per-batch operand reuse: fetch each cluster "
                         "block through the BlockStore (ring hop / cache "
                         "lock / mmap read) once per batch and let the "
                         "batch's tiles share the records (auto = on for "
                         "BlockStore fetch)")
    ap.add_argument("--u-cap-ladder", choices=("pow2", "fine"),
                    default="pow2",
                    help="slot-table bucket ladder: fine adds x1.5 "
                         "midpoints (fewer wasted pad-slot scans, ~2x the "
                         "bounded compile count)")
    ap.add_argument("--cache-fallback", choices=("on", "off"), default="on",
                    help="sharded cache: serve an unhealthy peer's "
                         "clusters from the pod's own full index copy "
                         "(ring = cache optimization, local copy = "
                         "availability floor); off restores the PR-5 "
                         "fail-on-peer-error contract")
    ap.add_argument("--peer-timeout-s", type=float, default=30.0,
                    help="sharded cache, socket transport: per-request "
                         "deadline on every peer fetch")
    ap.add_argument("--peer-retries", type=int, default=1,
                    help="sharded cache, socket transport: reconnect "
                         "retries per fetch (capped exponential backoff)")
    ap.add_argument("--probe-interval-s", type=float, default=None,
                    help="sharded cache: active health-probe period for "
                         "open peer circuits (default: passive half-open "
                         "probes only)")
    ap.add_argument("--delta-budget-mb", type=float, default=None,
                    help="disk tier, layout-v3 checkpoint: attach a RAM "
                         "delta tier of this many MiB and run a live "
                         "add/tombstone/compact demo phase (new vectors "
                         "searchable the very next batch)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="delta tier: republish (compact_deltas + between-"
                         "batch refresh) every this many live updates "
                         "(0 = never republish during the demo)")
    ap.add_argument("--compact-rows", type=int, default=0,
                    help="delta tier: pressure-driven republish when the "
                         "delta holds at least this many rows (0 = off)")
    ap.add_argument("--compact-stale-frac", type=float, default=0.0,
                    help="delta tier: pressure-driven republish when "
                         "pending tombstones exceed this fraction of the "
                         "cold tier's live rows (0 = off)")
    ap.add_argument("--device-cache-mb", type=float, default=None,
                    help="disk tier: cross-batch device-resident block "
                         "cache of this many MiB — repeat traffic reuses "
                         "fully assembled on-device operand blocks (zero "
                         "host assembly, zero H2D), heat-weighted LRU "
                         "keyed on (cluster_id, gen)")
    ap.add_argument("--delta-quantize", choices=("auto", "on"),
                    default="auto",
                    help="delta tier: store delta rows SQ8-quantized even "
                         "over a float cold tier (~4x rows per MiB; scores "
                         "agree to quantization tolerance, republish "
                         "dequantizes); auto = match the cold tier")
    ap.add_argument("--termination", choices=("exact", "bounded"),
                    default=None,
                    help="bound-driven early termination: reorder probes "
                         "best-bound-first and drop probes that provably "
                         "(exact, bit-identical) or probably (bounded, "
                         "recall >= 1-epsilon) cannot enter the top-k")
    ap.add_argument("--epsilon", type=float, default=0.0,
                    help="bounded termination: per-query probability "
                         "budget for dropping a probe that might hold a "
                         "top-k hit (needs --termination bounded)")
    ap.add_argument("--partition-attrs", default=None,
                    help="build filter-specialized sub-partitions along "
                         "these attribute indices (comma-separated, or "
                         "'auto' to choose from the summary histograms) "
                         "and persist them as a layout-v4 checkpoint on "
                         "--save / the disk-tier auto-checkpoint")
    ap.add_argument("--partition-max-depth", type=int, default=3,
                    help="sliding-window ladder depth for ordered "
                         "partition attributes: level l has 8*2^l windows "
                         "(deeper = narrower windows, so narrower filters "
                         "still route to a sub-partition)")
    ap.add_argument("--partitions", choices=("auto", "on", "off"),
                    default="auto",
                    help="planner-side partition routing: per query, scan "
                         "the narrowest catalog entry whose predicate "
                         "subsumes the filter (auto = route when the index "
                         "carries a catalog; results are bit-identical "
                         "either way)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text exposition of the flat "
                         "engine metrics at http://localhost:PORT/metrics")
    args = ap.parse_args()
    if args.t_max is not None and args.t_max != "auto":
        args.t_max = int(args.t_max)

    from repro.core import HybridSpec, build_ivf, storage
    from repro.core.disk import DiskIVFIndex
    from repro.core.serving import SearchServer, make_fused_search_fn
    from repro.data import synthetic_attributes, synthetic_embeddings

    def _save_checkpoint(idx, directory, n_shards=4):
        """Persists the index; with --partition-attrs, additionally builds
        the filter-specialized sub-partition plane (storage layout v4)."""
        if args.partition_attrs is None:
            storage.save_index(idx, directory, n_shards=n_shards)
            return
        from repro.core import partitions as partitions_lib

        p_attrs = (None if args.partition_attrs == "auto"
                   else [int(a) for a in args.partition_attrs.split(",")])
        build = partitions_lib.build_partitions(
            idx, attrs=p_attrs, max_depth=args.partition_max_depth
        )
        storage.save_index(idx, directory, n_shards=n_shards, layout=4,
                           partitions=build)
        print(f"partitioned checkpoint: {build.n_subs} sub-partitions, "
              f"{build.catalog.n_entries} catalog entries")

    index_dir = args.load
    index = None
    if args.load and args.tier == "disk":
        # Disk tier: never materialize the index in RAM — that would defeat
        # serving an index larger than host memory.  Query vectors for the
        # demo traffic are sampled from a few paged-in clusters instead.
        pass
    elif args.load:
        index = storage.load_index(args.load)
        core = np.asarray(index.vectors).reshape(-1, index.spec.dim)
        print(f"restored index: K={index.n_clusters}, "
              f"{int(index.n_live)} vectors")
    else:
        core = synthetic_embeddings(0, args.n, args.dim)
        attrs = synthetic_attributes(0, args.n, args.n_attrs,
                                     cardinalities=[8])
        spec = HybridSpec(dim=args.dim, n_attrs=args.n_attrs,
                          core_dtype=jnp.float32)
        index, stats = build_ivf(
            jax.random.key(0), spec, jnp.asarray(core), jnp.asarray(attrs),
            n_clusters=args.clusters, kmeans_steps=40,
        )
        print(f"built index: K={index.n_clusters}, "
              f"mean list {stats.mean_list_len:.0f}")
        if args.save:
            _save_checkpoint(index, args.save)
            print(f"persisted to {args.save}")
            index_dir = args.save

    if args.tier == "disk":
        if index_dir is None:  # disk tier needs a checkpoint to page from
            index_dir = tempfile.mkdtemp(prefix="ivf_disk_")
            _save_checkpoint(index, index_dir)
            print(f"wrote disk-tier checkpoint to {index_dir}")
        budget = (args.resident_budget_mb * 1024 * 1024
                  if args.resident_budget_mb else None)
        serving_index = DiskIVFIndex.open(
            index_dir, resident_budget_bytes=budget
        )
        print(f"disk tier: K={serving_index.n_clusters}, record stride "
              f"{serving_index.reader.stride} B, budget "
              f"{budget or 'unbounded'}")
        if index is None:  # --load: sample demo queries from a few clusters
            core = _sample_queries(serving_index)
    else:
        serving_index = index

    if args.cache_shards > 1 and args.tier != "disk":
        raise SystemExit("--cache-shards needs --tier disk")
    if args.delta_budget_mb is not None and args.tier != "disk":
        raise SystemExit("--delta-budget-mb needs --tier disk (the RAM "
                         "tier mutates in place via core.update)")
    if args.device_cache_mb is not None and args.tier != "disk":
        raise SystemExit("--device-cache-mb needs --tier disk (the RAM "
                         "tier is already device-resident)")
    search_fn = make_fused_search_fn(
        serving_index, k=args.k, n_probes=args.probes, q_block=args.batch,
        prune=args.prune, t_max=args.t_max, pipeline=args.pipeline,
        pipeline_depth=args.pipeline_depth,
        operand_cache=args.operand_cache, u_cap_ladder=args.u_cap_ladder,
        cache_shards=args.cache_shards,
        cache_transport=args.cache_transport,
        cache_fallback=args.cache_fallback == "on",
        peer_timeout_s=args.peer_timeout_s,
        peer_retries=args.peer_retries,
        probe_interval_s=args.probe_interval_s,
        delta_budget_mb=args.delta_budget_mb,
        delta_quantize=args.delta_quantize,
        device_cache_mb=args.device_cache_mb,
        termination=args.termination, epsilon=args.epsilon,
        partitions=args.partitions,
    )
    metrics_httpd = None
    if args.metrics_port is not None:
        import http.server
        import threading

        metrics_text = search_fn.metrics_text

        class _MetricsHandler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = metrics_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # keep the demo output clean
                pass

        metrics_httpd = http.server.ThreadingHTTPServer(
            ("127.0.0.1", args.metrics_port), _MetricsHandler)
        threading.Thread(target=metrics_httpd.serve_forever,
                         daemon=True).start()
        print(f"metrics: http://127.0.0.1:{metrics_httpd.server_address[1]}"
              f"/metrics")

    if search_fn.blockstore is not None and args.cache_shards > 1:
        bs = search_fn.blockstore
        print(f"sharded cluster cache: {args.cache_shards} nodes "
              f"({args.cache_transport} transport), ring "
              f"{bs.ownership.__class__.__name__}")

    server = SearchServer(
        search_fn, batch_size=args.batch, dim=serving_index.spec.dim,
        n_attrs=serving_index.spec.n_attrs, n_terms=1, n_shards=8,
    )
    server.start()
    rng = np.random.default_rng(1)
    t0 = time.time()
    futs = [
        server.submit(core[rng.integers(0, len(core))])
        for _ in range(args.requests)
    ]
    resps = [f.get(timeout=120) for f in futs]
    wall = time.time() - t0
    lat = np.asarray([r.latency_s for r in resps]) * 1e3
    print(f"{args.requests} requests in {wall:.2f}s "
          f"({args.requests/wall:.0f} QPS), p50 {np.percentile(lat,50):.1f}ms "
          f"p99 {np.percentile(lat,99):.1f}ms, "
          f"batches {server.stats['batches']}")

    if args.delta_budget_mb is not None:
        # Live-update phase: each step adds a vector (searchable the very
        # next batch), every 4th step tombstones a recent add, and every
        # --compact-every steps the delta folds into the cold tier and the
        # serving loop flips generation between batches — no drain.
        from repro.core.delta import compact_deltas, republish_pressure

        tier = search_fn.delta
        rng2 = np.random.default_rng(2)
        base = 1_000_000_000  # demo id space, clear of checkpoint ids
        steps = min(args.requests, 64)
        dim, m = serving_index.spec.dim, serving_index.spec.n_attrs
        for step in range(steps):
            v = core[rng2.integers(0, len(core))].astype(np.float32)
            v = v + 0.01 * rng2.standard_normal(dim).astype(np.float32)
            a = rng2.integers(0, 8, (1, m)).astype(np.int16)
            tier.add(v[None], a, np.asarray([base + step]))
            if step % 4 == 3:
                tier.tombstone(np.asarray([base + step - 2]))
            trigger = None
            if args.compact_every and (step + 1) % args.compact_every == 0:
                trigger = "manual"
            if trigger is None:
                trigger = republish_pressure(
                    tier,
                    rows_watermark=args.compact_rows or None,
                    stale_frac=args.compact_stale_frac or None,
                    n_live=int(serving_index.man["n_live"]),
                )
            if trigger is not None:
                st = compact_deltas(index_dir, tier, trigger=trigger)
                server.request_refresh()
                print(f"republished ({st.trigger}): "
                      f"{st.clusters_rewritten} clusters "
                      f"(gen {st.gen_max}), folded {st.rows_folded} rows, "
                      f"reclaimed {st.rows_reclaimed}")
            server.search_blocking(v)  # drains any pending refresh first
        print(f"live updates: {steps} adds, "
              f"{tier.stats()['tombstoned']} tombstones, "
              f"{tier.stats()['commits']} republish commits, "
              f"{tier.stats()['live_rows']} rows still in RAM delta")

    server.stop()
    if metrics_httpd is not None:
        metrics_httpd.shutdown()
    # One flat metrics surface (engine / store / cache / delta under
    # dotted keys) instead of per-layer ad-hoc reports.
    for key, val in sorted(search_fn.engine.metrics().items()):
        print(f"  {key} = {val}")
    if args.tier == "disk":
        on_disk = serving_index.reader.stride * serving_index.n_clusters
        print(f"resident {serving_index.resident_bytes()/2**20:.1f} MiB "
              f"(index on disk {on_disk/2**20:.1f} MiB)")
        search_fn.close()  # engine + sharded store (we opened the index)
        serving_index.close()


if __name__ == "__main__":
    main()
