"""Serving launcher: builds (or loads) a hybrid index and serves batched
filtered queries through the micro-batching server.

    PYTHONPATH=src python -m repro.launch.serve --n 100000 --requests 128
    PYTHONPATH=src python -m repro.launch.serve --load <index_dir>
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--n-attrs", type=int, default=6)
    ap.add_argument("--clusters", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--probes", type=int, default=7)
    ap.add_argument("--load", default=None, help="index dir to restore")
    ap.add_argument("--save", default=None, help="index dir to persist")
    args = ap.parse_args()

    from repro.core import HybridSpec, build_ivf, storage
    from repro.core.search import search_reference
    from repro.core.serving import SearchServer
    from repro.data import synthetic_attributes, synthetic_embeddings

    if args.load:
        index = storage.load_index(args.load)
        core = np.asarray(index.vectors).reshape(-1, index.spec.dim)
        print(f"restored index: K={index.n_clusters}, "
              f"{int(index.n_live)} vectors")
    else:
        core = synthetic_embeddings(0, args.n, args.dim)
        attrs = synthetic_attributes(0, args.n, args.n_attrs,
                                     cardinalities=[8])
        spec = HybridSpec(dim=args.dim, n_attrs=args.n_attrs,
                          core_dtype=jnp.float32)
        index, stats = build_ivf(
            jax.random.key(0), spec, jnp.asarray(core), jnp.asarray(attrs),
            n_clusters=args.clusters, kmeans_steps=40,
        )
        print(f"built index: K={index.n_clusters}, "
              f"mean list {stats.mean_list_len:.0f}")
        if args.save:
            storage.save_index(index, args.save, n_shards=4)
            print(f"persisted to {args.save}")

    def search_fn(queries, fspec, shard_ok):
        del shard_ok
        res = search_reference(index, queries, fspec, k=args.k,
                               n_probes=args.probes)
        return res.scores, res.ids

    server = SearchServer(
        search_fn, batch_size=args.batch, dim=index.spec.dim,
        n_attrs=index.spec.n_attrs, n_terms=1, n_shards=8,
    )
    server.start()
    rng = np.random.default_rng(1)
    t0 = time.time()
    futs = [
        server.submit(core[rng.integers(0, len(core))])
        for _ in range(args.requests)
    ]
    resps = [f.get(timeout=120) for f in futs]
    wall = time.time() - t0
    server.stop()
    lat = np.asarray([r.latency_s for r in resps]) * 1e3
    print(f"{args.requests} requests in {wall:.2f}s "
          f"({args.requests/wall:.0f} QPS), p50 {np.percentile(lat,50):.1f}ms "
          f"p99 {np.percentile(lat,99):.1f}ms, "
          f"batches {server.stats['batches']}")


if __name__ == "__main__":
    main()
