"""Cell registry: every assigned (architecture × input-shape) as a lowerable
step with abstract (ShapeDtypeStruct) inputs and production shardings.

40 assigned cells + the paper's own search step (`paper-ivf × search_1b`).
Skips (documented, DESIGN.md §6): long_500k for pure full-attention archs.

Each cell builds in one of two variants:
  exec — scanned layers / streamed slots: memory_analysis is the
         "fits-in-HBM" proof (this is the program you would run);
  cost — unrolled scans / single-block attention / vmapped slots: every op
         appears once in the HLO so cost_analysis FLOPs/bytes and the
         collective-bytes text parse are exact (XLA counts while-loop bodies
         once — measured 8× undercount on an 8-layer scan).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import (
    bst as bst_cfg,
    chatglm3_6b,
    deepseek_moe_16b,
    deepseek_v3_671b,
    dimenet as dimenet_cfg,
    din as din_cfg,
    gemma3_12b,
    gemma3_27b,
    sasrec as sasrec_cfg,
    wide_deep as wide_deep_cfg,
)
from repro.launch.mesh import dp_axes as mesh_dp_axes, n_chips

LM_ARCHS = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "gemma3-12b": gemma3_12b,
    "gemma3-27b": gemma3_27b,
    "chatglm3-6b": chatglm3_6b,
}
RECSYS_ARCHS = {
    "din": din_cfg,
    "sasrec": sasrec_cfg,
    "bst": bst_cfg,
    "wide-deep": wide_deep_cfg,
}

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

ALL_ARCHS = (
    list(LM_ARCHS) + ["dimenet"] + list(RECSYS_ARCHS) + ["paper-ivf"]
)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | search
    fn: Callable
    args: tuple  # abstract ShapeDtypeStructs with shardings
    meta: Dict[str, Any]
    donate: Tuple[int, ...] = ()
    skip_reason: Optional[str] = None


def list_cells() -> list:
    """All (arch, shape) pairs, with skip markers."""
    out = []
    for a in LM_ARCHS:
        for s in LM_SHAPES:
            skip = None
            if s == "long_500k" and not LM_ARCHS[a].config().sub_quadratic:
                skip = ("pure full attention on every layer (no windowed/"
                        "linear component) — long_500k skipped per DESIGN.md §6")
            out.append((a, s, skip))
    # §Perf hillclimb variants (EXPERIMENTS.md) — collective-bound MoE trains
    out.append(("deepseek-v3-671b", "train_4k_moescatter", None))
    out.append(("deepseek-moe-16b", "train_4k_moescatter", None))
    for s in GNN_SHAPES:
        out.append(("dimenet", s, None))
    out.append(("dimenet", "ogb_products_bf16", None))  # §Perf variant
    for a in RECSYS_ARCHS:
        for s in RECSYS_SHAPES:
            out.append((a, s, None))
    out.append(("paper-ivf", "search_1b", None))
    # §Perf hillclimb variants of the paper cell (EXPERIMENTS.md)
    out.append(("paper-ivf", "search_1b_sq8", None))
    out.append(("paper-ivf", "search_1b_sq8_tight", None))
    return out


def _abs(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _abs_tree(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda sh, spec: _abs(sh.shape, sh.dtype, mesh, spec),
        shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# =============================================================== LM cells ===
def _lm_override(cfg, layers: Optional[Tuple[int, ...]]):
    """Builds a reduced-depth probe config (same width, fewer layers).

    MoE archs: layers=(n_dense, n_moe); dense archs: layers=(n_layers,).
    Probes are compiled fully unrolled, so their cost_analysis is exact;
    reported cost is linear in the layer counts (loop body once + per-layer
    optimizer/grad terms), so 2–3 probes solve for per-layer costs and the
    full-depth totals follow analytically (see dryrun.synthesize_lm_cost).
    """
    if layers is None:
        return cfg
    if cfg.moe is not None:
        nd, nm = layers
        return dataclasses.replace(
            cfg, n_layers=nd + nm,
            moe=dataclasses.replace(cfg.moe, first_dense_layers=nd),
        )
    (nl,) = layers
    return dataclasses.replace(cfg, n_layers=nl)


def _lm_train_cell(arch: str, mesh: Mesh, variant: str,
                   layers: Optional[Tuple[int, ...]] = None,
                   moe_combine: str = "psum") -> Cell:
    from repro.models.transformer import init_params, lm_loss, param_pspecs
    from repro.train.optimizer import (
        OptimizerConfig, adafactor_state_pspecs, adamw_state_pspecs,
        clip_by_global_norm, make_optimizer,
    )

    cfg = LM_ARCHS[arch].config()
    b, s = 256, 4096
    if variant == "cost":
        cfg = dataclasses.replace(cfg, scan_unroll=True, attn_block_k=s,
                                  remat=False)
    cfg = dataclasses.replace(_lm_override(cfg, layers),
                              moe_combine=moe_combine)
    dp = mesh_dp_axes(mesh)
    # 671B needs factored optimizer state to fit (see train/optimizer.py)
    opt_name = "adafactor" if cfg.n_params() > 1e11 else "adamw"
    opt_cfg = OptimizerConfig(name=opt_name, weight_decay=0.0)
    opt_init, opt_update = make_optimizer(opt_cfg)

    def train_step(params, opt_state, tokens, labels):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels, mesh=mesh, dp_axes=dp),
            has_aux=True,
        )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_state = opt_update(grads, opt_state, params,
                                           jnp.float32(1e-4))
        return new_params, new_state, loss, gnorm

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))
    pspecs = param_pspecs(cfg)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    opt_pspecs = (
        adafactor_state_pspecs(pspecs, params_shape, opt_cfg)
        if opt_name == "adafactor" else adamw_state_pspecs(pspecs)
    )
    params_abs = _abs_tree(params_shape, pspecs, mesh)
    opt_abs = _abs_tree(opt_shape, opt_pspecs, mesh)
    tok = _abs((b, s), jnp.int32, mesh, P(dp, None))

    tokens_total = b * s
    n_layer_flops = 6 * cfg.n_active_params() * tokens_total
    return Cell(
        arch, "train_4k", "train", train_step,
        (params_abs, opt_abs, tok, tok),
        meta=dict(
            model_flops=float(n_layer_flops),
            tokens=tokens_total,
            loop_trip_counts={"dense": cfg.n_dense_layers,
                              "moe": cfg.n_moe_layers},
            optimizer=opt_name,
        ),
        donate=(0, 1),
    )


def _lm_prefill_cell(arch: str, mesh: Mesh, variant: str,
                     layers: Optional[Tuple[int, ...]] = None) -> Cell:
    from repro.models.decoding import prefill
    from repro.models.transformer import init_params, param_pspecs

    cfg = LM_ARCHS[arch].config()
    b, s = 32, 32768
    if variant == "cost":
        cfg = dataclasses.replace(cfg, scan_unroll=True, attn_block_k=4096,
                                  remat=False)
    cfg = _lm_override(cfg, layers)
    dp = mesh_dp_axes(mesh)

    def prefill_step(params, tokens):
        logits, cache = prefill(params, cfg, tokens, s_max=s, mesh=mesh,
                                dp_axes=dp)
        return logits[:, -1, :], cache  # last-token logits + decode cache

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))
    params_abs = _abs_tree(params_shape, param_pspecs(cfg), mesh)
    tok = _abs((b, s), jnp.int32, mesh, P(dp, None))
    return Cell(
        arch, "prefill_32k", "prefill", prefill_step, (params_abs, tok),
        meta=dict(
            model_flops=float(2 * cfg.n_active_params() * b * s),
            tokens=b * s,
            loop_trip_counts={"layers": cfg.n_layers},
        ),
    )


def _lm_decode_cell(arch: str, shape: str, mesh: Mesh, variant: str,
                    layers: Optional[Tuple[int, ...]] = None) -> Cell:
    from repro.models.decoding import cache_spec, decode_step
    from repro.models.transformer import init_params, param_pspecs

    cfg = LM_ARCHS[arch].config()
    cfg = _lm_override(cfg, layers)
    if shape == "decode_32k":
        b, s_max = 128, 32768
    else:  # long_500k
        b, s_max = 1, 524288
    dp = mesh_dp_axes(mesh)
    # serving plan: no FSDP regather per token; 256-expert archs widen EP
    ep = (("model", "data")
          if (cfg.moe and cfg.moe.n_routed % (16 * 16) == 0)
          else ("model",))
    cfg = dataclasses.replace(cfg, fsdp_axis=None, moe_ep_axes=ep,
                              remat=False)
    if variant == "cost":
        cfg = dataclasses.replace(cfg, scan_unroll=True)

    pspecs = param_pspecs(cfg)
    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))
    if cfg.sub_quadratic:  # decode layout re-lays the blocks
        from repro.models.decoding import decode_layout

        params_shape = jax.eval_shape(
            lambda p: decode_layout(p, cfg), params_shape
        )
        blk = pspecs.pop("blocks")
        pspecs["blocks_local"] = blk
        pspecs["blocks_global"] = blk
        if "blocks_tail" in params_shape:
            pspecs["blocks_tail"] = blk
    params_abs = _abs_tree(params_shape, pspecs, mesh)

    # cache shardings: batch over dp when divisible, KV length over the rest
    cspec = cache_spec(cfg, b, s_max)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b_axes = dp if b % n_dp == 0 else None
    s_axes = (
        "model" if b_axes is not None
        else tuple(mesh.axis_names)  # B=1: spread KV length over everything
    )

    def kv_spec(leaf):
        # [n_stack, B, S_cache, ...] — shard S_cache only if divisible
        s_cache = leaf.shape[2]
        n_s = 1
        for a in ((s_axes,) if isinstance(s_axes, str) else s_axes):
            n_s *= mesh.shape[a]
        s_ax = s_axes if s_cache % n_s == 0 else None
        rest = (None,) * (len(leaf.shape) - 3)
        return P(None, b_axes, s_ax, *rest)

    cache_pspecs = jax.tree.map(
        kv_spec, cspec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    cache_abs = _abs_tree(cspec, cache_pspecs, mesh)
    tok = _abs((b,), jnp.int32, mesh, P(b_axes))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos, mesh=mesh,
                           dp_axes=dp if b_axes is not None else ())

    n_rounds = cfg.n_layers // cfg.global_every if cfg.sub_quadratic else 0
    return Cell(
        arch, shape, "decode", step, (params_abs, cache_abs, tok, pos),
        meta=dict(
            model_flops=float(2 * cfg.n_active_params() * b),
            tokens=b,
            loop_trip_counts=(
                {"rounds": n_rounds} if cfg.sub_quadratic
                else {"dense": cfg.n_dense_layers, "moe": cfg.n_moe_layers}
            ),
            ep_axes=ep,
        ),
        donate=(1,),
    )


# ============================================================== GNN cells ===
GNN_SHAPE_DEFS = {
    # n_nodes, n_edges, d_feat, trip_per_edge, readout, n_graphs, batch note
    "full_graph_sm": dict(n=2816, e=11264, d_feat=1433, tpe=8,
                          readout="node", n_graphs=1),
    "minibatch_lg": dict(n=172032, e=172032, d_feat=602, tpe=12,
                         readout="node", n_graphs=1),
    "ogb_products": dict(n=2449408, e=61866496, d_feat=100, tpe=8,
                         readout="node", n_graphs=1),
    "molecule": dict(n=3840, e=8192, d_feat=16, tpe=8,
                     readout="graph", n_graphs=128),
    # §Perf iteration: bf16 messages halve the cross-shard gather traffic
    # of the collective-bound ogb_products cell (EXPERIMENTS.md)
    "ogb_products_bf16": dict(n=2449408, e=61866496, d_feat=100, tpe=8,
                              readout="node", n_graphs=1,
                              dtype=jnp.bfloat16),
}


def _gnn_cell(shape: str, mesh: Mesh, variant: str) -> Cell:
    from repro.models.gnn.dimenet import (
        DimeNetConfig, GraphBatch, init_params, loss_fn,
    )
    from repro.train.optimizer import OptimizerConfig, make_optimizer

    sd = GNN_SHAPE_DEFS[shape]
    cfg = dimenet_cfg.config(
        d_feat=sd["d_feat"],
        d_out=1 if sd["readout"] == "graph" else 47,
        readout=sd["readout"],
    )
    if variant == "cost":
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if sd.get("dtype") is not None:  # §Perf: bf16 message variant
        cfg = dataclasses.replace(cfg, dtype=sd["dtype"])
    n, e, t = sd["n"], sd["e"], sd["e"] * sd["tpe"]
    all_axes = tuple(mesh.axis_names)
    shard1 = P(all_axes)  # 1-D arrays over every chip
    rep = P()

    opt_cfg = OptimizerConfig(name="adamw", weight_decay=0.0)
    opt_init, opt_update = make_optimizer(opt_cfg)

    def train_step(params, opt_state, g, labels):
        (loss, m), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, g, labels), has_aux=True
        )(params)
        new_params, new_state = opt_update(grads, opt_state, params,
                                           jnp.float32(1e-3))
        return new_params, new_state, loss

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))
    rep_specs = jax.tree.map(lambda _: rep, params_shape)
    params_abs = _abs_tree(params_shape, rep_specs, mesh)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    opt_abs = _abs_tree(opt_shape, jax.tree.map(lambda _: rep, opt_shape),
                        mesh)
    g_abs = GraphBatch(
        node_feat=_abs((n, sd["d_feat"]), jnp.float32, mesh, rep),
        positions=_abs((n, 3), jnp.float32, mesh, rep),
        edge_src=_abs((e,), jnp.int32, mesh, shard1),
        edge_dst=_abs((e,), jnp.int32, mesh, shard1),
        edge_mask=_abs((e,), jnp.bool_, mesh, shard1),
        trip_in=_abs((t,), jnp.int32, mesh, shard1),
        trip_out=_abs((t,), jnp.int32, mesh, shard1),
        trip_mask=_abs((t,), jnp.bool_, mesh, shard1),
        graph_id=_abs((n,), jnp.int32, mesh, rep),
        n_graphs=sd["n_graphs"],
    )
    labels = _abs(
        (sd["n_graphs"],) if sd["readout"] == "graph" else (n,),
        jnp.float32 if sd["readout"] == "graph" else jnp.int32,
        mesh, rep,
    )
    d = cfg.d_hidden
    flops = 3 * 2 * (  # fwd(+bwd×2) matmul-dominant terms
        e * 3 * d * d  # embedding block
        + cfg.n_blocks * (
            2 * e * d * d  # msg/src projections
            + t * cfg.n_bilinear * d * d  # bilinear triplet interaction
            + 2 * e * d * d  # residual MLP
            + n * d * d  # output block
        )
    )
    return Cell(
        "dimenet", shape, "train", train_step,
        (params_abs, opt_abs, g_abs, labels),
        meta=dict(model_flops=float(flops), tokens=n,
                  loop_trip_counts={"blocks": cfg.n_blocks}),
        donate=(0, 1),
    )


# =========================================================== recsys cells ===
RECSYS_SHAPE_DEFS = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_cand=1_048_576, kind="retrieval"),
}


def _recsys_cell(arch: str, shape: str, mesh: Mesh, variant: str) -> Cell:
    from repro.models.recsys.models import (
        RecsysBatch, forward, init_params, loss_fn, retrieval_scores,
    )
    from repro.train.optimizer import OptimizerConfig, make_optimizer

    cfg = RECSYS_ARCHS[arch].config()
    sd = RECSYS_SHAPE_DEFS[shape]
    b = sd["batch"]
    dp = mesh_dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b_axes = dp if b % n_dp == 0 else None
    all_axes = tuple(mesh.axis_names)

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  jax.random.key(0))

    def pspec(path_key, leaf):
        if leaf.ndim == 2 and leaf.shape[0] >= 100_000:
            return P(all_axes, None)  # huge tables: row-sharded everywhere
        return P()

    pspecs = {}
    for key, leaf in params_shape.items():
        pspecs[key] = (
            jax.tree.map(lambda l: pspec(key, l), leaf,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            if not isinstance(leaf, jax.ShapeDtypeStruct)
            else pspec(key, leaf)
        )
    params_abs = _abs_tree(params_shape, pspecs, mesh)

    L = max(cfg.seq_len, 1)
    batch_abs = RecsysBatch(
        dense=_abs((b, cfg.n_dense), jnp.float32, mesh, P(b_axes, None)),
        sparse=_abs((b, max(cfg.n_sparse, 1)), jnp.int32, mesh,
                    P(b_axes, None)),
        hist=_abs((b, L), jnp.int32, mesh, P(b_axes, None)),
        target=_abs((b,), jnp.int32, mesh, P(b_axes)),
        label=_abs((b,), jnp.float32, mesh, P(b_axes)),
    )

    mlp_flops = 0
    prev = cfg.embed_dim * 4 + cfg.n_dense
    for hdim in cfg.mlp_dims:
        mlp_flops += 2 * prev * hdim
        prev = hdim
    attn_flops = (
        2 * cfg.seq_len * cfg.seq_len * cfg.embed_dim * max(cfg.n_blocks, 1)
        if cfg.arch in ("sasrec", "bst") else
        2 * cfg.seq_len * 4 * cfg.embed_dim * sum(cfg.attn_mlp_dims or (1,))
    )
    per_ex = mlp_flops + attn_flops

    if sd["kind"] == "train":
        opt_cfg = OptimizerConfig(name="adamw", weight_decay=0.0)
        opt_init, opt_update = make_optimizer(opt_cfg)

        def step(params, opt_state, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch), has_aux=True
            )(params)
            new_params, new_state = opt_update(grads, opt_state, params,
                                               jnp.float32(1e-3))
            return new_params, new_state, loss

        opt_shape = jax.eval_shape(opt_init, params_shape)
        opt_abs = _abs_tree(
            opt_shape,
            jax.tree.map(
                lambda l: (P(all_axes, None)
                           if l.ndim == 2 and l.shape[0] >= 100_000 else P()),
                opt_shape,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            ),
            mesh,
        )
        args = (params_abs, opt_abs, batch_abs)
        flops = 3 * b * per_ex
        donate = (0, 1)
    elif sd["kind"] == "serve":
        def step(params, batch):
            return forward(params, cfg, batch)

        args = (params_abs, batch_abs)
        flops = b * per_ex
        donate = ()
    else:  # retrieval
        n_cand = sd["n_cand"]
        cands = _abs((n_cand, cfg.embed_dim), jnp.float32, mesh,
                     P(all_axes, None))

        def step(params, batch, candidates):
            return retrieval_scores(params, cfg, batch, candidates, k=100)

        args = (params_abs, batch_abs, cands)
        flops = b * (per_ex + 2 * n_cand * cfg.embed_dim)
        donate = ()

    return Cell(
        arch, shape, sd["kind"], step, args,
        meta=dict(model_flops=float(flops), tokens=b, loop_trip_counts={}),
        donate=donate,
    )


# =========================================================== paper-ivf =====
def _ivf_cell(mesh: Mesh, variant: str, *, quantized: bool = False,
              p_cap_slack: float = 2.0, shape_name: str = "search_1b"
              ) -> Cell:
    """The paper's §4.4 search over the 1B-vector index (Table 1 scale).

    ``quantized``/``p_cap_slack`` are the §Perf hillclimb levers: SQ8 lists
    halve the dominant HBM stream; tighter dispatch slack cuts the padded
    probe slots each chip scans.
    """
    from repro.core.distributed import (
        ShardedSearchConfig, make_sharded_search,
    )
    from repro.core.hybrid import HybridSpec

    q, k_clusters, vpad, d, m, f = 1024, 32768, 36864, 768, 10, 2
    chips = n_chips(mesh)
    cfg = ShardedSearchConfig(
        k=100, n_probes=7, v_block=256, p_cap_slack=p_cap_slack,
        backend="xla_vmap" if variant == "cost" else "xla_map",
        quantized=quantized,
    )
    search_fn, shardings, info = make_sharded_search(
        mesh, "dot", q_total=q, n_clusters=k_clusters, cfg=cfg,
    )
    all_axes = tuple(mesh.axis_names)
    sh = P(all_axes)

    def step(centroids, vectors, attrs, ids, counts, scales, queries, lo,
             hi, shard_ok):
        from repro.core.ivf import IVFFlatIndex
        from repro.core.filters import FilterSpec

        spec = HybridSpec(dim=d, n_attrs=m)
        index = IVFFlatIndex(
            spec=spec, centroids=centroids, vectors=vectors, attrs=attrs,
            ids=ids, counts=counts, norms=None,
            scales=scales if quantized else None,
        )
        res = search_fn(index, queries, FilterSpec(lo=lo, hi=hi), shard_ok)
        return res.scores, res.ids, res.n_scanned

    vec_dtype = jnp.int8 if quantized else jnp.bfloat16
    args = (
        _abs((k_clusters, d), jnp.float32, mesh, P()),  # centroids
        _abs((k_clusters, vpad, d), vec_dtype, mesh, P(all_axes)),
        _abs((k_clusters, vpad, m), jnp.int16, mesh, P(all_axes)),
        _abs((k_clusters, vpad), jnp.int32, mesh, P(all_axes)),
        _abs((k_clusters,), jnp.int32, mesh, P(all_axes)),
        _abs((k_clusters, vpad) if quantized else (k_clusters, 1),
             jnp.float32, mesh, P(all_axes)),
        _abs((q, d), jnp.float32, mesh, P()),  # queries (replicated)
        _abs((q, f, m), jnp.int16, mesh, P()),
        _abs((q, f, m), jnp.int16, mesh, P()),
        _abs((info["n_shards"],), jnp.bool_, mesh, P()),
    )
    v_mean = 31250  # paper Table 1
    flops = float(q * 7 * v_mean * d * 2 + q * k_clusters * d * 2)
    return Cell(
        "paper-ivf", shape_name, "search", step, args,
        meta=dict(
            model_flops=flops, tokens=q,
            loop_trip_counts={"slots": info["p_cap"]},
            p_cap=info["p_cap"], k_local=info["k_local"],
            n_vectors=int(1e9), vpad=vpad, quantized=quantized,
            p_cap_slack=p_cap_slack,
        ),
    )


# ============================================================== dispatch ===
def build_cell(arch: str, shape: str, mesh: Mesh, variant: str = "exec",
               layers: Optional[Tuple[int, ...]] = None) -> Cell:
    if arch in LM_ARCHS:
        if shape == "train_4k":
            return _lm_train_cell(arch, mesh, variant, layers)
        if shape == "train_4k_moescatter":  # §Perf: rs-combine MoE output
            return _lm_train_cell(arch, mesh, variant, layers,
                                  moe_combine="scatter")
        if shape == "prefill_32k":
            return _lm_prefill_cell(arch, mesh, variant, layers)
        if shape in ("decode_32k", "long_500k"):
            return _lm_decode_cell(arch, shape, mesh, variant, layers)
        raise ValueError(shape)
    if arch == "dimenet":
        return _gnn_cell(shape, mesh, variant)
    if arch in RECSYS_ARCHS:
        return _recsys_cell(arch, shape, mesh, variant)
    if arch == "paper-ivf":
        if shape == "search_1b":
            return _ivf_cell(mesh, variant)
        if shape == "search_1b_sq8":  # §Perf iteration 1: SQ8 lists
            return _ivf_cell(mesh, variant, quantized=True,
                             shape_name=shape)
        if shape == "search_1b_sq8_tight":  # §Perf iter 2: + slack 1.25
            return _ivf_cell(mesh, variant, quantized=True,
                             p_cap_slack=1.25, shape_name=shape)
        raise ValueError(shape)
    raise ValueError(arch)


def lm_probe_plan(arch: str, shape: str):
    """Probe layer-counts and the linear synthesis for full-depth cost.

    Returns (probes, solve) where probes is a list of layer tuples and
    solve(costs: list[float-like dict-free vectors]) maps probe costs to the
    full-depth value. Costs combine linearly because XLA counts while bodies
    once and per-layer param ops (optimizer, grads) are elementwise in L.
    """
    cfg = LM_ARCHS[arch].config()
    is_decode = shape in ("decode_32k", "long_500k")
    if cfg.moe is not None:
        nd, nm = cfg.n_dense_layers, cfg.n_moe_layers
        probes = [(1, 1), (1, 3), (2, 1)]

        def solve(f11, f13, f21):
            bm = (f13 - f11) / 2.0
            bd = f21 - f11
            const = f11 - bd - bm
            return const + bd * nd + bm * nm

        return probes, solve
    if cfg.sub_quadratic and is_decode:
        g = cfg.global_every
        rounds = cfg.n_layers // g
        tail = cfg.n_layers - rounds * g
        probes = [(g,), (2 * g,), (g + 2,)]

        def solve(f6, f12, f8):
            br = f12 - f6
            const = f6 - br
            bt = (f8 - f6) / 2.0
            return const + br * rounds + bt * tail

        return probes, solve
    nl = cfg.n_layers
    probes = [(2,), (4,)]

    def solve(f2, f4):
        bl = (f4 - f2) / 2.0
        const = f2 - 2 * bl
        return const + bl * nl

    return probes, solve
