"""DimeNet smoke tests: forward/train step on sampled + molecular graphs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import dimenet as dimenet_cfg
from repro.models.gnn import GraphBatch, forward, init_params, loss_fn
from repro.models.gnn.sampler import (
    CSRGraph,
    build_triplets,
    make_graph_batch_arrays,
    random_graph,
    sample_subgraph,
)


def make_batch_from_arrays(arrs, n_graphs=1):
    return GraphBatch(
        node_feat=jnp.asarray(arrs["node_feat"]),
        positions=jnp.asarray(arrs["positions"]),
        edge_src=jnp.asarray(arrs["edge_src"]),
        edge_dst=jnp.asarray(arrs["edge_dst"]),
        edge_mask=jnp.asarray(arrs["edge_mask"]),
        trip_in=jnp.asarray(arrs["trip_in"]),
        trip_out=jnp.asarray(arrs["trip_out"]),
        trip_mask=jnp.asarray(arrs["trip_mask"]),
        graph_id=jnp.asarray(arrs["graph_id"]),
        n_graphs=n_graphs,
    ), jnp.asarray(arrs["labels"])


@pytest.fixture(scope="module")
def sampled_batch():
    rng = np.random.default_rng(0)
    cfg = dimenet_cfg.smoke_config()
    g = random_graph(rng, n_nodes=500, avg_degree=6, d_feat=cfg.d_feat,
                     n_classes=cfg.d_out)
    seeds = rng.choice(g.n_nodes, 32, replace=False).astype(np.int32)
    nodes, esrc, edst = sample_subgraph(rng, g, seeds, fanouts=[5, 3])
    arrs = make_graph_batch_arrays(
        g, nodes, esrc, edst, n_pad=len(nodes) + 8,
        e_pad=len(esrc) + 16, t_pad=4 * len(esrc) + 16,
    )
    return make_batch_from_arrays(arrs)


def test_forward_node_readout(sampled_batch):
    batch, labels = sampled_batch
    cfg = dimenet_cfg.smoke_config()
    params = init_params(jax.random.key(0), cfg)
    out = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert out.shape == (batch.node_feat.shape[0], cfg.d_out)
    assert np.isfinite(np.asarray(out)).all()


def test_train_step_decreases_loss(sampled_batch):
    batch, labels = sampled_batch
    cfg = dimenet_cfg.smoke_config()
    params = init_params(jax.random.key(1), cfg)

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(
            lambda q: loss_fn(q, cfg, batch, labels), has_aux=True
        )(p)
        return l, jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    l0, params = step(params)
    for _ in range(4):
        l1, params = step(params)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0)


def test_molecule_graph_regression():
    """Batched small graphs (the 'molecule' shape) with graph readout."""
    import dataclasses

    rng = np.random.default_rng(2)
    cfg = dataclasses.replace(
        dimenet_cfg.smoke_config(), readout="graph", d_out=1, d_feat=8
    )
    n_graphs, n_per, e_per = 4, 10, 24
    N, E = n_graphs * n_per, n_graphs * e_per
    esrc = np.concatenate([
        rng.integers(0, n_per, e_per) + g * n_per for g in range(n_graphs)
    ]).astype(np.int32)
    edst = np.concatenate([
        rng.integers(0, n_per, e_per) + g * n_per for g in range(n_graphs)
    ]).astype(np.int32)
    t_in, t_out = build_triplets(esrc, edst, N, max_per_edge=6)
    batch = GraphBatch(
        node_feat=jnp.asarray(rng.standard_normal((N, 8)).astype(np.float32)),
        positions=jnp.asarray(rng.standard_normal((N, 3)).astype(np.float32)),
        edge_src=jnp.asarray(esrc),
        edge_dst=jnp.asarray(edst),
        edge_mask=jnp.ones(E, bool),
        trip_in=jnp.asarray(t_in),
        trip_out=jnp.asarray(t_out),
        trip_mask=jnp.ones(len(t_in), bool),
        graph_id=jnp.asarray(np.repeat(np.arange(n_graphs), n_per).astype(np.int32)),
        n_graphs=n_graphs,
    )
    params = init_params(jax.random.key(3), cfg)
    out = forward(params, cfg, batch)
    assert out.shape == (n_graphs, 1)
    labels = jnp.asarray(rng.standard_normal(n_graphs).astype(np.float32))
    loss, _ = loss_fn(params, cfg, batch, labels)
    assert np.isfinite(float(loss))


def test_triplets_exclude_backedge():
    esrc = np.asarray([0, 1], np.int32)  # 0→1, 1→0
    edst = np.asarray([1, 0], np.int32)
    t_in, t_out = build_triplets(esrc, edst, 2, max_per_edge=4)
    # edge (1→0) has in-edge (0→1) at j=1, but its source is 0 == dst ⇒ excluded
    assert len(t_in) == 0


def test_padding_invariance(sampled_batch):
    """Masked padding must not change real-node outputs."""
    batch, labels = sampled_batch
    cfg = dimenet_cfg.smoke_config()
    params = init_params(jax.random.key(4), cfg)
    out1 = forward(params, cfg, batch)

    import dataclasses as dc
    pad_more = lambda x, fill=0: jnp.concatenate(
        [x, jnp.full((16,) + x.shape[1:], fill, x.dtype)], 0
    )
    batch2 = GraphBatch(
        node_feat=pad_more(batch.node_feat),
        positions=pad_more(batch.positions),
        edge_src=pad_more(batch.edge_src),
        edge_dst=pad_more(batch.edge_dst),
        edge_mask=pad_more(batch.edge_mask, False),
        trip_in=pad_more(batch.trip_in),
        trip_out=pad_more(batch.trip_out),
        trip_mask=pad_more(batch.trip_mask, False),
        graph_id=pad_more(batch.graph_id),
        n_graphs=batch.n_graphs,
    )
    out2 = forward(params, cfg, batch2)
    n = batch.node_feat.shape[0]
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(out2[:n]), rtol=1e-5, atol=1e-5
    )
