"""Per-arch LM smoke tests: reduced config, one forward + loss + grad step
on CPU; asserts output shapes and finiteness (brief requirement (f))."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import chatglm3_6b, deepseek_moe_16b, deepseek_v3_671b, \
    gemma3_12b, gemma3_27b
from repro.models.transformer import forward, init_params, lm_loss, \
    logits_from_hidden

ARCHS = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "gemma3-12b": gemma3_12b,
    "gemma3-27b": gemma3_27b,
    "chatglm3-6b": chatglm3_6b,
}


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1
    return jnp.asarray(tokens), jnp.asarray(labels)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    mod = ARCHS[arch]
    cfg = mod.smoke_config()
    params = init_params(jax.random.key(0), cfg)
    tokens, _ = make_batch(cfg)
    h, aux = jax.jit(
        lambda p, t: forward(p, cfg, t)
    )(params, tokens)
    assert h.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(h, dtype=np.float32)).all()
    logits = logits_from_hidden(params, cfg, h)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_loss_and_grad_step(arch):
    mod = ARCHS[arch]
    cfg = mod.smoke_config()
    params = init_params(jax.random.key(1), cfg)
    tokens, labels = make_batch(cfg, seed=1)

    @jax.jit
    def loss_and_grad(p):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: lm_loss(q, cfg, tokens, labels), has_aux=True
        )(p)
        return loss, metrics, grads

    loss, metrics, grads = loss_and_grad(params)
    loss = float(loss)
    assert np.isfinite(loss)
    # random init ⇒ loss ≈ ln(V); generous band
    assert 0.2 * np.log(cfg.vocab_size) < loss < 3.0 * np.log(cfg.vocab_size)
    gnorm = float(
        jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
    )
    assert np.isfinite(gnorm) and gnorm > 0

    # one SGD step reduces loss (lr small)
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                           params, grads)
    loss2 = float(lm_loss(params2, cfg, tokens, labels)[0])
    assert loss2 < loss


def test_window_pattern_gemma():
    cfg = gemma3_12b.config()
    wp = cfg.window_pattern()
    assert wp.shape == (48,)
    assert (wp[5::6] == 0).all()  # every 6th layer global
    assert (np.delete(wp, np.s_[5::6]) == 1024).all()
    assert cfg.sub_quadratic
    assert not deepseek_v3_671b.config().sub_quadratic
    assert not chatglm3_6b.config().sub_quadratic


def test_param_counts_sane():
    cfg = deepseek_v3_671b.config()
    n = cfg.n_params()
    assert 6.0e11 < n < 7.5e11, n  # ≈671B
    na = cfg.n_active_params()
    assert 3.0e10 < na < 4.5e10, na  # ≈37B active
    cfg2 = deepseek_moe_16b.config()
    assert 1.3e10 < cfg2.n_params() < 2.0e10, cfg2.n_params()
    cfg3 = gemma3_27b.config()
    assert 2.0e10 < cfg3.n_params() < 3.2e10, cfg3.n_params()


def test_moe_dispatch_conservation():
    """Every kept (token, expert) pair contributes once; drops are counted."""
    from repro.models.moe import _dispatch_table

    rng = np.random.default_rng(0)
    n, k, e = 64, 2, 8
    ids = jnp.asarray(rng.integers(0, e, (n, k)).astype(np.int32))
    w = jnp.asarray(rng.random((n, k)).astype(np.float32))
    tok, wt, valid, dropped = _dispatch_table(
        ids, w, e_lo=jnp.int32(0), e_local=e, capacity=32
    )
    assert int(dropped) == 0
    assert int(valid.sum()) == n * k
    # weights preserved as a multiset
    np.testing.assert_allclose(
        np.sort(np.asarray(wt)[np.asarray(valid)]),
        np.sort(np.asarray(w).reshape(-1)),
        rtol=1e-6,
    )
    # tiny capacity ⇒ drops counted
    _, _, valid2, dropped2 = _dispatch_table(
        ids, w, e_lo=jnp.int32(0), e_local=e, capacity=8
    )
    assert int(dropped2) == n * k - int(valid2.sum())
