"""Multi-device distributed-search selftest (run in a subprocess with 8 fake
devices so the main pytest process keeps a single device).

Checks, on a (data=2, model=4) mesh:
  1. sharded search == single-device reference (ids + scores);
  2. straggler drop (shard_ok=False on one chip) yields a valid subset —
     every returned id still satisfies the filter and appears in the
     reference candidate set, and healthy-shard results are unchanged;
  3. dispatch overflow is counted when P_cap is forced tiny.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import (  # noqa: E402
    FilterBuilder,
    HybridSpec,
    build_ivf,
    from_builders,
    match_all,
)
from repro.core.distributed import (  # noqa: E402
    ShardedSearchConfig,
    dispatch_probes,
    make_sharded_search,
    probe_capacity,
)
from repro.core.search import search_reference  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(0)
    n, d, m, kc = 4096, 32, 4, 16
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 8, (n, m)).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32)
    index, stats = build_ivf(
        jax.random.key(0), spec, core, attrs, n_clusters=kc,
        kmeans_mode="lloyd", kmeans_steps=5,
    )
    assert stats.n_dropped == 0

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    q = 16
    cfg = ShardedSearchConfig(k=20, n_probes=4, v_block=128)
    search_fn, shardings, info = make_sharded_search(
        mesh, "dot", q_total=q, n_clusters=kc, cfg=cfg,
    )
    assert info["n_shards"] == 8 and info["k_local"] == 2

    # place index shards
    import dataclasses
    index = dataclasses.replace(
        index,
        centroids=jax.device_put(index.centroids, shardings["centroids"]),
        vectors=jax.device_put(index.vectors, shardings["vectors"]),
        attrs=jax.device_put(index.attrs, shardings["attrs"]),
        ids=jax.device_put(index.ids, shardings["ids"]),
        counts=jax.device_put(index.counts, shardings["counts"]),
    )

    queries = jnp.asarray(core[:q] + 0.01 * rng.standard_normal((q, d)).astype(np.float32))
    builders = [FilterBuilder(m).le(0, 5).ge(1, 1) for _ in range(q)]
    fspec = from_builders(builders)

    res = search_fn(index, queries, fspec)
    ref = search_reference(index, queries, fspec, k=20, n_probes=4)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    live = np.asarray(ref.scores) > -1e38
    np.testing.assert_allclose(
        np.asarray(res.scores)[live], np.asarray(ref.scores)[live],
        rtol=1e-5, atol=1e-5,
    )
    print("OK distributed == reference")

    # ---- tiled backend: per-shard probe dedup + streaming top-k ----
    cfg_tiled = ShardedSearchConfig(
        k=20, n_probes=4, v_block=128, scan_q_block=8, backend="xla_tiled",
    )
    search_fn_t, _, info_t = make_sharded_search(
        mesh, "dot", q_total=q, n_clusters=kc, cfg=cfg_tiled,
    )
    res_t = search_fn_t(index, queries, fspec)
    np.testing.assert_array_equal(np.asarray(res_t.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(res_t.scores)[live], np.asarray(ref.scores)[live],
        rtol=1e-5, atol=1e-5,
    )
    print("OK tiled distributed == reference")

    # ---- filter-aware pruned dispatch: shards skip filtered-out clusters --
    # Topic-mixture index with a topic-correlated attr0 "timestamp" (one
    # cluster per topic, narrow per-topic band): a selective window filter
    # provably excludes most probed clusters, so the summary mask threaded
    # through dispatch_probes_tiled must actually drop probes — and ids must
    # stay bit-identical to both the unpruned dispatch and the reference.
    from repro.core.ivf import build_from_assignments
    from repro.core.summaries import can_match
    from repro.core.filters import FilterSpec

    centers = rng.standard_normal((kc, d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = (np.arange(n) * kc) // n
    core2 = centers[topic] + 0.05 * rng.standard_normal((n, d)).astype(
        np.float32
    )
    core2 /= np.linalg.norm(core2, axis=-1, keepdims=True)
    ts_range = 8192
    band = ts_range // kc
    attrs2 = rng.integers(0, 8, (n, m)).astype(np.int16)
    attrs2[:, 0] = (topic * band + rng.integers(0, band, n)).astype(np.int16)
    index2, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core2), jnp.asarray(attrs2),
        jnp.asarray(topic),
    )
    assert index2.summaries is not None
    index2 = dataclasses.replace(
        index2,
        centroids=jax.device_put(index2.centroids, shardings["centroids"]),
        vectors=jax.device_put(index2.vectors, shardings["vectors"]),
        attrs=jax.device_put(index2.attrs, shardings["attrs"]),
        ids=jax.device_put(index2.ids, shardings["ids"]),
        counts=jax.device_put(index2.counts, shardings["counts"]),
    )
    queries2 = jnp.asarray(core2[:q] + 0.01)
    w = band  # ~1-2 topics wide → most of the 4 probes prunable
    lo2 = np.full((q, 1, m), -32768, np.int16)
    hi2 = np.full((q, 1, m), 32767, np.int16)
    start = rng.integers(0, ts_range - w, q)
    lo2[:, 0, 0] = start.astype(np.int16)
    hi2[:, 0, 0] = (start + w - 1).astype(np.int16)
    fspec2 = FilterSpec(lo=jnp.asarray(lo2), hi=jnp.asarray(hi2))
    cm = np.asarray(can_match(index2.summaries, fspec2.lo, fspec2.hi))
    assert (~cm).sum() > 0, "window filter should exclude some clusters"
    ref2 = search_reference(index2, queries2, fspec2, k=20, n_probes=4)
    for backend in ("pallas_interpret", "xla_tiled"):
        outs = {}
        for prune in ("on", "off"):
            cfg_p = ShardedSearchConfig(
                k=20, n_probes=4, v_block=128, scan_q_block=8,
                backend=backend, prune=prune,
            )
            fn_p, _, _ = make_sharded_search(
                mesh, "dot", q_total=q, n_clusters=kc, cfg=cfg_p,
            )
            outs[prune] = fn_p(index2, queries2, fspec2)
        np.testing.assert_array_equal(
            np.asarray(outs["on"].ids), np.asarray(outs["off"].ids),
            err_msg=f"pruned != unpruned ids ({backend})",
        )
        np.testing.assert_array_equal(
            np.asarray(outs["on"].ids), np.asarray(ref2.ids),
            err_msg=f"pruned != reference ids ({backend})",
        )
    print("OK pruned dispatch == unpruned == reference "
          f"({int((~cm).sum())}/{cm.size} (q,cluster) pairs excluded)")

    # ---- straggler drop ----
    # Dropping shard 3 (clusters 6..7) must (a) never return an id stored in
    # those clusters, (b) keep every returned id filter-compliant, (c) not
    # grow the live-result count.  It MAY surface lower-ranked healthy
    # candidates that weren't in the full top-k — that is the designed
    # graceful degradation, not an error.
    shard_ok = jnp.ones((8,), jnp.bool_).at[3].set(False)
    res_drop = search_fn(index, queries, fspec, shard_ok)
    k_local = info["k_local"]
    dropped_cluster_ids = {
        int(i)
        for c in range(3 * k_local, 4 * k_local)
        for i in np.asarray(index.ids[c])
        if i >= 0
    }
    for row in np.asarray(res_drop.ids):
        for i in row:
            if i >= 0:
                assert int(i) not in dropped_cluster_ids
                assert attrs[i, 0] <= 5 and attrs[i, 1] >= 1
    n_live_drop = int(np.sum(np.asarray(res_drop.ids) >= 0))
    n_live_full = int(np.sum(np.asarray(res.ids) >= 0))
    assert n_live_drop <= n_live_full
    print("OK straggler drop is a sound partial merge")

    # ---- overflow accounting ----
    probe_ids = jnp.zeros((q, 4), jnp.int32)  # all probes hit shard 0
    sc, sq, sv, n_drop = dispatch_probes(
        probe_ids, n_shards=8, k_local=2, p_cap=8
    )
    assert int(n_drop) == q * 4 - 8, int(n_drop)
    assert int(jnp.sum(sv.astype(jnp.int32))) == 8
    print("OK overflow counted:", int(n_drop))

    # ---- p_cap sizing sanity ----
    assert probe_capacity(1024, 7, 512, 2.0) >= 2 * (1024 * 7 // 512)

    # ---- MoE combine: reduce-scatter == psum (§Perf optimization) ----
    import dataclasses as dc

    from repro.configs import deepseek_moe_16b
    from repro.models.transformer import forward, init_params

    cfg0 = deepseek_moe_16b.smoke_config()
    cfg0 = dc.replace(cfg0, dtype=jnp.float32, remat=False)
    params_t = init_params(jax.random.key(5), cfg0)
    toks = jnp.asarray(
        rng.integers(0, cfg0.vocab_size, (4, 32)).astype(np.int32)
    )
    outs = {}
    for combine in ("psum", "scatter"):
        cfgc = dc.replace(cfg0, moe_combine=combine)
        with compat.use_mesh(mesh):
            h, _ = jax.jit(
                lambda p, t: forward(p, cfgc, t, mesh=mesh,
                                     dp_axes=("data",))
            )(params_t, toks)
        outs[combine] = np.asarray(jax.device_get(h), np.float32)
    np.testing.assert_allclose(outs["psum"], outs["scatter"],
                               rtol=2e-4, atol=2e-4)
    print("OK MoE reduce-scatter combine == psum combine")

    print("ALL DISTRIBUTED SELFTESTS PASSED")


if __name__ == "__main__":
    main()
