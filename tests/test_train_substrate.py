"""Train substrate: optimizers, loop, checkpoint/restart, preemption,
divergence recovery, gradient compression."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import ShardedFeeder, lm_batch
from repro.distributed import (
    compressed_psum_tree,
    init_error_feedback,
    quantize_int8,
    dequantize_int8,
)
from repro.train.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.train.optimizer import (
    OptimizerConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    cosine_schedule,
)
from repro.train.train_loop import Trainer, TrainLoopConfig


def quad_problem(seed=0, n=32):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    target = jnp.asarray(rng.standard_normal((n,)).astype(np.float32))
    params = {"x": jnp.zeros((n,)), "w": jnp.zeros((n, n))}

    def loss(p, batch=None):
        r = a @ p["x"] + jnp.sum(p["w"], -1) - target
        return jnp.sum(r * r), {"r": jnp.sum(r * r)}

    return params, loss


def test_adamw_converges():
    params, loss = quad_problem()
    cfg = OptimizerConfig(name="adamw", weight_decay=0.0)
    state = adamw_init(params)
    l0 = float(loss(params)[0])
    for _ in range(200):
        g = jax.grad(lambda p: loss(p)[0])(params)
        params, state = adamw_update(g, state, params, jnp.float32(0.05), cfg)
    assert float(loss(params)[0]) < 0.01 * l0


def test_adafactor_converges_and_is_factored():
    # well-scaled linear regression (rank-deficient/aggregated losses make
    # any RMS-clipped sign-like optimizer oscillate — not the target regime)
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
    w_true = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
    b_true = jnp.asarray(rng.standard_normal((32,)).astype(np.float32))
    y = w_true @ z + b_true[:, None]
    params = {"w": jnp.zeros((32, 32)), "x": jnp.zeros((32,))}

    def loss(p):
        return jnp.mean((p["w"] @ z + p["x"][:, None] - y) ** 2)

    cfg = OptimizerConfig(name="adafactor", weight_decay=0.0,
                          factored_min_dim=8)
    state = adafactor_init(params, cfg)
    # factored: w [32,32] gets row/col stats, x [32] gets full
    assert state.v_row["w"].shape == (32,)
    assert state.v_col["w"].shape == (32,)
    assert state.v_row["x"].shape == (32,)
    l0 = float(loss(params))
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(g, state, params, jnp.float32(0.1),
                                         cfg)
    assert float(loss(params)) < 0.1 * l0


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(110))) < 1e-6
    assert 0.4 < float(lr(jnp.int32(60))) < 0.6


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "nested": {"b": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, state, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    step, restored, extra = restore_checkpoint(str(tmp_path), state)
    assert step == 7 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_checkpoint_prune_keeps_latest(tmp_path):
    state = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    from repro.train.checkpoint import all_steps

    assert all_steps(str(tmp_path)) == [4, 5]


@pytest.mark.slow
def test_trainer_restart_continues(tmp_path):
    """Kill-and-restart: the restored run continues from the checkpoint."""
    from repro.configs import chatglm3_6b
    from repro.models.transformer import init_params, lm_loss

    cfg = chatglm3_6b.smoke_config()
    params = init_params(jax.random.key(0), cfg)

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch["tokens"], batch["labels"])

    tl_cfg = TrainLoopConfig(
        total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path), log_every=100,
        lr=1e-3, warmup=2,
    )
    gen = lambda seed, step: lm_batch(seed, step, 2, 16, cfg.vocab_size)

    trainer = Trainer(loss_fn, params, tl_cfg)
    feeder = ShardedFeeder(gen, seed=0)
    hist1 = trainer.run(feeder, max_steps=5)  # "preempted" after 5 steps
    feeder.close()
    assert trainer.step == 5
    assert latest_step(str(tmp_path)) == 5  # final save on exit

    # new process: fresh trainer restores and continues to total_steps
    trainer2 = Trainer(loss_fn, init_params(jax.random.key(0), cfg), tl_cfg)
    feeder2 = ShardedFeeder(gen, seed=0)
    hist2 = trainer2.run(feeder2)
    feeder2.close()
    assert trainer2.step == 8
    # training on RANDOM tokens can only learn the marginal (≈ ln V); the
    # restart contract is mechanical continuity + sane losses, not progress
    assert all(np.isfinite(hist2["loss"]))
    assert np.mean(hist2["loss"]) < 1.2 * np.log(cfg.vocab_size)
    assert hist2["step"][0] == 6  # continued exactly after the checkpoint


def test_quantize_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))}
    err = init_error_feedback(g)
    q, scale = quantize_int8(g["w"])
    assert q.dtype == jnp.int8
    deq = dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g["w"]))) < float(scale) * 0.51

    # error feedback: accumulated applied gradient ≈ accumulated true gradient
    applied = jnp.zeros_like(g["w"])
    true_sum = jnp.zeros_like(g["w"])
    for i in range(20):
        gi = {"w": g["w"] * (1.0 + 0.01 * i)}
        out, err = compressed_psum_tree(gi, err, None, 1)
        applied = applied + out["w"]
        true_sum = true_sum + gi["w"]
    # residual is bounded by one quantization step, not growing
    resid = float(jnp.max(jnp.abs(applied - true_sum)))
    assert resid < 2 * float(scale), resid
