"""BlockStore fetch layer: store parity with the sync local path, the
consistent-hash ring (ownership, rebalance), the socket transport, per-owner
fetch splitting, the per-batch operand cache, and dispatch/cache ownership
agreement.

Parity bar mirrors ``tests/test_engine.py``: any store composed with the
engine must return BIT-IDENTICAL ids/scores/stats to the PR-4 sync local
path across metrics × SQ8 × prune × pipeline — the fetch layer must be
unobservable in results, only in where blocks come from.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import FilterSpec, HybridSpec, match_all, storage
from repro.core import blockstore as bs
from repro.core import probes as probes_lib
from repro.core.disk import DiskIVFIndex
from repro.core.distributed import dispatch_probes, probe_capacity
from repro.core.engine import SearchEngine, search_fused_tiled
from repro.core.ivf import build_from_assignments, quantize_index

N, D, M, KC = 1536, 32, 6, 12
TS_RANGE = 6000


def _topic_index(metric="dot"):
    rng = np.random.default_rng(3)
    centers = rng.standard_normal((KC, D)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = (np.arange(N) * KC) // N
    core = centers[topic] + 0.05 * rng.standard_normal((N, D)).astype(
        np.float32
    )
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    band = TS_RANGE // KC
    attrs = rng.integers(0, 16, (N, M)).astype(np.int16)
    attrs[:, 0] = (topic * band + rng.integers(0, band, N)).astype(np.int16)
    spec = HybridSpec(dim=D, n_attrs=M, core_dtype=jnp.float32,
                      metric=metric)
    index, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(topic),
    )
    return index, core


def _window_fspec(q, width):
    rng = np.random.default_rng(7)
    lo = np.full((q, 1, M), -32768, np.int16)
    hi = np.full((q, 1, M), 32767, np.int16)
    start = rng.integers(0, max(TS_RANGE - width, 1), q)
    lo[:, 0, 0] = start.astype(np.int16)
    hi[:, 0, 0] = (start + width - 1).astype(np.int16)
    return FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))


@pytest.fixture(scope="module", params=["dot", "l2"])
def built(request, tmp_path_factory):
    index, core = _topic_index(request.param)
    ckpt = str(tmp_path_factory.mktemp(f"bstore_{request.param}"))
    storage.save_index(index, ckpt, n_shards=2)
    yield index, core, ckpt


def _assert_identical(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(b.ids), np.asarray(a.ids),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.scores), np.asarray(a.scores),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.n_scanned),
                                  np.asarray(a.n_scanned), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.n_passed),
                                  np.asarray(a.n_passed), err_msg=msg)


# ---------------------------------------------------------------------------
# Store parity matrix: Local + Sharded(loopback, 3 nodes) vs the PR-4 sync
# local path, metric × prune × pipeline (+ SQ8 below)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", ["off", "on"])
@pytest.mark.parametrize("prune", ["off", "on"])
def test_stores_match_sync_local_path(built, prune, pipeline):
    index, core, ckpt = built
    q = 21  # ragged multi-tile at q_block=8 → 3 tiles
    queries = jnp.asarray(core[5:5 + q] + 0.01)
    kw = dict(k=10, n_probes=4, q_block=8, v_block=128, backend="xla",
              prune=prune)
    for fspec in (match_all(q, M), _window_fspec(q, TS_RANGE // KC)):
        # the PR-4 sync path: legacy gather, no BlockStore, no operand cache
        with DiskIVFIndex.open(ckpt) as disk:
            sync = SearchEngine(disk, gather_fn=disk.gather, pipeline="off",
                                **kw).search(queries, fspec)
            local = disk.search(queries, fspec, pipeline=pipeline, **kw)
            _assert_identical(sync, local,
                              f"LocalBlockStore prune={prune} "
                              f"pipeline={pipeline}")
        sharded = bs.open_sharded(ckpt, n_nodes=3)
        try:
            with DiskIVFIndex.open(ckpt) as disk:
                got = disk.search(queries, fspec, pipeline=pipeline,
                                  blockstore=sharded, **kw)
            _assert_identical(sync, got,
                              f"ShardedBlockStore prune={prune} "
                              f"pipeline={pipeline}")
        finally:
            sharded.close()


def test_sharded_sq8_matches_ram(built, tmp_path):
    index, core, _ = built
    if index.spec.metric == "l2":
        pytest.skip("SQ8 + l2 not wired (matches non-tiled kernel)")
    qindex = quantize_index(index)
    ckpt = str(tmp_path / "sq8")
    storage.save_index(qindex, ckpt, n_shards=2)
    q = 21
    queries = jnp.asarray(core[:q])
    kw = dict(k=8, n_probes=4, q_block=8, v_block=128, backend="xla")
    ram = search_fused_tiled(qindex, queries, match_all(q, M), **kw)
    sharded = bs.open_sharded(ckpt, n_nodes=3)
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            got = disk.search(queries, match_all(q, M), pipeline="on",
                              blockstore=sharded, **kw)
        _assert_identical(ram, got, "sq8 sharded")
    finally:
        sharded.close()


def test_resident_store_records_match_index(built):
    index, *_ = built
    store = bs.ResidentBlockStore(index)
    recs = store.get([0, 3, 7])
    for cid in (0, 3, 7):
        np.testing.assert_array_equal(recs[cid]["vectors"],
                                      np.asarray(index.vectors[cid]))
        np.testing.assert_array_equal(recs[cid]["ids"],
                                      np.asarray(index.ids[cid]))
    assert store.stats()["blocks"] == 3
    store.close()


def test_resident_store_as_sharded_peers(built):
    """A RAM-tier ring: 3 ResidentBlockStore peers serve bit-identical
    results — no checkpoint needed to exercise sharded routing."""
    index, core, _ = built
    q = 16
    queries = jnp.asarray(core[:q])
    fspec = match_all(q, M)
    kw = dict(k=10, n_probes=4, q_block=8, backend="xla")
    ref = search_fused_tiled(index, queries, fspec, **kw)
    peers = {i: bs.LoopbackTransport(bs.ResidentBlockStore(index))
             for i in range(3)}
    store = bs.ShardedBlockStore(peers)
    try:
        eng = SearchEngine(index, blockstore=store, pipeline="on", **kw)
        got = eng.search(queries, fspec)
        _assert_identical(ref, got, "resident sharded")
        assert eng.stats.blocks_fetched > 0
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Ring: determinism, rebalance moves only the removed node's clusters
# ---------------------------------------------------------------------------


def test_hash_ring_deterministic_and_covering():
    ring = bs.HashRing(range(3))
    cids = np.arange(1000)
    owners = ring.owner_of(cids)
    owners2 = bs.HashRing(range(3)).owner_of(cids)
    np.testing.assert_array_equal(owners, owners2)  # stable across builds
    assert set(np.unique(owners)) == {0, 1, 2}  # every node owns something


def test_hash_ring_removal_moves_only_removed_nodes_keys():
    ring = bs.HashRing(range(4))
    cids = np.arange(5000)
    before = ring.owner_of(cids)
    after = ring.without(2).owner_of(cids)
    kept = before != 2
    np.testing.assert_array_equal(after[kept], before[kept])
    assert not (after == 2).any()
    assert (before == 2).sum() > 0  # the removed node actually owned keys


def test_ring_rebalance_mid_run_identical_results(built):
    """Fault-injection style: a node leaves the ring between batches of a
    stream; results stay bit-identical — only ownership (and therefore
    which peer served each block) moves."""
    index, core, ckpt = built
    q = 16
    kw = dict(k=10, n_probes=4, q_block=8, backend="xla")
    batches = [jnp.asarray(core[i * 16:i * 16 + q]) for i in range(4)]
    fspec = match_all(q, M)
    refs = [search_fused_tiled(index, b, fspec, **kw) for b in batches]
    store = bs.open_sharded(ckpt, n_nodes=3, l1_records=2)
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            eng = SearchEngine(disk, blockstore=store, pipeline="on", **kw)
            owners_before = store.ownership.owner_of(np.arange(KC))
            for b, ref in zip(batches[:2], refs[:2]):
                _assert_identical(ref, eng.search(b, fspec), "pre-removal")
            store.remove_node(1)  # mid-run: the stream keeps flowing
            owners_after = store.ownership.owner_of(np.arange(KC))
            for b, ref in zip(batches[2:], refs[2:]):
                _assert_identical(ref, eng.search(b, fspec), "post-removal")
            # the first two batches must also replay identically
            for b, ref in zip(batches[:2], refs[:2]):
                _assert_identical(ref, eng.search(b, fspec), "replay")
        # ownership moved exactly for the removed node's clusters
        kept = owners_before != 1
        np.testing.assert_array_equal(owners_after[kept],
                                      owners_before[kept])
        assert 1 not in set(np.unique(owners_after))
        assert 1 not in store.transports
    finally:
        store.close()


def test_remove_last_node_rejected():
    store = bs.ShardedBlockStore({0: bs.LoopbackTransport(None)})
    try:
        with pytest.raises(ValueError, match="last node"):
            store.remove_node(0)
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Per-owner fetch splitting
# ---------------------------------------------------------------------------


def test_split_fetch_by_owner_partitions_in_order():
    ring = bs.HashRing(range(3))
    fetch = np.asarray([9, 4, 11, 0, 7, 2, 5], np.int64)
    parts = probes_lib.split_fetch_by_owner(fetch, ring.owner_of)
    owners = ring.owner_of(fetch)
    rebuilt = {}
    for o, sub in parts.items():
        np.testing.assert_array_equal(sub, fetch[owners == o])  # order kept
        for c in sub:
            rebuilt[int(c)] = o
    assert set(rebuilt) == set(fetch.tolist())  # a partition, nothing lost
    assert probes_lib.split_fetch_by_owner([], ring.owner_of) == {}


def test_range_ownership_agrees_with_dispatch():
    """The dispatch's default owner map == an explicit RangeOwnership, and a
    ShardedBlockStore given the same map routes every cluster to the shard
    that scans it."""
    n_shards, k_local, q, t = 4, 3, 8, 4
    own = bs.RangeOwnership(n_shards, k_local)
    rng = np.random.default_rng(0)
    probe_ids = jnp.asarray(
        rng.integers(0, n_shards * k_local, (q, t)), jnp.int32
    )
    p_cap = probe_capacity(q, t, n_shards)
    default = dispatch_probes(probe_ids, n_shards=n_shards, k_local=k_local,
                              p_cap=p_cap)
    explicit = dispatch_probes(probe_ids, n_shards=n_shards,
                               k_local=k_local, p_cap=p_cap, ownership=own)
    for a, b in zip(default, explicit):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # cache routing with the same map = shard routing
    cids = np.arange(n_shards * k_local)
    np.testing.assert_array_equal(own.owner_of(cids), cids // k_local)
    store = bs.ShardedBlockStore(
        {i: bs.LoopbackTransport(None) for i in range(n_shards)},
        ownership=own,
    )
    try:
        parts = probes_lib.split_fetch_by_owner(cids, store.ownership.owner_of)
        for o, sub in parts.items():
            assert (sub // k_local == o).all()
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Socket transport
# ---------------------------------------------------------------------------


def test_socket_transport_roundtrip(built):
    index, core, ckpt = built
    local = bs.LocalBlockStore.open(ckpt)
    server = bs.BlockStoreServer(local)
    client = bs.SocketTransport(server.host, server.port)
    try:
        want = local.get([0, 5, 3])
        got = client.fetch([0, 5, 3])
        assert set(got) == {0, 5, 3}
        for cid in got:
            for field, arr in want[cid].items():
                np.testing.assert_array_equal(got[cid][field], arr)
        assert client.fetch([]) == {}
        assert client.stats()["blocks"] == 3
    finally:
        client.close()
        server.close()
        local.close()


def test_socket_sharded_search_identical(built):
    index, core, ckpt = built
    q = 16
    queries = jnp.asarray(core[:q])
    fspec = match_all(q, M)
    kw = dict(k=10, n_probes=4, q_block=8, backend="xla")
    ref = search_fused_tiled(index, queries, fspec, **kw)
    store = bs.open_sharded(ckpt, n_nodes=2, transport="socket")
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            got = disk.search(queries, fspec, pipeline="on",
                              blockstore=store, **kw)
        _assert_identical(ref, got, "socket sharded")
        stats = store.stats()
        assert sum(n["blocks_served"] for n in stats["per_node"].values()) > 0
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Per-batch operand cache
# ---------------------------------------------------------------------------


def test_operand_cache_reuses_and_stays_exact(built):
    """Fine-grained pipelining with the operand cache: shared clusters are
    device-put once per batch (reuse counter > 0), results bit-identical to
    operand_cache='off' and to the sync path."""
    index, core, ckpt = built
    q = 32  # 4 tiles at q_block=8; hot traffic → tiles share clusters
    rng = np.random.default_rng(5)
    hot = core[rng.integers(0, N, 4)]
    queries = jnp.asarray(
        hot[rng.integers(0, 4, q)]
        + 0.01 * rng.standard_normal((q, D)).astype(np.float32)
    )
    fspec = match_all(q, M)
    kw = dict(k=10, n_probes=4, q_block=8, v_block=128, backend="xla")
    ref = search_fused_tiled(index, queries, fspec, **kw)
    with DiskIVFIndex.open(ckpt) as disk:
        eng_on = SearchEngine(disk, pipeline="on", operand_cache="on", **kw)
        eng_off = SearchEngine(disk, pipeline="on", operand_cache="off",
                               **kw)
        r_on = eng_on.search(queries, fspec)
        r_off = eng_off.search(queries, fspec)
        _assert_identical(ref, r_on, "operand cache on")
        _assert_identical(ref, r_off, "operand cache off")
        assert eng_on.stats.blocks_reused > 0
        assert eng_off.stats.blocks_reused == 0
        # reuse is real work saved: the cache-on engine fetched fewer blocks
        assert eng_on.stats.blocks_fetched < eng_off.stats.blocks_fetched


def test_tile_release_lists_partition_and_mirror_fetch():
    """fetch lists split by FIRST need, release lists by LAST need; both
    partition the batch's unique clusters, and a cluster's release tile is
    ≥ its fetch tile."""
    sc = np.asarray([
        [3, 5, 7, 7],   # tile 0 (n_unique 3)
        [5, 9, 9, 9],   # tile 1 (n_unique 2)
        [3, 9, 2, 2],   # tile 2 (n_unique 3)
    ])
    nu = np.asarray([3, 2, 3])
    fetch = probes_lib.tile_fetch_lists(sc, nu, 4)
    release = probes_lib.tile_release_lists(sc, nu, 4)
    np.testing.assert_array_equal(fetch[0], [3, 5, 7])
    np.testing.assert_array_equal(fetch[1], [9])
    np.testing.assert_array_equal(fetch[2], [2])
    np.testing.assert_array_equal(release[0], [7])
    np.testing.assert_array_equal(release[1], [5])
    np.testing.assert_array_equal(release[2], [3, 9, 2])
    all_f = np.concatenate(fetch)
    all_r = np.concatenate(release)
    assert sorted(all_f.tolist()) == sorted(all_r.tolist())
    first = {int(c): t for t, fs in enumerate(fetch) for c in fs}
    last = {int(c): t for t, rs in enumerate(release) for c in rs}
    assert all(last[c] >= first[c] for c in first)


def test_operand_cache_released_after_last_need(built):
    """The per-batch operand cache frees each record after its last
    consuming tile — by batch end it holds only the final tile's live
    range, not the batch's whole unique set (the disk tier's budget must
    not be defeated by reuse keeping evicted records alive)."""
    index, core, ckpt = built
    q = 32
    queries = jnp.asarray(core[np.linspace(0, N - 1, q).astype(int)])
    fspec = match_all(q, M)
    kw = dict(k=10, n_probes=4, q_block=8, backend="xla")
    with DiskIVFIndex.open(ckpt) as disk:
        eng = SearchEngine(disk, pipeline="on", operand_cache="on", **kw)
        plan = eng.plan(queries, fspec)
        inflight = eng._start_inflight(plan, depth=2)
        res = eng._run_tiles(plan, inflight)
        ref = search_fused_tiled(index, queries, fspec, **kw)
        _assert_identical(ref, res, "released operand cache")
        # release lists partition the fetched set, so after the final
        # tile's assembly every record has been freed
        assert len(plan.operands) == 0
        assert eng.stats.blocks_reused > 0  # reuse still happened en route


def test_operand_cache_is_per_batch(built):
    """Two submitted batches in flight keep separate operand caches (a
    cluster is device-put once per batch, not once per engine)."""
    index, core, ckpt = built
    q = 16
    fspec = match_all(q, M)
    kw = dict(k=10, n_probes=4, q_block=8, backend="xla")
    with DiskIVFIndex.open(ckpt) as disk:
        eng = SearchEngine(disk, pipeline="on", **kw)
        a = eng.submit(jnp.asarray(core[:q]), fspec)
        b = eng.submit(jnp.asarray(core[:q]), fspec)
        assert a.plan.operands is not b.plan.operands
        ra, rb = eng.result(a), eng.result(b)
        ref = search_fused_tiled(index, jnp.asarray(core[:q]), fspec, **kw)
        _assert_identical(ref, ra, "batch a")
        _assert_identical(ref, rb, "batch b")


def test_operand_cache_on_requires_store(built):
    index, *_ = built
    with pytest.raises(ValueError, match="operand_cache"):
        SearchEngine(index, k=5, n_probes=3, operand_cache="on")


def test_submit_after_close_raises(built):
    """A late submit against a closed store must surface loudly — not
    quietly rebuild a fetch pool over a stopped cache."""
    *_, ckpt = built
    store = bs.LocalBlockStore.open(ckpt)
    store.close()
    store.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        store.submit([0])
    with pytest.raises(RuntimeError, match="closed"):
        store.gather_submit(np.asarray([0, 1]))
    # disk index delegates: same guard through the legacy surface
    disk = DiskIVFIndex.open(ckpt)
    disk.close()
    with pytest.raises(RuntimeError, match="closed"):
        disk.gather_submit(np.asarray([0]))


def test_sharded_socket_self_node_disabled(built):
    """Behind a socket every peer costs a round trip, so no node skips the
    L1; loopback keeps the co-located fast path."""
    *_, ckpt = built
    sock = bs.open_sharded(ckpt, n_nodes=2, transport="socket")
    loop = bs.open_sharded(ckpt, n_nodes=2, transport="loopback")
    try:
        assert sock.self_node is None
        assert loop.self_node == 0
        got = sock.get([0, 1, 2, 3])
        assert set(got) == {0, 1, 2, 3}
        sock.get([0, 1, 2, 3])  # every repeat now hits the L1
        assert sock.l1_hits >= 4
    finally:
        sock.close()
        loop.close()


# ---------------------------------------------------------------------------
# Serving-layer integration
# ---------------------------------------------------------------------------


def test_serving_fn_sharded_cache(built):
    from repro.core.serving import make_fused_search_fn

    index, core, ckpt = built
    q = 8
    queries = jnp.asarray(core[:q])
    fspec = match_all(q, M)
    ram_fn = make_fused_search_fn(index, k=5, n_probes=4, q_block=8)
    fn = make_fused_search_fn(ckpt, k=5, n_probes=4, q_block=8,
                              cache_shards=3)
    try:
        ram_scores, ram_ids = ram_fn(queries, fspec, None)
        scores, ids = fn(queries, fspec, None)
        np.testing.assert_array_equal(np.asarray(ram_ids), np.asarray(ids))
        np.testing.assert_array_equal(np.asarray(ram_scores),
                                      np.asarray(scores))
        stats = fn.blockstore.stats()
        assert stats["kind"] == "sharded" and len(stats["per_node"]) == 3
    finally:
        fn.close()


def test_serving_fn_cache_shards_needs_disk(built):
    from repro.core.serving import make_fused_search_fn

    index, *_ = built
    with pytest.raises(ValueError, match="cache_shards"):
        make_fused_search_fn(index, k=5, n_probes=4, cache_shards=2)
