"""Kernel vs oracle: shape/dtype sweeps + hypothesis properties (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import given, needs_hypothesis, settings, st  # noqa: E402

from repro.core import (
    FilterBuilder,
    HybridSpec,
    brute_force,
    build_ivf,
    from_builders,
    match_all,
)
from repro.core.search import search_reference
from repro.kernels.filtered_scan import (
    filtered_scan,
    filtered_scan_ref,
    search_fused,
)

NEG_INF = -3.0e38


def make_case(seed, *, p, q, k_clusters, vpad, d, m, f, core_dtype=np.float32):
    rng = np.random.default_rng(seed)
    slot_cluster = rng.integers(0, k_clusters, p).astype(np.int32)
    slot_query = rng.integers(0, q, p).astype(np.int32)
    queries = rng.standard_normal((q, d)).astype(core_dtype)
    lo = rng.integers(-20, 5, (q, f, m)).astype(np.int16)
    hi = (lo + rng.integers(0, 30, (q, f, m))).astype(np.int16)
    vectors = rng.standard_normal((k_clusters, vpad, d)).astype(core_dtype)
    attrs = rng.integers(-25, 25, (k_clusters, vpad, m)).astype(np.int16)
    ids = rng.integers(-1, 50, (k_clusters, vpad)).astype(np.int32)
    norms = np.sum(vectors.astype(np.float32) ** 2, -1)
    return dict(
        slot_cluster=jnp.asarray(slot_cluster),
        slot_query=jnp.asarray(slot_query),
        queries=jnp.asarray(queries),
        lo=jnp.asarray(lo),
        hi=jnp.asarray(hi),
        vectors=jnp.asarray(vectors),
        attrs=jnp.asarray(attrs),
        ids=jnp.asarray(ids),
        norms=jnp.asarray(norms),
    )


SWEEP = [
    # p, q, K, vpad, d, m, f, v_block, dtype
    (4, 2, 3, 256, 32, 4, 1, 128, np.float32),
    (8, 4, 6, 512, 64, 10, 2, 256, np.float32),
    (3, 3, 3, 128, 16, 1, 1, 128, np.float32),
    (16, 8, 8, 256, 128, 6, 3, 64, np.float32),
    (5, 2, 4, 384, 48, 4, 2, 128, np.float32),
    (4, 2, 3, 256, 32, 4, 1, 128, np.float16),
]


@pytest.mark.parametrize("p,q,K,vpad,d,m,f,vb,dt", SWEEP)
def test_kernel_matches_ref_dot(p, q, K, vpad, d, m, f, vb, dt):
    c = make_case(hash((p, q, K, vpad)) % 2**31, p=p, q=q, k_clusters=K,
                  vpad=vpad, d=d, m=m, f=f, core_dtype=dt)
    out = filtered_scan(
        c["slot_cluster"], c["slot_query"], c["queries"], c["lo"], c["hi"],
        c["vectors"], c["attrs"], c["ids"], metric="dot", v_block=vb,
        interpret=True,
    )
    ref = filtered_scan_ref(
        c["slot_cluster"], c["slot_query"], c["queries"], c["lo"], c["hi"],
        c["vectors"], c["attrs"], c["ids"], metric="dot",
    )
    rtol = 1e-5 if dt == np.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=rtol, atol=1e-5)


@pytest.mark.parametrize("p,q,K,vpad,d,m,f,vb,dt", SWEEP[:3])
def test_kernel_matches_ref_l2(p, q, K, vpad, d, m, f, vb, dt):
    c = make_case(7 + p, p=p, q=q, k_clusters=K, vpad=vpad, d=d, m=m, f=f,
                  core_dtype=dt)
    out = filtered_scan(
        c["slot_cluster"], c["slot_query"], c["queries"], c["lo"], c["hi"],
        c["vectors"], c["attrs"], c["ids"], c["norms"], metric="l2",
        v_block=vb, interpret=True,
    )
    ref = filtered_scan_ref(
        c["slot_cluster"], c["slot_query"], c["queries"], c["lo"], c["hi"],
        c["vectors"], c["attrs"], c["ids"], c["norms"], metric="l2",
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@needs_hypothesis
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**20),
    p=st.integers(1, 6),
    q=st.integers(1, 4),
    K=st.integers(1, 5),
    m=st.integers(1, 6),
    f=st.integers(1, 3),
)
def test_kernel_property_mask_soundness(seed, p, q, K, m, f):
    """Property: kernel score is NEG_INF exactly where the oracle masks."""
    c = make_case(seed, p=p, q=q, k_clusters=K, vpad=128, d=16, m=m, f=f)
    out = np.asarray(
        filtered_scan(
            c["slot_cluster"], c["slot_query"], c["queries"], c["lo"],
            c["hi"], c["vectors"], c["attrs"], c["ids"], metric="dot",
            v_block=64, interpret=True,
        )
    )
    ref = np.asarray(
        filtered_scan_ref(
            c["slot_cluster"], c["slot_query"], c["queries"], c["lo"],
            c["hi"], c["vectors"], c["attrs"], c["ids"], metric="dot",
        )
    )
    np.testing.assert_array_equal(out <= NEG_INF / 2, ref <= NEG_INF / 2)
    live = ref > NEG_INF / 2
    np.testing.assert_allclose(out[live], ref[live], rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(0)
    n, d, m = 1024, 32, 6
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 10, (n, m)).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32)
    index, _ = build_ivf(
        jax.random.key(0), spec, core, attrs, n_clusters=8,
        kmeans_mode="lloyd", kmeans_steps=6,
    )
    return index, core, attrs


def test_search_fused_equals_reference(built):
    index, core, attrs = built
    q = 6
    queries = jnp.asarray(core[:q] + 0.01)
    builders = [FilterBuilder(6).le(0, 6).ge(1, 2) for _ in range(q)]
    fspec = from_builders(builders)
    fused = search_fused(index, queries, fspec, k=10, n_probes=4,
                         v_block=128, interpret=True)
    ref = search_reference(index, queries, fspec, k=10, n_probes=4)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(ref.scores), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(fused.n_passed), np.asarray(ref.n_passed)
    )


def test_search_fused_full_probe_is_exact(built):
    index, core, attrs = built
    queries = jnp.asarray(core[50:54])
    fspec = match_all(4, 6)
    fused = search_fused(index, queries, fspec, k=8,
                         n_probes=index.n_clusters, v_block=128,
                         interpret=True)
    oracle = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries,
                         fspec, k=8)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(oracle.ids))


def test_search_fused_l2(built):
    index, core, attrs = built
    # rebuild with l2 metric
    spec = HybridSpec(dim=32, n_attrs=6, core_dtype=jnp.float32, metric="l2")
    index_l2, _ = build_ivf(
        jax.random.key(1), spec, core, attrs, n_clusters=8,
        kmeans_mode="lloyd", kmeans_steps=6,
    )
    queries = jnp.asarray(core[10:14] * 1.3)
    fspec = match_all(4, 6)
    fused = search_fused(index_l2, queries, fspec, k=6,
                         n_probes=8, v_block=128, interpret=True)
    oracle = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries,
                         fspec, k=6, metric="l2")
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(oracle.ids))
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(oracle.scores), rtol=1e-4, atol=1e-4
    )
