"""Core index behaviour: build invariants, filtered search vs oracle, updates."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    FilterBuilder,
    HybridSpec,
    brute_force,
    build_ivf,
    from_builders,
    match_all,
    recall_at_k,
    search_reference,
    add_vectors,
    tombstone,
    compact_cluster,
    validity_mask,
)


def make_data(seed, n=512, d=16, m=4, n_attr_vals=8):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, n_attr_vals, size=(n, m)).astype(np.int16)
    return core, attrs


@pytest.fixture(scope="module")
def small_index():
    core, attrs = make_data(0)
    spec = HybridSpec(dim=16, n_attrs=4, core_dtype=jnp.float32)
    key = jax.random.key(0)
    index, stats = build_ivf(
        key, spec, core, attrs, n_clusters=8, kmeans_mode="lloyd",
        kmeans_steps=8,
    )
    return index, stats, core, attrs


def test_build_partition_exact(small_index):
    """Every input id appears in exactly one live slot (IVF partition, §3.1)."""
    index, stats, core, attrs = small_index
    assert stats.n_dropped == 0
    ids = np.asarray(index.ids)
    live = ids[np.asarray(validity_mask(index))]
    assert sorted(live.tolist()) == list(range(core.shape[0]))
    assert int(jnp.sum(index.counts)) == core.shape[0]


def test_slot_contents_match_source(small_index):
    """Vectors/attrs land in the slot holding their id."""
    index, _, core, attrs = small_index
    ids = np.asarray(index.ids)
    vecs = np.asarray(index.vectors, dtype=np.float32)
    atts = np.asarray(index.attrs)
    k, vpad = ids.shape
    for c in range(k):
        for s in range(int(index.counts[c])):
            i = ids[c, s]
            assert i >= 0
            np.testing.assert_allclose(vecs[c, s], core[i], rtol=1e-6)
            np.testing.assert_array_equal(atts[c, s], attrs[i])


def test_full_probe_no_filter_equals_brute_force(small_index):
    """T=K and wildcard filter ⇒ IVF search IS exact search."""
    index, _, core, attrs = small_index
    queries = jnp.asarray(core[:7] + 0.01)
    fspec = match_all(7, 4)
    res = search_reference(index, queries, fspec, k=10, n_probes=index.n_clusters)
    ref = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries, fspec, k=10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(res.scores), np.asarray(ref.scores), rtol=1e-5, atol=1e-5
    )


def test_filtered_results_satisfy_filter(small_index):
    """No returned id may violate its query's filter (soundness)."""
    index, _, core, attrs = small_index
    q = 5
    queries = jnp.asarray(core[10 : 10 + q])
    builders = [
        FilterBuilder(4).eq(0, i % 3).between(1, 0, 5) for i in range(q)
    ]
    fspec = from_builders(builders)
    res = search_reference(index, queries, fspec, k=8, n_probes=index.n_clusters)
    ids = np.asarray(res.ids)
    for qi in range(q):
        for i in ids[qi]:
            if i < 0:
                continue
            assert attrs[i, 0] == qi % 3
            assert 0 <= attrs[i, 1] <= 5


def test_filtered_equals_filtered_brute_force(small_index):
    index, _, core, attrs = small_index
    q = 4
    queries = jnp.asarray(core[30 : 30 + q] + 0.02)
    builders = [FilterBuilder(4).le(2, 4).ge(3, 2) for _ in range(q)]
    fspec = from_builders(builders)
    res = search_reference(index, queries, fspec, k=12, n_probes=index.n_clusters)
    ref = brute_force(
        jnp.asarray(core), jnp.asarray(attrs), queries, fspec, k=12
    )
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_recall_monotone_in_probes(small_index):
    """Paper §4.3: larger T ⇒ recall must not get materially worse."""
    index, _, core, attrs = small_index
    rng = np.random.default_rng(3)
    queries = jnp.asarray(
        rng.standard_normal((16, 16)).astype(np.float32)
    )
    fspec = match_all(16, 4)
    ref = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries, fspec, k=10)
    recalls = []
    for t in (1, 2, 4, 8):
        res = search_reference(index, queries, fspec, k=10, n_probes=t)
        recalls.append(recall_at_k(res, ref))
    assert recalls[-1] == 1.0  # T=K is exact
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:]))


def test_isin_filter_or_semantics(small_index):
    index, _, core, attrs = small_index
    queries = jnp.asarray(core[:3])
    builders = [FilterBuilder(4).isin(0, [1, 3]) for _ in range(3)]
    fspec = from_builders(builders)
    res = search_reference(index, queries, fspec, k=8, n_probes=index.n_clusters)
    ids = np.asarray(res.ids)
    for row in ids:
        for i in row:
            if i >= 0:
                assert attrs[i, 0] in (1, 3)


def test_empty_filter_returns_no_hits(small_index):
    index, _, core, attrs = small_index
    queries = jnp.asarray(core[:2])
    builders = [FilterBuilder(4).eq(0, 999) for _ in range(2)]  # impossible
    fspec = from_builders(builders)
    res = search_reference(index, queries, fspec, k=5, n_probes=index.n_clusters)
    assert np.all(np.asarray(res.ids) == -1)
    assert np.all(np.asarray(res.n_passed) == 0)


def test_add_vector_then_search_finds_it(small_index):
    """Paper §4.5: the appended vector becomes retrievable."""
    index, _, core, attrs = small_index
    rng = np.random.default_rng(7)
    new_core = rng.standard_normal((3, 16)).astype(np.float32)
    new_core /= np.linalg.norm(new_core, axis=-1, keepdims=True)
    new_attrs = np.full((3, 4), 7, np.int16)
    new_ids = jnp.asarray([1000, 1001, 1002], dtype=jnp.int32)
    index2, dropped = add_vectors(
        index, jnp.asarray(new_core), jnp.asarray(new_attrs), new_ids
    )
    assert int(dropped) == 0
    assert int(index2.n_live) == int(index.n_live) + 3
    queries = jnp.asarray(new_core)
    fspec = match_all(3, 4)
    res = search_reference(index2, queries, fspec, k=1, n_probes=index.n_clusters)
    np.testing.assert_array_equal(
        np.asarray(res.ids)[:, 0], [1000, 1001, 1002]
    )


def test_tombstone_hides_vector(small_index):
    index, _, core, attrs = small_index
    # find location of id 0
    loc = np.argwhere(np.asarray(index.ids) == 0)[0]
    index2 = tombstone(index, jnp.asarray([loc[0]]), jnp.asarray([loc[1]]))
    queries = jnp.asarray(core[:1])
    fspec = match_all(1, 4)
    res = search_reference(index2, queries, fspec, k=5, n_probes=index.n_clusters)
    assert 0 not in np.asarray(res.ids)[0].tolist()
    # compaction keeps everything else intact
    index3 = compact_cluster(index2, int(loc[0]))
    assert int(index3.counts[loc[0]]) == int(index.counts[loc[0]]) - 1
    res3 = search_reference(index3, queries, fspec, k=5, n_probes=index.n_clusters)
    np.testing.assert_array_equal(np.asarray(res3.ids), np.asarray(res.ids))


def test_l2_metric_matches_brute_force():
    core, attrs = make_data(11, n=256, d=8)
    spec = HybridSpec(dim=8, n_attrs=4, core_dtype=jnp.float32, metric="l2")
    index, _ = build_ivf(
        jax.random.key(1), spec, core, attrs, n_clusters=6,
        kmeans_mode="lloyd", kmeans_steps=5,
    )
    queries = jnp.asarray(core[:4] * 1.5)
    fspec = match_all(4, 4)
    res = search_reference(index, queries, fspec, k=6, n_probes=6)
    ref = brute_force(
        jnp.asarray(core), jnp.asarray(attrs), queries, fspec, k=6, metric="l2"
    )
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(res.scores), np.asarray(ref.scores), rtol=1e-4, atol=1e-4
    )


def test_minibatch_kmeans_reduces_inertia():
    from repro.core.kmeans import minibatch_kmeans, pairwise_neg_dist2, init_from_sample

    core, _ = make_data(5, n=1024, d=8)
    x = jnp.asarray(core)
    key = jax.random.key(2)
    st0 = init_from_sample(key, x, 16)
    st = minibatch_kmeans(key, x, n_clusters=16, n_steps=50, batch_size=256)

    def inertia(c):
        s = pairwise_neg_dist2(x, c)
        return float(jnp.sum(jnp.sum(x * x, -1) - jnp.max(s, -1)))

    assert inertia(st.centroids) < inertia(st0.centroids) * 0.9
