"""Fault-tolerant serving: circuit-breaker state machine, transport
deadlines/typed errors, and the fault matrix.

The bar (ISSUE 6): no matter which fault fires — connection refusal,
mid-stream disconnect, payload truncation, latency spike, slow-peer
brownout — every batch completes and results stay BIT-IDENTICAL to the
healthy ``LocalBlockStore`` sync path.  Failover changes where bytes come
from, never what is returned.  The breaker tests run on a fake clock, the
chaos tests on the deterministic :mod:`repro.core.faults` schedule, and
the rogue-server tests on hand-rolled sockets — nothing here is timing-
or luck-dependent beyond generous deadlines.
"""

import socket
import struct
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import HybridSpec, match_all, storage
from repro.core import blockstore as bs
from repro.core import faults
from repro.core.disk import DiskIVFIndex
from repro.core.engine import SearchEngine
from repro.core.health import CLOSED, HALF_OPEN, OPEN, CircuitBreaker, \
    PeerHealth
from repro.core.ivf import build_from_assignments
from repro.core.transport import (
    _FRAME,
    BlockStoreServer,
    SocketTransport,
    TransportError,
    TransportTimeout,
    _recv_frame,
    _send_frame,
)

N, D, M, KC = 1536, 32, 6, 12
TS_RANGE = 6000


def _topic_index():
    rng = np.random.default_rng(3)
    centers = rng.standard_normal((KC, D)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = (np.arange(N) * KC) // N
    core = centers[topic] + 0.05 * rng.standard_normal((N, D)).astype(
        np.float32
    )
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    band = TS_RANGE // KC
    attrs = rng.integers(0, 16, (N, M)).astype(np.int16)
    attrs[:, 0] = (topic * band + rng.integers(0, band, N)).astype(np.int16)
    spec = HybridSpec(dim=D, n_attrs=M, core_dtype=jnp.float32)
    index, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(topic),
    )
    return index, core


KW = dict(k=10, n_probes=4, q_block=8, v_block=128, backend="xla")
Q = 21  # ragged multi-tile at q_block=8 → 3 tiles → several store gets


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    index, core = _topic_index()
    ckpt = str(tmp_path_factory.mktemp("faults"))
    storage.save_index(index, ckpt, n_shards=2)
    queries = jnp.asarray(core[5:5 + Q] + 0.01)
    fspec = match_all(Q, M)
    with DiskIVFIndex.open(ckpt) as disk:
        ref = {
            prune: disk.search(queries, fspec, prune=prune, **KW)
            for prune in ("off", "on")
        }
    yield ckpt, queries, fspec, ref


def _assert_identical(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(b.ids), np.asarray(a.ids),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.scores), np.asarray(a.scores),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.n_scanned),
                                  np.asarray(a.n_scanned), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.n_passed),
                                  np.asarray(a.n_passed), err_msg=msg)


# ---------------------------------------------------------------------------
# Circuit breaker state machine (fake clock — no sleeps)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _breaker(clock, **kw):
    kw.setdefault("failure_threshold", 3)
    kw.setdefault("cooldown_s", 1.0)
    kw.setdefault("half_open_successes", 2)
    return CircuitBreaker(clock=clock, **kw)


def test_breaker_opens_on_threshold():
    clk = FakeClock()
    br = _breaker(clk)
    for _ in range(2):
        br.record_failure()
    assert br.state == CLOSED  # below threshold
    br.record_failure()
    assert br.state == OPEN
    assert not br.allow()  # cooldown not elapsed


def test_breaker_no_flapping_on_intermittent_faults():
    """Successes reset the consecutive-failure count: a peer that fails
    every other request never trips a threshold-3 breaker."""
    clk = FakeClock()
    br = _breaker(clk)
    for _ in range(20):
        br.record_failure()
        br.record_failure()
        br.record_success(0.001)
    assert br.state == CLOSED
    assert br.trips == 0


def test_breaker_half_open_probe_and_close():
    clk = FakeClock()
    br = _breaker(clk)
    for _ in range(3):
        br.record_failure()
    assert br.state == OPEN
    clk.advance(1.1)
    assert br.allow()  # the half-open probe token
    assert br.state == HALF_OPEN
    assert not br.allow()  # only one probe in flight at a time
    br.record_success(0.001)
    assert br.state == HALF_OPEN  # needs half_open_successes=2
    assert br.allow()
    br.record_success(0.001)
    assert br.state == CLOSED


def test_breaker_half_open_failure_escalates_cooldown():
    clk = FakeClock()
    br = _breaker(clk, cooldown_s=1.0, cooldown_factor=2.0,
                  cooldown_max_s=3.0)
    for _ in range(3):
        br.record_failure()
    clk.advance(1.1)
    assert br.allow()
    br.record_failure()  # probe failed → reopen, cooldown ×2
    assert br.state == OPEN
    clk.advance(1.1)
    assert not br.allow()  # 1.1 < escalated 2.0
    clk.advance(1.0)
    assert br.allow()
    br.record_failure()  # ×2 again, capped at 3.0
    clk.advance(2.9)
    assert not br.allow()
    clk.advance(0.2)
    assert br.allow()


def test_breaker_brownout_trips_on_latency_ewma():
    clk = FakeClock()
    br = _breaker(clk, brownout_latency_s=0.05, latency_alpha=0.5)
    br.record_success(0.001)
    assert br.state == CLOSED
    for _ in range(8):  # EWMA climbs toward 0.2
        br.record_success(0.2)
        if br.state == OPEN:
            break
    assert br.state == OPEN
    # recovery: the peer answers fast now — probes close the circuit
    clk.advance(1.1)
    assert br.allow()
    br.record_success(0.001)
    assert br.allow()
    br.record_success(0.001)
    assert br.state == CLOSED


def test_breaker_half_open_slow_answer_is_not_recovery():
    clk = FakeClock()
    br = _breaker(clk, brownout_latency_s=0.05, latency_alpha=1.0)
    br.record_success(0.2)  # instant trip at alpha=1
    assert br.state == OPEN
    clk.advance(1.1)
    assert br.allow()
    br.record_success(0.2)  # answered, but still browned out
    assert br.state == OPEN


def test_peer_health_registry():
    clk = FakeClock()
    ph = PeerHealth([0, 1, 2], breaker_kwargs=dict(failure_threshold=1),
                    clock=clk)
    assert not ph.degraded
    ph.on_failure(1)
    assert ph.state(1) == OPEN and ph.state(0) == CLOSED
    assert ph.degraded
    assert not ph.allow(1)
    clk.advance(1.1)
    calls = []
    assert ph.probe(1, lambda: calls.append(1))
    assert ph.probe(1, lambda: calls.append(1))
    assert calls == [1, 1]
    assert ph.state(1) == CLOSED  # default half_open_successes=2
    assert not ph.probe(1, lambda: calls.append(1))  # closed → no probe


# ---------------------------------------------------------------------------
# Fault matrix: every fault class × pipeline × prune — bit-identical
# ---------------------------------------------------------------------------

ERROR_KINDS = ("refuse", "disconnect", "truncate")


@pytest.mark.parametrize("pipeline", ["off", "on"])
@pytest.mark.parametrize("prune", ["off", "on"])
@pytest.mark.parametrize("kind",
                         list(ERROR_KINDS) + ["latency", "brownout"])
def test_fault_matrix_bit_identical(built, kind, prune, pipeline):
    ckpt, queries, fspec, ref = built
    if kind in ERROR_KINDS:
        # first op succeeds, then the peer dies mid-run and stays dead
        rules = (faults.FaultRule(kind, after=1),)
        breaker = dict(failure_threshold=1, cooldown_s=60.0)
    elif kind == "latency":  # a bounded spike — absorbed, never tripped
        rules = (faults.FaultRule("latency", latency_s=0.02, count=2),)
        breaker = dict(failure_threshold=1, cooldown_s=60.0)
    else:  # brownout: answers, slowly, forever → EWMA tripwire
        rules = (faults.FaultRule("latency", latency_s=0.06),)
        breaker = dict(failure_threshold=1, cooldown_s=60.0,
                       brownout_latency_s=0.02, latency_alpha=1.0)
    store = bs.open_sharded(ckpt, n_nodes=3, breaker_kwargs=breaker)
    faults.inject(store, 1, rules)
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            for _ in range(2):
                # drop the L1 between batches: batch 1 warms the peer
                # (op 0 passes), batch 2 must re-fetch through the ring
                # and hits the now-armed fault mid-stream
                got = disk.search(queries, fspec, prune=prune,
                                  pipeline=pipeline, blockstore=store, **KW)
                with store._l1_lock:
                    store._l1.clear()
        _assert_identical(ref[prune], got,
                          f"{kind} prune={prune} pipeline={pipeline}")
        s = store.stats()
        if kind in ERROR_KINDS:
            assert s["failovers"] >= 1
            assert s["fallback_blocks"] > 0
            assert s["health"][1] == OPEN
        elif kind == "latency":
            assert s["failovers"] == 0
            assert s["health"][1] == CLOSED
        else:  # brownout
            assert s["health"][1] == OPEN
            assert s["fallback_blocks"] > 0
    finally:
        store.close()


def test_no_fallback_preserves_fail_fast(built):
    """Without an availability floor the PR-5 contract holds: the typed
    transport error surfaces instead of being silently absorbed."""
    ckpt, queries, fspec, ref = built
    store = bs.open_sharded(ckpt, n_nodes=3, fallback=None)
    faults.inject(store, 1, faults.kill_peer())
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            with pytest.raises(ConnectionError):
                disk.search(queries, fspec, pipeline="off",
                            blockstore=store, **KW)
    finally:
        store.close()


def test_engine_counts_degraded_batches(built):
    ckpt, queries, fspec, ref = built
    store = bs.open_sharded(
        ckpt, n_nodes=3,
        breaker_kwargs=dict(failure_threshold=1, cooldown_s=60.0),
    )
    faults.inject(store, 1, faults.kill_peer())
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            eng = SearchEngine(disk, blockstore=store, pipeline="on",
                               prune="off", **KW)
            got = eng.search(queries, fspec)
            _assert_identical(ref["off"], got, "degraded engine batch")
            assert eng.stats.degraded_batches >= 1
            eng.close()
    finally:
        store.close()


def test_recovery_closes_circuit_and_resumes_remote(built):
    """Peer dies for 2 ops, then answers again: the active probe notices
    (L1 adoption means passive traffic may never re-touch the peer), the
    circuit closes, and remote fetches resume without a restart."""
    ckpt, queries, fspec, ref = built
    store = bs.open_sharded(
        ckpt, n_nodes=3,
        breaker_kwargs=dict(failure_threshold=1, cooldown_s=0.05,
                            half_open_successes=1),
    )
    faults.inject(store, 1, (faults.FaultRule("refuse", after=0, count=2),))
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            got = disk.search(queries, fspec, prune="off",
                              blockstore=store, **KW)
            _assert_identical(ref["off"], got, "during outage")
            assert store.health.state(1) == OPEN
            deadline = time.monotonic() + 30
            while (store.health.state(1) != CLOSED
                   and time.monotonic() < deadline):
                store.probe_peers()
                time.sleep(0.06)
            assert store.health.state(1) == CLOSED
            assert not store.degraded
            # remote fetches resume: bypass the adopted L1 and refetch
            with store._l1_lock:
                store._l1.clear()
            served_before = store.stats()["per_node"][1]["blocks_served"]
            store.get(np.arange(KC))
            assert (store.stats()["per_node"][1]["blocks_served"]
                    > served_before)
            got = disk.search(queries, fspec, prune="off",
                              blockstore=store, **KW)
            _assert_identical(ref["off"], got, "after recovery")
    finally:
        store.close()


def test_socket_peer_killed_mid_stream(built):
    """Real wire path: one of three BlockStoreServers is closed mid-run.
    Batches keep completing (bit-identical) and stats report failovers;
    double-closing the dead server is a no-op."""
    ckpt, queries, fspec, ref = built
    store = bs.open_sharded(
        ckpt, n_nodes=3, transport="socket", timeout_s=5.0, retries=1,
        breaker_kwargs=dict(failure_threshold=1, cooldown_s=60.0),
    )
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            got = disk.search(queries, fspec, prune="off", pipeline="on",
                              blockstore=store, **KW)
            _assert_identical(ref["off"], got, "healthy ring")
            store._owned_servers[1].close()  # the kill
            store._owned_servers[1].close()  # idempotent double-close
            with store._l1_lock:
                store._l1.clear()  # force re-fetching through the ring
            got = disk.search(queries, fspec, prune="off", pipeline="on",
                              blockstore=store, **KW)
            _assert_identical(ref["off"], got, "one peer dead")
        s = store.stats()
        assert s["failovers"] >= 1 or s["redirected_blocks"] > 0
        assert s["fallback_blocks"] > 0
        assert s["health"][1] == OPEN
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Transport: typed errors, deadlines, coalescing, pool
# ---------------------------------------------------------------------------


def _rogue_server(behavior):
    """One-shot server: accepts one connection, reads the request frame,
    then misbehaves per ``behavior(conn)``."""
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    host, port = lsock.getsockname()

    def run():
        conn, _ = lsock.accept()
        try:
            _recv_frame(conn)
            behavior(conn)
        finally:
            conn.close()
            lsock.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return host, port


def test_short_read_raises_typed_error_not_decode_garbage():
    """Peer closes mid-payload → TransportError (a ConnectionError), not a
    struct.error / zipfile decode error two layers up (the PR-5 bug)."""
    def close_mid_payload(conn):
        conn.sendall(_FRAME.pack(1000) + b"xy")  # promise 1000, send 2

    host, port = _rogue_server(close_mid_payload)
    tr = SocketTransport(host, port, timeout=5.0, retries=0)
    try:
        with pytest.raises(TransportError) as ei:
            tr.fetch([0, 1])
        assert isinstance(ei.value, ConnectionError)  # old callers catch it
        assert not isinstance(ei.value, struct.error)
    finally:
        tr.close()


def test_corrupt_payload_raises_typed_error():
    def garbage_payload(conn):
        _send_frame(conn, b"this is not an npz archive")

    host, port = _rogue_server(garbage_payload)
    tr = SocketTransport(host, port, timeout=5.0, retries=0)
    try:
        with pytest.raises(TransportError):
            tr.fetch([0])
    finally:
        tr.close()


def test_connection_refused_raises_typed_error():
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    host, port = lsock.getsockname()
    lsock.close()  # nothing listens here
    tr = SocketTransport(host, port, timeout=1.0, retries=1,
                         backoff_s=0.01)
    try:
        with pytest.raises(TransportError):
            tr.fetch([0])
        assert tr.stats()["retries"] == 1  # backoff+retry actually ran
    finally:
        tr.close()


def test_deadline_bounded_fetch(built):
    """A server stalled past the client deadline costs one bounded wait
    and a TransportTimeout — never a hung batch."""
    ckpt, *_ = built
    lstore = bs.LocalBlockStore.open(ckpt)
    sched = faults.FaultSchedule(
        (faults.FaultRule("latency", latency_s=5.0),)
    )
    srv = BlockStoreServer(faults.FaultyBlockStore(lstore, sched))
    tr = SocketTransport(srv.host, srv.port, timeout=0.3, retries=0)
    try:
        t0 = time.monotonic()
        with pytest.raises(TransportTimeout):
            tr.fetch([0])
        assert time.monotonic() - t0 < 3.0
        assert tr.stats()["timeouts"] >= 1
    finally:
        tr.close()
        srv.close()
        lstore.close()


def test_coalescing_one_wire_fetch_per_cluster(built):
    """Two threads requesting the same ids through one transport issue one
    wire fetch; the follower is served from the leader's response."""
    ckpt, *_ = built
    lstore = bs.LocalBlockStore.open(ckpt)
    sched = faults.FaultSchedule(
        (faults.FaultRule("latency", latency_s=0.1),)  # one slow op →
    )                                                  # guaranteed overlap
    srv = BlockStoreServer(faults.FaultyBlockStore(lstore, sched))
    tr = SocketTransport(srv.host, srv.port, timeout=10.0)
    try:
        res = [None, None]

        def go(i):
            res[i] = tr.fetch([0, 1, 2])

        ts = [threading.Thread(target=go, args=(i,)) for i in range(2)]
        ts[0].start()
        time.sleep(0.03)  # leader is mid-flight (0.1s server stall) when
        ts[1].start()     # the follower asks for the same ids
        for t in ts:
            t.join()
        assert res[0].keys() == res[1].keys() == {0, 1, 2}
        for cid in (0, 1, 2):
            np.testing.assert_array_equal(res[0][cid]["ids"],
                                          res[1][cid]["ids"])
        s = tr.stats()
        assert s["coalesced"] >= 1
        assert s["requests"] + s["coalesced"] // 3 <= 3
    finally:
        tr.close()
        srv.close()
        lstore.close()


def test_ping_round_trip(built):
    ckpt, *_ = built
    lstore = bs.LocalBlockStore.open(ckpt)
    srv = BlockStoreServer(lstore)
    tr = SocketTransport(srv.host, srv.port, timeout=5.0)
    try:
        tr.ping()  # a real empty-request wire exchange
        assert tr.stats()["requests"] >= 1
        srv.close()
        with pytest.raises(TransportError):
            tr.ping()  # dead server → typed failure (the probe signal)
    finally:
        tr.close()
        lstore.close()


# ---------------------------------------------------------------------------
# BlockStoreServer close semantics
# ---------------------------------------------------------------------------


def test_server_close_is_idempotent_and_unblocks_accepter(built):
    ckpt, *_ = built
    lstore = bs.LocalBlockStore.open(ckpt)
    srv = BlockStoreServer(lstore)
    assert srv._accepter.is_alive()
    srv.close()
    assert not srv._accepter.is_alive()
    srv.close()  # double close: no-op, no error
    assert not srv._accepter.is_alive()
    lstore.close()


def test_server_close_with_request_in_flight(built):
    """close() while a handler is mid-request returns promptly, the client
    gets a typed error (not a hang), and the accepter is gone."""
    ckpt, *_ = built
    lstore = bs.LocalBlockStore.open(ckpt)
    sched = faults.FaultSchedule(
        (faults.FaultRule("latency", latency_s=1.0),)
    )
    srv = BlockStoreServer(faults.FaultyBlockStore(lstore, sched))
    tr = SocketTransport(srv.host, srv.port, timeout=10.0, retries=0)
    errs = []

    def go():
        try:
            tr.fetch([0, 1])
        except TransportError as e:
            errs.append(e)

    t = threading.Thread(target=go)
    t.start()
    time.sleep(0.2)  # request is in flight, handler sleeping in the store
    t0 = time.monotonic()
    srv.close()
    assert time.monotonic() - t0 < 6.0
    t.join(timeout=10)
    assert not t.is_alive()
    assert not srv._accepter.is_alive()
    assert len(errs) == 1  # the in-flight request surfaced a typed error
    tr.close()
    lstore.close()
