"""Bound-driven early termination: ε=0 bit-identity vs the untruncated
engine across the metric × SQ8 × prune × pipeline × store × delta matrix,
bound soundness, monotone recall-vs-ε, and the compile-count bound for the
segmented bound-ordered scans.

The parity bar mirrors the engine refactor's: ``termination="exact"`` may
only reorder and provably skip work — ids and scores must stay BITWISE
identical to ``termination=None`` while ``stats.probes_terminated`` shows
the provable exits actually fire on a selective stream.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import needs_hypothesis, given, settings, st

from repro.core import FilterSpec, HybridSpec, storage
from repro.core.delta import DeltaTier
from repro.core.disk import DiskIVFIndex
from repro.core.engine import SearchEngine, scan_compile_count
from repro.core.ivf import build_from_assignments, quantize_index
from repro.core.serving import make_fused_search_fn

N, D, M = 1536, 32, 6
KC = 16            # one topic per histogram bin: categories never alias
TS_RANGE = 6000
K, NP, QB = 10, 4, 8


def _twin_index(metric="dot"):
    """Twin-pair topic index on which provable drops actually fire.

    Clusters come in near-duplicate pairs (twin cosine ≈ 0.97) while
    cross-pair centers are near-orthogonal, so a query aimed at one pair
    sees the other probed clusters' upper bounds fall strictly below its
    running kth score.  attr0 is a topic-owned time band and attr1 the
    topic id itself; one planted uniform-ts row per histogram bin and two
    rows per category (disjoint populations, so no planted row passes a
    joint filter) pin every cluster's summary to full range — surviving
    probes then carry small *expected-passing* mass, which is what the
    ε tier drops.
    """
    rng = np.random.default_rng(5)
    base = rng.standard_normal((KC // 2, D)).astype(np.float32)
    base /= np.linalg.norm(base, axis=-1, keepdims=True)
    step = rng.standard_normal((KC // 2, D)).astype(np.float32)
    step /= np.linalg.norm(step, axis=-1, keepdims=True)
    centers = np.empty((KC, D), np.float32)
    centers[0::2] = base
    twin = base + 0.25 * step
    centers[1::2] = twin / np.linalg.norm(twin, axis=-1, keepdims=True)

    topic = (np.arange(N) * KC) // N
    core = centers[topic] + 0.05 * rng.standard_normal((N, D)).astype(
        np.float32
    )
    core /= np.linalg.norm(core, axis=-1, keepdims=True)

    band_of = rng.permutation(KC)
    band = TS_RANGE // KC
    ts = band_of[topic] * band + rng.integers(0, band, N)
    cat = topic.copy()
    bin_ts = (np.arange(KC) * (TS_RANGE - 1)) // (KC - 1)
    for t in range(KC):
        rows = np.where(topic == t)[0]
        ts[rows[:KC]] = bin_ts
        cat[rows[KC:3 * KC]] = np.repeat(np.arange(KC), 2)

    attrs = rng.integers(0, 16, (N, M)).astype(np.int16)
    attrs[:, 0] = ts.astype(np.int16)
    attrs[:, 1] = cat.astype(np.int16)
    spec = HybridSpec(dim=D, n_attrs=M, core_dtype=jnp.float32,
                      metric=metric)
    index, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(topic),
    )
    return index, core, centers, band_of


def _twin_stream(centers, band_of, q, seed=17, selectivity=0.03):
    """Selective stream: tight queries on a few hot topics (distinct
    pairs), a thin attr0 window inside the topic's band AND attr1 == topic.

    Default selectivity leaves ~2k of each hot cluster's rows passing —
    enough to fill top-k (kth > −inf is what arms the provable drops) while
    staying far below the match-all stream."""
    rng = np.random.default_rng(seed)
    band = TS_RANGE // KC
    w = max(int(selectivity * TS_RANGE), 1)
    pairs = rng.permutation(KC // 2)[:3]
    hot = 2 * pairs + rng.integers(0, 2, 3)
    topics = hot[rng.integers(0, 3, q)]
    qs = centers[topics] + 0.01 * rng.standard_normal((q, D)).astype(
        np.float32
    )
    lo = np.full((q, 1, M), -32768, np.int16)
    hi = np.full((q, 1, M), 32767, np.int16)
    start = band_of[topics] * band + rng.integers(0, max(band - w, 1), q)
    lo[:, 0, 0] = start.astype(np.int16)
    hi[:, 0, 0] = (start + w - 1).astype(np.int16)
    lo[:, 0, 1] = topics.astype(np.int16)
    hi[:, 0, 1] = topics.astype(np.int16)
    return jnp.asarray(qs), FilterSpec(lo=jnp.asarray(lo),
                                       hi=jnp.asarray(hi))


@pytest.fixture(scope="module", params=["dot", "l2"])
def built(request, tmp_path_factory):
    index, core, centers, band_of = _twin_index(request.param)
    ckpt = str(tmp_path_factory.mktemp(f"term_{request.param}"))
    storage.save_index(index, ckpt, n_shards=2)
    disk = DiskIVFIndex.open(ckpt)
    yield index, disk, core, centers, band_of, ckpt
    disk.close()


def _assert_bitwise(base, term, msg=""):
    """ids + scores bitwise; n_scanned/n_passed legitimately differ
    (terminated probes never reach the scan)."""
    np.testing.assert_array_equal(np.asarray(term.ids),
                                  np.asarray(base.ids), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(term.scores),
                                  np.asarray(base.scores), err_msg=msg)


# ---------------------------------------------------------------------------
# ε=0 bit-identity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["f32", "sq8"])
@pytest.mark.parametrize("prune", ["off", "on"])
@pytest.mark.parametrize("pipeline", ["off", "on"])
def test_exact_identity_ram(built, quantized, prune, pipeline):
    index, _, _, centers, band_of, _ = built
    target = quantize_index(index) if quantized else index
    queries, fspec = _twin_stream(centers, band_of, 21)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune=prune, pipeline=pipeline)
    base = SearchEngine(target, **kw)
    term = SearchEngine(target, termination="exact", **kw)
    r0 = base.search(queries, fspec)
    r1 = term.search(queries, fspec)
    _assert_bitwise(r0, r1,
                    msg=f"sq8={quantized} prune={prune} pipe={pipeline}")
    assert term.stats.probes_terminated > 0, "provable exits never fired"
    base.close()
    term.close()


@pytest.mark.parametrize("prune", ["off", "on"])
def test_exact_identity_disk(built, prune):
    _, disk, _, centers, band_of, _ = built
    queries, fspec = _twin_stream(centers, band_of, 21)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune=prune)
    base = SearchEngine(disk, **kw)
    term = SearchEngine(disk, termination="exact", **kw)
    r0 = base.search(queries, fspec)
    r1 = term.search(queries, fspec)
    _assert_bitwise(r0, r1, msg=f"disk prune={prune}")
    assert term.stats.probes_terminated > 0
    base.close()
    term.close()


def test_exact_identity_sharded(built):
    *_, centers, band_of, ckpt = built
    queries, fspec = _twin_stream(centers, band_of, 21)
    kw = dict(k=K, n_probes=NP, q_block=QB, cache_shards=2)
    base_fn = make_fused_search_fn(ckpt, **kw)
    term_fn = make_fused_search_fn(ckpt, termination="exact", **kw)
    s0, i0 = base_fn(queries, fspec, True)
    s1, i1 = term_fn(queries, fspec, True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
    assert term_fn.engine.stats.probes_terminated > 0
    base_fn.index.close()
    term_fn.index.close()


def test_exact_identity_delta_live(built, tmp_path):
    """Delta tier live (adds + cold/delta tombstones): the RAM delta fold
    runs after the terminated scan and must not disturb bit-identity."""
    index, _, core, centers, band_of, _ = built
    ckpt = str(tmp_path / "ck")
    storage.save_index(index, ckpt, n_shards=2)
    disk = DiskIVFIndex.open(ckpt)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier

    rng = np.random.default_rng(11)
    add = (centers[rng.integers(0, KC, 48)]
           + 0.05 * rng.standard_normal((48, D))).astype(np.float32)
    add /= np.linalg.norm(add, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, TS_RANGE, (48, M)).astype(np.int16)
    tier.add(add, add_attrs, np.arange(N, N + 48, dtype=np.int64))
    cold_dead = rng.choice(N, 32, replace=False)
    tier.tombstone(cold_dead, clusters=(np.arange(N) * KC // N)[cold_dead])
    tier.tombstone(np.arange(N, N + 5, dtype=np.int64))

    queries, fspec = _twin_stream(centers, band_of, 21)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    base = SearchEngine(disk, **kw)
    term = SearchEngine(disk, termination="exact", **kw)
    r0 = base.search(queries, fspec)
    r1 = term.search(queries, fspec)
    _assert_bitwise(r0, r1, msg="delta live")
    assert term.stats.probes_terminated > 0
    base.close()
    term.close()
    disk.close()


# ---------------------------------------------------------------------------
# Bound soundness
# ---------------------------------------------------------------------------


def test_bounds_sound_vs_bruteforce(built):
    """The per-(query, cluster) upper bound dominates the true max stored
    score — the invariant that makes a provable drop lossless."""
    index, _, _, centers, band_of, _ = built
    eng = SearchEngine(index, k=K, n_probes=NP, q_block=QB,
                       termination="exact")
    bounds = eng._resolve_bounds()
    radius = np.asarray(bounds.radius, np.float64)
    slack = np.asarray(bounds.slack, np.float64)
    vec = np.asarray(index.vectors, np.float64)       # [KC, Vpad, D]
    ids = np.asarray(index.ids)
    C = np.asarray(index.centroids, np.float64)
    live = ids >= 0

    queries, _ = _twin_stream(centers, band_of, 8)
    qs = np.asarray(queries, np.float64)
    metric = index.spec.metric
    for qi in range(qs.shape[0]):
        q = qs[qi]
        for c in range(KC):
            rows = vec[c][live[c]]
            if not rows.size:
                continue
            if metric == "dot":
                true_max = float(np.max(rows @ q))
                ub = float(q @ C[c]) + float(np.linalg.norm(q)) * radius[c]
            else:
                # kernel space pre-fixup: 2q·x̂ − ‖x̂‖², bounded via the
                # ‖q‖² − max(d − r, 0)² ball bound plus the norm slack
                true_max = float(np.max(
                    2.0 * rows @ q - np.sum(rows * rows, axis=-1)
                ))
                d = float(np.linalg.norm(q - C[c]))
                near = max(d - radius[c], 0.0)
                ub = float(q @ q) - near * near + slack[c]
            assert true_max <= ub + 1e-3 + 1e-4 * abs(ub), (
                f"bound violated q={qi} c={c}: max {true_max} > ub {ub}"
            )
    eng.close()


def test_dropped_probe_never_held_topk(built):
    """ε=0 soundness restated on results: across many random selective
    streams the terminated engine (drops firing every batch) returns the
    untruncated engine's exact ids."""
    index, _, _, centers, band_of, _ = built
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    base = SearchEngine(index, **kw)
    term = SearchEngine(index, termination="exact", **kw)
    total = 0
    for seed in range(5):
        queries, fspec = _twin_stream(centers, band_of, 16, seed=100 + seed)
        r0 = base.search(queries, fspec)
        r1 = term.search(queries, fspec)
        _assert_bitwise(r0, r1, msg=f"seed={seed}")
        total = term.stats.probes_terminated
    assert total > 0
    base.close()
    term.close()


# ---------------------------------------------------------------------------
# Monotone recall vs ε
# ---------------------------------------------------------------------------


def _recall_vs(base_ids, ids):
    hit = 0
    for row_b, row in zip(np.asarray(base_ids), np.asarray(ids)):
        hit += len(set(row_b.tolist()) & set(row.tolist()))
    return hit / base_ids.size


@needs_hypothesis
@settings(max_examples=6, deadline=None)
@given(e1=st.floats(0.0, 0.4), e2=st.floats(0.0, 0.4),
       seed=st.integers(0, 2**16))
def test_recall_monotone_in_epsilon(built_dot_cached, e1, e2):
    """Same stream, growing ε ⇒ the kept candidate pool only shrinks, so
    recall vs the untruncated baseline is non-increasing (pointwise — the
    ε decision fires once, at the first segment boundary, where state is
    identical across ε)."""
    index, centers, band_of = built_dot_cached
    lo, hi = sorted((e1, e2))
    queries, fspec = _twin_stream(centers, band_of, 16, seed=seed)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    base = SearchEngine(index, **kw)
    r0 = base.search(queries, fspec)
    recalls = []
    for eps in (lo, hi):
        eng = SearchEngine(index, termination="bounded", epsilon=eps, **kw)
        recalls.append(_recall_vs(r0.ids, eng.search(queries, fspec).ids))
        eng.close()
    base.close()
    assert recalls[1] <= recalls[0] + 1e-12, (
        f"recall rose with ε: ε={lo}->{recalls[0]}, ε={hi}->{recalls[1]}"
    )


@pytest.fixture(scope="module")
def built_dot_cached():
    index, _, centers, band_of = _twin_index("dot")
    return index, centers, band_of


# ---------------------------------------------------------------------------
# Compile-count bound
# ---------------------------------------------------------------------------


def test_terminated_scan_compile_count_bounded(built_dot_cached):
    """Varied filters and streams must reuse the segmented scan's compiled
    cells: batch shapes are bucketed, so after the first batch no new
    specializations appear."""
    index, centers, band_of = built_dot_cached
    eng = SearchEngine(index, k=K, n_probes=NP, q_block=QB, prune="on",
                       termination="bounded", epsilon=0.01)
    queries, fspec = _twin_stream(centers, band_of, 16, seed=900)
    eng.search(queries, fspec)
    warm = scan_compile_count()
    for seed in range(901, 907):
        queries, fspec = _twin_stream(
            centers, band_of, 16, seed=seed,
            selectivity=(0.03 if seed % 2 else 0.08),
        )
        eng.search(queries, fspec)
    assert scan_compile_count() == warm, (
        "terminated scan recompiled on a same-shape batch"
    )
    eng.close()
