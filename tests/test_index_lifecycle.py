"""Index lifecycle: SQ8 persistence, pad_k probe-exclusion, compaction.

These pin the bugs the disk tier shipped with: scales dropped by
``save_index``/``load_index``, scales not padded by ``pad_k``, padded
clusters probeable under dot with negative query sums, and
``compact_cluster`` desyncing SQ8 rows from their dequantization scales.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HybridSpec,
    add_vectors,
    compact_cluster,
    match_all,
    tombstone,
)
from repro.core import build_ivf, storage
from repro.core.ivf import quantize_index
from repro.core.search import search_centroids, search_reference


def _build(metric="dot", seed=0, n=600, d=12, m=3, kc=6):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 5, (n, m)).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32, metric=metric)
    index, _ = build_ivf(
        jax.random.key(0), spec, core, attrs, n_clusters=kc,
        kmeans_mode="lloyd", kmeans_steps=4,
    )
    return index, core, attrs


def _assert_same_search(a, b, queries, k=8, n_probes=None):
    n_probes = n_probes or a.n_clusters
    fspec = match_all(queries.shape[0], a.spec.n_attrs)
    ra = search_reference(a, queries, fspec, k=k, n_probes=n_probes)
    rb = search_reference(b, queries, fspec, k=k, n_probes=n_probes)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_allclose(
        np.asarray(ra.scores), np.asarray(rb.scores), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("metric", ["dot", "l2"])
@pytest.mark.parametrize("layout", [1, 2])
def test_sq8_save_load_roundtrip(tmp_path, metric, layout):
    """Quantized save→load→search must equal pre-save search exactly."""
    index, core, _ = _build(metric)
    qindex = quantize_index(index)
    d = str(tmp_path / f"sq8_{metric}_{layout}")
    storage.save_index(qindex, d, n_shards=3, layout=layout)

    man = storage.load_manifest(d)
    assert man["quantized"] is True

    loaded = storage.load_index(d)
    assert loaded.quantized
    assert loaded.vectors.dtype == jnp.int8  # codes stay codes, no cast
    np.testing.assert_allclose(
        np.asarray(loaded.scales), np.asarray(qindex.scales), rtol=0, atol=0
    )
    _assert_same_search(qindex, loaded, jnp.asarray(core[:8]))


def test_unquantized_roundtrip_both_layouts(tmp_path):
    """v1 (legacy npz) stays readable and agrees with v2 on the same index."""
    index, core, _ = _build("l2")
    d1, d2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    storage.save_index(index, d1, n_shards=2, layout=1)
    storage.save_index(index, d2, n_shards=2, layout=2)
    assert storage.load_manifest(d1)["layout"] == 1
    assert storage.load_manifest(d2)["layout"] == 2
    q = jnp.asarray(core[:6])
    _assert_same_search(index, storage.load_index(d1), q)
    _assert_same_search(index, storage.load_index(d2), q)


def test_pad_k_pads_scales():
    index, _, _ = _build()
    qindex = quantize_index(index)
    padded = storage.pad_k(qindex, qindex.n_clusters + 4)
    assert padded.scales is not None
    assert padded.scales.shape == (qindex.n_clusters + 4, qindex.vpad)
    np.testing.assert_array_equal(
        np.asarray(padded.scales[: qindex.n_clusters]),
        np.asarray(qindex.scales),
    )


@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_pad_k_clusters_unprobeable(metric):
    """Padded (counts==0) clusters never receive probe budget — including
    for dot queries whose components sum negative (the sentinel-sign bug)."""
    index, core, _ = _build(metric)
    k = index.n_clusters
    padded = storage.pad_k(quantize_index(index), k + 6)
    rng = np.random.default_rng(3)
    negq = -np.abs(rng.standard_normal((16, core.shape[1]))).astype(np.float32)
    for queries in (jnp.asarray(negq), jnp.asarray(core[:16])):
        probe_ids, _ = search_centroids(padded, queries, k)
        probed_counts = np.asarray(padded.counts)[np.asarray(probe_ids)]
        assert (probed_counts > 0).all(), "probe budget spent on empty pads"
    # and the padded index returns the same results as the original
    _assert_same_search(
        quantize_index(index), padded, jnp.asarray(core[:8]), n_probes=k
    )


def test_lifecycle_add_tombstone_compact_quantized():
    """add→tombstone→compact on SQ8 must preserve scores bit-exactly: the
    compaction permutes int8 rows and their scales together."""
    index, core, _ = _build()
    qindex = quantize_index(index)
    rng = np.random.default_rng(7)
    d, m = core.shape[1], 3
    new = rng.standard_normal((4, d)).astype(np.float32)
    new /= np.linalg.norm(new, axis=-1, keepdims=True)
    na = np.full((4, m), 2, np.int16)
    q2, dropped = add_vectors(
        qindex, jnp.asarray(new), jnp.asarray(na),
        jnp.asarray([900, 901, 902, 903], jnp.int32),
    )
    assert int(dropped) == 0

    cluster = int(np.argmax(np.asarray(q2.counts)))
    q3 = tombstone(q2, jnp.asarray([cluster]), jnp.asarray([0]))

    queries = jnp.asarray(np.concatenate([core[:6], new], 0))
    fspec = match_all(queries.shape[0], m)
    pre = search_reference(q3, queries, fspec, k=8, n_probes=q3.n_clusters)
    q4 = compact_cluster(q3, cluster)
    post = search_reference(q4, queries, fspec, k=8, n_probes=q4.n_clusters)

    np.testing.assert_array_equal(np.asarray(pre.ids), np.asarray(post.ids))
    np.testing.assert_allclose(
        np.asarray(pre.scores), np.asarray(post.scores), rtol=0, atol=0
    )
    # the tombstoned slot was actually reclaimed
    assert int(q4.counts[cluster]) == int(q3.counts[cluster]) - 1


def test_quantized_v1_pre_fix_checkpoint_rejected(tmp_path):
    """A v1 checkpoint claiming quantized but lacking scales (written by the
    pre-fix saver) must fail loudly, not silently score garbage."""
    import json
    import os

    index, _, _ = _build()
    qindex = quantize_index(index)
    d = str(tmp_path / "pre_fix")
    storage.save_index(qindex, d, n_shards=1, layout=1)
    # simulate the pre-fix writer: strip scales from the payload
    path = storage.shard_paths(d, storage.load_manifest(d))[0]
    data = dict(np.load(path))
    data.pop("scales")
    np.savez(path, **data)
    with pytest.raises(ValueError, match="scales"):
        storage.load_index(d)

    # a genuinely pre-fix manifest (no 'quantized' key at all) must be
    # rejected too: the int8 codes betray the quantization even when the
    # flag is missing — casting them to float would silently score garbage
    mpath = os.path.join(d, storage.MANIFEST)
    man = json.load(open(mpath))
    del man["quantized"]
    json.dump(man, open(mpath, "w"))
    with pytest.raises(ValueError, match="scales"):
        storage.load_index(d)


def test_cluster_cache_pin_swap_budget_and_pin_safety(tmp_path):
    """Pin-aware eviction accounting: through pin_refresh swaps — even with
    pin_fraction=1.0 — resident_bytes() never exceeds the budget's cache
    allotment and pinned entries are never evicted (the old fallback evicted
    a *pinned* victim once a swap pinned the whole capacity)."""
    from repro.core.disk import ClusterCache, DiskIVFIndex, ShardReader

    index, core, _ = _build(n=1200, kc=12)
    d = str(tmp_path / "pin_swap")
    storage.save_index(index, d, n_shards=2)
    man = storage.load_manifest(d)
    reader = ShardReader(d, man)
    capacity = 4
    cache = ClusterCache(reader, capacity_records=capacity, n_clusters=12,
                         pin_fraction=1.0, pin_refresh=1)  # swap every batch
    cap_bytes = capacity * reader.stride
    try:
        rng = np.random.default_rng(0)
        hot = [0, 1, 2]  # always-probed clusters: the pin set converges here
        for _ in range(20):
            want = hot + rng.integers(3, 12, 3).tolist()
            cache.get_many([int(c) for c in want])
            assert cache.resident_bytes() <= cap_bytes
            # pins never exceed capacity-1: one slot stays evictable, so an
            # insert never has to break a pin to respect the budget
            assert len(cache.pinned) <= capacity - 1
        assert cache.stats.evictions > 0  # churn actually happened
        # the hot clusters are pinned and stayed resident through the churn
        assert set(hot) <= cache.pinned
        misses_before = cache.stats.misses
        cache.get_many(hot)
        assert cache.stats.misses == misses_before, "a pinned entry was " \
            "evicted under pin_refresh churn"
    finally:
        cache.stop()

    with pytest.raises(ValueError, match="pin_fraction"):
        ClusterCache(reader, capacity_records=4, n_clusters=12,
                     pin_fraction=1.5)

    # end-to-end: a budgeted disk index under swap-heavy traffic holds the
    # resident_bytes() ≤ resident_budget_bytes invariant at every step
    overhead = index.centroids.size * 4 + index.n_clusters * 4 + (
        index.summaries.nbytes() if index.summaries is not None else 0
    )
    budget = overhead + 3 * man["record_stride"] + 1024
    disk = DiskIVFIndex.open(d, resident_budget_bytes=budget,
                             pin_fraction=1.0, pin_refresh=1)
    try:
        fspec = match_all(8, index.spec.n_attrs)
        for rep in range(6):
            queries = jnp.asarray(core[rep * 8:rep * 8 + 8])
            disk.search(queries, fspec, k=5, n_probes=4, q_block=8,
                        backend="xla")
            assert disk.resident_bytes() <= budget
    finally:
        disk.close()
