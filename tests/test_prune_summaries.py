"""Cluster attribute summaries + filter-aware probe pruning.

The contract under test (core/summaries.py, the plan stage in
kernels/filtered_scan/ops.py): summaries may only prune clusters with ZERO
rows passing the query's filter, so ``search_fused_tiled(prune='on')`` must
return bit-identical ids/scores/n_passed to ``prune='off'`` across metrics ×
SQ8 × DNF-term counts × both tiers.  Widening (t_max) trades bit-identity
for recall: every surfaced hit must still be an exact (query, vector) score
and recall must not drop.  Maintenance (add / tombstone / compact) must keep
the summaries on the conservative side of that line.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import given, needs_hypothesis, settings, st  # noqa: E402

from repro.core import (
    FilterBuilder,
    FilterSpec,
    HybridSpec,
    brute_force,
    build_summaries,
    can_match,
    from_builders,
    match_all,
    recall_at_k,
    selectivity,
)
from repro.core.filters import filter_mask
from repro.core.hybrid import ATTR_MAX, ATTR_MIN
from repro.core.ivf import build_from_assignments, quantize_index
from repro.core.probes import fetch_order, plan_probe_tiles
from repro.core.search import search_reference
from repro.core.summaries import expected_passing
from repro.core.update import add_vectors, compact_cluster, tombstone
from repro.kernels.filtered_scan import search_fused_tiled


# ---------------------------------------------------------------------------
# fixtures: an index whose attributes correlate with its clusters (the
# workload pruning exists for) built from known assignments
# ---------------------------------------------------------------------------


def _make_index(metric="dot", *, n=1200, d=16, m=4, kc=12, seed=0,
                quantize=False):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    assignment = rng.integers(0, kc, n)
    attrs = rng.integers(0, 50, (n, m)).astype(np.int16)
    # attr0: cluster-correlated narrow band -> interval pruning bites
    attrs[:, 0] = (assignment * 10 + rng.integers(0, 3, n)).astype(np.int16)
    # attr1: cluster-correlated category with gaps -> histogram pruning bites
    attrs[:, 1] = ((assignment % 5) * 7).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32,
                      metric=metric)
    centroids = np.stack([
        core[assignment == c].mean(0) if (assignment == c).any()
        else np.zeros(d, np.float32)
        for c in range(kc)
    ]).astype(np.float32)
    index, _ = build_from_assignments(
        spec, jnp.asarray(centroids), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(assignment),
    )
    if quantize:
        index = quantize_index(index)
    return index, core, attrs


@pytest.fixture(scope="module")
def built():
    return _make_index("dot")


def _selective_fspecs(q, m):
    """Filters that actually prune on _make_index's attribute layout."""
    out = {
        "band": from_builders(
            [FilterBuilder(m).between(0, 30 + 10 * (i % 3), 42 + (i % 3))
             for i in range(q)]
        ),
        "eq_gap": from_builders(  # attr1 only takes {0,7,14,21,28}
            [FilterBuilder(m).between(1, 1 + (i % 3), 6) for i in range(q)]
        ),
        "isin": from_builders(
            [FilterBuilder(m).isin(0, [11, 52, 90 + (i % 5)])
             for i in range(q)],
        ),
        "match_all": match_all(q, m),
    }
    return out


# ---------------------------------------------------------------------------
# summary construction
# ---------------------------------------------------------------------------


def test_build_summaries_matches_numpy(built):
    index, _, _ = built
    s = index.summaries
    assert s is not None
    A = np.asarray(index.attrs)
    ids = np.asarray(index.ids)
    K, vpad, m = A.shape
    for c in range(K):
        live = ids[c] >= 0
        if not live.any():
            assert (np.asarray(s.amin[c]) == ATTR_MAX).all()
            assert (np.asarray(s.amax[c]) == ATTR_MIN).all()
            assert (np.asarray(s.hist[c]) == 0).all()
            continue
        np.testing.assert_array_equal(np.asarray(s.amin[c]),
                                      A[c][live].min(0))
        np.testing.assert_array_equal(np.asarray(s.amax[c]),
                                      A[c][live].max(0))
        assert (np.asarray(s.hist[c]).sum(-1) == live.sum()).all()


def test_summary_histogram_bins_are_monotone(built):
    """Row mass lands in the bin range its value maps to: for every cluster
    and attribute, the summed hist equals the live count and zero-mass bins
    really contain no live values."""
    index, _, _ = built
    s = index.summaries
    A = np.asarray(index.attrs)
    ids = np.asarray(index.ids)
    B = s.n_bins
    lo = np.asarray(s.edges_lo, np.int64)
    span = np.maximum(np.asarray(s.edges_hi, np.int64) - lo + 1, 1)
    for c in range(index.n_clusters):
        live = ids[c] >= 0
        vals = A[c][live]  # [n_live, M]
        bins = np.clip((vals - lo) * B // span, 0, B - 1)
        for mm in range(index.spec.n_attrs):
            counts = np.bincount(bins[:, mm], minlength=B)
            np.testing.assert_array_equal(np.asarray(s.hist[c, mm]), counts)


# ---------------------------------------------------------------------------
# pruning soundness: can_match == False  =>  zero passing rows
# ---------------------------------------------------------------------------


def _assert_prune_sound(index, fspec):
    cm = np.asarray(can_match(index.summaries, fspec.lo, fspec.hi))
    A = np.asarray(index.attrs)
    ids = np.asarray(index.ids)
    q = len(fspec)
    for qi in range(q):
        row = FilterSpec(lo=fspec.lo[qi:qi + 1], hi=fspec.hi[qi:qi + 1])
        for c in range(index.n_clusters):
            if cm[qi, c]:
                continue  # True promises nothing
            live = ids[c] >= 0
            passing = np.asarray(
                filter_mask(row, jnp.asarray(A[c][None]))
            )[0]
            assert not np.logical_and(passing, live).any(), (
                f"cluster {c} pruned for query {qi} but has passing rows"
            )


def test_can_match_sound_on_selective_filters(built):
    index, _, _ = built
    for name, fspec in _selective_fspecs(6, 4).items():
        _assert_prune_sound(index, fspec)


def test_can_match_wildcard_never_prunes(built):
    index, _, _ = built
    fspec = match_all(5, 4, n_terms=3)  # spare voided terms included
    cm = np.asarray(can_match(index.summaries, fspec.lo, fspec.hi))
    live_clusters = np.asarray(index.counts) > 0
    assert cm[:, live_clusters].all()


@needs_hypothesis
@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_can_match_sound_random(seed, n_terms):
    rng = np.random.default_rng(seed)
    kc, vpad, m = 6, 32, 3
    attrs = rng.integers(-40, 40, (kc, vpad, m)).astype(np.int16)
    ids = rng.integers(-1, 30, (kc, vpad)).astype(np.int32)
    s = build_summaries(jnp.asarray(attrs), jnp.asarray(ids), n_bins=8)
    q = 4
    lo = rng.integers(-60, 40, (q, n_terms, m)).astype(np.int16)
    hi = (lo + rng.integers(-5, 30, (q, n_terms, m))).astype(np.int16)
    cm = np.asarray(can_match(s, jnp.asarray(lo), jnp.asarray(hi)))
    for qi in range(q):
        inside = np.logical_and(
            attrs[..., None, :] >= lo[qi][None, None],
            attrs[..., None, :] <= hi[qi][None, None],
        )  # [kc, vpad, F, m]
        passing = np.any(np.all(inside, -1), -1) & (ids >= 0)
        for c in range(kc):
            if not cm[qi, c]:
                assert not passing[c].any()


def test_expected_passing_estimator_limits(built):
    """The ranking estimate hits its two exact anchors: a wildcard filter
    expects every live row to pass (est == counts), a voided filter expects
    none (est == 0).  In between it is only a ranking signal — soundness
    never rides on it."""
    index, _, _ = built
    wild = match_all(3, 4, n_terms=2)  # includes voided spare terms
    ep = np.asarray(expected_passing(index.summaries, wild.lo, wild.hi,
                                     index.counts))
    np.testing.assert_allclose(
        ep, np.broadcast_to(np.asarray(index.counts, np.float32), ep.shape),
        rtol=1e-6,
    )
    void = FilterSpec(  # lo > hi everywhere: no term can match
        lo=jnp.full((3, 2, 4), ATTR_MAX, jnp.int16),
        hi=jnp.full((3, 2, 4), ATTR_MIN, jnp.int16),
    )
    ep0 = np.asarray(expected_passing(index.summaries, void.lo, void.hi,
                                      index.counts))
    assert (ep0 == 0).all()


# ---------------------------------------------------------------------------
# the property: prune=on is bit-identical to prune=off (both tiers)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["dot", "l2"])
@pytest.mark.parametrize("quantize", [False, True])
def test_prune_parity_ram(metric, quantize):
    if quantize and metric == "l2":
        pytest.skip("SQ8 + l2 not wired (matches non-tiled kernel)")
    index, core, _ = _make_index(metric, quantize=quantize)
    q = 21  # ragged tiles at q_block=16
    queries = jnp.asarray(core[5:5 + q] + 0.01)
    for name, fspec in _selective_fspecs(q, 4).items():
        kw = dict(k=9, n_probes=4, q_block=16, backend="xla")
        off = search_fused_tiled(index, queries, fspec, prune="off", **kw)
        on = search_fused_tiled(index, queries, fspec, prune="on", **kw)
        np.testing.assert_array_equal(np.asarray(on.ids),
                                      np.asarray(off.ids), err_msg=name)
        np.testing.assert_array_equal(np.asarray(on.scores),
                                      np.asarray(off.scores), err_msg=name)
        np.testing.assert_array_equal(np.asarray(on.n_passed),
                                      np.asarray(off.n_passed), err_msg=name)
        # pruning is also exact vs the reference pipeline
        ref = search_reference(index, queries, fspec, k=9, n_probes=4)
        np.testing.assert_array_equal(np.asarray(on.ids),
                                      np.asarray(ref.ids), err_msg=name)
        # accounting: pruned probes are real and scanned rows shrink
        assert np.asarray(off.n_pruned).sum() == 0
        if name != "match_all":
            assert np.asarray(on.n_pruned).sum() > 0
            assert (np.asarray(on.n_scanned)
                    <= np.asarray(off.n_scanned)).all()
        else:
            assert np.asarray(on.n_pruned).sum() == 0
            np.testing.assert_array_equal(np.asarray(on.n_scanned),
                                          np.asarray(off.n_scanned))


def test_prune_parity_interpret_backend(built):
    """Pruning lives in the plan stage, so the Pallas kernel (interpret
    mode) must agree with the XLA executor on a pruned plan too."""
    index, core, _ = built
    q = 8
    queries = jnp.asarray(core[:q] + 0.01)
    fspec = _selective_fspecs(q, 4)["band"]
    kw = dict(k=7, n_probes=4, q_block=8, v_block=128)
    on = search_fused_tiled(index, queries, fspec, prune="on",
                            backend="pallas_interpret", **kw)
    off = search_fused_tiled(index, queries, fspec, prune="off",
                             backend="xla", **kw)
    np.testing.assert_array_equal(np.asarray(on.ids), np.asarray(off.ids))
    np.testing.assert_allclose(np.asarray(on.scores),
                               np.asarray(off.scores), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_terms", [1, 2, 4])
def test_prune_parity_term_counts(built, n_terms):
    index, core, _ = built
    q = 10
    queries = jnp.asarray(core[:q] + 0.01)
    builders = [
        FilterBuilder(4).isin(0, [13 + (i % 3), 52, 101, 7][:n_terms])
        for i in range(q)
    ]
    fspec = from_builders(builders)  # exactly n_terms DNF terms per query
    kw = dict(k=7, n_probes=5, q_block=8, backend="xla")
    off = search_fused_tiled(index, queries, fspec, prune="off", **kw)
    on = search_fused_tiled(index, queries, fspec, prune="on", **kw)
    np.testing.assert_array_equal(np.asarray(on.ids), np.asarray(off.ids))
    np.testing.assert_array_equal(np.asarray(on.scores),
                                  np.asarray(off.scores))


def test_prune_parity_disk_tier(tmp_path):
    from repro.core import storage
    from repro.core.disk import DiskIVFIndex

    index, core, _ = _make_index("dot")
    storage.save_index(index, str(tmp_path / "ckpt"), n_shards=4)
    disk = DiskIVFIndex.open(str(tmp_path / "ckpt"))
    try:
        assert disk.summaries is not None  # resident, loaded from v2.1
        q = 12
        queries = jnp.asarray(core[:q] + 0.01)
        for name, fspec in _selective_fspecs(q, 4).items():
            kw = dict(k=8, n_probes=4, q_block=8)
            on = disk.search(queries, fspec, prune="on", **kw)
            off = disk.search(queries, fspec, prune="off", **kw)
            ram = search_fused_tiled(index, queries, fspec, prune="off",
                                     backend="xla", **kw)
            np.testing.assert_array_equal(np.asarray(on.ids),
                                          np.asarray(off.ids), err_msg=name)
            np.testing.assert_array_equal(np.asarray(on.ids),
                                          np.asarray(ram.ids), err_msg=name)
            np.testing.assert_array_equal(np.asarray(on.scores),
                                          np.asarray(off.scores),
                                          err_msg=name)
    finally:
        disk.close()


def test_prune_shrinks_disk_fetch_list(tmp_path):
    """The point of the tentpole: pruned clusters never reach the cache."""
    from repro.core import storage
    from repro.core.disk import DiskIVFIndex

    index, core, _ = _make_index("dot")
    storage.save_index(index, str(tmp_path / "ckpt"), n_shards=4)
    q = 16
    queries = jnp.asarray(core[:q] + 0.01)
    fspec = _selective_fspecs(q, 4)["band"]

    def run(prune):
        disk = DiskIVFIndex.open(str(tmp_path / "ckpt"))
        try:
            res = disk.search(queries, fspec, k=8, n_probes=4, q_block=8,
                              prune=prune)
            fetched = disk.cache.stats.misses + disk.cache.stats.prefetched
        finally:
            disk.close()
        return res, fetched

    on, fetched_on = run("on")
    off, fetched_off = run("off")
    assert np.asarray(on.n_pruned).sum() > 0
    assert fetched_on < fetched_off
    np.testing.assert_array_equal(np.asarray(on.ids), np.asarray(off.ids))


def test_prune_on_without_summaries_raises(built):
    index, core, _ = built
    bare = dataclasses.replace(index, summaries=None)
    with pytest.raises(ValueError, match="no cluster summaries"):
        search_fused_tiled(bare, jnp.asarray(core[:8]), match_all(8, 4),
                           k=5, n_probes=3, prune="on", backend="xla")
    # auto degrades to unpruned silently
    res = search_fused_tiled(bare, jnp.asarray(core[:8]), match_all(8, 4),
                             k=5, n_probes=3, prune="auto", backend="xla")
    assert np.asarray(res.n_pruned).sum() == 0


# ---------------------------------------------------------------------------
# adaptive probe widening
# ---------------------------------------------------------------------------


def test_widening_recovers_recall_exactly(built):
    """t_max refill: recall vs the filtered oracle must not drop below the
    narrow plan's, surfaced scores must be exact, unfiltered queries must be
    untouched (bit-identical to prune=off)."""
    index, core, attrs = built
    q = 16
    queries = jnp.asarray(core[100:100 + q] + 0.01)
    fspec = _selective_fspecs(q, 4)["band"]
    kw = dict(k=8, n_probes=3, q_block=8, backend="xla")
    narrow = search_fused_tiled(index, queries, fspec, prune="on", **kw)
    wide = search_fused_tiled(index, queries, fspec, prune="on",
                              t_max=10, **kw)
    oracle = brute_force(
        jnp.asarray(core), jnp.asarray(attrs), queries, fspec, k=8,
        metric="dot",
    )
    assert recall_at_k(wide, oracle) >= recall_at_k(narrow, oracle)
    assert (np.asarray(wide.ids) >= 0).sum() >= (
        np.asarray(narrow.ids) >= 0
    ).sum()
    # every surfaced hit is a real exact score of a row passing the filter
    ids_ = np.asarray(wide.ids)
    scores_ = np.asarray(wide.scores)
    qn = np.asarray(queries)
    for qi in range(q):
        for j in range(8):
            vid = ids_[qi, j]
            if vid >= 0:
                np.testing.assert_allclose(
                    scores_[qi, j], float(qn[qi] @ core[vid]),
                    rtol=1e-4, atol=1e-4,
                )
                row = FilterSpec(lo=fspec.lo[qi:qi + 1],
                                 hi=fspec.hi[qi:qi + 1])
                assert np.asarray(
                    filter_mask(row, jnp.asarray(attrs[vid][None, None]))
                )[0, 0]

    # unfiltered traffic: widening must be a no-op
    wild = match_all(q, 4)
    base = search_fused_tiled(index, queries, wild, prune="off", **kw)
    widew = search_fused_tiled(index, queries, wild, prune="on",
                               t_max=10, **kw)
    np.testing.assert_array_equal(np.asarray(widew.ids),
                                  np.asarray(base.ids))
    np.testing.assert_array_equal(np.asarray(widew.scores),
                                  np.asarray(base.scores))


def test_widening_validation(built):
    index, core, _ = built
    with pytest.raises(ValueError, match="t_max"):
        search_fused_tiled(index, jnp.asarray(core[:8]), match_all(8, 4),
                           k=5, n_probes=4, t_max=2, backend="xla")


# ---------------------------------------------------------------------------
# maintenance keeps the contract
# ---------------------------------------------------------------------------


def _parity(index, queries, fspec, **kw):
    on = search_fused_tiled(index, queries, fspec, prune="on", **kw)
    off = search_fused_tiled(index, queries, fspec, prune="off", **kw)
    np.testing.assert_array_equal(np.asarray(on.ids), np.asarray(off.ids))
    np.testing.assert_array_equal(np.asarray(on.scores),
                                  np.asarray(off.scores))
    return on


def test_add_widens_summaries(built):
    index, core, _ = built
    rng = np.random.default_rng(7)
    b = 16
    new_core = rng.standard_normal((b, 16)).astype(np.float32)
    new_core /= np.linalg.norm(new_core, axis=-1, keepdims=True)
    # attribute values outside every existing cluster band
    new_attrs = np.full((b, 4), 205, np.int16)
    idx2, n_dropped = add_vectors(
        index, jnp.asarray(new_core), jnp.asarray(new_attrs),
        jnp.arange(5000, 5000 + b),
    )
    assert int(n_dropped) == 0
    # the widened summaries must now admit the new band where it landed...
    fspec = from_builders([FilterBuilder(4).eq(0, 205) for _ in range(b)])
    queries = jnp.asarray(new_core)
    on = _parity(idx2, queries, fspec, k=5, n_probes=4, q_block=8,
                 backend="xla")
    found = np.asarray(on.ids)
    assert (found >= 5000).any(), "added rows must stay reachable under prune"
    # ...and the soundness property still holds everywhere
    _assert_prune_sound(idx2, _selective_fspecs(6, 4)["band"])


def test_tombstone_stays_conservative(built):
    index, core, _ = built
    # tombstone a handful of rows of cluster 2
    idx2 = tombstone(index, jnp.asarray([2, 2, 2]), jnp.asarray([0, 1, 2]))
    q = 10
    queries = jnp.asarray(core[:q] + 0.01)
    for fspec in _selective_fspecs(q, 4).values():
        _parity(idx2, queries, fspec, k=7, n_probes=4, q_block=8,
                backend="xla")
    _assert_prune_sound(idx2, _selective_fspecs(6, 4)["band"])


def test_compact_rebuilds_exactly(built):
    index, core, _ = built
    idx2 = tombstone(index, jnp.asarray([3] * 5), jnp.asarray(list(range(5))))
    idx3 = compact_cluster(idx2, 3)
    # compaction recomputes cluster 3's summary from its surviving rows
    A = np.asarray(idx3.attrs[3])
    live = np.asarray(idx3.ids[3]) >= 0
    np.testing.assert_array_equal(np.asarray(idx3.summaries.amin[3]),
                                  A[live].min(0))
    np.testing.assert_array_equal(np.asarray(idx3.summaries.amax[3]),
                                  A[live].max(0))
    assert (np.asarray(idx3.summaries.hist[3]).sum(-1) == live.sum()).all()
    q = 8
    queries = jnp.asarray(core[:q] + 0.01)
    for fspec in _selective_fspecs(q, 4).values():
        _parity(idx3, queries, fspec, k=6, n_probes=4, q_block=8,
                backend="xla")


# ---------------------------------------------------------------------------
# storage: layout v2.1 round-trip + back-compat
# ---------------------------------------------------------------------------


def test_storage_roundtrip_v21(built, tmp_path):
    from repro.core import storage

    index, _, _ = built
    d = str(tmp_path / "v21")
    storage.save_index(index, d, n_shards=4)
    man = storage.load_manifest(d)
    assert man["has_summaries"] and man["summary_bins"] == 16
    assert man.get("layout_minor") == 1
    loaded = storage.load_index(d)
    for f in ("amin", "amax", "hist", "edges_lo", "edges_hi"):
        np.testing.assert_array_equal(
            np.asarray(getattr(loaded.summaries, f)),
            np.asarray(getattr(index.summaries, f)), err_msg=f,
        )


def test_storage_backcompat_no_summaries(built, tmp_path):
    from repro.core import storage

    index, core, _ = built
    bare = dataclasses.replace(index, summaries=None)
    d = str(tmp_path / "v20")
    storage.save_index(bare, d, n_shards=2)
    man = storage.load_manifest(d)
    assert not man["has_summaries"]
    loaded = storage.load_index(d)
    assert loaded.summaries is None
    # pre-v2.1 checkpoint: auto pruning degrades to off, results intact
    q = 8
    queries = jnp.asarray(core[:q])
    res = search_fused_tiled(loaded, queries, match_all(q, 4), k=5,
                             n_probes=3, backend="xla")
    ref = search_reference(index, queries, match_all(q, 4), k=5, n_probes=3)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_storage_missing_summary_file_rejected(built, tmp_path):
    import os

    from repro.core import storage

    index, _, _ = built
    d = str(tmp_path / "broken")
    storage.save_index(index, d, n_shards=2)
    os.unlink(os.path.join(d, storage.SUMMARY_FILES["hist"]))
    with pytest.raises(FileNotFoundError, match="incomplete"):
        storage.load_index(d)


def test_pad_k_pads_summaries_void(built):
    from repro.core import storage

    index, _, _ = built
    padded = storage.pad_k(index, index.n_clusters + 4)
    s = padded.summaries
    assert s.n_clusters == index.n_clusters + 4
    assert (np.asarray(s.amin[-4:]) == ATTR_MAX).all()
    assert (np.asarray(s.amax[-4:]) == ATTR_MIN).all()
    assert (np.asarray(s.hist[-4:]) == 0).all()
    # void rows can never match anything
    cm = np.asarray(can_match(s, match_all(3, 4).lo, match_all(3, 4).hi))
    assert not cm[:, -4:].any()


# ---------------------------------------------------------------------------
# satellites: vectorized fetch_order + sampled selectivity
# ---------------------------------------------------------------------------


def _fetch_order_loop(slot_cluster, n_unique, u_cap):
    """The original per-tile Python double loop (parity oracle)."""
    sc = np.asarray(slot_cluster).reshape(-1, u_cap)
    nu = np.asarray(n_unique)
    seen = {}
    for tile in range(sc.shape[0]):
        for cid in sc[tile, : int(nu[tile])]:
            seen.setdefault(int(cid), None)
    return np.fromiter(seen.keys(), dtype=np.int64, count=len(seen))


@pytest.mark.parametrize("seed", range(5))
def test_fetch_order_matches_loop(seed):
    rng = np.random.default_rng(seed)
    q_block, t, kc = 8, 4, 9
    qpad = 32
    probe_ids = jnp.asarray(rng.integers(0, kc, (qpad, t)), jnp.int32)
    u_cap = min(q_block * t, kc)
    slot_cluster, _, _, _, n_unique = plan_probe_tiles(
        probe_ids, q_block=q_block, u_cap=u_cap
    )
    got = fetch_order(slot_cluster, n_unique, u_cap)
    want = _fetch_order_loop(slot_cluster, n_unique, u_cap)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int64


def test_fetch_order_empty_tiles():
    sc = jnp.zeros((8,), jnp.int32)
    got = fetch_order(sc, jnp.asarray([0, 0]), 4)
    assert got.size == 0


def test_selectivity_sampled_estimator(built):
    index, core, attrs = built
    q = 6
    fspec = _selective_fspecs(q, 4)["band"]
    flat_attrs = jnp.asarray(attrs)
    exact = np.asarray(selectivity(fspec, flat_attrs))
    # exact path agrees with a direct full-mask computation
    want = np.stack([
        np.asarray(filter_mask(
            FilterSpec(lo=fspec.lo[i:i + 1], hi=fspec.hi[i:i + 1]),
            flat_attrs[None],
        ))[0].mean()
        for i in range(q)
    ])
    np.testing.assert_allclose(exact, want, atol=1e-6)
    # sampled path: deterministic in seed, within a loose tolerance
    est1 = np.asarray(selectivity(fspec, flat_attrs, sample_size=400,
                                  seed=3))
    est2 = np.asarray(selectivity(fspec, flat_attrs, sample_size=400,
                                  seed=3))
    np.testing.assert_array_equal(est1, est2)
    np.testing.assert_allclose(est1, want, atol=0.1)
