"""Decode-vs-forward consistency: prefill + step-by-step decode must
reproduce the full-sequence forward logits (catches cache/mask/RoPE bugs,
including the MLA absorbed path and gemma ring buffers)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import chatglm3_6b, deepseek_moe_16b, deepseek_v3_671b, \
    gemma3_12b
from repro.models.decoding import (
    decode_layout,
    decode_step,
    greedy_generate,
    init_cache,
    prefill,
)
from repro.models.transformer import forward, init_params, logits_from_hidden

ARCHS = {
    "deepseek-v3-671b": deepseek_v3_671b,  # MLA absorbed decode
    "deepseek-moe-16b": deepseek_moe_16b,  # MoE decode
    "gemma3-12b": gemma3_12b,  # ring buffers + dual theta
    "chatglm3-6b": chatglm3_6b,  # partial rotary + qkv bias
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    cfg = ARCHS[arch].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    b, s_prompt, s_total = 2, 16, 24
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_total)).astype(np.int32)
    )

    # reference: full forward at every prefix length
    h_full, _ = forward(params, cfg, tokens)
    ref_logits = logits_from_hidden(params, cfg, h_full)  # [B, S, V]

    # prefill + teacher-forced decode
    dparams = decode_layout(params, cfg)
    pre_logits, cache = prefill(params, cfg, tokens[:, :s_prompt], s_total)
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32),
        np.asarray(ref_logits[:, :s_prompt], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    for j in range(s_prompt, s_total):
        logits_j, cache = decode_step(
            dparams, cfg, cache, tokens[:, j], jnp.int32(j)
        )
        np.testing.assert_allclose(
            np.asarray(logits_j, np.float32),
            np.asarray(ref_logits[:, j], np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} step {j}",
        )


def test_ring_cache_wraps_correctly():
    """Past the window, ring decode must equal forward (window masks both)."""
    cfg = gemma3_12b.smoke_config()
    assert cfg.window == 16 and cfg.sub_quadratic
    params = init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(1)
    b, s_prompt, s_total = 1, 20, 40  # decode well past one window
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_total)).astype(np.int32)
    )
    h_full, _ = forward(params, cfg, tokens)
    ref_logits = logits_from_hidden(params, cfg, h_full)
    dparams = decode_layout(params, cfg)
    _, cache = prefill(params, cfg, tokens[:, :s_prompt], s_total)
    for j in range(s_prompt, s_total):
        logits_j, cache = decode_step(
            dparams, cfg, cache, tokens[:, j], jnp.int32(j)
        )
        np.testing.assert_allclose(
            np.asarray(logits_j, np.float32),
            np.asarray(ref_logits[:, j], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"step {j}",
        )


def test_greedy_generate_runs():
    cfg = chatglm3_6b.smoke_config()
    params = init_params(jax.random.key(2), cfg)
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
        dtype=jnp.int32,
    )
    out = greedy_generate(params, cfg, prompt, n_new=6, s_max=16)
    assert out.shape == (2, 6)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_init_cache_shapes():
    cfg = gemma3_12b.smoke_config()
    cache = init_cache(cfg, batch=2, s_max=64)
    assert set(cache) == {"local", "global"}  # 4 layers → 2 rounds, no tail
    k_local = cache["local"][0]
    assert k_local.shape[2] == cfg.window  # ring length
    k_global = cache["global"][0]
    assert k_global.shape[2] == 64
