"""Live-updating hot/cold tiered serving: the RAM delta tier + generation-
tagged cluster blocks.

The invariant under test, end to end: for ANY interleaving of add /
tombstone / compact_deltas / refresh, search results are BIT-IDENTICAL to a
from-scratch rebuild of the index at the same logical state — across
metrics × SQ8 × prune × pipeline, under the local and sharded stores, and
with a peer lagging (or killed) mid-republish.  ``n_scanned``/``n_passed``
are deliberately excluded: the delta scan and in-scan tombstone masking
count work differently from a rebuild, by design.

Generation precision: a republish must invalidate exactly the rewritten
``(cluster_id, gen)`` cache entries — asserted via the cache/L1
invalidation counters — and a stale peer answer must be re-fetched, never
silently served.
"""

import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DeltaOverflowError,
    DeltaTier,
    FilterSpec,
    GenerationMismatchError,
    HybridSpec,
    compact_deltas,
    compact_stale,
    match_all,
    stale_counts,
    storage,
)
from repro.core import blockstore as bs
from repro.core import faults
from repro.core import kmeans as kmeans_lib
from repro.core import update as update_lib
from repro.core.disk import DiskIVFIndex
from repro.core.engine import SearchEngine
from repro.core.ivf import build_from_assignments, quantize_index
from repro.core.serving import make_fused_search_fn

N, D, M, KC = 1536, 32, 6, 12
TS_RANGE = 6000
K, NP, QB = 10, 5, 8


def _topic_data(seed=3):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((KC, D)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = (np.arange(N) * KC) // N
    core = centers[topic] + 0.05 * rng.standard_normal((N, D)).astype(
        np.float32
    )
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    band = TS_RANGE // KC
    attrs = rng.integers(0, 16, (N, M)).astype(np.int16)
    attrs[:, 0] = (topic * band + rng.integers(0, band, N)).astype(np.int16)
    return centers, core, attrs, topic


def _build(metric, quantized):
    centers, core, attrs, topic = _topic_data()
    spec = HybridSpec(dim=D, n_attrs=M, core_dtype=jnp.float32,
                      metric=metric)
    # vpad headroom so republished clusters can absorb folded delta rows
    vpad = int(np.bincount(topic, minlength=KC).max()) + 96
    index, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(topic), vpad=vpad, ids=jnp.arange(N),
    )
    if quantized:
        index = quantize_index(index)
    return index, centers, core, attrs, topic


class Logical:
    """The ground-truth logical state a rebuild oracle is built from:
    every row ever added (checkpoint rows first, then delta adds in add
    order), with a liveness mask."""

    def __init__(self, centers, core, attrs, topic):
        self.centers = centers
        self.core = core.copy()
        self.attrs = attrs.copy()
        self.ids = np.arange(len(core))
        self.clusters = topic.copy().astype(np.int64)
        self.alive = np.ones(len(core), bool)
        self.next_id = len(core)

    def add(self, core, attrs):
        ids = np.arange(self.next_id, self.next_id + len(core))
        self.next_id += len(core)
        a = np.asarray(
            kmeans_lib.assign(jnp.asarray(core), jnp.asarray(self.centers))
        )
        self.core = np.concatenate([self.core, core])
        self.attrs = np.concatenate([self.attrs, attrs])
        self.ids = np.concatenate([self.ids, ids])
        self.clusters = np.concatenate([self.clusters, a.astype(np.int64)])
        self.alive = np.concatenate([self.alive, np.ones(len(core), bool)])
        return ids

    def kill(self, ids):
        self.alive[np.isin(self.ids, ids)] = False

    def cluster_of(self, ids):
        pos = np.searchsorted(self.ids, ids)
        return self.clusters[pos]

    def oracle_engine(self, spec, quantized, **engine_kw):
        m = self.alive
        idx, _ = build_from_assignments(
            spec, jnp.asarray(self.centers), jnp.asarray(self.core[m]),
            jnp.asarray(self.attrs[m]), jnp.asarray(self.clusters[m]),
            ids=jnp.asarray(self.ids[m]),
        )
        if quantized:
            idx = quantize_index(idx)
        return SearchEngine(idx, **engine_kw)


def _window_fspec(q, width, seed=7):
    rng = np.random.default_rng(seed)
    lo = np.full((q, 1, M), -32768, np.int16)
    hi = np.full((q, 1, M), 32767, np.int16)
    start = rng.integers(0, max(TS_RANGE - width, 1), q)
    lo[:, 0, 0] = start.astype(np.int16)
    hi[:, 0, 0] = (start + width - 1).astype(np.int16)
    return FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))


def _assert_results_equal(live, oracle, msg=""):
    np.testing.assert_array_equal(np.asarray(live.ids),
                                  np.asarray(oracle.ids), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(live.scores),
                                  np.asarray(oracle.scores), err_msg=msg)


@pytest.fixture(scope="module", params=[
    ("dot", False), ("l2", False), ("dot", True), ("l2", True),
], ids=["dot-f32", "l2-f32", "dot-sq8", "l2-sq8"])
def built_all(request):
    metric, quantized = request.param
    return _build(metric, quantized) + (metric, quantized)


@pytest.fixture(scope="module")
def built_dot():
    return _build("dot", False)


def _open_live(index, ckpt_dir, budget_mb=8.0):
    storage.save_index(index, ckpt_dir, n_shards=2)
    disk = DiskIVFIndex.open(ckpt_dir)
    tier = DeltaTier.for_index(disk, budget_mb)
    disk.delta = tier
    return disk, tier


# ---------------------------------------------------------------------------
# Parity matrix: metric × SQ8 × prune × pipeline, pre- and post-republish
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", ["off", "on"])
@pytest.mark.parametrize("prune", ["off", "on"])
def test_delta_parity_matrix(built_all, prune, pipeline, tmp_path):
    index, centers, core, attrs, topic, metric, quantized = built_all
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    state = Logical(centers, core, attrs, topic)
    rng = np.random.default_rng(11)

    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB,
                       prune=prune, pipeline=pipeline)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune=prune)
    q = 21  # ragged multi-tile at q_block=8
    queries = jnp.asarray(core[5:5 + q] + 0.01)
    specs = {"all": match_all(q, M), "window": _window_fspec(q, 900)}

    # adds + cold tombstones + delta tombstones, then check both filters
    add_core = (centers[rng.integers(0, KC, 60)]
                + 0.05 * rng.standard_normal((60, D))).astype(np.float32)
    add_core /= np.linalg.norm(add_core, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, TS_RANGE, (60, M)).astype(np.int16)
    new_ids = state.add(add_core, add_attrs)
    tier.add(add_core, add_attrs, new_ids)

    cold_dead = rng.choice(N, 40, replace=False)
    tier.tombstone(cold_dead, clusters=topic[cold_dead])
    state.kill(cold_dead)
    delta_dead = new_ids[:7]
    tier.tombstone(delta_dead)
    state.kill(delta_dead)

    oracle = state.oracle_engine(index.spec, quantized, **kw)
    for name, fs in specs.items():
        _assert_results_equal(eng.search(queries, fs),
                              oracle.search(queries, fs),
                              f"pre-republish {name}")

    # republish + between-batch adoption: same logical state, delta empty
    st = compact_deltas(str(tmp_path / "ck"), tier)
    assert st.clusters_rewritten > 0 and st.rows_folded == 53  # 60 − 7 dead
    assert eng.refresh()
    assert tier.stats()["rows"] == 0
    for name, fs in specs.items():
        _assert_results_equal(eng.search(queries, fs),
                              oracle.search(queries, fs),
                              f"post-republish {name}")
    assert eng.stats.delta_folds > 0
    eng.close()
    oracle.close()
    disk.close()


# ---------------------------------------------------------------------------
# Tombstones mask cold hits immediately; the (k+1)-th candidate surfaces
# ---------------------------------------------------------------------------


def test_tombstone_surfaces_next_candidate(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    q = jnp.asarray(core[100:101])
    fs = match_all(1, M)
    before = eng.search(q, fs)
    top = int(np.asarray(before.ids)[0, 0])
    runner_up = np.asarray(before.ids)[0, 1:]

    tier.tombstone(np.asarray([top]), clusters=np.asarray([topic[top]]))
    after = eng.search(q, fs)
    ids_after = np.asarray(after.ids)[0]
    assert top not in ids_after
    # the old ranks 2..k shift up one; a fresh (k+1)-th candidate fills in
    np.testing.assert_array_equal(ids_after[:K - 1], runner_up)
    assert ids_after[K - 1] >= 0
    eng.close()
    disk.close()


def test_delta_add_visible_next_batch(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    rng = np.random.default_rng(5)
    v = core[200] + 0.001 * rng.standard_normal(D).astype(np.float32)
    v = (v / np.linalg.norm(v)).astype(np.float32)
    tier.add(v[None], np.zeros((1, M), np.int16), np.asarray([N + 1]))
    res = eng.search(jnp.asarray(v[None]), match_all(1, M))
    assert int(np.asarray(res.ids)[0, 0]) == N + 1  # its own NN, next batch
    eng.close()
    disk.close()


# ---------------------------------------------------------------------------
# Randomized interleaving: add/tombstone/compact/publish in random order,
# bit-identical to a rebuild at every step
# ---------------------------------------------------------------------------


def test_randomized_interleaving_bit_identity(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    disk, tier = _open_live(index, ck)
    state = Logical(centers, core, attrs, topic)
    rng = np.random.default_rng(23)
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    kw = dict(k=K, n_probes=NP, q_block=QB)
    q = 9
    queries = jnp.asarray(core[40:40 + q] + 0.01)
    fs = match_all(q, M)

    for step in range(12):
        op = rng.integers(0, 4)
        if op == 0:  # add a batch
            b = int(rng.integers(1, 16))
            add = (centers[rng.integers(0, KC, b)]
                   + 0.05 * rng.standard_normal((b, D))).astype(np.float32)
            aat = rng.integers(0, TS_RANGE, (b, M)).astype(np.int16)
            tier.add(add, aat, state.add(add, aat))
        elif op == 1:  # tombstone random live ids (cold or delta)
            live = state.ids[state.alive]
            dead = rng.choice(live, min(6, len(live)), replace=False)
            tier.tombstone(dead, clusters=state.cluster_of(dead))
            state.kill(dead)
        elif op == 2:  # background republish + between-batch adoption
            compact_deltas(ck, tier)
            eng.refresh()
        # op == 3: just search
        res = eng.search(queries, fs)
        oracle = state.oracle_engine(index.spec, False, **kw)
        _assert_results_equal(res, oracle.search(queries, fs),
                              f"step {step} op {op}")
        oracle.close()
    eng.close()
    disk.close()


# ---------------------------------------------------------------------------
# Generation precision: a republish invalidates exactly the rewritten
# (cluster, gen) entries
# ---------------------------------------------------------------------------


def test_republish_invalidates_only_rewritten(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    disk, tier = _open_live(index, ck)
    eng = SearchEngine(disk, k=K, n_probes=KC, q_block=QB)  # probe all
    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng.search(q, fs)
    cached = set(disk.cache._entries)
    assert cached == set(range(KC))  # everything cached

    # tombstone rows in exactly two clusters → republish touches only them
    victims = np.concatenate([
        np.nonzero(topic == 2)[0][:3], np.nonzero(topic == 9)[0][:3],
    ])
    tier.tombstone(victims, clusters=topic[victims])
    st = compact_deltas(ck, tier)
    assert st.clusters_rewritten == 2
    eng.refresh()
    assert np.count_nonzero(disk.gens) == 2

    base = disk.cache.stats.invalidations
    eng.search(q, fs)
    assert disk.cache.stats.invalidations - base == 2  # exactly the two
    # the other ten records never left the cache (no extra misses for them)
    eng.close()
    disk.close()


def test_sharded_l1_invalidates_only_rewritten(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    storage.save_index(index, ck, n_shards=2)
    store = bs.open_sharded(ck, n_nodes=3, l1_records=KC, self_node=None)
    disk = DiskIVFIndex.open(ck)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    eng = SearchEngine(disk, k=K, n_probes=KC, q_block=QB, blockstore=store)
    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng.search(q, fs)
    l1_before = set(store._l1)

    victims = np.nonzero(topic == 4)[0][:3]
    tier.tombstone(victims, clusters=topic[victims])
    compact_deltas(ck, tier)
    eng.refresh()  # refreshes the ring (owned stores + fallback) + index
    eng.search(q, fs)
    assert store.l1_invalidations == (1 if 4 in l1_before else 0)
    assert store.store_stats.stale_answers == 0  # peers were refreshed
    eng.close()
    store.close()
    disk.close()


# ---------------------------------------------------------------------------
# Sharded ring: stale peer answers are re-fetched, never silently served
# ---------------------------------------------------------------------------


class _StripGens:
    """A peer stuck on the pre-gen wire: forwards fetches without the
    expected generations, so a lagging server answers stale."""

    def __init__(self, inner):
        self.inner = inner

    def fetch(self, cluster_ids, gens=None):
        return self.inner.fetch(cluster_ids)  # drops gens

    def ping(self):
        self.inner.ping()

    def stats(self):
        return self.inner.stats()

    def close(self):
        self.inner.close()


def test_stale_peer_answer_refetched(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    storage.save_index(index, ck, n_shards=2)
    store = bs.open_sharded(ck, n_nodes=3, l1_records=4, self_node=None)
    disk = DiskIVFIndex.open(ck)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    state = Logical(centers, core, attrs, topic)
    eng = SearchEngine(disk, k=K, n_probes=KC, q_block=QB, blockstore=store)
    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng.search(q, fs)  # warm every peer's mmaps + caches

    rng = np.random.default_rng(31)
    add = (centers[np.arange(KC)]
           + 0.05 * rng.standard_normal((KC, D))).astype(np.float32)
    aat = rng.integers(0, TS_RANGE, (KC, M)).astype(np.int16)
    tier.add(add, aat, state.add(add, aat))  # every cluster rewritten
    compact_deltas(ck, tier)

    # node 1 lags the republish: its reader never reopens AND its wire
    # predates gen stamping (otherwise the gen-aware cache self-heals)
    lag = 1
    store.transports[lag] = _StripGens(store.transports[lag])
    store._owned_stores[lag].refresh = lambda: None
    eng.refresh()

    res = eng.search(q, fs)
    assert store.store_stats.stale_answers > 0
    oracle = state.oracle_engine(index.spec, False, k=K, n_probes=KC,
                                 q_block=QB)
    _assert_results_equal(res, oracle.search(q, fs), "lagging peer")
    oracle.close()
    eng.close()
    store.close()
    disk.close()


def test_lagging_peer_self_heals_with_gen_stamped_fetch(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    storage.save_index(index, ck, n_shards=2)
    store = bs.open_sharded(ck, n_nodes=2, l1_records=4, self_node=None)
    disk = DiskIVFIndex.open(ck)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    state = Logical(centers, core, attrs, topic)
    eng = SearchEngine(disk, k=K, n_probes=KC, q_block=QB, blockstore=store)
    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng.search(q, fs)

    rng = np.random.default_rng(37)
    add = (centers[np.arange(KC)]
           + 0.05 * rng.standard_normal((KC, D))).astype(np.float32)
    aat = rng.integers(0, TS_RANGE, (KC, M)).astype(np.int16)
    tier.add(add, aat, state.add(add, aat))
    compact_deltas(ck, tier)

    # peer 0 lags, but gen-stamped fetches reach it: its cache detects the
    # stale generation, reopens its own reader, and serves fresh
    store._owned_stores[0].refresh = lambda: None
    eng.refresh()
    res = eng.search(q, fs)
    assert store.store_stats.stale_answers == 0
    assert store._owned_stores[0].cache.stats.invalidations > 0
    oracle = state.oracle_engine(index.spec, False, k=K, n_probes=KC,
                                 q_block=QB)
    _assert_results_equal(res, oracle.search(q, fs), "self-healed peer")
    oracle.close()
    eng.close()
    store.close()
    disk.close()


def test_kill_peer_mid_republish(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    storage.save_index(index, ck, n_shards=2)
    store = bs.open_sharded(ck, n_nodes=3, l1_records=4, self_node=None)
    disk = DiskIVFIndex.open(ck)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    state = Logical(centers, core, attrs, topic)
    eng = SearchEngine(disk, k=K, n_probes=KC, q_block=QB, blockstore=store)
    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng.search(q, fs)

    rng = np.random.default_rng(41)
    add = (centers[np.arange(KC)]
           + 0.05 * rng.standard_normal((KC, D))).astype(np.float32)
    aat = rng.integers(0, TS_RANGE, (KC, M)).astype(np.int16)
    tier.add(add, aat, state.add(add, aat))

    # the peer dies between the republish and the flip — the exact window
    # where a stale block could slip through without gen tagging
    faults.inject(store, 1, faults.kill_peer(after=0))
    compact_deltas(ck, tier)
    eng.refresh()
    res = eng.search(q, fs)
    s = store.stats()
    assert s["failovers"] + s["redirected_blocks"] > 0
    oracle = state.oracle_engine(index.spec, False, k=K, n_probes=KC,
                                 q_block=QB)
    _assert_results_equal(res, oracle.search(q, fs), "killed peer")
    oracle.close()
    eng.close()
    store.close()
    disk.close()


# ---------------------------------------------------------------------------
# Freeze/commit handshake: tombstones racing a pending republish
# ---------------------------------------------------------------------------


def test_late_tombstone_during_pending_republish(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    disk, tier = _open_live(index, ck)
    state = Logical(centers, core, attrs, topic)
    rng = np.random.default_rng(43)
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    kw = dict(k=K, n_probes=NP, q_block=QB)
    q = jnp.asarray(core[60:69] + 0.01)
    fs = match_all(9, M)

    add = (centers[rng.integers(0, KC, 20)]
           + 0.05 * rng.standard_normal((20, D))).astype(np.float32)
    aat = rng.integers(0, TS_RANGE, (20, M)).astype(np.int16)
    new_ids = state.add(add, aat)
    tier.add(add, aat, new_ids)

    compact_deltas(ck, tier)  # freeze + rewrite; NOT yet adopted
    assert tier.stats()["pending"]
    # a frozen (already-folded) row dies while the republish is pending
    late = new_ids[:4]
    tier.tombstone(late)
    state.kill(late)

    # pre-adoption: old cold view + delta minus the late-dead rows
    oracle = state.oracle_engine(index.spec, False, **kw)
    _assert_results_equal(eng.search(q, fs), oracle.search(q, fs),
                          "pending republish")
    # adoption: the republished cold copy CONTAINS the folded rows; the
    # carried-over tombstones must keep masking them
    assert eng.refresh()
    assert not tier.stats()["pending"]
    _assert_results_equal(eng.search(q, fs), oracle.search(q, fs),
                          "after adoption")
    # a second republish reclaims them from the cold tier for good
    compact_deltas(ck, tier)
    eng.refresh()
    assert tier.stats()["tombstones"] == 0
    _assert_results_equal(eng.search(q, fs), oracle.search(q, fs),
                          "after second republish")
    oracle.close()
    eng.close()
    disk.close()


def test_delta_overflow_is_loud(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    disk, _ = _open_live(index, str(tmp_path / "ck"))
    tier = DeltaTier(disk, capacity=4)
    a = np.zeros((3, M), np.int16)
    tier.add(core[:3], a, np.asarray([9000, 9001, 9002]))
    with pytest.raises(DeltaOverflowError):
        tier.add(core[3:6], a, np.asarray([9003, 9004, 9005]))
    assert tier.stats()["rows"] == 3  # failed add landed nothing
    disk.close()


# ---------------------------------------------------------------------------
# Back-compat + typed errors
# ---------------------------------------------------------------------------


def test_v2_checkpoint_serves_with_gen_zero(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "v2")
    storage.save_index(index, ck, n_shards=2, layout=2)
    disk = DiskIVFIndex.open(ck)
    assert disk.man["layout"] == 2
    assert np.array_equal(disk.gens, np.zeros(KC, np.int64))
    assert int(disk.reader.read(0)["gen"][0]) == 0  # synthesized

    q = jnp.asarray(core[:8])
    fs = match_all(8, M)
    eng_d = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    eng_r = SearchEngine(index, k=K, n_probes=NP, q_block=QB)
    _assert_results_equal(eng_d.search(q, fs), eng_r.search(q, fs), "v2")

    with pytest.raises(GenerationMismatchError):
        make_fused_search_fn(disk, k=K, n_probes=NP, delta_budget_mb=1.0)
    with pytest.raises(GenerationMismatchError):
        compact_deltas(ck)
    eng_d.close()
    eng_r.close()
    disk.close()


def test_check_complete_validates_gens(built_dot, tmp_path):
    index = built_dot[0]
    ck = str(tmp_path / "v3")
    storage.save_index(index, ck, n_shards=2)
    man = storage.load_manifest(ck)
    storage.check_complete(ck, man)  # intact: fine
    os.remove(os.path.join(ck, storage.GENS_FILE))
    with pytest.raises(FileNotFoundError):
        storage.check_complete(ck, man)
    with pytest.raises(GenerationMismatchError):
        storage.load_gens(ck, man)
    # shape mismatch (truncated vector) is the typed error too
    np.save(os.path.join(ck, storage.GENS_FILE),
            np.zeros(KC - 1, np.int64))
    with pytest.raises(GenerationMismatchError):
        storage.load_gens(ck, man)


def test_refresh_noop_without_republish(built_dot, tmp_path):
    index = built_dot[0]
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    assert eng.refresh() is False  # nothing published → nothing to adopt
    eng.close()
    disk.close()


# ---------------------------------------------------------------------------
# Satellite: stale-summary accounting + compaction on the RAM tier
# ---------------------------------------------------------------------------


def test_stale_counts_and_compact_stale(built_dot):
    index, centers, core, attrs, topic = built_dot
    # tombstone 3 rows of cluster 1 and 2 rows of cluster 5
    cl = jnp.asarray([1, 1, 1, 5, 5])
    sl = jnp.asarray([0, 1, 2, 0, 1])
    tombed = update_lib.tombstone(index, cl, sl)
    sc = np.asarray(stale_counts(tombed))
    expect = np.zeros(KC, np.int32)
    expect[1], expect[5] = 3, 2
    np.testing.assert_array_equal(sc, expect)

    compacted, n = compact_stale(tombed, threshold=1)
    assert n == 2
    assert not np.asarray(stale_counts(compacted)).any()
    # compaction only reclaims slots + tightens summaries: results identical
    q = jnp.asarray(core[:8])
    fs = _window_fspec(8, 900)
    ea = SearchEngine(tombed, k=K, n_probes=NP, q_block=QB, prune="on")
    eb = SearchEngine(compacted, k=K, n_probes=NP, q_block=QB, prune="on")
    _assert_results_equal(ea.search(q, fs), eb.search(q, fs), "compacted")
    # and the tightened summaries prune at least as hard
    assert (np.asarray(eb.search(q, fs).n_scanned).sum()
            <= np.asarray(ea.search(q, fs).n_scanned).sum())
    ea.close()
    eb.close()


# ---------------------------------------------------------------------------
# Satellite: one flat metrics surface
# ---------------------------------------------------------------------------


def test_engine_metrics_flat(built_dot, tmp_path):
    index, centers, core, attrs, topic = built_dot
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    tier.add(core[:2], attrs[:2].astype(np.int16),
             np.asarray([8000, 8001]))
    eng.search(jnp.asarray(core[:8]), match_all(8, M))
    m = eng.metrics()
    assert isinstance(m, dict)
    for key, val in m.items():
        assert isinstance(key, str) and "." in key, key
        assert isinstance(val, (bool, int, float, str, type(None))), (
            key, type(val))
    for prefix in ("engine.", "store.", "cache.", "delta."):
        assert any(k.startswith(prefix) for k in m), prefix
    assert m["engine.delta_folds"] >= 1
    assert m["delta.rows"] == 2
    eng.close()
    disk.close()


# ---------------------------------------------------------------------------
# Satellite: SQ8 delta rows (quantize="on") — ~4× capacity per budget,
# near-float parity live, and a dequantizing republish over a float cold
# tier
# ---------------------------------------------------------------------------


def test_delta_quantize_capacity_ratio(built_dot, tmp_path):
    """`for_index(quantize="on")` sizes rows at 1 byte/dim + 4-byte scale
    — the exact row-formula ratio over the float32 sizing (~3.5× at D=32,
    →4× as D grows)."""
    index, *_ = built_dot
    disk, _ = _open_live(index, str(tmp_path / "ck"))
    t_f = DeltaTier.for_index(disk, 8.0)
    t_q = DeltaTier.for_index(disk, 8.0, quantize="on")
    row_f = D * 4 + M * 2 + 8
    row_q = D * 1 + M * 2 + 8 + 4
    assert t_f.capacity == (8 * 2 ** 20) // row_f
    assert t_q.capacity == (8 * 2 ** 20) // row_q
    assert t_q.capacity * row_f >= t_f.capacity * row_q  # strictly denser
    assert t_q.quantized and not t_f.quantized
    disk.close()


def test_delta_quantize_on_near_float_parity(built_dot, tmp_path):
    """Quantized delta rows over a FLOAT cold tier: ids match a float
    delta tier's results almost everywhere and scores agree to SQ8
    precision (≈1e-2 relative)."""
    index, centers, core, attrs, topic = built_dot
    rng = np.random.default_rng(23)
    add = (centers[rng.integers(0, KC, 64)]
           + 0.05 * rng.standard_normal((64, D))).astype(np.float32)
    add /= np.linalg.norm(add, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, TS_RANGE, (64, M)).astype(np.int16)
    new_ids = np.arange(N, N + 64)

    results = {}
    for mode in ("auto", "on"):
        ck = str(tmp_path / f"ck_{mode}")
        storage.save_index(index, ck, n_shards=2)
        disk = DiskIVFIndex.open(ck)
        tier = DeltaTier.for_index(disk, 8.0, quantize=mode)
        disk.delta = tier
        tier.add(add, add_attrs, new_ids)
        tier.tombstone(new_ids[:5])
        eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
        q = jnp.asarray(add[5:21] + 0.001)
        results[mode] = eng.search(q, match_all(16, M))
        eng.close()
        disk.close()

    ids_f = np.asarray(results["auto"].ids)
    ids_q = np.asarray(results["on"].ids)
    agree = np.mean(ids_f == ids_q)
    assert agree >= 0.9, f"id agreement {agree}"
    np.testing.assert_allclose(np.asarray(results["on"].scores),
                               np.asarray(results["auto"].scores),
                               rtol=2e-2, atol=2e-2)
    # none of the tombstoned delta rows surfaced
    assert not np.isin(ids_q, new_ids[:5]).any()


def test_delta_quantize_republish_dequantizes(built_dot, tmp_path):
    """compact_deltas over a float cold tier folds quantized delta rows by
    DEQUANTIZING codes·scales — the checkpoint stays float (no manifest
    flip) and post-republish results match the live pre-republish view."""
    index, centers, core, attrs, topic = built_dot
    ck = str(tmp_path / "ck")
    disk, tier = _open_live(index, ck)
    tier2 = DeltaTier.for_index(disk, 8.0, quantize="on")
    disk.delta = tier2

    rng = np.random.default_rng(29)
    add = (centers[rng.integers(0, KC, 48)]
           + 0.05 * rng.standard_normal((48, D))).astype(np.float32)
    add /= np.linalg.norm(add, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, TS_RANGE, (48, M)).astype(np.int16)
    new_ids = np.arange(N, N + 48)
    tier2.add(add, add_attrs, new_ids)

    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB)
    q = jnp.asarray(add[:16] + 0.001)
    fs = match_all(16, M)
    before = eng.search(q, fs)

    st = compact_deltas(ck, tier2)
    assert st.rows_folded == 48
    assert eng.refresh()
    assert tier2.stats()["rows"] == 0
    man = storage.load_manifest(ck)
    assert not man.get("quantized", False)  # cold tier still float

    after = eng.search(q, fs)
    np.testing.assert_array_equal(np.asarray(after.ids),
                                  np.asarray(before.ids))
    np.testing.assert_allclose(np.asarray(after.scores),
                               np.asarray(before.scores),
                               rtol=1e-4, atol=1e-4)
    eng.close()
    disk.close()


def test_metrics_text_stage_latency_histograms(built_dot, tmp_path):
    """Satellite: fixed-bucket Prometheus latency histograms per pipeline
    stage — plan/fetch/scan/merge/delta_fold — with classic cumulative
    ``le`` semantics and matching ``_count``/``_sum`` rows."""
    index, centers, core, attrs, topic = built_dot
    disk, tier = _open_live(index, str(tmp_path / "ck"))
    tier.add(core[:4], attrs[:4].astype(np.int16),
             np.arange(8000, 8004))
    # pipelined executor: the per-tile fetch/scan overlap plus a distinct
    # merge stage, so all five stage histograms populate
    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB, pipeline="on")
    for _ in range(3):  # q=21 → 3 tiles: the merge stage actually runs
        eng.search(jnp.asarray(core[:21]), match_all(21, M))
    text = eng.metrics_text()
    assert "# TYPE repro_stage_latency_seconds histogram" in text
    for stage in ("plan", "fetch", "scan", "merge", "delta_fold"):
        bucket_counts = []
        for line in text.splitlines():
            if (line.startswith("repro_stage_latency_seconds_bucket")
                    and f'stage="{stage}"' in line):
                bucket_counts.append(int(line.rsplit(" ", 1)[1]))
        assert bucket_counts, f"no buckets for stage {stage}"
        # fixed bucket set, cumulative and non-decreasing
        assert bucket_counts == sorted(bucket_counts), (stage, bucket_counts)
        count_line = next(
            line for line in text.splitlines()
            if line.startswith("repro_stage_latency_seconds_count")
            and f'stage="{stage}"' in line
        )
        total = int(count_line.rsplit(" ", 1)[1])
        assert total >= 3 and bucket_counts[-1] <= total
        sum_line = next(
            line for line in text.splitlines()
            if line.startswith("repro_stage_latency_seconds_sum")
            and f'stage="{stage}"' in line
        )
        assert float(sum_line.rsplit(" ", 1)[1]) >= 0.0
    # the fixed edges render with le labels (first + implicit ordering)
    assert 'le="0.0005"' in text and 'le="2.5"' in text
    eng.close()
    disk.close()
