"""RecSys smoke tests: 4 archs × (forward, train step, retrieval) + the
EmbeddingBag substrate (fixed/ragged/sharded-equivalence)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import bst, din, sasrec, wide_deep
from repro.models.recsys import (
    RecsysBatch,
    embedding_bag,
    embedding_bag_ragged,
    forward,
    init_params,
    init_table,
    loss_fn,
    retrieval_scores,
    user_embedding,
)

ARCHS = {"din": din, "sasrec": sasrec, "bst": bst, "wide-deep": wide_deep}


def make_batch(cfg, b=16, seed=0):
    rng = np.random.default_rng(seed)
    L = max(cfg.seq_len, 1)
    hist = rng.integers(0, cfg.vocab_items, (b, L)).astype(np.int32)
    hist[rng.random((b, L)) < 0.2] = -1  # ragged padding
    return RecsysBatch(
        dense=jnp.asarray(rng.standard_normal((b, cfg.n_dense)).astype(np.float32)),
        sparse=jnp.asarray(
            rng.integers(0, cfg.vocab_sparse, (b, max(cfg.n_sparse, 1)))
            .astype(np.int32)
        ),
        hist=jnp.asarray(hist),
        target=jnp.asarray(rng.integers(0, cfg.vocab_items, b).astype(np.int32)),
        label=jnp.asarray((rng.random(b) > 0.5).astype(np.float32)),
    )


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = ARCHS[arch].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    logit = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logit.shape == (16,)
    assert np.isfinite(np.asarray(logit)).all()

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(
            lambda q: loss_fn(q, cfg, batch), has_aux=True
        )(p)
        return l, jax.tree.map(lambda a, b: a - 0.02 * b, p, g)

    l0, params = step(params)
    for _ in range(5):
        l1, params = step(params)
    assert float(l1) < float(l0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_retrieval_scores(arch):
    cfg = ARCHS[arch].smoke_config()
    params = init_params(jax.random.key(1), cfg)
    batch = make_batch(cfg, b=4)
    cands = init_table(jax.random.key(2), 512, cfg.embed_dim)
    vals, ids = retrieval_scores(params, cfg, batch, cands, k=10)
    assert vals.shape == (4, 10) and ids.shape == (4, 10)
    assert (np.diff(np.asarray(vals), axis=1) <= 1e-6).all()  # sorted
    u = user_embedding(params, cfg, batch)
    assert u.shape == (4, cfg.embed_dim)


def test_embedding_bag_modes():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((20, 4)).astype(np.float32))
    ids = jnp.asarray([[0, 1, -1], [2, -1, -1]], dtype=jnp.int32)
    s = embedding_bag(table, ids, mode="sum")
    np.testing.assert_allclose(
        np.asarray(s[0]), np.asarray(table[0] + table[1]), rtol=1e-6
    )
    m = embedding_bag(table, ids, mode="mean")
    np.testing.assert_allclose(
        np.asarray(m[0]), np.asarray((table[0] + table[1]) / 2), rtol=1e-6
    )
    np.testing.assert_allclose(np.asarray(m[1]), np.asarray(table[2]), rtol=1e-6)
    mx = embedding_bag(table, ids, mode="max")
    np.testing.assert_allclose(
        np.asarray(mx[0]),
        np.maximum(np.asarray(table[0]), np.asarray(table[1])),
        rtol=1e-6,
    )


def test_embedding_bag_ragged_matches_fixed():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((50, 8)).astype(np.float32))
    ids = jnp.asarray([[3, 7, 9], [11, -1, -1], [4, 5, -1]], dtype=jnp.int32)
    fixed = embedding_bag(table, ids, mode="sum")
    flat, bag = [], []
    for b, row in enumerate(np.asarray(ids)):
        for i in row:
            if i >= 0:
                flat.append(i)
                bag.append(b)
    ragged = embedding_bag_ragged(
        table, jnp.asarray(flat, dtype=jnp.int32),
        jnp.asarray(bag, dtype=jnp.int32), 3,
    )
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged), rtol=1e-6)
