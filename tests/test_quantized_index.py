"""SQ8 quantized lists (beyond-paper §Perf iteration): accuracy, recall,
kernel parity, and online-add on the compressed index."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HybridSpec,
    add_vectors,
    brute_force,
    build_ivf,
    match_all,
    recall_at_k,
)
from repro.core.ivf import dequantize_rows, quantize_index
from repro.core.search import search_reference
from repro.kernels.filtered_scan import search_fused


@pytest.fixture(scope="module")
def indexes():
    rng = np.random.default_rng(0)
    n, d, m = 2048, 48, 4
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 6, (n, m)).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32)
    index, _ = build_ivf(
        jax.random.key(0), spec, core, attrs, n_clusters=16,
        kmeans_mode="lloyd", kmeans_steps=6,
    )
    return index, quantize_index(index), core, attrs


def test_quantization_roundtrip_error(indexes):
    index, qindex, core, attrs = indexes
    assert qindex.vectors.dtype == jnp.int8
    deq = dequantize_rows(qindex.vectors, qindex.scales)
    orig = np.asarray(index.vectors, np.float32)
    err = np.abs(np.asarray(deq) - orig)
    # per-row error bounded by scale/2
    bound = np.asarray(qindex.scales)[..., None] * 0.51
    assert (err <= bound + 1e-7).all()
    # storage halved (int8 vs f32 here; bf16→int8 in prod = 2x)
    assert qindex.vectors.nbytes == index.vectors.nbytes // 4


def test_quantized_recall_close_to_exact(indexes):
    index, qindex, core, attrs = indexes
    q = 16
    rng = np.random.default_rng(1)
    queries = jnp.asarray(core[rng.integers(0, len(core), q)])
    fspec = match_all(q, 4)
    oracle = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries,
                         fspec, k=10)
    full = search_reference(index, queries, fspec, k=10,
                            n_probes=index.n_clusters)
    quant = search_reference(qindex, queries, fspec, k=10,
                             n_probes=index.n_clusters)
    r_full = recall_at_k(full, oracle)
    r_quant = recall_at_k(quant, oracle)
    assert r_full == 1.0  # full-probe exact
    assert r_quant >= 0.95, r_quant  # SQ8 costs at most a few points


def test_quantized_kernel_matches_reference(indexes):
    _, qindex, core, attrs = indexes
    q = 8
    queries = jnp.asarray(core[:q])
    fspec = match_all(q, 4)
    ref = search_reference(qindex, queries, fspec, k=8, n_probes=4)
    fused = search_fused(qindex, queries, fspec, k=8, n_probes=4,
                         v_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(ref.scores), rtol=1e-5,
        atol=1e-5,
    )


def test_add_vectors_on_quantized_index(indexes):
    _, qindex, core, attrs = indexes
    rng = np.random.default_rng(2)
    new = rng.standard_normal((3, 48)).astype(np.float32)
    new /= np.linalg.norm(new, axis=-1, keepdims=True)
    na = np.full((3, 4), 2, np.int16)
    ids = jnp.asarray([9000, 9001, 9002], jnp.int32)
    q2, dropped = add_vectors(qindex, jnp.asarray(new), jnp.asarray(na), ids)
    assert int(dropped) == 0
    res = search_reference(q2, jnp.asarray(new), match_all(3, 4), k=1,
                           n_probes=q2.n_clusters)
    np.testing.assert_array_equal(np.asarray(res.ids)[:, 0],
                                  [9000, 9001, 9002])
