"""Runs the multi-device selftests in subprocesses (8 fake CPU devices each,
so the main pytest process keeps exactly one device)."""

import os
import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")


def run_script(name, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(HERE / name)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.mark.slow
def test_distributed_search_selftest():
    out = run_script("dist_selftest.py")
    assert "ALL DISTRIBUTED SELFTESTS PASSED" in out
