"""Filter-specialized sub-partitions: routing soundness + bit-identity.

Two contracts, end to end:

1. **Routing is sound.**  The planner may route a query to a catalog entry
   only when the entry's predicate *subsumes* the query's filter (every
   non-void term per-attribute contained in the entry box); among subsuming
   entries the fewest-rows one wins; anything else falls back flat.
   Property-tested over randomized catalogs and filters against an
   independent oracle.

2. **Routing is unobservable in results.**  A partition-routed search
   returns BIT-IDENTICAL ids/scores to the flat path over the same logical
   state — across metrics × SQ8, sync and pipelined executors, all three
   stores (Resident / Local / Sharded), the segmented terminated executor,
   and add/tombstone/compact_deltas interleavings.  ``n_scanned`` is
   excluded by design: scanning fewer rows is the whole point.

The workload has attr0 *uncorrelated* with the clustering (uniform
timestamps), so summary pruning cannot shrink the scan and only the
physical sub-partition layout distinguishes the routed plan.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DeltaTier,
    FilterSpec,
    HybridSpec,
    compact_deltas,
    storage,
)
from repro.core import blockstore as bs
from repro.core import partitions as partitions_lib
from repro.core import probes as probes_lib
from repro.core import summaries as summaries_lib
from repro.core import update as update_lib
from repro.core.disk import DiskIVFIndex
from repro.core.engine import SearchEngine
from repro.core.ivf import build_from_assignments, quantize_index
from repro.core.search import search_reference

N, D, M, KC = 1536, 32, 6, 12
TS_RANGE = 6000
K, NP, QB = 10, 4, 8
W = 150  # query window width: under the finest ladder stride, always routed


def _uniform_ts_index(metric="dot", quantized=False):
    """Topic mixture whose attr0 timestamp is uniform and independent of the
    topic: every cluster's summary interval covers the full range, so
    interval pruning is blind to the time filter and the flat path scans
    every probed cluster — the regime sub-partitions exist for."""
    rng = np.random.default_rng(3)
    centers = rng.standard_normal((KC, D)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = (np.arange(N) * KC) // N
    core = centers[topic] + 0.05 * rng.standard_normal((N, D)).astype(
        np.float32
    )
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 16, (N, M)).astype(np.int16)
    attrs[:, 0] = rng.integers(0, TS_RANGE, N).astype(np.int16)
    spec = HybridSpec(dim=D, n_attrs=M, core_dtype=jnp.float32,
                      metric=metric)
    # vpad headroom so republished clusters can absorb folded delta rows
    vpad = int(np.bincount(topic, minlength=KC).max()) + 96
    index, _ = build_from_assignments(
        spec, jnp.asarray(centers), jnp.asarray(core), jnp.asarray(attrs),
        jnp.asarray(topic), vpad=vpad, ids=jnp.arange(N),
    )
    if quantized:
        index = quantize_index(index)
    return index, core, centers


def _window_fspec(q, width, seed=7):
    rng = np.random.default_rng(seed)
    lo = np.full((q, 1, M), -32768, np.int16)
    hi = np.full((q, 1, M), 32767, np.int16)
    start = rng.integers(0, max(TS_RANGE - width, 1), q)
    lo[:, 0, 0] = start.astype(np.int16)
    hi[:, 0, 0] = (start + width - 1).astype(np.int16)
    return FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi))


def _queries(core, q, seed=11):
    rng = np.random.default_rng(seed)
    qs = core[rng.integers(0, N, q)] + 0.01 * rng.standard_normal(
        (q, D)
    ).astype(np.float32)
    return jnp.asarray(qs)


def _assert_bitwise(a, b, msg=""):
    """ids + scores bitwise; n_scanned/n_passed legitimately differ (the
    routed plan scans only each cluster's in-window rows)."""
    np.testing.assert_array_equal(np.asarray(b.ids), np.asarray(a.ids),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(b.scores),
                                  np.asarray(a.scores), err_msg=msg)


@pytest.fixture(scope="module", params=[
    ("dot", False), ("l2", False), ("dot", True),
], ids=["dot-f32", "l2-f32", "dot-sq8"])
def built(request, tmp_path_factory):
    metric, quantized = request.param
    index, core, centers = _uniform_ts_index(metric, quantized)
    build_p = partitions_lib.build_partitions(index, attrs=[0])
    assert build_p.n_subs > 0 and build_p.catalog.n_entries > 0
    attached = partitions_lib.attach(index, build_p)
    ckpt = str(tmp_path_factory.mktemp(f"part_{request.param[0]}"))
    storage.save_index(index, ckpt, n_shards=2, layout=4,
                       partitions=build_p)
    return index, attached, build_p, core, centers, ckpt


@pytest.fixture(scope="module")
def built_dot(tmp_path_factory):
    index, core, centers = _uniform_ts_index("dot", False)
    build_p = partitions_lib.build_partitions(index, attrs=[0])
    ckpt = str(tmp_path_factory.mktemp("part_dot_live"))
    storage.save_index(index, ckpt, n_shards=2, layout=4,
                       partitions=build_p)
    return index, build_p, core, centers, ckpt


# ---------------------------------------------------------------------------
# 1. Routing soundness: randomized catalogs × randomized filters vs oracle
# ---------------------------------------------------------------------------


def _rand_catalog(rng, n_entries, m):
    lo = rng.integers(-60, 40, (n_entries, m)).astype(np.int16)
    hi = (lo + rng.integers(0, 80, (n_entries, m))).astype(np.int16)
    full = rng.random((n_entries, m)) < 0.6  # most attrs unconstrained
    lo[full], hi[full] = summaries_lib.ATTR_MIN, summaries_lib.ATTR_MAX
    # build invariant: every entry constrains its partition attribute —
    # an all-full-range entry would (soundly but uselessly) subsume even
    # unfiltered queries, and the builder never emits one
    allfull = np.nonzero(full.all(axis=1))[0]
    keep = rng.integers(0, m, allfull.size)
    lo[allfull, keep] = rng.integers(-60, 40, allfull.size).astype(np.int16)
    hi[allfull, keep] = (
        lo[allfull, keep] + rng.integers(0, 80, allfull.size)
    ).astype(np.int16)
    return partitions_lib.PartitionCatalog(
        pred_lo=lo, pred_hi=hi,
        members=np.full((n_entries, 1), -1, np.int32),
        entry_rows=rng.integers(1, 500, n_entries).astype(np.int64),
        parent=np.zeros(0, np.int32),
        sub_lo=np.zeros((0, m), np.int16), sub_hi=np.zeros((0, m), np.int16),
        sub_counts=np.zeros(0, np.int32),
        sub_amin=np.zeros((0, m), np.int16),
        sub_amax=np.zeros((0, m), np.int16),
        n_base=1,
    )


def _rand_filters(rng, q, n_terms, m):
    lo = rng.integers(-60, 40, (q, n_terms, m)).astype(np.int16)
    hi = (lo + rng.integers(-10, 40, (q, n_terms, m))).astype(np.int16)
    full = rng.random((q, n_terms, m)) < 0.7
    lo[full], hi[full] = summaries_lib.ATTR_MIN, summaries_lib.ATTR_MAX
    return lo, hi


def _route_oracle(cat, lo, hi):
    """Independent reimplementation of the routing contract, by loops."""
    q, n_terms, _ = lo.shape
    out = np.full(q, -1, np.int32)
    for qi in range(q):
        nonvoid = [t for t in range(n_terms)
                   if np.all(lo[qi, t] <= hi[qi, t])]
        if not nonvoid:
            continue
        subsuming = [
            e for e in range(cat.n_entries)
            if all(np.all(cat.pred_lo[e] <= lo[qi, t])
                   and np.all(hi[qi, t] <= cat.pred_hi[e])
                   for t in nonvoid)
        ]
        if subsuming:
            rows = np.asarray([cat.entry_rows[e] for e in subsuming])
            out[qi] = subsuming[int(np.argmin(rows))]
    return out


def test_route_subsumption_property():
    rng = np.random.default_rng(0)
    for trial in range(60):
        m = int(rng.integers(1, 5))
        cat = _rand_catalog(rng, int(rng.integers(1, 24)), m)
        lo, hi = _rand_filters(rng, int(rng.integers(1, 16)),
                               int(rng.integers(1, 3)), m)
        route = cat.route(lo, hi)
        oracle = _route_oracle(cat, lo, hi)
        for qi in range(lo.shape[0]):
            r = int(route[qi])
            if r < 0:
                assert oracle[qi] < 0, (
                    f"trial {trial} q{qi}: router declined but entry "
                    f"{oracle[qi]} subsumes"
                )
                continue
            # chosen entry must subsume every non-void term
            for t in range(lo.shape[1]):
                if np.all(lo[qi, t] <= hi[qi, t]):
                    assert np.all(cat.pred_lo[r] <= lo[qi, t]), (trial, qi)
                    assert np.all(hi[qi, t] <= cat.pred_hi[r]), (trial, qi)
            # and be the narrowest such entry
            assert oracle[qi] >= 0
            assert cat.entry_rows[r] == cat.entry_rows[oracle[qi]], (
                f"trial {trial} q{qi}: routed entry reaches "
                f"{cat.entry_rows[r]} rows, narrowest is "
                f"{cat.entry_rows[oracle[qi]]}"
            )


def test_route_unfiltered_and_void_fall_back():
    rng = np.random.default_rng(1)
    cat = _rand_catalog(rng, 8, 3)
    q = 5
    lo = np.full((q, 1, 3), summaries_lib.ATTR_MIN, np.int16)
    hi = np.full((q, 1, 3), summaries_lib.ATTR_MAX, np.int16)
    assert np.all(cat.route(lo, hi) == -1), "match-all must not route"
    lo[:, 0, 0], hi[:, 0, 0] = 5, 4  # void term
    assert np.all(cat.route(lo, hi) == -1), "all-void must not route"


# ---------------------------------------------------------------------------
# 2. Bit-identity: routed vs flat, stores × executors (× metric × SQ8 via
#    the fixture params)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", ["off", "on"])
def test_routed_matches_flat_all_stores(built, pipeline):
    index, attached, build_p, core, _, ckpt = built
    q = 21  # ragged multi-tile at q_block=8
    queries = _queries(core, q)
    fspec = _window_fspec(q, W)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on", pipeline=pipeline)

    ref = search_reference(index, queries, fspec, k=K, n_probes=NP)

    # RAM tier: attached arrays behind a ResidentBlockStore
    for store_tag, mk in (
        ("resident", lambda: bs.ResidentBlockStore(attached)),
        ("sharded-resident", lambda: bs.ShardedBlockStore(
            {i: bs.LoopbackTransport(bs.ResidentBlockStore(attached))
             for i in range(3)}
        )),
    ):
        store = mk()
        try:
            flat = SearchEngine(attached, blockstore=store,
                                partitions="off", **kw)
            routed = SearchEngine(attached, blockstore=store,
                                  partitions="auto", **kw)
            r0 = flat.search(queries, fspec)
            r1 = routed.search(queries, fspec)
            _assert_bitwise(r0, r1, f"{store_tag} pipeline={pipeline}")
            _assert_bitwise(ref, r1, f"{store_tag} vs reference")
            assert routed.stats.partition_hits > 0, store_tag
            assert flat.stats.partition_hits == 0, store_tag
        finally:
            store.close()

    # disk tier: LocalBlockStore behind DiskIVFIndex over the v4 checkpoint
    with DiskIVFIndex.open(ckpt) as disk:
        flat = SearchEngine(disk, partitions="off", **kw)
        routed = SearchEngine(disk, partitions="auto", **kw)
        r0 = flat.search(queries, fspec)
        r1 = routed.search(queries, fspec)
        _assert_bitwise(r0, r1, f"local pipeline={pipeline}")
        _assert_bitwise(ref, r1, "local vs reference")
        assert routed.stats.partition_hits > 0


def test_routed_matches_flat_sharded_terminated(built):
    """The segmented terminated executor routes fetches at sub-partition
    granularity through the ring; results must stay bit-identical."""
    index, _, _, core, _, ckpt = built
    q = 16
    queries = _queries(core, q)
    fspec = _window_fspec(q, W)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    ref = search_reference(index, queries, fspec, k=K, n_probes=NP)
    sharded = bs.open_sharded(ckpt, n_nodes=3)
    try:
        with DiskIVFIndex.open(ckpt) as disk:
            routed = SearchEngine(disk, blockstore=sharded,
                                  termination="exact", partitions="auto",
                                  **kw)
            r1 = routed.search(queries, fspec)
            _assert_bitwise(ref, r1, "sharded terminated routed")
            assert routed.stats.partition_hits > 0
    finally:
        sharded.close()


def test_unroutable_predicate_is_flat_bit_identical(built):
    """A window wider than every catalog entry must decline — and the
    fallback plan is the flat plan verbatim, n_scanned included."""
    _, attached, _, core, _, _ = built
    q = 16
    queries = _queries(core, q)
    wide = _window_fspec(q, TS_RANGE // 2)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    flat = SearchEngine(attached, partitions="off", **kw)
    routed = SearchEngine(attached, partitions="auto", **kw)
    r0 = flat.search(queries, wide)
    r1 = routed.search(queries, wide)
    _assert_bitwise(r0, r1, "fallback")
    np.testing.assert_array_equal(np.asarray(r1.n_scanned),
                                  np.asarray(r0.n_scanned))
    assert routed.stats.partition_hits == 0
    assert routed.stats.partition_fallbacks > 0


# ---------------------------------------------------------------------------
# 3. Interleaving parity: add / tombstone / compact_deltas / post-republish
# ---------------------------------------------------------------------------


def test_interleaving_parity_routed_vs_flat(built_dot, tmp_path):
    index, build_p, core, centers, _ = built_dot
    ckpt = str(tmp_path / "ck")
    storage.save_index(index, ckpt, n_shards=2, layout=4,
                       partitions=build_p)
    disk = DiskIVFIndex.open(ckpt)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    flat = SearchEngine(disk, partitions="off", **kw)
    routed = SearchEngine(disk, partitions="auto", **kw)
    rng = np.random.default_rng(5)
    q = 16
    queries = _queries(core, q)
    fspec = _window_fspec(q, W)

    def check(stage):
        _assert_bitwise(flat.search(queries, fspec),
                        routed.search(queries, fspec), stage)

    # adds land in the delta tier (assigned to BASE clusters)
    add = (centers[rng.integers(0, KC, 64)]
           + 0.05 * rng.standard_normal((64, D))).astype(np.float32)
    add /= np.linalg.norm(add, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, 16, (64, M)).astype(np.int16)
    add_attrs[:, 0] = rng.integers(0, TS_RANGE, 64).astype(np.int16)
    tier.add(add, add_attrs, np.arange(N, N + 64, dtype=np.int64))
    check("after adds")

    # tombstones: cold rows (inside sub-partition copies too) + fresh rows
    cold_dead = rng.choice(N, 48, replace=False)
    tier.tombstone(cold_dead, clusters=(np.arange(N) * KC // N)[cold_dead])
    tier.tombstone(np.arange(N, N + 8, dtype=np.int64))
    check("after tombstones")

    # republish: folds deltas, reclaims tombstones, REBUILDS the touched
    # parents' sub-partitions (new gens) and rewrites the catalog
    st = compact_deltas(ckpt, tier)
    assert st.clusters_rewritten > 0
    assert flat.refresh()
    routed.refresh()  # shared index already flipped: engine-side no-op
    check("after compact_deltas")
    assert routed.stats.partition_hits > 0

    # keep serving on the republished generation
    add2 = (centers[rng.integers(0, KC, 32)]
            + 0.05 * rng.standard_normal((32, D))).astype(np.float32)
    add2 /= np.linalg.norm(add2, axis=-1, keepdims=True)
    add2_attrs = rng.integers(0, 16, (32, M)).astype(np.int16)
    add2_attrs[:, 0] = rng.integers(0, TS_RANGE, 32).astype(np.int16)
    tier.add(add2, add2_attrs, np.arange(N + 64, N + 96, dtype=np.int64))
    check("post-republish adds")
    flat.close()
    routed.close()
    disk.close()


def test_resync_partitions_after_ram_updates(built_dot):
    """RAM-tier maintenance: tombstone base rows, resync the attached sub
    copies, and the routed plan must agree with the flat plan again."""
    index, build_p, core, _, _ = built_dot
    attached = partitions_lib.attach(index, build_p)
    cat = attached.partitions
    # tombstone a batch of live rows in a parent that actually has subs
    parent = int(cat.parent[0])
    slots = jnp.arange(8)
    out = update_lib.tombstone(attached, jnp.full(8, parent), slots)
    out.partitions = cat  # plain attribute: dataclasses.replace drops it
    out = update_lib.resync_partitions(out)
    new_cat = out.partitions
    assert new_cat.sub_counts.sum() < cat.sub_counts.sum(), (
        "resync did not drop the tombstoned rows from any sub copy"
    )
    q = 16
    queries = _queries(core, q)
    fspec = _window_fspec(q, W)
    kw = dict(k=K, n_probes=NP, q_block=QB, prune="on")
    flat = SearchEngine(out, partitions="off", **kw)
    routed = SearchEngine(out, partitions="auto", **kw)
    _assert_bitwise(flat.search(queries, fspec),
                    routed.search(queries, fspec), "post-resync")
    assert routed.stats.partition_hits > 0


# ---------------------------------------------------------------------------
# 4. Dead-cluster fetch shrink: per-owner lists + the store skip counter
# ---------------------------------------------------------------------------


def test_split_fetch_by_owner_drops_dead():
    fetch = np.asarray([4, 9, 2, 7, 11], np.int64)
    alive = np.asarray([True, False, True, True, False])
    got = probes_lib.split_fetch_by_owner(fetch, lambda c: c % 2,
                                          alive=alive)
    np.testing.assert_array_equal(got[0], [4, 2])
    np.testing.assert_array_equal(got[1], [7])
    assert 9 not in np.concatenate(list(got.values()))
    assert probes_lib.split_fetch_by_owner(
        fetch, lambda c: c % 2, alive=np.zeros(5, bool)
    ) == {}


def test_sharded_store_skips_dead_fetches(built_dot):
    index, *_ = built_dot
    peers = {i: bs.LoopbackTransport(bs.ResidentBlockStore(index))
             for i in range(3)}
    store = bs.ShardedBlockStore(peers)
    try:
        recs = store.get([0, 1, 2, 3], alive=[True, False, True, False])
        assert sorted(recs) == [0, 2]
        assert store.stats()["fetches_skipped"] == 2
        # skipped ids are fetched for real when later alive
        recs = store.get([1, 3], alive=[True, True])
        assert sorted(recs) == [1, 3]
        assert store.stats()["fetches_skipped"] == 2
    finally:
        store.close()


# ---------------------------------------------------------------------------
# 5. Storage round-trip + delta interval pruning rides along
# ---------------------------------------------------------------------------


def test_v4_catalog_roundtrip(built):
    _, _, build_p, _, _, ckpt = built
    man = storage.load_manifest(ckpt)
    assert man["has_partitions"]
    assert man["partitions"]["n_subs"] == build_p.n_subs
    loaded = storage.load_partitions(ckpt, man)
    cat = build_p.catalog
    for field in ("pred_lo", "pred_hi", "members", "entry_rows", "parent",
                  "sub_lo", "sub_hi", "sub_counts", "sub_amin", "sub_amax"):
        np.testing.assert_array_equal(
            getattr(loaded, field), getattr(cat, field), err_msg=field
        )
    assert loaded.n_base == cat.n_base


def test_delta_attr_interval_skips_disjoint_fold(built_dot, tmp_path):
    """The delta fold is skipped outright when the filter is disjoint with
    the tier's per-attribute envelope on ANY attribute — and the envelope
    tightens again on commit."""
    index, build_p, core, centers, _ = built_dot
    ckpt = str(tmp_path / "ck")
    storage.save_index(index, ckpt, n_shards=2, layout=4,
                       partitions=build_p)
    disk = DiskIVFIndex.open(ckpt)
    tier = DeltaTier.for_index(disk, 8.0)
    disk.delta = tier
    rng = np.random.default_rng(9)
    add = (centers[rng.integers(0, KC, 16)]
           + 0.05 * rng.standard_normal((16, D))).astype(np.float32)
    add /= np.linalg.norm(add, axis=-1, keepdims=True)
    add_attrs = rng.integers(0, 16, (16, M)).astype(np.int16)
    add_attrs[:, 0] = rng.integers(100, 200, 16).astype(np.int16)
    tier.add(add, add_attrs, np.arange(N, N + 16, dtype=np.int64))

    eng = SearchEngine(disk, k=K, n_probes=NP, q_block=QB, prune="on")
    q = 8
    queries = _queries(core, q)
    lo = np.full((q, 1, M), -32768, np.int16)
    hi = np.full((q, 1, M), 32767, np.int16)
    lo[:, 0, 0], hi[:, 0, 0] = 4000, 4200  # disjoint with [100, 200]
    eng.search(queries, FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi)))
    assert eng.stats.delta_interval_skips > 0
    # overlapping window folds the delta
    skips = eng.stats.delta_interval_skips
    lo[:, 0, 0], hi[:, 0, 0] = 100, 250
    eng.search(queries, FilterSpec(lo=jnp.asarray(lo), hi=jnp.asarray(hi)))
    assert eng.stats.delta_interval_skips == skips
    eng.close()
    disk.close()
