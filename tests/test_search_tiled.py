"""Tiled, probe-deduplicated fused search: plan unit tests + parity matrix.

Parity bar: `search_fused_tiled` must return IDENTICAL ids/scores to
`search_reference` (continuous random scores ⇒ no meaningful ties) across
metrics, SQ8 on/off, selective vs match-all filters, ragged query tiles and
both executors ("xla" streaming, "pallas_interpret" kernel).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    FilterBuilder,
    HybridSpec,
    brute_force,
    build_ivf,
    from_builders,
    match_all,
    recall_at_k,
)
from repro.core.ivf import quantize_index
from repro.core.probes import dedup_rows, plan_probe_tiles
from repro.core.search import search_centroids, search_reference
from repro.core.serving import make_fused_search_fn
from repro.kernels.filtered_scan import (
    filtered_scan_tiled,
    filtered_scan_tiled_ref,
    search_fused_tiled,
)

BACKENDS = ("xla", "pallas_interpret")


# ---------------------------------------------------------------------------
# probe-plan unit tests
# ---------------------------------------------------------------------------


def test_dedup_rows_basic():
    keys = jnp.asarray([[3, 1, 3, 1, 7, 7], [5, 5, 5, 5, 5, 5]], jnp.int32)
    table, slot_of, count = dedup_rows(keys, None, cap=4)
    np.testing.assert_array_equal(np.asarray(count), [3, 1])
    # ascending uniques, tail padded with the last unique key
    np.testing.assert_array_equal(np.asarray(table[0]), [1, 3, 7, 7])
    np.testing.assert_array_equal(np.asarray(table[1]), [5, 5, 5, 5])
    # every entry's slot points at its own key
    t, s = np.asarray(table), np.asarray(slot_of)
    for r in range(2):
        np.testing.assert_array_equal(
            t[r][s[r]], np.asarray(keys[r])
        )


def test_dedup_rows_invalid_and_empty():
    keys = jnp.asarray([[9, 2, 9, 4], [1, 1, 1, 1]], jnp.int32)
    valid = jnp.asarray([[True, False, True, True], [False] * 4])
    table, slot_of, count = dedup_rows(keys, valid, cap=4)
    np.testing.assert_array_equal(np.asarray(count), [2, 0])
    np.testing.assert_array_equal(np.asarray(table[0]), [4, 9, 9, 9])
    np.testing.assert_array_equal(np.asarray(table[1]), [0, 0, 0, 0])
    # valid entries map to their key; slot indices stay in range either way
    assert int(table[0, slot_of[0, 0]]) == 9
    assert int(table[0, slot_of[0, 3]]) == 4
    assert np.asarray(slot_of).max() < 4 and np.asarray(slot_of).min() >= 0


def test_plan_probe_tiles_streams_each_cluster_once():
    """The acceptance property: per tile, every probed cluster gets exactly
    one live slot, however many queries probe it."""
    rng = np.random.default_rng(0)
    q_block, t, kc = 8, 4, 6
    probe_ids = jnp.asarray(rng.integers(0, kc, (16, t)), jnp.int32)
    u_cap = min(q_block * t, kc)
    slot_cluster, slot_tile, slot_of_probe, probe_ok, n_unique = (
        plan_probe_tiles(probe_ids, q_block=q_block, u_cap=u_cap)
    )
    assert np.asarray(probe_ok).all()  # u_cap=min(QB·T, K) never overflows
    sc = np.asarray(slot_cluster).reshape(2, u_cap)
    for tile in range(2):
        probed = np.unique(np.asarray(probe_ids[tile * 8:(tile + 1) * 8]))
        n = int(n_unique[tile])
        assert n == len(probed)  # deduped: one slot per distinct cluster
        np.testing.assert_array_equal(np.sort(sc[tile][:n]), probed)
        # pads repeat the last unique id (Pallas revisiting fast path)
        assert (sc[tile][n:] == sc[tile][n - 1]).all()
    # every probe's slot scans that probe's cluster, in the right tile
    sc_flat = np.asarray(slot_cluster)
    st_flat = np.asarray(slot_tile)
    sop = np.asarray(slot_of_probe)
    for qi in range(16):
        for ti in range(t):
            assert sc_flat[sop[qi, ti]] == int(probe_ids[qi, ti])
            assert st_flat[sop[qi, ti]] == qi // q_block


# ---------------------------------------------------------------------------
# kernel vs gather oracle
# ---------------------------------------------------------------------------


def _tiled_case(seed, *, s, n_tiles, q_block, kc, vpad, d, m, f):
    rng = np.random.default_rng(seed)
    return dict(
        slot_cluster=jnp.asarray(rng.integers(0, kc, s), jnp.int32),
        slot_tile=jnp.asarray(rng.integers(0, n_tiles, s), jnp.int32),
        queries=jnp.asarray(
            rng.standard_normal((n_tiles * q_block, d)).astype(np.float32)
        ),
        lo=jnp.asarray(
            rng.integers(-20, 5, (n_tiles * q_block, f, m)), jnp.int16
        ),
        hi=jnp.asarray(
            rng.integers(5, 30, (n_tiles * q_block, f, m)), jnp.int16
        ),
        vectors=jnp.asarray(
            rng.standard_normal((kc, vpad, d)).astype(np.float32)
        ),
        attrs=jnp.asarray(rng.integers(-25, 25, (kc, vpad, m)), jnp.int16),
        ids=jnp.asarray(rng.integers(-1, 60, (kc, vpad)), jnp.int32),
    )


@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_tiled_kernel_matches_ref(metric):
    c = _tiled_case(3, s=5, n_tiles=2, q_block=8, kc=4, vpad=256, d=32,
                    m=4, f=2)
    norms = jnp.sum(c["vectors"].astype(jnp.float32) ** 2, -1)
    args = (c["slot_cluster"], c["slot_tile"], c["queries"], c["lo"],
            c["hi"], c["vectors"], c["attrs"], c["ids"],
            norms if metric == "l2" else None)
    kw = dict(metric=metric, k=7, q_block=8)
    vals, ids, npass = filtered_scan_tiled(*args, interpret=True,
                                           v_block=128, **kw)
    rvals, rids, rnpass = filtered_scan_tiled_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(npass), np.asarray(rnpass))


def test_tiled_kernel_sq8_matches_ref():
    c = _tiled_case(4, s=4, n_tiles=1, q_block=8, kc=3, vpad=128, d=16,
                    m=3, f=1)
    v32 = c["vectors"].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(v32), -1), 1e-12) / 127.0
    q8 = jnp.clip(jnp.round(v32 / scale[..., None]), -127, 127).astype(
        jnp.int8
    )
    args = (c["slot_cluster"], c["slot_tile"], c["queries"], c["lo"],
            c["hi"], q8, c["attrs"], c["ids"], None, scale)
    kw = dict(metric="dot", k=5, q_block=8)
    vals, ids, npass = filtered_scan_tiled(*args, interpret=True,
                                           v_block=64, **kw)
    rvals, rids, rnpass = filtered_scan_tiled_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(npass), np.asarray(rnpass))


# ---------------------------------------------------------------------------
# end-to-end parity matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=["dot", "l2"])
def built(request):
    metric = request.param
    rng = np.random.default_rng(0)
    n, d, m = 1536, 32, 6
    core = rng.standard_normal((n, d)).astype(np.float32)
    core /= np.linalg.norm(core, axis=-1, keepdims=True)
    attrs = rng.integers(0, 10, (n, m)).astype(np.int16)
    spec = HybridSpec(dim=d, n_attrs=m, core_dtype=jnp.float32,
                      metric=metric)
    index, _ = build_ivf(
        jax.random.key(0), spec, core, attrs, n_clusters=10,
        kmeans_mode="lloyd", kmeans_steps=6,
    )
    return index, core, attrs


def _fspecs(q, m):
    selective = from_builders(
        [FilterBuilder(m).le(0, 5).ge(1, 2) for _ in range(q)]
    )
    return {"match_all": match_all(q, m), "selective": selective}


# Q values chosen to exercise ragged tiles: 5 (sub-tile), 21 (ragged
# multi-tile), 32 (exact tiles) at q_block=16.
@pytest.mark.parametrize("q", [5, 21, 32])
@pytest.mark.parametrize("backend", BACKENDS)
def test_tiled_matches_reference(built, q, backend):
    index, core, attrs = built
    queries = jnp.asarray(core[7:7 + q] + 0.01)
    for name, fspec in _fspecs(q, 6).items():
        ref = search_reference(index, queries, fspec, k=10, n_probes=4)
        tiled = search_fused_tiled(
            index, queries, fspec, k=10, n_probes=4, q_block=16,
            v_block=128, backend=backend,
        )
        np.testing.assert_array_equal(
            np.asarray(tiled.ids), np.asarray(ref.ids), err_msg=name
        )
        np.testing.assert_allclose(
            np.asarray(tiled.scores), np.asarray(ref.scores),
            rtol=1e-5, atol=1e-5, err_msg=name,
        )
        np.testing.assert_array_equal(
            np.asarray(tiled.n_passed), np.asarray(ref.n_passed),
            err_msg=name,
        )
        np.testing.assert_array_equal(
            np.asarray(tiled.n_scanned), np.asarray(ref.n_scanned),
            err_msg=name,
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_tiled_sq8_matches_reference(built, backend):
    index, core, attrs = built
    if index.spec.metric == "l2":
        pytest.skip("SQ8 + l2 not wired (matches non-tiled kernel)")
    qindex = quantize_index(index)
    q = 12
    queries = jnp.asarray(core[:q])
    fspec = match_all(q, 6)
    ref = search_reference(qindex, queries, fspec, k=8, n_probes=4)
    tiled = search_fused_tiled(qindex, queries, fspec, k=8, n_probes=4,
                               q_block=8, v_block=128, backend=backend)
    np.testing.assert_array_equal(np.asarray(tiled.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(np.asarray(tiled.scores),
                               np.asarray(ref.scores), rtol=1e-5, atol=1e-5)


def test_tiled_full_probe_matches_brute_force(built):
    index, core, attrs = built
    q = 9
    queries = jnp.asarray(core[40:40 + q])
    fspec = match_all(q, 6)
    oracle = brute_force(jnp.asarray(core), jnp.asarray(attrs), queries,
                         fspec, k=8, metric=index.spec.metric)
    tiled = search_fused_tiled(index, queries, fspec, k=8,
                               n_probes=index.n_clusters, q_block=8,
                               v_block=128, backend="xla")
    np.testing.assert_array_equal(np.asarray(tiled.ids),
                                  np.asarray(oracle.ids))
    assert recall_at_k(tiled, oracle) == 1.0


def test_tiled_shares_duplicate_probes(built):
    """Batch of identical queries ⇒ one tile's slot table collapses to T
    unique slots (each duplicate cluster streamed once), results intact."""
    index, core, attrs = built
    q, t = 16, 4
    queries = jnp.broadcast_to(jnp.asarray(core[3]), (q, 32))
    probe_ids, _ = search_centroids(index, queries, t)
    _, _, _, _, n_unique = plan_probe_tiles(
        jnp.asarray(probe_ids), q_block=16, u_cap=min(16 * t, 10)
    )
    assert int(n_unique[0]) == t  # Q·T = 64 probes → T unique slots
    fspec = match_all(q, 6)
    ref = search_reference(index, queries, fspec, k=6, n_probes=t)
    tiled = search_fused_tiled(index, queries, fspec, k=6, n_probes=t,
                               q_block=16, backend="xla")
    np.testing.assert_array_equal(np.asarray(tiled.ids), np.asarray(ref.ids))


def test_tiled_undersized_u_cap_degrades_soundly(built):
    """u_cap below the tile's unique-probe count must DROP probes (counted
    candidates shrink) — never surface wrong ids or fabricated scores."""
    index, core, attrs = built
    if index.spec.metric == "l2":
        pytest.skip("score spot-check below is written for dot")
    q = 16
    queries = jnp.asarray(core[:q] + 0.01)
    fspec = match_all(q, 6)
    ref = search_reference(index, queries, fspec, k=6, n_probes=4)
    small = search_fused_tiled(index, queries, fspec, k=6, n_probes=4,
                               q_block=16, u_cap=4, backend="xla")
    ids_ = np.asarray(small.ids)
    scores_ = np.asarray(small.scores)
    qn = np.asarray(queries)
    for qi in range(q):
        for j in range(6):
            vid = ids_[qi, j]
            if vid >= 0:  # every surfaced hit is a real (query, vector) score
                np.testing.assert_allclose(
                    scores_[qi, j], float(qn[qi] @ core[vid]),
                    rtol=1e-4, atol=1e-4,
                )
    assert (ids_ >= 0).sum() <= (np.asarray(ref.ids) >= 0).sum()
    assert (np.asarray(small.n_passed) <= np.asarray(ref.n_passed)).all()


def test_serving_search_fn_uses_tiled_path(built):
    index, core, attrs = built
    fn = make_fused_search_fn(index, k=5, n_probes=4, q_block=8)
    q = 8
    queries = jnp.asarray(core[:q])
    scores, ids = fn(queries, match_all(q, 6), None)
    ref = search_reference(index, queries, match_all(q, 6), k=5, n_probes=4)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref.ids))
