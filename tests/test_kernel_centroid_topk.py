"""centroid_topk kernel vs lax.top_k oracle (permutation-tolerant on ties)."""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import given, needs_hypothesis, settings, st  # noqa: E402

from repro.kernels.centroid_topk import (
    centroid_topk,
    centroid_topk_ref,
    probe_centroids,
)


def check_topk_equiv(vals_a, ids_a, vals_b, ids_b, rtol=1e-5):
    """Set-equivalence check robust to tie ordering: same score multiset,
    and every id's score matches its rank's score."""
    np.testing.assert_allclose(
        np.sort(np.asarray(vals_a), -1), np.sort(np.asarray(vals_b), -1),
        rtol=rtol, atol=1e-5,
    )
    # ids must agree where scores are strictly separated
    va, vb = np.asarray(vals_a), np.asarray(vals_b)
    ia, ib = np.asarray(ids_a), np.asarray(ids_b)
    for r in range(va.shape[0]):
        strict = np.abs(va[r][:, None] - va[r][None, :]) > 1e-6
        unique = strict.sum(-1) == va.shape[1] - 1
        np.testing.assert_array_equal(ia[r][unique], ib[r][unique])


@pytest.mark.parametrize(
    "q,k,d,t,qb,kb,metric",
    [
        (8, 64, 16, 4, 8, 32, "dot"),
        (16, 128, 32, 7, 8, 64, "dot"),
        (4, 256, 64, 3, 4, 128, "dot"),
        (8, 64, 16, 4, 8, 32, "l2"),
        (32, 512, 8, 16, 16, 128, "dot"),
    ],
)
def test_kernel_matches_ref(q, k, d, t, qb, kb, metric):
    rng = np.random.default_rng(q * k + t)
    queries = jnp.asarray(rng.standard_normal((q, d)).astype(np.float32))
    cents = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    vals, ids = centroid_topk(
        queries, cents, t=t, q_block=qb, k_block=kb, metric=metric,
        interpret=True,
    )
    rvals, rids = centroid_topk_ref(queries, cents, t=t, metric=metric)
    check_topk_equiv(vals, ids, rvals, rids)


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**20),
    q=st.sampled_from([4, 8]),
    k=st.sampled_from([32, 96, 160]),
    t=st.integers(1, 5),
)
def test_probe_centroids_padding_safe(seed, q, k, t):
    """probe_centroids pads K to the block size; padded ids never surface."""
    rng = np.random.default_rng(seed)
    queries = jnp.asarray(rng.standard_normal((q, 8)).astype(np.float32))
    cents = jnp.asarray(rng.standard_normal((k, 8)).astype(np.float32))
    vals, ids = probe_centroids(
        queries, cents, t=t, q_block=4, k_block=64, interpret=True
    )
    rvals, rids = centroid_topk_ref(queries, cents, t=t)
    assert np.all(np.asarray(ids) < k)
    check_topk_equiv(vals, ids, rvals, rids)


def test_bf16_inputs():
    rng = np.random.default_rng(0)
    queries = jnp.asarray(rng.standard_normal((8, 32))).astype(jnp.bfloat16)
    cents = jnp.asarray(rng.standard_normal((64, 32))).astype(jnp.bfloat16)
    vals, ids = centroid_topk(
        queries, cents, t=4, q_block=8, k_block=32, interpret=True
    )
    rvals, rids = centroid_topk_ref(queries, cents, t=4)
    check_topk_equiv(vals, ids, rvals, rids, rtol=2e-2)
